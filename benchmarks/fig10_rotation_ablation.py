"""Paper Fig. 10: LP (rotating) vs w/o LP (temporal-only partitioning)."""
from __future__ import annotations

from .common import lp_vs_centralized

STEPS, K = 6, 2


def run(print_csv=True):
    rot = lp_vs_centralized(STEPS, K, 0.5, seed=1, dims=(0, 1, 2))
    fixed = lp_vs_centralized(STEPS, K, 0.5, seed=1, dims=(0,))
    if print_csv:
        print(f"fig10_ablation/rotating,0,rel_l2={rot['rel_l2']:.4f}")
        print(f"fig10_ablation/temporal_only,0,rel_l2={fixed['rel_l2']:.4f}")
        print(f"fig10_ablation/verdict,0,"
              f"rotation_better={rot['rel_l2'] < fixed['rel_l2']}")
    assert rot["rel_l2"] < fixed["rel_l2"], (rot, fixed)
    return rot, fixed


if __name__ == "__main__":
    run()
