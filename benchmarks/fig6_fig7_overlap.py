"""Paper Figs. 6-7: overlap ratio r -> communication and quality."""
from __future__ import annotations

from repro.core import comm_model as cm
from .common import lp_vs_centralized

STEPS, K = 6, 2


def run(print_csv=True):
    cfg = cm.wan21_comm_config(49)
    out = []
    for r in (0.1, 0.25, 0.5, 0.75, 1.0):
        comm = cm.comm_lp_measured(cfg, 4, r) / 2**20
        out.append((r, comm))
        if print_csv:
            print(f"fig6_overlap_comm/r={r},0,comm={comm:.0f}MB")
    # paper: comm roughly doubles from r=0.1 to r=1.0, still << HP
    assert out[-1][1] < cm.comm_hp_xdit(cfg, 4) / 2**20
    assert 1.5 < out[-1][1] / out[0][1] < 3.0

    qual = {}
    for r in (0.0, 0.5, 1.0):
        d = lp_vs_centralized(STEPS, K, r, seed=2)
        qual[r] = d
        if print_csv:
            print(f"fig7_overlap_quality/r={r},0,"
                  f"rel_l2={d['rel_l2']:.4f} psnr={d['psnr_db']:.1f}dB")
    # paper: quality improves with r and saturates by r~0.5
    assert qual[1.0]["rel_l2"] <= qual[0.0]["rel_l2"]
    return out, qual


if __name__ == "__main__":
    run()
