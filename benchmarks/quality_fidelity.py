"""§5.2 quality parity: exactness with a local denoiser + DiT divergence
statistics (the VBench proxy; see DESIGN.md §6) + the wire-codec quality
gate (lossy halo exchange must stay within serving tolerance)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import LPStepCompiler, lp_denoise
from repro.diffusion import FlowMatchEuler, generate_centralized, generate_lp
from .common import divergence, lp_vs_centralized, reduced_dit_denoiser

CODEC_PSNR_GATE_DB = 40.0  # int8-residual must stay above this vs exact


def codec_gate(steps=4, K=2, r=0.5, print_csv=True):
    """Wire-codec quality gate on the reduced DiT: the int8-residual
    halo path must reconstruct within CODEC_PSNR_GATE_DB of the exact
    fp32 path (bf16 is reported alongside as the near-lossless bound)."""
    den, z_T, cfg = reduced_dit_denoiser(3, latent=(6, 8, 12))
    sampler = FlowMatchEuler(steps)

    def den_fast(w, t):
        return den(w, jnp.full((w.shape[0],), t, jnp.float32))

    outs = {}
    for name in ("fp32", "bf16", "int8-residual"):
        comp = LPStepCompiler(den_fast, sampler.update, K, r,
                              cfg.patch_sizes, (1, 2, 3), uniform=True,
                              codec=name)
        outs[name] = lp_denoise(None, z_T, sampler, steps, K, r,
                                cfg.patch_sizes, (1, 2, 3), uniform=True,
                                compiler=comp)
    gates = {}
    for name in ("bf16", "int8-residual"):
        d = divergence(outs[name], outs["fp32"])
        gates[name] = d
        if print_csv:
            print(f"quality/codec_{name},0,rel_l2={d['rel_l2']:.5f} "
                  f"psnr={d['psnr_db']:.1f}dB")
    assert gates["int8-residual"]["psnr_db"] >= CODEC_PSNR_GATE_DB, gates
    return gates


def run(print_csv=True):
    # exact-stitch invariant
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(1, 8, 8, 12, 4)).astype(np.float32))
    den = lambda zz, t: 0.2 * zz
    s = FlowMatchEuler(5)
    z_c = generate_centralized(den, z, 5, s)
    z_lp = generate_lp(den, z, 5, 2, 1.0, (1, 2, 2), s)
    exact = float(jnp.abs(z_c - z_lp).max())
    if print_csv:
        print(f"quality/exact_stitch,0,max_diff={exact:.2e}")
    assert exact < 1e-5

    d = lp_vs_centralized(8, 2, 0.5, seed=5)
    if print_csv:
        print(f"quality/dit_divergence,0,rel_l2={d['rel_l2']:.4f} "
              f"psnr={d['psnr_db']:.1f}dB")
    d["codec_gates"] = codec_gate(print_csv=print_csv)
    return d


if __name__ == "__main__":
    run()
