"""§5.2 quality parity: exactness with a local denoiser + DiT divergence
statistics (the VBench proxy; see DESIGN.md §6)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.diffusion import FlowMatchEuler, generate_centralized, generate_lp
from .common import divergence, lp_vs_centralized


def run(print_csv=True):
    # exact-stitch invariant
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(1, 8, 8, 12, 4)).astype(np.float32))
    den = lambda zz, t: 0.2 * zz
    s = FlowMatchEuler(5)
    z_c = generate_centralized(den, z, 5, s)
    z_lp = generate_lp(den, z, 5, 2, 1.0, (1, 2, 2), s)
    exact = float(jnp.abs(z_c - z_lp).max())
    if print_csv:
        print(f"quality/exact_stitch,0,max_diff={exact:.2e}")
    assert exact < 1e-5

    d = lp_vs_centralized(8, 2, 0.5, seed=5)
    if print_csv:
        print(f"quality/dit_divergence,0,rel_l2={d['rel_l2']:.4f} "
              f"psnr={d['psnr_db']:.1f}dB")
    return d


if __name__ == "__main__":
    run()
