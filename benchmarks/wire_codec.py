"""Wire-codec benchmark -> BENCH_wire_codec.json.

Per codec (fp32 / bf16 / int8 / int4 / int8-residual):

1. **wire bytes** — analytic per-step bytes of the codec'd halo engine
   (``comm_model.comm_lp_halo_codec``) on the wan21 smoke geometry
   (49-frame 480p latent, K=4, r=0.5), cross-checked against
   trip-count-aware HLO measurements of the engine compiled for a 4-way
   CPU mesh in a subprocess (the device-count XLA flag must not leak);
2. **step latency** — warm per-step wall time of the compiled LP loop on
   the reduced WAN DiT, codec round-trips included
   (``comm.wire.simulate_halo_forward`` through ``LPStepCompiler``);
3. **reconstruction PSNR** — final-latent divergence vs the exact fp32
   path for the same seeds/steps (the §5.2 proxy).

Gates (the PR's acceptance bar): int8-residual moves >= 3.5x fewer
wire bytes than the fp32 halo path, with PSNR >= 40 dB.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LPStepCompiler, lp_denoise
from repro.core import comm_model as cm
from repro.diffusion import FlowMatchEuler

from .common import divergence, reduced_dit_denoiser
from repro.obs.clock import perf_s

CODECS = ("fp32", "bf16", "int8", "int4", "int8-residual")
STEPS = 6
R = 0.5
OUT_JSON = "BENCH_wire_codec.json"

_COMM_SCRIPT = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import plan_uniform
    from repro.core.spmd import lp_forward_halo
    from repro.distributed.collectives import halo_spec

    mesh = compat.make_mesh((4,), ("data",))
    # wan21 smoke latent geometry (13, 60, 104, 16), partitioned on height
    z = jnp.zeros((13, 60, 104, 16), jnp.float32)
    plan = plan_uniform(60, 2, 4, 0.5, dim=1)
    den = lambda x: jnp.tanh(x) * 0.5 + x
    out = {}
    for name in %s:
        codec = get_codec(name)
        if codec.stateful:
            st = init_halo_wire_state(
                codec, halo_spec(plan),
                tuple(s for i, s in enumerate(z.shape) if i != 1))
            fn = jax.jit(lambda zz, s: lp_forward_halo(
                den, zz, plan, 1, mesh, codec=codec, codec_state=s)[0])
            hlo = fn.lower(z, st).compile().as_text()
        elif name == "fp32":
            fn = jax.jit(lambda zz: lp_forward_halo(den, zz, plan, 1, mesh))
            hlo = fn.lower(z).compile().as_text()
        else:
            fn = jax.jit(lambda zz: lp_forward_halo(
                den, zz, plan, 1, mesh, codec=codec))
            hlo = fn.lower(z).compile().as_text()
        a = analyze(hlo)
        out[name] = {k: float(v) for k, v in a.collective_bytes.items()}
    print("JSON:" + json.dumps(out))
    """
)


def _measured_comm(codecs):
    """Per-device collective payloads (HLO accounting) of one codec'd
    halo LP step per codec, on 4 fake CPU devices in a subprocess."""
    res = subprocess.run(
        [sys.executable, "-c", _COMM_SCRIPT % repr(tuple(codecs))],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=560,
    )
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[len("JSON:"):])
    return {"error": res.stderr[-500:]}


def run(print_csv=True, measure_hlo=True):
    den, z_T, cfg = reduced_dit_denoiser(0, latent=(6, 8, 12))
    sampler = FlowMatchEuler(STEPS)

    def den_fast(w, t):
        tv = jnp.full((w.shape[0],), t, jnp.float32)
        return den(w, tv)

    # ---- latency + PSNR on the reduced DiT (simulate-halo engine)
    quality = {}
    for K in (2, 4):
        exact = None
        for name in CODECS:
            comp = LPStepCompiler(
                den_fast, sampler.update, K, R, cfg.patch_sizes, (1, 2, 3),
                uniform=True, codec=name,
            )

            def loop():
                return lp_denoise(None, z_T, sampler, STEPS, K, R,
                                  cfg.patch_sizes, (1, 2, 3), uniform=True,
                                  compiler=comp)

            jax.block_until_ready(loop())          # compile
            t0 = perf_s()
            z0 = loop()
            jax.block_until_ready(z0)
            step_ms = (perf_s() - t0) / STEPS * 1e3
            if name == "fp32":
                exact = z0
                div = {"rel_l2": 0.0, "psnr_db": float("inf")}
            else:
                div = divergence(z0, exact)
            quality[f"{name}/K{K}"] = {
                "step_ms": step_ms,
                "compiles": comp.compiles,
                **div,
            }

    # ---- wire bytes: analytic model on the wan21 smoke geometry
    ccfg = cm.wan21_comm_config(49, num_steps=1)
    K = 4
    fp32_wire = cm.comm_lp_halo(ccfg, K, R)
    bytes_rec = {}
    for name in CODECS:
        wire = (fp32_wire if name == "fp32"
                else cm.comm_lp_halo_codec(ccfg, K, R, name))
        bytes_rec[name] = {
            "wire_bytes_per_step": wire,
            "reduction_vs_fp32_halo": fp32_wire / wire,
            "hlo_modeled_height_step": cm.lp_halo_codec_step_collectives(
                ccfg, K, R, dim=1, codec=name
            ),
        }

    measured = _measured_comm(CODECS) if measure_hlo else {}

    record = {
        "config": "wan21_dit_1p3b reduced / wan21 49f smoke geometry",
        "num_steps": STEPS,
        "overlap_ratio": R,
        "quality_latency": quality,
        "comm_modeled": bytes_rec,
        "comm_measured_per_device": measured,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    # ---- gates
    red = bytes_rec["int8-residual"]["reduction_vs_fp32_halo"]
    psnr = min(quality["int8-residual/K2"]["psnr_db"],
               quality["int8-residual/K4"]["psnr_db"])
    assert red >= 3.5, f"int8-residual wire reduction {red:.2f}x < 3.5x"
    assert psnr >= 40.0, f"int8-residual PSNR {psnr:.1f} dB < 40 dB"
    if isinstance(measured, dict) and "error" not in measured:
        for name in ("bf16", "int8"):
            want = bytes_rec[name]["hlo_modeled_height_step"]
            got = measured.get(name, {})
            for kind in ("all-gather", "collective-permute"):
                g, w = got.get(kind, 0), want[kind]
                assert abs(g - w) <= 0.02 * w, (name, kind, g, w)

    if print_csv:
        for key, q in quality.items():
            print(f"wire_codec/{key},{q['step_ms']*1e3:.0f},"
                  f"psnr={q['psnr_db']:.1f}dB compiles={q['compiles']}")
        for name, b in bytes_rec.items():
            print(f"wire_codec/bytes/{name},0,"
                  f"per_step={b['wire_bytes_per_step']/2**20:.2f}MB "
                  f"reduction={b['reduction_vs_fp32_halo']:.2f}x")
        if isinstance(measured, dict) and "error" not in measured:
            print("wire_codec/hlo_match,0,modeled==measured for "
                  + ",".join(k for k in measured))
        print(f"wire_codec/json,0,wrote {OUT_JSON}")
    return record


if __name__ == "__main__":
    run()
