"""Shared benchmark utilities: timing, reduced-DiT setup, divergence."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config
from repro.diffusion import FlowMatchEuler, generate_centralized, generate_lp
from repro.diffusion.pipeline import make_guided_denoiser
from repro.models import dit, frontends
from repro.obs.clock import perf_s


def time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = perf_s()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (perf_s() - t0) / iters * 1e6


def reduced_dit_denoiser(seed: int = 0, latent=(6, 8, 12), guidance=3.0):
    """(guided_denoiser, z_T, cfg) on the reduced WAN DiT."""
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    ctx = frontends.text_context(jax.random.PRNGKey(seed + 1), 1, cfg)

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    den = make_guided_denoiser(fwd, params, cfg, ctx, jnp.zeros_like(ctx),
                               guidance=guidance)
    rng = np.random.default_rng(seed)
    z_T = jnp.asarray(
        rng.normal(size=(1, *latent, cfg.latent_channels)).astype(np.float32))
    return den, z_T, cfg


def divergence(a, b) -> dict:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    rel = float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12))
    mse = float(np.mean((a - b) ** 2))
    peak = float(np.abs(b).max())
    psnr = float(10 * np.log10(peak ** 2 / max(mse, 1e-12)))
    return {"rel_l2": rel, "psnr_db": psnr}


def lp_vs_centralized(steps: int, K: int, r: float, seed: int = 0,
                      latent=(6, 8, 12), dims=None):
    den, z_T, cfg = reduced_dit_denoiser(seed, latent)
    sampler = FlowMatchEuler(steps)
    z_c = generate_centralized(den, z_T, steps, sampler)
    if dims is None:
        z_lp = generate_lp(den, z_T, steps, num_partitions=K,
                           overlap_ratio=r, patch_sizes=cfg.patch_sizes,
                           sampler=sampler)
    else:
        from repro.core.lp_step import lp_forward
        from repro.core.partition import plan_partition
        from repro.core.schedule import rotation_dim

        z_lp = z_T
        for i in range(1, steps + 1):
            dim = rotation_dim(i, dims)
            axis = 1 + dim
            plan = plan_partition(z_lp.shape[axis], cfg.patch_sizes[dim], K, r, dim)

            def fn(sub, _i=i):
                t = jnp.full((sub.shape[0],), sampler.timestep(_i), jnp.float32)
                return den(sub, t)

            pred = lp_forward(fn, z_lp, plan, axis)
            z_lp = sampler.step(z_lp, pred, i)
    return divergence(z_lp, z_c)
