"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--only table1]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from repro.obs.clock import perf_s

from . import (
    codec_schedule,
    displaced_halo,
    fault_recovery,
    fig6_fig7_overlap,
    fig8_gpu_scaling,
    fig9_duration,
    fig10_rotation_ablation,
    hybrid_lp_tp,
    obs_overhead,
    quality_fidelity,
    router_resilience,
    serving_load,
    step_latency,
    table1_comm,
    table2_latency,
    wire_codec,
    wire_shard,
)

ALL = {
    "table1": table1_comm.run,
    "table2": table2_latency.run,
    "fig6_fig7": fig6_fig7_overlap.run,
    "fig8": fig8_gpu_scaling.run,
    "fig9": fig9_duration.run,
    "fig10": fig10_rotation_ablation.run,
    "quality": quality_fidelity.run,
    "step_latency": step_latency.run,
    "wire_codec": wire_codec.run,
    "hybrid_lp_tp": hybrid_lp_tp.run,
    "codec_schedule": codec_schedule.run,
    "wire_shard": wire_shard.run,
    "displaced_halo": displaced_halo.run,
    "fault_recovery": fault_recovery.run,
    "obs_overhead": obs_overhead.run,
    "serving_load": serving_load.run,
    "router_resilience": router_resilience.run,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = perf_s()
        try:
            ALL[name]()
            print(f"{name}/_total,{(perf_s()-t0)*1e6:.0f},ok")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{name}/_total,{(perf_s()-t0)*1e6:.0f},"
                  f"FAILED:{type(e).__name__}:{e}")
    return 1 if failures else 0


def run_all():
    return main([])


if __name__ == "__main__":
    sys.exit(main())
