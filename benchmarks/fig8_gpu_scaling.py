"""Paper Fig. 8: quality across 2-8 partitions (GPU counts)."""
from __future__ import annotations

from .common import lp_vs_centralized

STEPS = 6


def run(print_csv=True):
    out = {}
    for K in (2, 4, 8):
        d = lp_vs_centralized(STEPS, K, 1.0, seed=3, latent=(8, 16, 16))
        out[K] = d
        if print_csv:
            print(f"fig8_gpu_scaling/K={K},0,"
                  f"rel_l2={d['rel_l2']:.4f} psnr={d['psnr_db']:.1f}dB")
    # paper: quality robust across K (no blow-up)
    assert all(d["rel_l2"] < 0.5 for d in out.values()), out
    return out


if __name__ == "__main__":
    run()
