"""Paper Table 2: end-to-end latency, NMP vs LP.

The container has no GPUs/TPUs, so wall-clock latency is modeled:
  latency = compute_time (roofline, per strategy identical) +
            comm_bytes / interconnect_bw  (PCIe 16 GB/s, the paper's rig)
plus a REAL CPU microbenchmark of one LP step vs one centralized step on
the reduced DiT (partition+blend overhead must be negligible).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import comm_model as cm
from .common import reduced_dit_denoiser, time_us

PCIE_BW = 16e9     # the paper's A6000 PCIe rig
A6000_FLOPS = 38.7e12 * 0.45  # fp16 w/ realistic 45% MFU
PAPER = {"NMP": 239.33, "LP r=1.0": 220.69, "LP r=0.5": 195.27}


def modeled_latency(frames=49):
    cfg = cm.wan21_comm_config(frames)
    # per-request DiT flops: 2 passes x steps x 2ND
    n_params = 1.3e9
    flops = 2 * cfg.num_steps * 2 * n_params * cfg.num_tokens
    compute_s = flops / (4 * A6000_FLOPS)
    out = {}
    for name, comm in [
        ("NMP", cm.comm_nmp(cfg, 4)),
        ("LP r=1.0", cm.comm_lp_measured(cfg, 4, 1.0)),
        ("LP r=0.5", cm.comm_lp_measured(cfg, 4, 0.5)),
    ]:
        # NMP serializes compute across stages; LP parallelizes over K
        eff = 1.0 if name == "NMP" else (
            cm.gamma_factor(cfg, 4, 1.0 if "1.0" in name else 0.5) / 4)
        out[name] = compute_s * eff + comm / PCIE_BW
    return out


def run(print_csv=True):
    lat = modeled_latency()
    for name, s in lat.items():
        paper = PAPER[name]
        if print_csv:
            print(f"table2_latency/{name},0,model={s:.1f}s paper={paper}s")
    # ordering claim: LP r=0.5 < LP r=1.0 < NMP
    assert lat["LP r=0.5"] < lat["LP r=1.0"] < lat["NMP"], lat

    # CPU microbench: LP step overhead vs centralized step (reduced DiT)
    from repro.core import plan_uniform
    from repro.core.lp_step import lp_forward_uniform
    import jax

    den, z_T, cfg = reduced_dit_denoiser()
    t = jnp.full((1,), 500.0)
    cent = jax.jit(lambda z: den(z, t))
    plan = plan_uniform(z_T.shape[2], cfg.patch_sizes[1], 2, 0.5, dim=1)
    lp = jax.jit(lambda z: lp_forward_uniform(lambda s: den(s, t), z, plan, 2))
    us_c = time_us(cent, z_T)
    us_lp = time_us(lp, z_T)
    if print_csv:
        print(f"table2_latency/centralized_step,{us_c:.0f},reduced-dit-cpu")
        print(f"table2_latency/lp_step,{us_lp:.0f},"
              f"overhead={us_lp/us_c:.2f}x (windows overlap => >1x flops; "
              f"comm win dominates on real interconnects)")
    return lat


if __name__ == "__main__":
    run()
