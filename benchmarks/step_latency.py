"""LP step latency + communication baseline -> BENCH_lp_step.json.

Three measurements on the wan21_dit_1p3b smoke (reduced) config:

1. per-step wall time of the SEED loop (fresh Python closure per step,
   timestep baked in, eager dispatch — ``lp_denoise_reference``);
2. per-step wall time of the compiled fast path (traced-timestep steps,
   LRU compiled-step cache, scan fusion — ``lp_denoise``), warm;
3. denoiser trace counts for both (T vs <= #rotation-dims);

plus communication: the analytic per-step bytes of the psum engine vs the
halo-exchange engine (``comm_model``), cross-checked against
trip-count-aware HLO measurements of both engines compiled for a 4-way
CPU mesh in a subprocess (the 4-device XLA flag must not leak here).
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LPStepCompiler, lp_denoise, lp_denoise_reference
from repro.core import comm_model as cm
from repro.diffusion import FlowMatchEuler

from .common import reduced_dit_denoiser
from repro.obs.clock import perf_s

STEPS = 6
K = 2
R = 0.5
OUT_JSON = "BENCH_lp_step.json"

_COMM_SCRIPT = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.analysis.hlo_analyzer import analyze
    from repro.core import plan_uniform
    from repro.core.spmd import lp_forward_halo, lp_forward_shard_map

    mesh = compat.make_mesh((4,), ("data",))
    # wan21 smoke latent geometry (13, 60, 104, 16), partitioned on height
    z = jnp.zeros((13, 60, 104, 16), jnp.float32)
    plan = plan_uniform(60, 2, 4, 0.5, dim=1)
    den = lambda x: jnp.tanh(x) * 0.5 + x
    out = {}
    for name, fwd in (("psum", lp_forward_shard_map), ("halo", lp_forward_halo)):
        hlo = jax.jit(
            lambda zz: fwd(den, zz, plan, 1, mesh)
        ).lower(z).compile().as_text()
        a = analyze(hlo)
        out[name] = {k: float(v) for k, v in a.collective_bytes.items()}
    print("JSON:" + json.dumps(out))
    """
)


def _measured_comm():
    """Per-device collective payloads (HLO accounting) for one LP step of
    the smoke geometry, psum vs halo engines, on 4 fake CPU devices."""
    res = subprocess.run(
        [sys.executable, "-c", _COMM_SCRIPT],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=300,
    )
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[len("JSON:"):])
    return {"error": res.stderr[-500:]}


def run(print_csv=True):
    den, z_T, cfg = reduced_dit_denoiser(0, latent=(6, 8, 12))
    sampler = FlowMatchEuler(STEPS)

    # ---- seed loop: fresh closure per step, timestep baked in
    seed_traces = {"n": 0}

    def den_for_step(i, dim):
        t_val = sampler.timestep(i)

        def fn(sub):
            seed_traces["n"] += 1
            t = jnp.full((sub.shape[0],), t_val, jnp.float32)
            return den(sub, t)

        return fn

    def seed_loop():
        return lp_denoise_reference(
            den_for_step, z_T, lambda z, p, i: sampler.step(z, p, i),
            STEPS, K, R, cfg.patch_sizes, (1, 2, 3), uniform=True,
        )

    jax.block_until_ready(seed_loop())  # warm the op caches
    seed_traces["n"] = 0
    t0 = perf_s()
    jax.block_until_ready(seed_loop())
    seed_step_ms = (perf_s() - t0) / STEPS * 1e3

    # ---- compiled fast path
    fast_traces = {"n": 0}

    def den_fast(w, t):
        fast_traces["n"] += 1
        tv = jnp.full((w.shape[0],), t, jnp.float32)
        return den(w, tv)

    comp = LPStepCompiler(den_fast, sampler.update, K, R, cfg.patch_sizes,
                          (1, 2, 3), uniform=True)

    def fast_loop():
        return lp_denoise(None, z_T, sampler, STEPS, K, R, cfg.patch_sizes,
                          (1, 2, 3), uniform=True, compiler=comp)

    t0 = perf_s()
    jax.block_until_ready(fast_loop())  # compiles (<= one per rotation dim)
    cold_step_ms = (perf_s() - t0) / STEPS * 1e3
    fast_compile_traces = fast_traces["n"]
    t0 = perf_s()
    jax.block_until_ready(fast_loop())
    fast_step_ms = (perf_s() - t0) / STEPS * 1e3

    # ---- communication: analytic model + measured HLO (4-dev subprocess)
    ccfg = cm.wan21_comm_config(49, num_steps=1)
    modeled = {
        "psum_wire_bytes_per_step": cm.comm_lp_spmd(ccfg, 4, R),
        "halo_wire_bytes_per_step": cm.comm_lp_halo(ccfg, 4, R),
        "halo_hlo_bytes_height_step": cm.lp_halo_step_collectives(
            ccfg, 4, R, dim=1
        ),
    }
    measured = _measured_comm()

    record = {
        "config": "wan21_dit_1p3b reduced",
        "latent": [1, 6, 8, 12, int(cfg.latent_channels)],
        "num_steps": STEPS,
        "num_partitions": K,
        "overlap_ratio": R,
        "seed_loop": {
            "step_ms": seed_step_ms,
            "denoiser_traces": seed_traces["n"],
        },
        "compiled_loop": {
            "step_ms": fast_step_ms,
            "first_run_step_ms": cold_step_ms,
            "denoiser_traces": fast_compile_traces,
            "compiles": comp.compiles,
            "cache_hits": comp.hits,
        },
        "speedup_vs_seed": seed_step_ms / max(fast_step_ms, 1e-9),
        "comm_modeled": modeled,
        "comm_measured_per_device": measured,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    if print_csv:
        print(f"step_latency/seed_loop,{seed_step_ms * 1e3:.0f},"
              f"traces={seed_traces['n']}")
        print(f"step_latency/compiled,{fast_step_ms * 1e3:.0f},"
              f"traces={fast_compile_traces} compiles={comp.compiles}")
        print(f"step_latency/speedup,0,{record['speedup_vs_seed']:.2f}x")
        if "halo" in measured:
            h = sum(measured["halo"].values())
            p = sum(measured["psum"].values())
            print(f"step_latency/comm_measured,0,"
                  f"halo={h / 2 ** 20:.2f}MB psum={p / 2 ** 20:.2f}MB")
        print(f"step_latency/json,0,wrote {OUT_JSON}")
    return record


if __name__ == "__main__":
    run()
