"""Elastic fault-recovery benchmark -> BENCH_fault_recovery.json.

A scripted LP-group death mid-denoise on a 2D ``(lp=3, tp=2)`` mesh of
fake CPU devices (subprocess, so the device-count XLA flag never
leaks), exercising the whole recovery path end to end:

1. **mesh-shrink recovery** — ``--inject-fault dead:1@3`` kills group 1
   at denoise step 3; the health monitor burns its miss budget, the
   engine evicts the group, rebuilds a ``(2, 2)`` mesh with re-bound
   halo hooks (``launch/mesh.shrink_hybrid_mesh`` +
   ``LPServingEngine._build_forward``), and finishes the batch.
2. **boundary-snapshot resume** — the retry resumes from the last
   dim-rotation boundary snapshot, not from z_T: steps lost to the
   fault must be <= one dim-run of the rotation schedule.
3. **compile discipline** — recompiles across the whole drill stay
   <= 3 x num_segments per plan epoch (the pre- and post-eviction
   geometries are separate epochs; retries must hit the step cache).
4. **output quality** — PSNR of the recovered output vs the same
   request served fault-free on the intact (3, 2) mesh must meet the
   wire codec's conformance-envelope floor
   (``policy/envelope.PSNR_ENVELOPE_DB``): losing a group mid-flight
   may not cost more quality than the codec itself is allowed to.

Gates: evictions == 1 landing on a (2, 2) compiler/mesh; restarts
within the default budget; steps_lost <= one dim-run; compiles <=
3 x segments x epochs; PSNR >= envelope floor; finite output.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

MESH_M, MESH_T = 3, 2
NUM_STEPS = 4
FAULT = "dead:1@3"
WIRE_CODEC = "int8-residual"
OUT_JSON = "BENCH_fault_recovery.json"

_SCRIPT = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro import models
    from repro.configs import get_config
    from repro.core.schedule import rotation_schedule, usable_dims
    from repro.launch.mesh import make_hybrid_mesh
    from repro.models import dit, frontends
    from repro.serving.engine import LPServingEngine, VideoRequest

    M, T, STEPS = %(M)d, %(T)d, %(STEPS)d
    FAULT, CODEC = %(FAULT)r, %(CODEC)r
    SHAPE = (8, 8, 12)

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)
    def req():
        return VideoRequest(
            request_id=0,
            context=frontends.text_context(jax.random.PRNGKey(1), 1, cfg),
            latent_shape=SHAPE, seed=0,
        )
    def engine(mesh, **kw):
        return LPServingEngine(
            fwd, params, cfg, num_partitions=M, overlap_ratio=0.5,
            num_steps=STEPS, max_batch=1, wire_codec=CODEC,
            lp_impl="halo_hybrid", mesh=mesh, **kw)

    # ---- reference: the same request served fault-free on (M, T)
    ref = engine(make_hybrid_mesh(M, T))
    ref.submit(req())
    z_ref = np.asarray(ref.run()[0].latent, np.float64)

    # ---- drill: group death mid-denoise, elastic recovery
    eng = engine(make_hybrid_mesh(M, T), elastic=True, inject_fault=FAULT)
    eng.submit(req())
    res = eng.run()[0]
    z = np.asarray(res.latent, np.float64)

    mse = float(np.mean((z - z_ref) ** 2))
    psnr = float(10 * np.log10(
        float(np.abs(z_ref).max()) ** 2 / max(mse, 1e-30)))

    # one dim-run = longest stretch of consecutive steps partitioning the
    # same dim (the snapshot cadence lp_denoise guarantees)
    dims = usable_dims(SHAPE, cfg.patch_sizes, M)
    sched = rotation_schedule(STEPS, dims)
    dim_run = run = 1
    for a, b in zip(sched, sched[1:]):
        run = run + 1 if a == b else 1
        dim_run = max(dim_run, run)

    out = {
        "mesh": [M, T], "num_steps": STEPS, "fault": FAULT,
        "wire_codec": CODEC,
        "evictions": eng.evictions, "K": eng.K,
        "compiler_mesh_shape": list(eng._compiler.mesh_shape),
        "mesh_devices": list(np.asarray(eng.mesh.devices).shape),
        "restarts": res.restarts,
        "resumed_from_step": res.resumed_from_step,
        "steps_lost": eng.last_steps_lost,
        "dim_run": dim_run,
        "compiles": eng._compiler.compiles,
        "num_segments": len(eng.plan.segments) if eng.plan else 1,
        "psnr_vs_fault_free_db": psnr,
        "finite": bool(np.isfinite(z).all()),
    }
    print("JSON:" + json.dumps(out))
    """
)


def run(print_csv=True):
    res = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT % {"M": MESH_M, "T": MESH_T, "STEPS": NUM_STEPS,
                    "FAULT": FAULT, "CODEC": WIRE_CODEC}],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=560,
    )
    rec = None
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            rec = json.loads(line[len("JSON:"):])
    if rec is None:
        raise RuntimeError(
            f"fault_recovery subprocess failed:\n"
            f"{res.stdout}\n{res.stderr[-2000:]}")

    from repro.policy.envelope import codec_floor_db

    # ---- gate 1: exactly one eviction, landing on a (M-1, T) geometry
    assert rec["evictions"] == 1, rec
    assert rec["K"] == MESH_M - 1, rec
    assert rec["compiler_mesh_shape"] == [MESH_M - 1, MESH_T], rec
    assert rec["mesh_devices"] == [MESH_M - 1, MESH_T], rec
    # ---- gate 2: snapshot resume — bounded restarts, <= one dim-run lost
    assert 1 <= rec["restarts"] <= 2, rec
    assert rec["resumed_from_step"] >= 1, rec
    assert rec["steps_lost"] <= rec["dim_run"], rec
    # ---- gate 3: compile discipline across both plan epochs
    budget = 3 * rec["num_segments"] * (rec["evictions"] + 1)
    assert rec["compiles"] <= budget, (rec["compiles"], budget)
    # ---- gate 4: recovered output meets the codec's envelope floor
    floor = codec_floor_db(WIRE_CODEC)
    assert rec["finite"], rec
    assert rec["psnr_vs_fault_free_db"] >= floor, (
        rec["psnr_vs_fault_free_db"], floor)

    with open(OUT_JSON, "w") as f:
        json.dump(rec, f, indent=1)

    if print_csv:
        print(f"fault_recovery/evict,0,K={MESH_M}->{rec['K']} "
              f"mesh={rec['compiler_mesh_shape']} fault={rec['fault']}")
        print(f"fault_recovery/resume,0,restarts={rec['restarts']} "
              f"resumed_from={rec['resumed_from_step']} "
              f"steps_lost={rec['steps_lost']} (<= {rec['dim_run']})")
        print(f"fault_recovery/compiles,0,{rec['compiles']} (<= {budget})")
        print(f"fault_recovery/psnr,0,"
              f"{rec['psnr_vs_fault_free_db']:.1f}dB (>= {floor})")
        print(f"fault_recovery/json,0,wrote {OUT_JSON}")
    return rec


if __name__ == "__main__":
    run()
