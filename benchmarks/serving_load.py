"""Serving load-harness gate -> BENCH_serving_load.json.

Four sub-gates over one offered-load replay of the reduced WAN DiT
serving engine (``serving/loadgen.py`` + ``obs/slo.py``):

* **workload determinism** — the same ``WorkloadSpec`` seed must yield
  a byte-identical workload (sha256 digest equality), and a different
  seed a different one;
* **latency/goodput under load** — at a fixed offered load (0.6 x the
  calibrated single-batch capacity) the replay must keep goodput >=
  half the offered rate and e2e p99 within a small multiple of the
  warm batch wall.  Both gates are *relative* to the calibrated wall,
  so they hold on any host;
* **offline == live** — the SLO report recomputed from the written
  ``--trace-out`` artifact must equal the live report byte-for-byte
  (the evaluator only reads raw stamps; JSON float round-trip is
  exact);
* **lifecycle-obs overhead** — with full request-lifecycle tracing on
  (recorder + SLO spec), serving the same batch must cost <= 3% wall
  and exactly 0 extra compiles vs. the bare engine, extending the
  ``benchmarks/obs_overhead.py`` invariant to the serve path.

Artifacts (trace/metrics/report) land under ``artifacts/`` —
gitignored, uploaded by CI.
"""
from __future__ import annotations

import json
import os

import jax

from repro import models
from repro.configs import get_config
from repro.models import dit, frontends
from repro.obs import FlightRecorder
from repro.obs.clock import perf_s
from repro.obs.slo import SLOSpec, evaluate_slo, rows_from_trace
from repro.serving.engine import LPServingEngine, VideoRequest
from repro.serving.loadgen import (
    RequestClass,
    VirtualClock,
    WorkloadSpec,
    build_workload,
    run_workload,
    workload_digest,
)

STEPS = 4
K = 2
SHAPE = (6, 8, 12)
MAX_BATCH = 4
NUM_REQUESTS = 16
SEED = 0
UTILIZATION = 0.6          # offered load as a fraction of capacity
MIN_GOODPUT_FRAC = 0.5     # goodput >= this fraction of offered load
MAX_P99_BATCH_WALLS = 15.0  # e2e p99 <= this many warm batch walls
MAX_OVERHEAD_PCT = 3.0
OVERHEAD_ITERS = 10
OUT_JSON = "BENCH_serving_load.json"
ART_DIR = "artifacts"
OUT_TRACE = os.path.join(ART_DIR, "load_trace.json")
OUT_METRICS = os.path.join(ART_DIR, "load_metrics.jsonl")
OUT_REPORT = os.path.join(ART_DIR, "load_slo_report.json")

# one latent geometry for every class (one compiled step; the classes
# differ only in SLO priority) — per-shape compile costs are
# step_latency's business, not this gate's
MIX = (
    RequestClass("interactive", SHAPE, priority="interactive", weight=1.0),
    RequestClass("standard", SHAPE, priority="standard", weight=2.0),
    RequestClass("batch", SHAPE, priority="batch", weight=1.0),
)


def _engine():
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    return LPServingEngine(fwd, params, cfg, num_partitions=K,
                           num_steps=STEPS, max_batch=MAX_BATCH,
                           clock=VirtualClock()), cfg


def _full_batch(cfg, n=MAX_BATCH, base_id=10_000):
    return [
        VideoRequest(request_id=base_id + i,
                     context=frontends.text_context(
                         jax.random.PRNGKey(i), 1, cfg),
                     latent_shape=SHAPE, seed=i)
        for i in range(n)
    ]


def run(print_csv=True):
    os.makedirs(ART_DIR, exist_ok=True)

    # -- gate 1: workload determinism (no devices involved) ------------
    def wl(seed):
        return build_workload(WorkloadSpec(
            rate_rps=1.0, num_requests=NUM_REQUESTS, seed=seed, mix=MIX))

    digest = workload_digest(wl(SEED))
    det_same = workload_digest(wl(SEED)) == digest
    det_diff = workload_digest(wl(SEED + 1)) != digest

    # -- calibrate: warm the compiled step, measure the batch wall -----
    engine, cfg = _engine()
    # warm-up: the replay's ragged admissions hit every batch size
    # 1..MAX_BATCH, and batch size is in the compiled shape — compile
    # them all here so the measured run has zero retraces
    for n in range(1, MAX_BATCH + 1):
        for r in _full_batch(cfg, n=n, base_id=10_000 + 100 * n):
            engine.submit(r)
        engine.run()
    walls = []
    for it in range(2):
        for r in _full_batch(cfg, base_id=20_000 + 100 * it):
            engine.submit(r)
        walls.append(engine.run()[0].batch_wall_s)
    warm_wall_s = min(walls)
    capacity_rps = MAX_BATCH / warm_wall_s
    offered_rps = UTILIZATION * capacity_rps

    # -- gate 2: offered-load replay with lifecycle obs on -------------
    slo = SLOSpec.parse(
        f"interactive:{10 * warm_wall_s:.6g},"
        f"standard:{20 * warm_wall_s:.6g}@0.95,"
        f"batch:{40 * warm_wall_s:.6g}@0.9")
    rec = FlightRecorder()
    engine.recorder = rec
    engine.slo = slo
    engine.clock = VirtualClock()
    spec = WorkloadSpec(rate_rps=offered_rps, num_requests=NUM_REQUESTS,
                        seed=SEED, mix=MIX)
    workload = build_workload(spec)
    results = run_workload(engine, workload)
    live = evaluate_slo(rec.request_rows, spec=slo, num_devices=1,
                        recorder=rec)
    goodput = live["goodput_rps"]
    p99_e2e = max(e["e2e_p99_s"] for e in live["classes"].values())
    pass_goodput = goodput >= MIN_GOODPUT_FRAC * offered_rps
    pass_p99 = p99_e2e <= MAX_P99_BATCH_WALLS * warm_wall_s

    # -- gate 3: offline report from the trace artifact == live --------
    rec.write_trace(OUT_TRACE)
    rec.write_metrics(OUT_METRICS)
    offline = evaluate_slo(rows_from_trace(json.load(open(OUT_TRACE))),
                           spec=slo, num_devices=1)
    # the live dict goes through the same JSON round-trip the offline
    # one did, so equality is over identical float representations
    pass_offline = json.loads(json.dumps(live)) == \
        json.loads(json.dumps(offline))
    with open(OUT_REPORT, "w") as f:
        json.dump(live, f, indent=2, sort_keys=True)

    # -- gate 4: lifecycle-obs overhead on the serve path --------------
    def serve_once():
        for r in _full_batch(cfg, base_id=30_000):
            engine.submit(r)
        t0 = perf_s()
        out = engine.run()
        jax.block_until_ready(out[0].latent)
        return perf_s() - t0

    engine.recorder = None
    engine.slo = None
    bare_s = min(serve_once() for _ in range(OVERHEAD_ITERS))
    compiles0 = engine._compiler.compiles
    engine.recorder = FlightRecorder()
    engine.slo = slo
    rec_s = min(serve_once() for _ in range(OVERHEAD_ITERS))
    extra_compiles = engine._compiler.compiles - compiles0
    overhead_pct = (rec_s - bare_s) / bare_s * 100.0
    pass_overhead = overhead_pct <= MAX_OVERHEAD_PCT
    pass_no_recompile = extra_compiles == 0

    record = {
        "config": "wan21_dit_1p3b reduced",
        "num_steps": STEPS,
        "num_partitions": K,
        "max_batch": MAX_BATCH,
        "num_requests": NUM_REQUESTS,
        "workload_seed": SEED,
        "workload_digest": digest,
        "warm_batch_wall_s": warm_wall_s,
        "capacity_rps": capacity_rps,
        "offered_rps": offered_rps,
        "served": len(results),
        "goodput_rps": goodput,
        "e2e_p99_s": p99_e2e,
        "violations": live["violations"],
        "slo_spec": slo.spec,
        "bare_serve_s": bare_s,
        "recorded_serve_s": rec_s,
        "overhead_pct": overhead_pct,
        "extra_compiles_with_recorder": extra_compiles,
        "pass_determinism": bool(det_same and det_diff),
        "pass_goodput": bool(pass_goodput),
        "pass_p99": bool(pass_p99),
        "pass_offline_equals_live": bool(pass_offline),
        "pass_overhead": bool(pass_overhead),
        "pass_no_recompile": bool(pass_no_recompile),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    if not (det_same and det_diff):
        raise AssertionError(
            f"workload not seed-deterministic (same={det_same}, "
            f"diff={det_diff})")
    if len(results) != NUM_REQUESTS:
        raise AssertionError(
            f"replay lost requests: {len(results)}/{NUM_REQUESTS}")
    if not pass_goodput:
        raise AssertionError(
            f"goodput {goodput:.3f}rps < {MIN_GOODPUT_FRAC} x offered "
            f"{offered_rps:.3f}rps")
    if not pass_p99:
        raise AssertionError(
            f"e2e p99 {p99_e2e:.2f}s > {MAX_P99_BATCH_WALLS} x warm "
            f"batch wall {warm_wall_s:.2f}s")
    if not pass_offline:
        raise AssertionError(
            "offline SLO report (from trace artifact) != live report")
    if not pass_no_recompile:
        raise AssertionError(
            f"lifecycle recorder caused {extra_compiles} extra compiles")
    if not pass_overhead:
        raise AssertionError(
            f"lifecycle obs overhead {overhead_pct:.2f}% > "
            f"{MAX_OVERHEAD_PCT}% (bare {bare_s:.3f}s vs recorded "
            f"{rec_s:.3f}s per full batch)")

    if print_csv:
        print(f"serving_load/warm_batch,{warm_wall_s * 1e6:.0f},"
              f"capacity={capacity_rps:.2f}rps")
        print(f"serving_load/goodput,0,{goodput:.3f}rps of "
              f"{offered_rps:.3f} offered")
        print(f"serving_load/e2e_p99,{p99_e2e * 1e6:.0f},"
              f"viol={live['violations']}")
        print(f"serving_load/offline_eq,0,"
              f"{'equal' if pass_offline else 'DIFF'}")
        print(f"serving_load/overhead,0,{overhead_pct:.2f}% "
              f"extra_compiles={extra_compiles}")
        print(f"serving_load/json,0,wrote {OUT_JSON}")
    return record


if __name__ == "__main__":
    run()
