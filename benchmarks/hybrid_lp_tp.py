"""Hybrid LP×TP benchmark -> BENCH_hybrid_lp_tp.json.

The §11 composition on a 2D ``(lp=M, tp=T)`` mesh
(``core/hybrid.lp_forward_halo_hybrid``), measured on 8 fake CPU devices
(mesh 4x2) in a subprocess so the device-count XLA flag never leaks:

1. **wire bytes** — per-device collective payloads of one hybrid halo
   step per codec (fp32 / bf16 / int8 / int8-residual), measured from
   the compiled 2D-mesh HLO (``analysis/hlo_analyzer``) and cross-checked
   EXACTLY against ``comm_model.lp_halo_hybrid_step_collectives`` — the
   acceptance contract of the hybrid engine.  The intra-group Phi_m psum
   is reported separately (all-reduce row) and never charged to LP.
2. **psum contrast** — the same step through the psum engine
   (``lp_forward_shard_map``) on the same mesh: its all-reduce is
   latent-sized; the hybrid halo schedule must move fewer wire bytes.
3. **step latency** — warm per-step wall time of both engines on the
   fake mesh (CPU collectives: directional only, recorded for trend).

Gates: exact analytic==measured byte match for fp32/bf16/int8, and
hybrid halo wire bytes < psum wire bytes at M=4.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

MESH_M, MESH_T = 4, 2
R = 0.5
OUT_JSON = "BENCH_hybrid_lp_tp.json"

_SCRIPT = textwrap.dedent(
    """
    import os, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import comm_model as cm
    from repro.core import plan_uniform
    from repro.core.hybrid import lp_forward_halo_hybrid
    from repro.core.lp_step import lp_forward_uniform
    from repro.core.spmd import lp_forward_shard_map
    from repro.distributed.collectives import halo_spec
    from repro.launch.mesh import make_hybrid_mesh

    M, T, R = %(M)d, %(T)d, %(R)s
    mesh = make_hybrid_mesh(M, T)
    # wan21 smoke latent geometry (13, 60, 104, 16), partitioned on height
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(13, 60, 104, 16)).astype(np.float32))
    plan = plan_uniform(60, 2, M, R, dim=1)

    d = 16
    w1 = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32)) * 0.05
    def tp_denoise(window):
        # Megatron-pattern Phi_m: each tp rank contracts half the
        # channels, the group psums the partials over the tp axis
        tp = jax.lax.axis_index("model")
        half = d // T
        w_slice = jax.lax.dynamic_slice_in_dim(w1, tp * half, half, 0)
        x_slice = jax.lax.dynamic_slice_in_dim(window, tp * half, half, 3)
        partial = jnp.einsum("thwc,cd->thwd", x_slice, w_slice)
        return jnp.tanh(window) * 0.5 + jax.lax.psum(partial, "model")

    def ref_denoise(x):
        return jnp.tanh(x) * 0.5 + jnp.einsum("thwc,cd->thwd", x, w1)

    ccfg = cm.VDMCommConfig(
        latent_dims=(13, 60, 104), latent_channels=16,
        patch_sizes=(1, 2, 2), d_model=1, num_blocks=1, num_steps=1,
    )
    ref = lp_forward_uniform(ref_denoise, z, plan, axis=1)

    def timed(fn, *a):
        jax.block_until_ready(fn(*a))
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(*a)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3 * 1e3

    out = {"mesh": [M, T], "measured": {}, "modeled": {}, "latency_ms": {},
           "rel_err": {}}
    for name in ("fp32", "bf16", "int8", "int8-residual"):
        codec = get_codec(name)
        if codec.stateful:
            st = init_halo_wire_state(
                codec, halo_spec(plan),
                tuple(s for i, s in enumerate(z.shape) if i != 1))
            fn = jax.jit(lambda zz, s: lp_forward_halo_hybrid(
                tp_denoise, zz, plan, 1, mesh, codec=codec,
                codec_state=s)[0])
            hlo = fn.lower(z, st).compile().as_text()
            val = np.asarray(fn(z, st))
        else:
            c = None if name == "fp32" else codec
            fn = jax.jit(lambda zz: lp_forward_halo_hybrid(
                tp_denoise, zz, plan, 1, mesh, codec=c))
            hlo = fn.lower(z).compile().as_text()
            val = np.asarray(fn(z))
            out["latency_ms"][name] = timed(fn, z)
        a = analyze(hlo)
        out["measured"][name] = {k: float(v)
                                 for k, v in a.collective_bytes.items()}
        out["modeled"][name] = cm.lp_halo_hybrid_step_collectives(
            ccfg, M, T, R, dim=1, codec=name)
        out["rel_err"][name] = float(
            np.linalg.norm(val - np.asarray(ref))
            / np.linalg.norm(np.asarray(ref)))

    # psum-engine contrast on the same 2D mesh
    fn_psum = jax.jit(lambda zz: lp_forward_shard_map(
        tp_denoise, zz, plan, 1, mesh, "data"))
    a = analyze(fn_psum.lower(z).compile().as_text())
    out["measured"]["psum"] = {k: float(v)
                               for k, v in a.collective_bytes.items()}
    out["latency_ms"]["psum"] = timed(fn_psum, z)
    print("JSON:" + json.dumps(out))
    """
)


def _ring_wire(collectives: dict, K: int) -> float:
    """Per-device ring wire bytes from HLO output-shape payloads."""
    from repro.core.comm_model import collective_wire_bytes

    return sum(
        collective_wire_bytes(kind, b, K)
        for kind, b in collectives.items()
    )


def run(print_csv=True):
    res = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT % {"M": MESH_M, "T": MESH_T, "R": R}],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=560,
    )
    rec = None
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            rec = json.loads(line[len("JSON:"):])
    if rec is None:
        raise RuntimeError(
            f"hybrid subprocess failed:\n{res.stdout}\n{res.stderr[-2000:]}")

    # ---- gates: analytic == measured, exactly, for the LP collectives
    for name in ("fp32", "bf16", "int8"):
        want = rec["modeled"][name]
        got = rec["measured"][name]
        for kind in ("all-gather", "collective-permute"):
            assert got.get(kind, 0) == want[kind], (name, kind, got, want)
    # the hybrid halo schedule must beat the psum engine's wire bytes
    # (compare the LP collectives only; the Phi_m psum is identical in
    # both programs and excluded)
    lp_kinds = ("all-gather", "collective-permute")
    halo_wire = _ring_wire(
        {k: rec["measured"]["fp32"].get(k, 0) for k in lp_kinds}, MESH_M)
    psum_all = rec["measured"]["psum"].get("all-reduce", 0)
    phi_psum = rec["measured"]["fp32"].get("all-reduce", 0)
    psum_wire = _ring_wire({"all-reduce": psum_all - phi_psum}, MESH_M)
    assert halo_wire < psum_wire, (halo_wire, psum_wire)

    rec["wire_per_device"] = {"halo_fp32": halo_wire, "psum": psum_wire,
                              "reduction": psum_wire / halo_wire}
    with open(OUT_JSON, "w") as f:
        json.dump(rec, f, indent=1)

    if print_csv:
        for name, m in rec["modeled"].items():
            print(f"hybrid_lp_tp/bytes/{name},0,"
                  f"ag={m['all-gather']} pp={m['collective-permute']} "
                  f"(modeled==measured)")
        for name, ms in rec["latency_ms"].items():
            print(f"hybrid_lp_tp/latency/{name},{ms*1e3:.0f},step_ms={ms:.1f}")
        w = rec["wire_per_device"]
        print(f"hybrid_lp_tp/wire,0,halo={w['halo_fp32']/2**20:.2f}MB "
              f"psum={w['psum']/2**20:.2f}MB "
              f"reduction={w['reduction']:.2f}x")
        print(f"hybrid_lp_tp/json,0,wrote {OUT_JSON}")
    return rec


if __name__ == "__main__":
    run()
