"""Paper Fig. 9: video duration -> communication (LP vs HP) + quality."""
from __future__ import annotations

from repro.core import comm_model as cm
from .common import lp_vs_centralized

GB = 2**30


def run(print_csv=True):
    out = []
    for frames, secs in ((49, 3), (81, 5), (161, 10)):
        cfg = cm.wan21_comm_config(frames)
        hp = cm.comm_hp_xdit(cfg, 4) / GB
        lp = cm.comm_lp_measured(cfg, 4, 1.0) / GB
        out.append((secs, hp, lp))
        if print_csv:
            print(f"fig9_duration/{secs}s,0,HP={hp:.2f}GB LP={lp:.2f}GB")
    hp_growth = out[-1][1] - out[0][1]
    lp_growth = out[-1][2] - out[0][2]
    if print_csv:
        print(f"fig9_duration/growth,0,HP+={hp_growth:.1f}GB "
              f"LP+={lp_growth:.1f}GB (paper: ~10GB vs ~4GB)")
    assert lp_growth < hp_growth
    d = lp_vs_centralized(4, 2, 1.0, seed=4, latent=(10, 8, 12))
    if print_csv:
        print(f"fig9_duration/quality_10s_proxy,0,rel_l2={d['rel_l2']:.4f}")
    return out


if __name__ == "__main__":
    run()
