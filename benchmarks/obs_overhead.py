"""Flight-recorder overhead gate -> BENCH_obs_overhead.json.

The observability invariant (docs/observability.md): attaching a
``repro.obs.FlightRecorder`` to ``lp_denoise`` must cost <= 3% step
latency and exactly 0 extra XLA compiles — the recorder is host state
and never enters ``LPStepCompiler``'s cache key.

Method: one shared compiler on the reduced WAN DiT, warmed bare; then
min-of-N full denoise loops without and with a recorder on the SAME
compiler.  min() is robust to scheduler noise; any compile the recorder
caused would show up in ``compiler.compiles`` (and dwarf the 3% gate).
The instrumented run's trace + metrics snapshots are written alongside
the JSON for CI artifact upload.
"""
from __future__ import annotations

import json
import os

import jax

from repro.core import LPStepCompiler, lp_denoise
from repro.diffusion import FlowMatchEuler
from repro.obs import FlightRecorder, perf_s, validate_trace

from .common import reduced_dit_denoiser

STEPS = 6
K = 2
R = 0.5
ITERS = 5
OUT_JSON = "BENCH_obs_overhead.json"
# trace/metrics snapshots are run artifacts, not baselines: they land
# under artifacts/ (gitignored, CI-uploaded), unlike the BENCH json
OUT_TRACE = os.path.join("artifacts", "obs_trace.json")
OUT_METRICS = os.path.join("artifacts", "obs_metrics.prom")
MAX_OVERHEAD_PCT = 3.0


def run(print_csv=True):
    den, z_T, cfg = reduced_dit_denoiser(0, latent=(6, 8, 12))
    sampler = FlowMatchEuler(STEPS)
    import jax.numpy as jnp

    def den_fast(w, t):
        tv = jnp.full((w.shape[0],), t, jnp.float32)
        return den(w, tv)

    comp = LPStepCompiler(den_fast, sampler.update, K, R, cfg.patch_sizes,
                          (1, 2, 3), uniform=True)

    def loop(recorder=None):
        return lp_denoise(None, z_T, sampler, STEPS, K, R, cfg.patch_sizes,
                          (1, 2, 3), uniform=True, compiler=comp,
                          recorder=recorder)

    jax.block_until_ready(loop())  # warm: compiles the per-dim steps
    compiles_warm = comp.compiles

    bare_s = []
    for _ in range(ITERS):
        t0 = perf_s()
        jax.block_until_ready(loop())
        bare_s.append(perf_s() - t0)
    compiles_bare = comp.compiles

    # the gate recorder: full trace + metrics planes on, same compiler
    rec = FlightRecorder()
    rec_s = []
    for _ in range(ITERS):
        t0 = perf_s()
        jax.block_until_ready(loop(recorder=rec))
        rec_s.append(perf_s() - t0)
    compiles_rec = comp.compiles

    bare_step_ms = min(bare_s) / STEPS * 1e3
    rec_step_ms = min(rec_s) / STEPS * 1e3
    overhead_pct = (rec_step_ms - bare_step_ms) / bare_step_ms * 100.0
    extra_compiles = compiles_rec - compiles_bare

    os.makedirs(os.path.dirname(OUT_TRACE), exist_ok=True)
    rec.write_trace(OUT_TRACE)
    rec.write_metrics(OUT_METRICS)
    trace_errors = validate_trace(json.load(open(OUT_TRACE)))

    record = {
        "config": "wan21_dit_1p3b reduced",
        "num_steps": STEPS,
        "num_partitions": K,
        "iters": ITERS,
        "bare_step_ms": bare_step_ms,
        "recorded_step_ms": rec_step_ms,
        "overhead_pct": overhead_pct,
        "max_overhead_pct": MAX_OVERHEAD_PCT,
        "compiles_after_warmup": compiles_warm,
        "extra_compiles_with_recorder": extra_compiles,
        "trace_events": len(rec.trace.events),
        "trace_schema_errors": trace_errors,
        "pass_overhead": bool(overhead_pct <= MAX_OVERHEAD_PCT),
        "pass_no_recompile": bool(extra_compiles == 0),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    if extra_compiles != 0:
        raise AssertionError(
            f"recorder caused {extra_compiles} extra compiles — it must "
            "never enter the LPStepCompiler cache key")
    if trace_errors:
        raise AssertionError(f"trace schema errors: {trace_errors}")
    if overhead_pct > MAX_OVERHEAD_PCT:
        raise AssertionError(
            f"recorder overhead {overhead_pct:.2f}% > "
            f"{MAX_OVERHEAD_PCT}% gate (bare {bare_step_ms:.2f}ms vs "
            f"recorded {rec_step_ms:.2f}ms per step)")

    if print_csv:
        print(f"obs_overhead/bare,{bare_step_ms * 1e3:.0f},per_step")
        print(f"obs_overhead/recorded,{rec_step_ms * 1e3:.0f},"
              f"overhead={overhead_pct:.2f}%")
        print(f"obs_overhead/compiles,0,extra={extra_compiles}")
        print(f"obs_overhead/json,0,wrote {OUT_JSON}")
    return record


if __name__ == "__main__":
    run()
