"""Replica-router resilience gate -> BENCH_router_resilience.json.

One seeded overload workload is replayed twice over a fleet of
``REPLICAS`` reduced WAN DiT engines behind ``serving/router.py``:
once fault-free (the baseline), once with ``replica:<K>:dead@1`` — the
last replica is killed at its first denoise step, mid-run.  Gates:

* **zero lost requests** — every admitted request has exactly one
  disposition (completed result, ``request.shed`` trace row, or
  terminal ``request.failed`` trace row): completed + shed + failed ==
  admitted, in the router's own stats AND recomputed from trace rows;
* **goodput floor** — goodput with the kill >= (N-1)/N x the
  fault-free goodput of the same workload (losing 1 of N replicas
  costs at most its capacity share, never a collapse);
* **degrade before violation** — the router's first ``router.degrade``
  instant fires before any high-priority (interactive) deadline
  violation completes: quality is spent before deadlines are;
* **offline == live, per replica** — the SLO report recomputed by the
  real ``loadtest --report-from`` CLI from the written trace artifact
  is byte-identical (canonical JSON serialization) to the live report,
  including the per-replica and disposition sections.

The burst at t=0 drives queue depth through both the shed and degrade
watermarks, so both code paths land rows in the artifact; shedding
happens at admission (before any service), so the baseline and the
kill run shed identically and stay goodput-comparable.

Artifacts land under ``artifacts/`` — gitignored, uploaded by CI.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

from repro import models
from repro.configs import get_config
from repro.models import dit, frontends
from repro.obs import FlightRecorder
from repro.obs.slo import SLOSpec, evaluate_slo
from repro.serving.engine import LPServingEngine, VideoRequest
from repro.serving.loadgen import (
    Arrival,
    RequestClass,
    VirtualClock,
    WorkloadSpec,
    build_workload,
)
from repro.serving.router import ReplicaRouter

STEPS = 2
K = 2                       # latent partitions per engine
SHAPE = (4, 8, 12)
MAX_BATCH = 2
REPLICAS = 3
BURST = 10                  # arrivals at t=0 (forces shed + degrade)
TRAILING = 8                # arrivals after the burst
TRAIL_UTIL = 0.3            # trailing rate as a fraction of capacity
SEED = 0
SHED_WATERMARK = 8          # < BURST: the burst must shed
DEGRADE_WATERMARK = 3
MAX_REDISPATCH = 2
PSNR_FLOOR_DB = 32.0
MIN_FLOOR_DB = 24.0
OUT_JSON = "BENCH_router_resilience.json"
ART_DIR = "artifacts"
OUT_TRACE = os.path.join(ART_DIR, "router_trace.json")
OUT_METRICS = os.path.join(ART_DIR, "router_metrics.jsonl")
OUT_REPORT = os.path.join(ART_DIR, "router_slo_report.json")
OUT_REPORT_OFFLINE = os.path.join(ART_DIR, "router_slo_report_offline.json")

MIX = (
    RequestClass("interactive", SHAPE, priority="interactive",
                 weight=1.0, psnr_floor=PSNR_FLOOR_DB),
    RequestClass("standard", SHAPE, priority="standard",
                 weight=2.0, psnr_floor=PSNR_FLOOR_DB),
)


def _engine(recorder, slo):
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    return LPServingEngine(fwd, params, cfg, num_partitions=K,
                           num_steps=STEPS, max_batch=MAX_BATCH,
                           max_queue=64, recorder=recorder, slo=slo,
                           clock=VirtualClock()), cfg


def _warm(engine, cfg):
    """Compile every batch size 1..MAX_BATCH so no measured dispatch
    pays JIT inside its virtual wall."""
    walls = []
    for n in range(1, MAX_BATCH + 1):
        for i in range(n):
            engine.submit(VideoRequest(
                request_id=90_000 + 10 * n + i,
                context=frontends.text_context(
                    jax.random.PRNGKey(i), 1, cfg),
                latent_shape=SHAPE, seed=i))
        out = engine.run()
        if n == MAX_BATCH:
            walls.append(out[0].batch_wall_s)
    # re-measure once warm
    for i in range(MAX_BATCH):
        engine.submit(VideoRequest(
            request_id=91_000 + i,
            context=frontends.text_context(jax.random.PRNGKey(i), 1, cfg),
            latent_shape=SHAPE, seed=i))
    walls.append(engine.run()[0].batch_wall_s)
    return min(walls)


def _workload(warm_wall_s):
    """BURST arrivals at t=0 (deep queue -> shed + degrade), then
    TRAILING more at a rate the (N-1)-replica survivor fleet can
    absorb — so the kill costs its capacity share, not a collapse."""
    fleet_rps = REPLICAS * MAX_BATCH / warm_wall_s
    spec = WorkloadSpec(rate_rps=TRAIL_UTIL * fleet_rps,
                        num_requests=BURST + TRAILING, seed=SEED,
                        mix=MIX)
    arrivals = build_workload(spec)
    out = [Arrival(a.request_id,
                   0.0 if a.request_id < BURST else a.arrival_s,
                   a.cls, a.seed)
           for a in arrivals]
    return out, spec


def _run_fleet(workload, slo, inject_fault=None):
    rec = FlightRecorder()
    engines = []
    cfg = None
    for _ in range(REPLICAS):
        eng, cfg = _engine(recorder=None, slo=slo)
        _warm(eng, cfg)
        eng.recorder = rec
        eng.clock = VirtualClock()
        engines.append(eng)
    router = ReplicaRouter(
        engines, recorder=rec, slo=slo,
        shed_watermark=SHED_WATERMARK,
        degrade_watermark=DEGRADE_WATERMARK,
        max_redispatch=MAX_REDISPATCH,
        min_psnr_floor_db=MIN_FLOOR_DB,
        inject_fault=inject_fault)
    results = router.serve(workload)
    return router, rec, results


def run(print_csv=True):
    os.makedirs(ART_DIR, exist_ok=True)

    # -- calibrate once, derive the SLO + workload from the warm wall --
    cal_engine, cal_cfg = _engine(recorder=None, slo=None)
    warm_wall_s = _warm(cal_engine, cal_cfg)
    del cal_engine
    slo = SLOSpec.parse(
        f"interactive:{40 * warm_wall_s:.6g},"
        f"standard:{80 * warm_wall_s:.6g}@0.95")
    workload, spec = _workload(warm_wall_s)

    # -- baseline: same workload, no faults ----------------------------
    base_router, base_rec, base_results = _run_fleet(workload, slo)
    base_live = evaluate_slo(
        base_rec.request_rows, spec=slo, num_devices=K,
        shed_rows=base_rec.shed_rows, failed_rows=base_rec.failed_rows)
    base_goodput = base_live["goodput_rps"]

    # -- the drill: kill the last replica at its first denoise step ----
    fault = f"replica:{REPLICAS - 1}:dead@1"
    router, rec, results = _run_fleet(workload, slo, inject_fault=fault)
    live = evaluate_slo(
        rec.request_rows, spec=slo, num_devices=K,
        shed_rows=rec.shed_rows, failed_rows=rec.failed_rows)
    goodput = live["goodput_rps"]

    # -- gate 1: zero lost requests (stats AND trace rows agree) -------
    admitted = router.stats["admitted"]
    accounted_stats = (router.stats["completed"] + router.stats["shed"]
                       + router.stats["failed"])
    disp = live["disposition"]
    pass_zero_lost = (
        admitted == BURST + TRAILING
        and accounted_stats == admitted
        and disp["accounted"] == admitted
        and len(results) == router.stats["completed"]
        and len(rec.shed_rows) == router.stats["shed"]
        and len(rec.failed_rows) == router.stats["failed"])
    killed = router.replicas[REPLICAS - 1]
    pass_kill_observed = (killed.state == "dead"
                          and router.stats["replica_deaths"] == 1
                          and router.stats["redispatches"] >= 1)

    # -- gate 2: goodput floor at (N-1)/N of fault-free ----------------
    goodput_floor = (REPLICAS - 1) / REPLICAS * base_goodput
    pass_goodput = goodput >= goodput_floor

    # -- gate 3: degrade fires before any interactive violation --------
    degrades = [e for e in rec.trace.events
                if e["name"] == "router.degrade"]
    first_degrade_s = (min(e["args"]["now_s"] for e in degrades)
                       if degrades else None)
    hi_violations = [
        r["done_s"] for r in rec.request_rows
        if r.get("priority") == "interactive"
        and r["e2e_s"] > slo.deadline_for("interactive")]
    first_violation_s = min(hi_violations) if hi_violations else None
    pass_degrade = (first_degrade_s is not None
                    and (first_violation_s is None
                         or first_degrade_s < first_violation_s))

    # -- gate 4: offline --report-from report byte-identical to live ---
    rec.write_trace(OUT_TRACE)
    rec.write_metrics(OUT_METRICS)
    with open(OUT_REPORT, "w") as f:
        json.dump(live, f, indent=2, sort_keys=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    subprocess.run(
        [sys.executable, "-m", "repro.launch.loadtest",
         "--report-from", OUT_TRACE, "--slo", slo.spec,
         "--num-devices", str(K), "--report-out", OUT_REPORT_OFFLINE],
        check=True, env=env, capture_output=True)
    with open(OUT_REPORT_OFFLINE) as f:
        offline = json.load(f)
    offline.pop("source", None)
    canon = lambda d: json.dumps(d, indent=2, sort_keys=True)  # noqa: E731
    pass_offline = canon(offline) == canon(json.loads(json.dumps(live)))
    pass_per_replica = (
        "replicas" in live
        and str(REPLICAS - 1) not in live["replicas"]  # the dead one
        and sum(e["count"] for e in live["replicas"].values())
        == router.stats["completed"]
        and "replicas" in offline)

    record = {
        "config": "wan21_dit_1p3b reduced",
        "num_steps": STEPS,
        "num_partitions": K,
        "max_batch": MAX_BATCH,
        "replicas": REPLICAS,
        "workload": {"burst": BURST, "trailing": TRAILING,
                     "seed": SEED, "rate_rps": spec.rate_rps},
        "inject_fault": fault,
        "warm_batch_wall_s": warm_wall_s,
        "slo_spec": slo.spec,
        "baseline": {
            "goodput_rps": base_goodput,
            "completed": base_router.stats["completed"],
            "shed": base_router.stats["shed"],
            "violations": base_live["violations"],
        },
        "fault_run": {
            "goodput_rps": goodput,
            "completed": router.stats["completed"],
            "shed": router.stats["shed"],
            "failed": router.stats["failed"],
            "redispatches": router.stats["redispatches"],
            "replica_deaths": router.stats["replica_deaths"],
            "replica_states": [r.state for r in router.replicas],
            "violations": live["violations"],
            "first_degrade_s": first_degrade_s,
            "first_interactive_violation_s": first_violation_s,
        },
        "goodput_floor_rps": goodput_floor,
        "pass_zero_lost": bool(pass_zero_lost),
        "pass_kill_observed": bool(pass_kill_observed),
        "pass_goodput": bool(pass_goodput),
        "pass_degrade_before_violation": bool(pass_degrade),
        "pass_offline_equals_live": bool(pass_offline),
        "pass_per_replica_report": bool(pass_per_replica),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    if not pass_kill_observed:
        raise AssertionError(
            f"kill not observed: state={killed.state} "
            f"deaths={router.stats['replica_deaths']} "
            f"redispatches={router.stats['redispatches']}")
    if not pass_zero_lost:
        raise AssertionError(
            f"lost requests: admitted={admitted} "
            f"completed={router.stats['completed']} "
            f"shed={router.stats['shed']} "
            f"failed={router.stats['failed']} "
            f"disposition={disp}")
    if not pass_goodput:
        raise AssertionError(
            f"goodput {goodput:.3f}rps < (N-1)/N x fault-free "
            f"{base_goodput:.3f}rps = {goodput_floor:.3f}rps")
    if not pass_degrade:
        raise AssertionError(
            f"degrade did not precede interactive violations "
            f"(first_degrade={first_degrade_s}, "
            f"first_violation={first_violation_s})")
    if not pass_offline:
        raise AssertionError(
            "offline --report-from report != live report")
    if not pass_per_replica:
        raise AssertionError(
            f"per-replica report malformed: {live.get('replicas')}")

    if print_csv:
        print(f"router_resilience/warm_batch,{warm_wall_s * 1e6:.0f},"
              f"replicas={REPLICAS}")
        print(f"router_resilience/zero_lost,0,admitted={admitted} "
              f"completed={router.stats['completed']} "
              f"shed={router.stats['shed']} "
              f"failed={router.stats['failed']}")
        print(f"router_resilience/goodput,0,{goodput:.3f}rps >= "
              f"{goodput_floor:.3f} floor (fault-free "
              f"{base_goodput:.3f})")
        print(f"router_resilience/degrade,0,first={first_degrade_s} "
              f"violations={live['violations']}")
        print(f"router_resilience/offline_eq,0,"
              f"{'equal' if pass_offline else 'DIFF'}")
        print(f"router_resilience/json,0,wrote {OUT_JSON}")
    return record


if __name__ == "__main__":
    run()
