"""Displaced (stale-slab) halo wire benchmark -> BENCH_displaced_halo.json.

The displaced halo exchange (``comm/wire.py``, ``displaced:*-residual``
codecs) on the single-rotation-dim long-video workload it exists for —
latent (61, 2, 2, 16), patch grid (61, 1, 1), so the dim rotation never
flushes the stale-slab carry — on a 2D ``(lp=2, tp=4)`` hybrid mesh of
8 fake CPU devices (subprocess; the device-count XLA flag never leaks):

1. **byte identity** — the compiled displaced step moves EXACTLY the
   bytes of its synchronous residual base, per collective per tier
   (``analysis/hlo_analyzer`` group-size breakdown vs
   ``comm_model.lp_halo_sharded_step_collectives``).  Displaced changes
   *when* bytes gate the step, never how many cross the wire.
2. **hidden-tier contract** — ``lp_halo_wire_profile``'s split obeys
   ``exposed + hidden == num_steps x measured step bytes`` (the HLO
   contract) with ``hidden == (S-1) x slab-ppermute bytes``.
3. **exposed wire time** — under the two-tier 10:1 ``LinkModel``
   (25/250 gbps), the displaced tp-sharded wire's exposed time is
   >= 2x lower than the eager synchronous halo baseline's at T=4.
   (Same transport, displaced-vs-sync alone is bounded < 2x: the core
   all-gather is never hidden and slab bytes <= gather bytes
   geometrically — the JSON reports that decomposition too.)
4. **recovered quality** — an 8-step displaced denoise on the simulate
   mirror (bit-faithful to the mesh) lands above the displaced
   envelope floors (``policy/envelope.py``; staleness + quantization).
5. **compile discipline** — a 6-step displaced ``lp_denoise`` stays at
   <= 3 x num_segments compiles (the staleness flag rides the scan
   carry, it is not a retrace axis).
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import numpy as np

MESH_M, MESH_T = 2, 4
R = 0.5
S = 4          # accounting steps (the displaced run being profiled)
OUT_JSON = "BENCH_displaced_halo.json"

_SCRIPT = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import comm_model as cm
    from repro.core import plan_uniform
    from repro.core.hybrid import lp_forward_halo_hybrid
    from repro.core.lp_step import LPStepCompiler, lp_denoise
    from repro.distributed.collectives import halo_spec
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.launch.mesh import make_hybrid_mesh

    M, T, R = %(M)d, %(T)d, %(R)s
    mesh = make_hybrid_mesh(M, T)
    # long-video single-rotation-dim latent: patch grid (61, 1, 1)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(61, 2, 2, 16)).astype(np.float32))
    plan = plan_uniform(61, 1, M, R, dim=0)

    d = 16
    w1 = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32)) * 0.05
    def tp_denoise(window):
        tp = jax.lax.axis_index("model")
        part = d // T
        w_slice = jax.lax.dynamic_slice_in_dim(w1, tp * part, part, 0)
        x_slice = jax.lax.dynamic_slice_in_dim(window, tp * part, part, 3)
        partial = jnp.einsum("thwc,cd->thwd", x_slice, w_slice)
        return jnp.tanh(window) * 0.5 + jax.lax.psum(partial, "model")

    rest = tuple(s for i, s in enumerate(z.shape) if i != 0)

    def lower(name):
        codec = get_codec(name)
        st = init_halo_wire_state(codec, halo_spec(plan), rest)
        fn = jax.jit(lambda zz, s: lp_forward_halo_hybrid(
            tp_denoise, zz, plan, 0, mesh, codec=codec, codec_state=s,
            wire_shard=True))
        hlo = fn.lower(z, st).compile().as_text()
        val, st_out = fn(z, st)
        a = analyze(hlo)
        return ({k: float(v) for k, v in a.collective_group_bytes.items()},
                np.asarray(val))

    out = {"mesh": [M, T], "measured": {}}
    for name in ("int8-residual", "displaced:int8-residual"):
        out["measured"][name], _ = lower(name)

    # compile discipline: 6-step single-dim displaced denoise (one
    # codec = one segment); the fresh flag is scan-carry state, so the
    # whole run is one fused scan per dim-run
    disp = get_codec("displaced:int8-residual")
    z6 = jnp.asarray(rng.normal(size=(1, 61, 2, 2, 16)).astype(np.float32))
    sampler = FlowMatchEuler(6)
    def fwd(fn, zz, pl, ax, st):
        return lp_forward_halo_hybrid(
            fn, zz, pl, ax, mesh, codec=disp, codec_state=st,
            wire_shard=True)
    comp = LPStepCompiler(
        lambda w, t: jnp.tanh(w) * 0.5 + w * (1 + 1e-4 * t),
        sampler.update, M, R, (1, 2, 2), (1, 2, 3), uniform=True,
        forward=fwd, codec=disp, mesh_shape=(M, T), wire_shard=True)
    o6 = lp_denoise(None, z6, sampler, 6, M, R, (1, 2, 2), (1, 2, 3),
                    uniform=True, compiler=comp)
    assert np.isfinite(np.asarray(o6)).all()
    out["denoise"] = {"compiles": comp.compiles, "num_segments": 1,
                      "state_inits": comp.state_inits}
    print("JSON:" + json.dumps(out))
    """
)


def _psnr_db(a, b) -> float:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    mse = float(np.mean((a - b) ** 2))
    return float(10 * np.log10(float(np.abs(b).max()) ** 2 / max(mse, 1e-30)))


def _recovered_psnr(name: str, steps: int = 8) -> float:
    """Displaced denoise on the simulate mirror vs the exact fp32 path
    — the mirror is bit-faithful to the mesh engine, codec round-trips
    included, so these are the mesh's quality numbers."""
    import jax.numpy as jnp

    from repro.comm import get_codec, init_halo_wire_state, \
        simulate_halo_forward
    from repro.core import plan_uniform
    from repro.core.lp_step import lp_forward_uniform
    from repro.distributed.collectives import halo_spec

    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.normal(size=(61, 2, 2, 16)).astype(np.float32))
    plan = plan_uniform(61, 1, MESH_M, R, dim=0)
    den = lambda x: jnp.tanh(x) * 0.5 + x  # noqa: E731
    codec = get_codec(name)
    rest = tuple(s for i, s in enumerate(z.shape) if i != 0)
    st = init_halo_wire_state(codec, halo_spec(plan), rest)
    zd = ze = z
    for _ in range(steps):
        od, st = simulate_halo_forward(den, zd, plan, 0, codec, st)
        zd = zd - 0.1 * od
        ze = ze - 0.1 * lp_forward_uniform(den, ze, plan, axis=0)
    return _psnr_db(zd, ze)


def run(print_csv=True):
    from repro.core import comm_model as cm
    from repro.policy.autotune import LinkModel
    from repro.policy.envelope import PSNR_ENVELOPE_DB

    res = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT % {"M": MESH_M, "T": MESH_T, "R": R}],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=560,
    )
    rec = None
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            rec = json.loads(line[len("JSON:"):])
    if rec is None:
        raise RuntimeError(
            f"displaced_halo subprocess failed:\n"
            f"{res.stdout}\n{res.stderr[-2000:]}")

    M, T = rec["mesh"]
    ccfg = cm.VDMCommConfig(
        latent_dims=(61, 2, 2), latent_channels=16, patch_sizes=(1, 2, 2),
        d_model=1, num_blocks=1, num_steps=S,
    )
    # ---- gate 1: byte identity, per collective per tier, measured ==
    # modeled EXACTLY, and displaced == its synchronous base
    want = cm.lp_halo_sharded_step_collectives(
        ccfg, M, T, R, dim=0, codec="displaced:int8-residual")
    exact = {
        "collective-permute": want["inter"]["collective-permute"],
        f"all-gather[{M}]": want["inter"]["all-gather"],
        f"all-gather[{T}]": want["intra"]["all-gather"],
    }
    for name in ("int8-residual", "displaced:int8-residual"):
        got = rec["measured"][name]
        for kind, v in exact.items():
            assert got.get(kind, 0) == v, (name, kind, got, exact)
    rec["modeled_step"] = {k: {c: float(b) for c, b in t.items()}
                           for k, t in want.items()}

    # ---- gate 2: hidden-tier contract over an S-step displaced run
    disp_codecs = ["displaced:int8-residual"] * S
    sync_codecs = ["int8-residual"] * S
    prof = cm.lp_halo_wire_profile(ccfg, M, T, R, disp_codecs,
                                   wire_shard=True)
    pp = want["inter"]["collective-permute"]
    step_inter = pp + want["inter"]["all-gather"]
    assert prof["hidden"] == (S - 1) * pp, prof
    assert prof["inter"] + prof["hidden"] == S * step_inter, prof
    assert prof["intra"] == S * want["intra"]["all-gather"], prof
    rec["profile"] = {k: float(v) for k, v in prof.items()}

    # ---- gate 3: exposed wire time >= 2x lower than the eager
    # synchronous halo baseline at T=4 under the 10:1 link model
    links = LinkModel()           # 25 / 250 gbps = the 10:1 two-tier
    base = cm.lp_halo_wire_profile(ccfg, M, T, R, sync_codecs,
                                   wire_shard=False)  # eager sync wire
    t_base = links.wire_time_ms(base["inter"], base["intra"])
    t_disp = links.wire_time_ms(prof["inter"], prof["intra"])
    sync_sh = cm.lp_halo_wire_profile(ccfg, M, T, R, sync_codecs,
                                      wire_shard=True)
    t_sync_sh = links.wire_time_ms(sync_sh["inter"], sync_sh["intra"])
    rec["wire_time_ms"] = {"eager_sync": t_base, "sync_sharded": t_sync_sh,
                           "displaced_sharded": t_disp}
    rec["exposed_speedup_vs_eager_sync"] = t_base / t_disp
    rec["exposed_speedup_same_transport"] = t_sync_sh / t_disp
    assert rec["exposed_speedup_vs_eager_sync"] >= 2.0, rec["wire_time_ms"]
    assert rec["exposed_speedup_same_transport"] > 1.0, rec["wire_time_ms"]

    # ---- gate 4: recovered PSNR >= the displaced envelope floors
    rec["psnr_db"] = {}
    for name in ("displaced:int8-residual", "displaced:int4-residual"):
        db = _recovered_psnr(name)
        rec["psnr_db"][name] = db
        assert db >= PSNR_ENVELOPE_DB[name], (name, db)

    # ---- gate 5: compile discipline
    dn = rec["denoise"]
    assert dn["compiles"] <= 3 * dn["num_segments"], dn

    with open(OUT_JSON, "w") as f:
        json.dump(rec, f, indent=1)

    if print_csv:
        print(f"displaced_halo/bytes,0,step pp={pp} "
              f"ag={exact[f'all-gather[{M}]']} (modeled==measured, "
              "displaced==sync)")
        print(f"displaced_halo/hidden,0,hidden={prof['hidden']} "
              f"exposed={prof['inter']} (S={S})")
        print(f"displaced_halo/wire_time,0,"
              f"{rec['exposed_speedup_vs_eager_sync']:.2f}x vs eager sync "
              f"({rec['exposed_speedup_same_transport']:.2f}x same "
              "transport)")
        for name, db in rec["psnr_db"].items():
            print(f"displaced_halo/psnr/{name},0,{db:.1f} dB "
                  f"(floor {PSNR_ENVELOPE_DB[name]})")
        print(f"displaced_halo/denoise,0,compiles={dn['compiles']} "
              f"(<= {3 * dn['num_segments']})")
        print(f"displaced_halo/json,0,wrote {OUT_JSON}")
    return rec


if __name__ == "__main__":
    run()
