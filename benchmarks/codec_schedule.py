"""Step-policy benchmark -> BENCH_codec_schedule.json.

Compares three wire policies on the wan21 smoke config (49-frame 480p
geometry for bytes, reduced WAN DiT for quality):

  * ``fp32``          — the uncompressed halo baseline;
  * ``int8-residual`` — PR 2's best fixed codec;
  * ``scheduled``     — the PR 4 auto-plan at a 40 dB floor
    (``policy.auto_plan``): sigma-scheduled codecs, int4-residual while
    the trajectory is high-noise, int8-residual tail.

Per policy it records analytic wire bytes per denoise
(``comm_model.comm_lp_halo_scheduled``), end-latent PSNR vs the exact
fp32 path, and the compile count of the segmented-scan execution.  The
measured-HLO cross-check compiles the halo engine once per schedule
segment codec on 4 fake CPU devices and requires the analytic
per-device step model to match the compiled collectives EXACTLY.

Gates (the PR's acceptance bar):
  * scheduled moves >= 2.5x fewer wire bytes than the fp32 halo path;
  * scheduled PSNR >= 40 dB (the floor the autotuner was asked for);
  * compiles <= 3 x num_segments per denoise;
  * analytic bytes == measured HLO bytes, exactly, per segment.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LPStepCompiler, lp_denoise
from repro.core import comm_model as cm
from repro.diffusion import FlowMatchEuler
from repro.policy import auto_plan

from .common import divergence, reduced_dit_denoiser
from repro.obs.clock import perf_s

STEPS = 6
K = 4
R = 0.5
PSNR_FLOOR = 40.0
OUT_JSON = "BENCH_codec_schedule.json"

_COMM_SCRIPT = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import plan_uniform
    from repro.core.spmd import lp_forward_halo
    from repro.distributed.collectives import halo_spec

    mesh = compat.make_mesh((4,), ("data",))
    # wan21 smoke latent geometry (13, 60, 104, 16), partitioned on height
    z = jnp.zeros((13, 60, 104, 16), jnp.float32)
    plan = plan_uniform(60, 2, 4, 0.5, dim=1)
    den = lambda x: jnp.tanh(x) * 0.5 + x
    out = {}
    for name in %s:
        codec = get_codec(name)
        if codec.stateful:
            st = init_halo_wire_state(
                codec, halo_spec(plan),
                tuple(s for i, s in enumerate(z.shape) if i != 1))
            fn = jax.jit(lambda zz, s: lp_forward_halo(
                den, zz, plan, 1, mesh, codec=codec, codec_state=s)[0])
            hlo = fn.lower(z, st).compile().as_text()
        elif name == "fp32":
            fn = jax.jit(lambda zz: lp_forward_halo(den, zz, plan, 1, mesh))
            hlo = fn.lower(z).compile().as_text()
        else:
            fn = jax.jit(lambda zz: lp_forward_halo(
                den, zz, plan, 1, mesh, codec=codec))
            hlo = fn.lower(z).compile().as_text()
        a = analyze(hlo)
        out[name] = {k: float(v) for k, v in a.collective_bytes.items()}
    print("JSON:" + json.dumps(out))
    """
)


def _measured_comm(codecs):
    """Per-device collective payloads (HLO output-shape accounting) of
    one halo LP step per codec, on 4 fake CPU devices in a subprocess."""
    res = subprocess.run(
        [sys.executable, "-c", _COMM_SCRIPT % repr(tuple(codecs))],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=560,
    )
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[len("JSON:"):])
    return {"error": res.stderr[-500:]}


def run(print_csv=True, measure_hlo=True):
    sampler = FlowMatchEuler(STEPS)
    ccfg = cm.wan21_comm_config(49, num_steps=STEPS)
    plan = auto_plan(ccfg, K, R, sampler, STEPS, psnr_floor_db=PSNR_FLOOR)

    policies = {
        "fp32": ("fp32",) * STEPS,
        "int8-residual": ("int8-residual",) * STEPS,
        "scheduled": plan.step_codecs,
    }

    # ---- analytic wire bytes per denoise (group aggregate)
    bytes_rec = {}
    fp32_wire = cm.comm_lp_halo_scheduled(ccfg, K, R, policies["fp32"])
    for name, step_codecs in policies.items():
        wire = cm.comm_lp_halo_scheduled(ccfg, K, R, step_codecs)
        bytes_rec[name] = {
            "wire_bytes_per_denoise": wire,
            "reduction_vs_fp32_halo": fp32_wire / wire,
            "segments": [
                {k: v for k, v in seg.items() if k != "per_dim"}
                for seg in cm.lp_halo_scheduled_segments(
                    ccfg, K, R, step_codecs)
            ],
        }

    # ---- PSNR + compile count on the reduced DiT (simulate-halo engine)
    den, z_T, cfg = reduced_dit_denoiser(3, latent=(6, 8, 12))

    def den_fast(w, t):
        return den(w, jnp.full((w.shape[0],), t, jnp.float32))

    quality = {}
    outs = {}
    for name in policies:
        kwargs = ({"schedule": plan.schedule.spec} if name == "scheduled"
                  else {"codec": name})
        comp = LPStepCompiler(
            den_fast, sampler.update, K, R, cfg.patch_sizes, (1, 2, 3),
            uniform=True, **kwargs,
        )

        def loop():
            return lp_denoise(None, z_T, sampler, STEPS, K, R,
                              cfg.patch_sizes, (1, 2, 3), uniform=True,
                              compiler=comp)

        jax.block_until_ready(loop())          # compile
        compiles = comp.compiles
        t0 = perf_s()
        z0 = loop()
        jax.block_until_ready(z0)
        step_ms = (perf_s() - t0) / STEPS * 1e3
        outs[name] = z0
        div = ({"rel_l2": 0.0, "psnr_db": float("inf")} if name == "fp32"
               else divergence(z0, outs["fp32"]))
        quality[name] = {"step_ms": step_ms, "compiles": compiles, **div}

    # ---- measured HLO per schedule segment (exact-match contract)
    seg_codecs = sorted({seg.codec for seg in plan.segments})
    measured = _measured_comm(seg_codecs) if measure_hlo else {}
    hlo_match = {}
    if isinstance(measured, dict) and "error" not in measured:
        for name in seg_codecs:
            want = cm.lp_halo_codec_step_collectives(ccfg, K, R, dim=1,
                                                     codec=name)
            got = measured[name]
            for kind in ("all-gather", "collective-permute"):
                assert got.get(kind, 0) == want[kind], (
                    f"{name}/{kind}: measured {got.get(kind)} != analytic "
                    f"{want[kind]} (exact-match contract)"
                )
            assert "all-reduce" not in got, (name, got)
            hlo_match[name] = {"modeled": want, "measured": got}

    record = {
        "config": "wan21_dit_1p3b reduced / wan21 49f smoke geometry",
        "num_steps": STEPS,
        "num_partitions": K,
        "overlap_ratio": R,
        "psnr_floor_db": PSNR_FLOOR,
        "auto_plan": {
            "lp_impl": plan.lp_impl,
            "schedule": plan.schedule.spec,
            "step_codecs": list(plan.step_codecs),
            "num_segments": plan.num_segments,
            "envelope_db": plan.envelope_db,
        },
        "comm_modeled": bytes_rec,
        "quality_latency": quality,
        "comm_measured_per_device": measured,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)

    # ---- gates
    red = bytes_rec["scheduled"]["reduction_vs_fp32_halo"]
    psnr = quality["scheduled"]["psnr_db"]
    compiles = quality["scheduled"]["compiles"]
    assert red >= 2.5, f"scheduled wire reduction {red:.2f}x < 2.5x"
    assert psnr >= PSNR_FLOOR, (
        f"scheduled PSNR {psnr:.1f} dB < {PSNR_FLOOR} dB floor"
    )
    assert compiles <= 3 * plan.num_segments, (
        f"{compiles} compiles > 3 x {plan.num_segments} segments"
    )
    # scheduled bytes must decompose into fixed-codec step sums
    seg_sum = sum(s["wire_bytes"]
                  for s in bytes_rec["scheduled"]["segments"])
    assert seg_sum == bytes_rec["scheduled"]["wire_bytes_per_denoise"]

    if print_csv:
        for name, q in quality.items():
            print(f"codec_schedule/{name},{q['step_ms']*1e3:.0f},"
                  f"psnr={q['psnr_db']:.1f}dB compiles={q['compiles']} "
                  f"reduction={bytes_rec[name]['reduction_vs_fp32_halo']:.2f}x")
        print(f"codec_schedule/plan,0,{plan.schedule.spec} "
              f"segments={plan.num_segments}")
        if hlo_match:
            print("codec_schedule/hlo_match,0,modeled==measured exactly "
                  "for " + ",".join(sorted(hlo_match)))
        print(f"codec_schedule/json,0,wrote {OUT_JSON}")
    return record


if __name__ == "__main__":
    run()
