"""Hierarchy-aware halo wire benchmark -> BENCH_wire_shard.json.

The tp-sharded halo wire (``core/hybrid.lp_forward_halo_hybrid(...,
wire_shard=True)``) on a 2D ``(lp=2, tp=4)`` mesh of 8 fake CPU devices,
in a subprocess so the device-count XLA flag never leaks:

1. **two-tier wire bytes** — per-device collective payloads of one
   sharded hybrid step per codec, measured from the compiled 2D-mesh
   HLO with the replica-group-size breakdown
   (``analysis/hlo_analyzer`` ``collective_group_bytes``: lp-axis
   collectives run in groups of M, tp-axis reassembly gathers in groups
   of T) and cross-checked EXACTLY against
   ``comm_model.lp_halo_sharded_step_collectives`` — the acceptance
   contract of the sharded wire, inter and intra tiers separately.
2. **T-fold inter-group reduction** — the same step unsharded
   (``comm_lp_halo_hybrid``'s per-device wire is the full slab on every
   tp rank); sharded inter-group bytes must be >= (T - eps) x smaller.
3. **value fidelity** — sharded output vs the unsharded hybrid engine
   (the split is transport-only, so 1e-5 is conservative: they are
   bit-identical), including the int8-residual scan-carry state.
4. **compile discipline** — a 6-step ``lp_denoise`` through
   ``LPStepCompiler`` with the mesh-bound sharded forward stays at
   <= 3 x num_segments compiles.

Gates: exact analytic==measured per collective per tier for
fp32/bf16/int8; inter reduction >= T - 0.25 at T=4; rel err <= 1e-5 vs
unsharded; compile count.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

MESH_M, MESH_T = 2, 4
R = 0.5
OUT_JSON = "BENCH_wire_shard.json"

_SCRIPT = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import comm_model as cm
    from repro.core import plan_uniform
    from repro.core.hybrid import lp_forward_halo_hybrid
    from repro.core.lp_step import LPStepCompiler, lp_denoise
    from repro.distributed.collectives import halo_spec
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.launch.mesh import make_hybrid_mesh

    M, T, R = %(M)d, %(T)d, %(R)s
    mesh = make_hybrid_mesh(M, T)
    # wan21 smoke latent geometry (13, 60, 104, 16), partitioned on height
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(13, 60, 104, 16)).astype(np.float32))
    plan = plan_uniform(60, 2, M, R, dim=1)

    d = 16
    w1 = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32)) * 0.05
    def tp_denoise(window):
        # Megatron-pattern Phi_m: each tp rank contracts 1/T of the
        # channels, the group psums the partials over the tp axis
        tp = jax.lax.axis_index("model")
        part = d // T
        w_slice = jax.lax.dynamic_slice_in_dim(w1, tp * part, part, 0)
        x_slice = jax.lax.dynamic_slice_in_dim(window, tp * part, part, 3)
        partial = jnp.einsum("thwc,cd->thwd", x_slice, w_slice)
        return jnp.tanh(window) * 0.5 + jax.lax.psum(partial, "model")

    ccfg = cm.VDMCommConfig(
        latent_dims=(13, 60, 104), latent_channels=16,
        patch_sizes=(1, 2, 2), d_model=1, num_blocks=1, num_steps=1,
    )

    def lower(name, shard):
        codec = get_codec(name)
        if codec.stateful:
            st = init_halo_wire_state(
                codec, halo_spec(plan),
                tuple(s for i, s in enumerate(z.shape) if i != 1))
            fn = jax.jit(lambda zz, s: lp_forward_halo_hybrid(
                tp_denoise, zz, plan, 1, mesh, codec=codec, codec_state=s,
                wire_shard=shard)[0])
            hlo = fn.lower(z, st).compile().as_text()
            val = np.asarray(fn(z, st))
        else:
            c = None if name == "fp32" else codec
            fn = jax.jit(lambda zz: lp_forward_halo_hybrid(
                tp_denoise, zz, plan, 1, mesh, codec=c, wire_shard=shard))
            hlo = fn.lower(z).compile().as_text()
            val = np.asarray(fn(z))
        a = analyze(hlo)
        return {k: float(v) for k, v in a.collective_group_bytes.items()}, val

    out = {"mesh": [M, T], "measured": {}, "modeled": {},
           "measured_unsharded": {}, "inter_reduction": {}, "rel_err": {}}
    lp_inter = ("collective-permute", "all-gather[%%d]" %% M)
    for name in ("fp32", "bf16", "int8", "int8-residual"):
        sh, v_sh = lower(name, True)
        un, v_un = lower(name, False)
        out["measured"][name] = sh
        out["measured_unsharded"][name] = un
        out["modeled"][name] = cm.lp_halo_sharded_step_collectives(
            ccfg, M, T, R, dim=1, codec=name)
        inter_sh = sum(sh.get(k, 0) for k in lp_inter)
        inter_un = sum(un.get(k, 0) for k in lp_inter)
        out["inter_reduction"][name] = inter_un / inter_sh
        out["rel_err"][name] = float(
            np.linalg.norm(v_sh - v_un) / np.linalg.norm(v_un))

    # compile discipline: 6-step denoise, int8-residual scan-carry state
    # through the mesh-bound sharded forward (one codec = one segment)
    res_codec = get_codec("int8-residual")
    z6 = jnp.asarray(rng.normal(size=(1, 8, 12, 10, 16)).astype(np.float32))
    sampler = FlowMatchEuler(6)
    def fwd(fn, zz, pl, ax, st):
        return lp_forward_halo_hybrid(
            fn, zz, pl, ax, mesh, codec=res_codec, codec_state=st,
            wire_shard=True)
    comp = LPStepCompiler(
        lambda w, t: jnp.tanh(w) * 0.5 + w * (1 + 1e-4 * t),
        sampler.update, M, R, (1, 2, 2), (1, 2, 3), uniform=True,
        forward=fwd, codec=res_codec, mesh_shape=(M, T), wire_shard=True)
    lp_denoise(None, z6, sampler, 6, M, R, (1, 2, 2), (1, 2, 3),
               uniform=True, compiler=comp)
    out["denoise"] = {"compiles": comp.compiles, "num_segments": 1,
                      "state_inits": comp.state_inits}
    print("JSON:" + json.dumps(out))
    """
)


def run(print_csv=True):
    res = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT % {"M": MESH_M, "T": MESH_T, "R": R}],
        capture_output=True, text=True, cwd=".",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        timeout=560,
    )
    rec = None
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            rec = json.loads(line[len("JSON:"):])
    if rec is None:
        raise RuntimeError(
            f"wire_shard subprocess failed:\n{res.stdout}\n{res.stderr[-2000:]}")

    M, T = rec["mesh"]
    # ---- gate 1: analytic == measured, exactly, per collective per tier
    for name in ("fp32", "bf16", "int8"):
        want = rec["modeled"][name]
        got = rec["measured"][name]
        exact = {
            "collective-permute": want["inter"]["collective-permute"],
            f"all-gather[{M}]": want["inter"]["all-gather"],
            f"all-gather[{T}]": want["intra"]["all-gather"],
        }
        for kind, v in exact.items():
            assert got.get(kind, 0) == v, (name, kind, got, want)
    # ---- gate 2: >= (T - eps)-fold inter-group reduction at T=4
    for name, red in rec["inter_reduction"].items():
        assert red >= T - 0.25, (name, red, T)
    # ---- gate 3: sharded values == unsharded hybrid engine
    for name, rel in rec["rel_err"].items():
        assert rel <= 1e-5, (name, rel)
    # ---- gate 4: compile discipline on the sharded denoise
    dn = rec["denoise"]
    assert dn["compiles"] <= 3 * dn["num_segments"], dn

    with open(OUT_JSON, "w") as f:
        json.dump(rec, f, indent=1)

    if print_csv:
        for name, red in rec["inter_reduction"].items():
            m = rec["modeled"][name]
            print(f"wire_shard/inter/{name},0,"
                  f"reduction={red:.2f}x pp={m['inter']['collective-permute']}"
                  f" ag={m['inter']['all-gather']} (modeled==measured)")
        for name in rec["modeled"]:
            m = rec["modeled"][name]
            print(f"wire_shard/intra/{name},0,"
                  f"ag={m['intra']['all-gather']} (modeled==measured)")
        print(f"wire_shard/denoise,0,compiles={dn['compiles']} "
              f"(<= {3 * dn['num_segments']})")
        print(f"wire_shard/json,0,wrote {OUT_JSON}")
    return rec


if __name__ == "__main__":
    run()
