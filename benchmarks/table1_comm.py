"""Paper Table 1: communication overhead (MB) per parallelism strategy,
49-frame and 81-frame 480p generation on 4 devices.

Sources: the §7 analytic model (core/comm_model.py) validated against the
paper's measured numbers, plus the LP-SPMD variant our TPU mapping uses.
"""
from __future__ import annotations

from repro.core import comm_model as cm

MB = 1024 * 1024

PAPER = {  # (frames, method) -> total MB from paper Table 1
    (49, "NMP"): 57950.17, (49, "PP"): 57590.16, (49, "HP"): 4758.08,
    (49, "LP r=1.0"): 1811.88, (49, "LP r=0.5"): 1354.34,
    (81, "NMP"): 93050.17, (81, "PP"): 92690.16, (81, "HP"): 7686.12,
    (81, "LP r=1.0"): 2912.81, (81, "LP r=0.5"): 2191.29,
}


def rows():
    out = []
    for frames in (49, 81):
        cfg = cm.wan21_comm_config(frames)
        shard = cm.comm_lp_halo_sharded(cfg, 2, 2, 0.5, "int8")
        ours = {
            "NMP": cm.comm_nmp(cfg, 4),
            "PP": cm.comm_pp(cfg, 4),
            "HP": cm.comm_hp_xdit(cfg, 4),
            "LP r=1.0": cm.comm_lp_measured(cfg, 4, 1.0),
            "LP r=0.5": cm.comm_lp_measured(cfg, 4, 0.5),
            "LP-SPMD (ours)": cm.comm_lp_spmd(cfg, 4, 0.5),
            "LP-halo (ours)": cm.comm_lp_halo(cfg, 4, 0.5),
            "LP-halo bf16 (ours)": cm.comm_lp_halo_codec(cfg, 4, 0.5, "bf16"),
            "LP-halo int8 (ours)": cm.comm_lp_halo_codec(cfg, 4, 0.5, "int8"),
            "LP-halo int8-res (ours)": cm.comm_lp_halo_codec(
                cfg, 4, 0.5, "int8-residual"),
            # GSPMD with a codec is value-faithful but its psum still
            # ships f32 — zero byte savings, kept to show why the halo
            # family is the codec path (comm_model.comm_lp_gspmd_codec)
            "LP-gspmd int8 (ours)": cm.comm_lp_gspmd_codec(
                cfg, 4, 0.5, "int8"),
            # §11 hybrid on the same 4 devices as a 2x2 (lp, tp) mesh:
            # group wire bytes of the inter-group halo schedule (the
            # intra-group Phi_m traffic is the TP model's, Eq. 50)
            "LP×TP 2x2 halo (ours)": cm.comm_lp_halo_hybrid(
                cfg, 2, 2, 0.5),
            "LP×TP 2x2 halo int8 (ours)": cm.comm_lp_halo_hybrid(
                cfg, 2, 2, 0.5, "int8"),
            # hierarchy-aware wire: sharding the slabs over the tp axis
            # collapses the T-replicated inter-group transfers back to
            # ~the 1D model; the honest price — the intra-group
            # reassembly gather — is its own row, not hidden
            "LP×TP 2x2 halo int8 shard inter (ours)": shard["inter"],
            "LP×TP 2x2 halo int8 shard intra (ours)": shard["intra"],
            # the paper's hub hybrid (Eq. 50) with the striped wire:
            # total includes the intra reassembly gather alongside the
            # NMP collectives (comm_hybrid wire_shard accounting)
            "Hybrid M=2 NMP (Eq.50)": cm.comm_hybrid(cfg, 4, 2, 0.5),
            "Hybrid M=2 NMP +shard (Eq.50)": cm.comm_hybrid(
                cfg, 4, 2, 0.5, wire_shard=True),
        }
        for method, bytes_ in ours.items():
            paper = PAPER.get((frames, method))
            out.append({
                "frames": frames, "method": method,
                "model_mb": bytes_ / MB,
                "paper_mb": paper,
                "dev_pct": (100 * (bytes_ / MB - paper) / paper)
                if paper else None,
            })
    return out


def run(print_csv=True):
    res = rows()
    if print_csv:
        for r in res:
            paper = f"{r['paper_mb']:.0f}" if r["paper_mb"] else "-"
            dev = f"{r['dev_pct']:+.0f}%" if r["dev_pct"] is not None else "-"
            print(f"table1_comm/{r['frames']}f/{r['method']},0,"
                  f"model={r['model_mb']:.0f}MB paper={paper}MB dev={dev}")
    # headline claims
    c81 = cm.wan21_comm_config(81)
    red = 1 - cm.comm_lp_measured(c81, 4, 0.5) / cm.comm_nmp(c81, 4)
    print(f"table1_comm/headline,0,reduction_vs_NMP={red:.1%} (paper: ~97%)")
    return res


if __name__ == "__main__":
    run()
