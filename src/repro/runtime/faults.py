"""Deterministic serving-fault injection: the public successor of the
engine's private ``_step_fault`` test hook.

A :class:`ServingFaultPlan` scripts failures against the serving engine's
per-step hook (``LPServingEngine`` installs it when ``inject_fault=`` is
set, the CLIs via ``--inject-fault``), reusing the fire-once bookkeeping
of ``runtime/ft.FailureInjector``:

  * ``dead:G@S``     — LP group G stops heartbeating at step S.  Every
    step from S on raises :class:`ServingFault` (the collective "times
    out") AND feeds a missed heartbeat into the engine's
    ``runtime/health.GroupHealthMonitor``; after the monitor's bounded
    retries the group is declared dead and evicted, at which point the
    fault stops firing (the dead hardware left the ring).
  * ``slow:GxF``     — group G's synthetic heartbeats run F× the
    baseline from step 1: exercises the EMA slow path (core re-sizing /
    eventual eviction), never raises.
  * ``corrupt@S``    — the wire payload of step S decodes to NaN
    (:class:`CorruptingCodec` swapped in for exactly that step); the
    decode-path NaN/Inf guard (``comm/wire.py`` ``nan_guard``) must
    absorb it by falling back to the rank-local stale slab.
  * ``replica:R:dead@S`` — the WHOLE replica R (its entire mesh, not
    one LP group) dies at denoise step S of whatever batch it is
    running: the step hook raises :class:`ReplicaDeath`, which is *not*
    recoverable engine-side (there is no surviving group to shrink to)
    and surfaces straight out of ``engine.run`` for the
    ``serving/router.ReplicaRouter`` to handle (requeue the in-flight
    batch to survivors, mark the replica dead).
  * ``replica:R:<chunk>`` — any base chunk (``dead:G@S`` / ``slow:GxF``
    / ``corrupt@S``) scoped to replica R only; the router splits these
    out per replica (:meth:`ServingFaultPlan.for_replica`) and hands
    each engine its own sub-plan.  A top-level plan with replica-scoped
    targets cannot be passed to a bare engine — only the router knows
    which replica it is.

Specs compose comma-separated: ``dead:1@4,corrupt@2`` or
``replica:1:dead@3,replica:0:slow:1x2``.  All injection is host-side
and deterministic — faults fire between compiled steps, so the same
spec replays bit-identically on fake CPU meshes (the
``benchmarks/fault_recovery.py`` and ``benchmarks/router_resilience.py``
gates rely on this).  Every parse error names the offending chunk, and
:meth:`ServingFaultPlan.describe` round-trips: parsing its output
yields an equivalent plan.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.comm.codecs import Codec, get_codec


class ServingFault(RuntimeError):
    """A denoise step failed for a *recoverable* serving reason (group
    death, injected wire fault).  ``LPServingEngine.run()`` retries only
    this and ``runtime/ft.DeviceFailure`` — anything else (a real jax /
    XLA / programming error) surfaces immediately instead of burning the
    restart budget on a deterministic failure.

    ``step`` records the 1-indexed denoise step that was about to run
    when the fault fired, so recovery can account lost work against the
    last boundary snapshot.
    """

    def __init__(self, msg: str, step: Optional[int] = None):
        super().__init__(msg)
        self.step = step


class ReplicaDeath(RuntimeError):
    """A whole serving replica (its entire mesh) died mid-batch.

    Deliberately NOT a :class:`ServingFault` subclass: the engine's
    retry loop must not burn restarts on it — with every LP group gone
    there is no smaller mesh to shrink to and no snapshot that helps.
    It surfaces straight out of ``LPServingEngine.run`` so the replica
    router can requeue the in-flight batch to surviving replicas and
    mark this one dead.

    ``replica`` is the router-level replica id, ``step`` the 1-indexed
    denoise step that was about to run when the replica died.
    """

    def __init__(self, msg: str, replica: Optional[int] = None,
                 step: Optional[int] = None):
        super().__init__(msg)
        self.replica = replica
        self.step = step


@dataclasses.dataclass(frozen=True)
class CorruptingCodec(Codec):
    """Wraps a stateless codec; its decode poisons every element to NaN.

    Models a corrupted wire payload (bit-flips on the link, a truncated
    DMA): the encode side is untouched — bytes on the wire, HLO
    collectives, and cache keys stay honest — but everything decoded
    from the wire is garbage.  Stateless only: ``comm/wire.py`` routes
    stateful codecs through ``isinstance(codec, ResidualCodec)``, so a
    corrupting wrapper there would silently demote them.  The name is
    distinct (``<base>-corrupt``) on purpose: it keys separate
    compiled-step cache entries, so swapping the codec for one step can
    never poison a healthy step's cached executable.
    """

    base: Codec = None  # type: ignore[assignment]

    @staticmethod
    def wrap(base) -> "CorruptingCodec":
        base = get_codec(base)
        if base.stateful:
            raise ValueError(
                f"CorruptingCodec wraps stateless codecs only, got "
                f"{base.name!r} (wrap its base instead)"
            )
        return CorruptingCodec(
            name=f"{base.name}-corrupt", bits=base.bits,
            meta_bytes=base.meta_bytes, stateful=False, base=base,
        )

    def encode(self, x):
        return self.base.encode(x)

    def decode(self, wire, meta, shape):
        return jnp.full(shape, jnp.nan, jnp.float32) + \
            0.0 * self.base.decode(wire, meta, shape)


_DEAD_RE = re.compile(r"^dead:(\d+)@(\d+)$")
_SLOW_RE = re.compile(r"^slow:(\d+)x([\d.]+)$")
_CORRUPT_RE = re.compile(r"^corrupt@(\d+)$")
_REPLICA_DEAD_RE = re.compile(r"^replica:(\d+):dead@(\d+)$")
_REPLICA_RE = re.compile(r"^replica:(\d+):(.+)$")


def _parse_error(chunk: str, why: str) -> ValueError:
    """Every fault-spec parse error names the offending chunk."""
    return ValueError(f"bad fault spec chunk {chunk!r}: {why}")


@dataclasses.dataclass
class ServingFaultPlan:
    """Scripted faults against the serving step hook (fire-once where it
    matters, like ``runtime/ft.FailureInjector``)."""

    dead: Tuple[Tuple[int, int], ...] = ()      # (group, from_step)
    slow: Tuple[Tuple[int, float], ...] = ()    # (group, factor)
    corrupt: Tuple[int, ...] = ()               # steps with a NaN wire
    # router-level targets (serving/router.ReplicaRouter splits these
    # out per replica; a bare engine refuses a plan that carries them):
    replica_dead: Tuple[Tuple[int, int], ...] = ()   # (replica, step)
    replica_scoped: Tuple[Tuple[int, str], ...] = () # (replica, chunk)
    # per-replica plan fields (set by ``for_replica``, never by parse):
    # the whole replica dies at ``die_step`` — the step hook raises
    # ReplicaDeath, sticky once fired
    die_step: Optional[int] = None
    die_replica: Optional[int] = None
    baseline_s: float = 1.0                     # synthetic healthy heartbeat
    _die_fired: bool = False
    _recovered: set = dataclasses.field(default_factory=set)
    _corrupt_fired: set = dataclasses.field(default_factory=set)
    # dead faults are STICKY once triggered: a batch retry resumes from
    # an earlier snapshot step, but the host that died at step S does
    # not resurrect because the step counter rewound — without this the
    # replayed healthy heartbeats would reset the monitor's miss budget
    # and recovery could never converge
    _dead_active: set = dataclasses.field(default_factory=set)
    # first-fire event log for the observability plane: one entry per
    # fault *activation* (corrupt swap, first step a group goes dead),
    # drained incrementally by the engine's step hook
    _events: List[dict] = dataclasses.field(default_factory=list)
    _drained: int = 0

    # ------------------------------------------------------------ parsing
    @staticmethod
    def _parse_base_chunk(chunk: str, dead, slow, corrupt,
                          seen_dead, seen_slow, seen_corrupt,
                          label: Optional[str] = None) -> None:
        """Parse one engine-level chunk into the accumulators, naming
        the offending chunk in every error (malformed form, bad value,
        duplicate target).  ``label`` overrides the name shown in
        errors — replica-scoped chunks report the full
        ``replica:R:...`` spelling the operator wrote."""
        err_name = chunk if label is None else label
        if m := _DEAD_RE.match(chunk):
            g, s = int(m.group(1)), int(m.group(2))
            if s < 1:
                raise _parse_error(err_name, "steps are 1-indexed")
            if g in seen_dead:
                raise _parse_error(
                    err_name, f"duplicate dead target: group {g} already "
                    f"dies at step {dict(dead)[g]}")
            seen_dead.add(g)
            dead.append((g, s))
        elif m := _SLOW_RE.match(chunk):
            g, f = int(m.group(1)), float(m.group(2))
            if f <= 0:
                raise _parse_error(err_name, "slowdown factor must be > 0")
            if g in seen_slow:
                raise _parse_error(
                    err_name, f"duplicate slow target: group {g} already "
                    f"has a factor")
            seen_slow.add(g)
            slow.append((g, f))
        elif m := _CORRUPT_RE.match(chunk):
            s = int(m.group(1))
            if s < 1:
                raise _parse_error(err_name, "steps are 1-indexed")
            if s in seen_corrupt:
                raise _parse_error(
                    err_name, f"duplicate corrupt target: step {s} is "
                    f"already poisoned")
            seen_corrupt.add(s)
            corrupt.append(s)
        else:
            raise _parse_error(
                err_name, "want dead:G@S, slow:GxF, corrupt@S, "
                "replica:R:dead@S or replica:R:<chunk> "
                "(comma-separated)")

    @staticmethod
    def parse(spec: str) -> "ServingFaultPlan":
        dead: List[Tuple[int, int]] = []
        slow: List[Tuple[int, float]] = []
        corrupt: List[int] = []
        replica_dead: List[Tuple[int, int]] = []
        replica_scoped: List[Tuple[int, str]] = []
        seen_dead: set = set()
        seen_slow: set = set()
        seen_corrupt: set = set()
        seen_replica_dead: set = set()
        # per-replica duplicate tracking for scoped chunks
        scoped_seen: dict = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if m := _REPLICA_DEAD_RE.match(part):
                r, s = int(m.group(1)), int(m.group(2))
                if s < 1:
                    raise _parse_error(part, "steps are 1-indexed")
                if r in seen_replica_dead:
                    raise _parse_error(
                        part, f"duplicate replica-dead target: replica "
                        f"{r} already dies at step "
                        f"{dict(replica_dead)[r]}")
                seen_replica_dead.add(r)
                replica_dead.append((r, s))
            elif m := _REPLICA_RE.match(part):
                r, sub = int(m.group(1)), m.group(2).strip()
                if sub.startswith("replica:"):
                    raise _parse_error(part, "replica targets do not nest")
                acc = scoped_seen.setdefault(
                    r, ([], [], [], set(), set(), set()))
                # validate (and duplicate-check within the replica) now,
                # so a bad scoped chunk fails at parse time, not when
                # the router splits the plan
                ServingFaultPlan._parse_base_chunk(sub, *acc, label=part)
                replica_scoped.append((r, sub))
            else:
                ServingFaultPlan._parse_base_chunk(
                    part, dead, slow, corrupt,
                    seen_dead, seen_slow, seen_corrupt)
        return ServingFaultPlan(
            dead=tuple(dead), slow=tuple(slow),
            corrupt=tuple(sorted(corrupt)),
            replica_dead=tuple(replica_dead),
            replica_scoped=tuple(replica_scoped))

    def describe(self) -> str:
        """Canonical string form; ``parse(describe())`` yields an
        equivalent plan (the round-trip the tests pin).  A per-replica
        sub-plan's whole-replica death renders back in top-level
        grammar (``replica:R:dead@S``)."""
        parts = [f"dead:{g}@{s}" for g, s in self.dead]
        parts += [f"slow:{g}x{f:g}" for g, f in self.slow]
        parts += [f"corrupt@{s}" for s in self.corrupt]
        parts += [f"replica:{r}:dead@{s}" for r, s in self.replica_dead]
        parts += [f"replica:{r}:{c}" for r, c in self.replica_scoped]
        if self.die_step is not None:
            parts.append(f"replica:{self.die_replica}:dead@{self.die_step}")
        return ",".join(parts) or "none"

    # -------------------------------------------------- replica routing
    @property
    def has_replica_targets(self) -> bool:
        """True when the plan carries router-level targets that a bare
        engine cannot interpret (it does not know which replica it is)."""
        return bool(self.replica_dead or self.replica_scoped)

    def replicas_targeted(self) -> List[int]:
        """Sorted replica ids named anywhere in the plan — the router
        validates them against its fleet size."""
        ids = {r for r, _ in self.replica_dead}
        ids |= {r for r, _ in self.replica_scoped}
        return sorted(ids)

    def for_replica(self, replica: int) -> Optional["ServingFaultPlan"]:
        """Split out replica ``replica``'s sub-plan: its scoped base
        chunks become a normal engine-level plan, and a
        ``replica:R:dead@S`` target becomes ``die_step`` (the step hook
        raises :class:`ReplicaDeath` there).  Returns ``None`` when the
        plan has nothing for this replica.  Engine-level chunks WITHOUT
        a replica scope are fleet-wide and deliberately not included —
        scope them explicitly when routing."""
        chunks = [c for r, c in self.replica_scoped if r == replica]
        die = dict(self.replica_dead).get(replica)
        if not chunks and die is None:
            return None
        sub = (ServingFaultPlan.parse(",".join(chunks)) if chunks
               else ServingFaultPlan())
        sub.die_step = die
        sub.die_replica = replica if die is not None else None
        sub.baseline_s = self.baseline_s
        return sub

    # ----------------------------------------------------------- behaviour
    def _activate_dead(self, group: int, step: int) -> None:
        """Mark a dead fault live, logging its first activation only."""
        if group not in self._dead_active:
            self._events.append(
                {"kind": "dead", "group": group, "step": step})
        self._dead_active.add(group)

    def drain_events(self) -> List[dict]:
        """Fault activations logged since the last drain — the trace
        feeder (``serving/engine.py`` forwards these to the flight
        recorder as ``fault.*`` instants).  Each event carries the step
        it fired at, so drain timing cannot skew the record."""
        new = self._events[self._drained:]
        self._drained = len(self._events)
        return new

    @property
    def touches_health(self) -> bool:
        """True when the plan needs heartbeats fed to a health monitor."""
        return bool(self.dead or self.slow)

    def heartbeats(self, step: int, num_groups: int) -> List[float]:
        """Synthetic per-group step times for ``step`` (what an external
        monitor would report): ``inf`` for a dead group past its fault
        step, ``factor * baseline`` for slow groups, baseline otherwise.
        Evicted dead groups (``mark_recovered``) drop out of the layout,
        so the list always matches the CURRENT group count."""
        t = [self.baseline_s] * num_groups
        for g, f in self.slow:
            if g < num_groups and g not in self._recovered:
                t[g] = f * self.baseline_s
        for g, s in self.dead:
            if g in self._recovered or g >= num_groups:
                continue
            if step >= s:
                self._activate_dead(g, step)
            if g in self._dead_active:
                t[g] = float("inf")
        return t

    def active_dead(self, step: int) -> Optional[int]:
        """The (first) dead group whose fault is live at ``step`` —
        sticky: once triggered it fires at every step (including steps
        before S replayed by a snapshot-resumed retry) until the engine
        evicts the group (``mark_recovered``)."""
        for g, s in self.dead:
            if g in self._recovered:
                continue
            if step >= s or g in self._dead_active:
                self._activate_dead(g, step)
                return g
        return None

    def mark_recovered(self, group: int) -> None:
        """The engine evicted ``group``: its dead/slow faults stop firing
        (the hardware left the ring; surviving groups re-index)."""
        self._recovered.add(group)

    def die_fires(self, step: int) -> bool:
        """Whole-replica death check (per-replica plans only): sticky —
        once ``die_step`` is reached the replica is gone at every later
        step too (including earlier steps replayed by a retry; dead
        hardware does not resurrect because a step counter rewound)."""
        if self.die_step is None:
            return False
        if self._die_fired or step >= self.die_step:
            if not self._die_fired:
                self._die_fired = True
                self._events.append({
                    "kind": "replica_dead",
                    "replica": self.die_replica, "step": step})
            return True
        return False

    def corrupt_fires(self, step: int) -> bool:
        """Fire-once check: True exactly the first time ``step`` is hit
        (a retried batch replays the step with a clean wire — the
        corruption was transient, as on real links)."""
        if step in self.corrupt and step not in self._corrupt_fired:
            self._corrupt_fired.add(step)
            self._events.append({"kind": "corrupt", "step": step})
            return True
        return False


def parse_fault_plan(spec) -> Optional[ServingFaultPlan]:
    """CLI/engine entry: None passes through, strings parse, plans are
    taken as-is."""
    if spec is None:
        return None
    if isinstance(spec, ServingFaultPlan):
        return spec
    return ServingFaultPlan.parse(spec)
