"""Deterministic serving-fault injection: the public successor of the
engine's private ``_step_fault`` test hook.

A :class:`ServingFaultPlan` scripts failures against the serving engine's
per-step hook (``LPServingEngine`` installs it when ``inject_fault=`` is
set, the CLIs via ``--inject-fault``), reusing the fire-once bookkeeping
of ``runtime/ft.FailureInjector``:

  * ``dead:G@S``     — LP group G stops heartbeating at step S.  Every
    step from S on raises :class:`ServingFault` (the collective "times
    out") AND feeds a missed heartbeat into the engine's
    ``runtime/health.GroupHealthMonitor``; after the monitor's bounded
    retries the group is declared dead and evicted, at which point the
    fault stops firing (the dead hardware left the ring).
  * ``slow:GxF``     — group G's synthetic heartbeats run F× the
    baseline from step 1: exercises the EMA slow path (core re-sizing /
    eventual eviction), never raises.
  * ``corrupt@S``    — the wire payload of step S decodes to NaN
    (:class:`CorruptingCodec` swapped in for exactly that step); the
    decode-path NaN/Inf guard (``comm/wire.py`` ``nan_guard``) must
    absorb it by falling back to the rank-local stale slab.

Specs compose comma-separated: ``dead:1@4,corrupt@2``.  All injection is
host-side and deterministic — faults fire between compiled steps, so the
same spec replays bit-identically on fake CPU meshes (the
``benchmarks/fault_recovery.py`` gate relies on this).
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.comm.codecs import Codec, get_codec


class ServingFault(RuntimeError):
    """A denoise step failed for a *recoverable* serving reason (group
    death, injected wire fault).  ``LPServingEngine.run()`` retries only
    this and ``runtime/ft.DeviceFailure`` — anything else (a real jax /
    XLA / programming error) surfaces immediately instead of burning the
    restart budget on a deterministic failure.

    ``step`` records the 1-indexed denoise step that was about to run
    when the fault fired, so recovery can account lost work against the
    last boundary snapshot.
    """

    def __init__(self, msg: str, step: Optional[int] = None):
        super().__init__(msg)
        self.step = step


@dataclasses.dataclass(frozen=True)
class CorruptingCodec(Codec):
    """Wraps a stateless codec; its decode poisons every element to NaN.

    Models a corrupted wire payload (bit-flips on the link, a truncated
    DMA): the encode side is untouched — bytes on the wire, HLO
    collectives, and cache keys stay honest — but everything decoded
    from the wire is garbage.  Stateless only: ``comm/wire.py`` routes
    stateful codecs through ``isinstance(codec, ResidualCodec)``, so a
    corrupting wrapper there would silently demote them.  The name is
    distinct (``<base>-corrupt``) on purpose: it keys separate
    compiled-step cache entries, so swapping the codec for one step can
    never poison a healthy step's cached executable.
    """

    base: Codec = None  # type: ignore[assignment]

    @staticmethod
    def wrap(base) -> "CorruptingCodec":
        base = get_codec(base)
        if base.stateful:
            raise ValueError(
                f"CorruptingCodec wraps stateless codecs only, got "
                f"{base.name!r} (wrap its base instead)"
            )
        return CorruptingCodec(
            name=f"{base.name}-corrupt", bits=base.bits,
            meta_bytes=base.meta_bytes, stateful=False, base=base,
        )

    def encode(self, x):
        return self.base.encode(x)

    def decode(self, wire, meta, shape):
        return jnp.full(shape, jnp.nan, jnp.float32) + \
            0.0 * self.base.decode(wire, meta, shape)


_DEAD_RE = re.compile(r"^dead:(\d+)@(\d+)$")
_SLOW_RE = re.compile(r"^slow:(\d+)x([\d.]+)$")
_CORRUPT_RE = re.compile(r"^corrupt@(\d+)$")


@dataclasses.dataclass
class ServingFaultPlan:
    """Scripted faults against the serving step hook (fire-once where it
    matters, like ``runtime/ft.FailureInjector``)."""

    dead: Tuple[Tuple[int, int], ...] = ()      # (group, from_step)
    slow: Tuple[Tuple[int, float], ...] = ()    # (group, factor)
    corrupt: Tuple[int, ...] = ()               # steps with a NaN wire
    baseline_s: float = 1.0                     # synthetic healthy heartbeat
    _recovered: set = dataclasses.field(default_factory=set)
    _corrupt_fired: set = dataclasses.field(default_factory=set)
    # dead faults are STICKY once triggered: a batch retry resumes from
    # an earlier snapshot step, but the host that died at step S does
    # not resurrect because the step counter rewound — without this the
    # replayed healthy heartbeats would reset the monitor's miss budget
    # and recovery could never converge
    _dead_active: set = dataclasses.field(default_factory=set)
    # first-fire event log for the observability plane: one entry per
    # fault *activation* (corrupt swap, first step a group goes dead),
    # drained incrementally by the engine's step hook
    _events: List[dict] = dataclasses.field(default_factory=list)
    _drained: int = 0

    # ------------------------------------------------------------ parsing
    @staticmethod
    def parse(spec: str) -> "ServingFaultPlan":
        dead: List[Tuple[int, int]] = []
        slow: List[Tuple[int, float]] = []
        corrupt: List[int] = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if m := _DEAD_RE.match(part):
                dead.append((int(m.group(1)), int(m.group(2))))
            elif m := _SLOW_RE.match(part):
                slow.append((int(m.group(1)), float(m.group(2))))
            elif m := _CORRUPT_RE.match(part):
                corrupt.append(int(m.group(1)))
            else:
                raise ValueError(
                    f"bad fault spec {part!r}: want dead:G@S, slow:GxF "
                    f"or corrupt@S (comma-separated)"
                )
        return ServingFaultPlan(dead=tuple(dead), slow=tuple(slow),
                                corrupt=tuple(sorted(set(corrupt))))

    def describe(self) -> str:
        parts = [f"dead:{g}@{s}" for g, s in self.dead]
        parts += [f"slow:{g}x{f:g}" for g, f in self.slow]
        parts += [f"corrupt@{s}" for s in self.corrupt]
        return ",".join(parts) or "none"

    # ----------------------------------------------------------- behaviour
    def _activate_dead(self, group: int, step: int) -> None:
        """Mark a dead fault live, logging its first activation only."""
        if group not in self._dead_active:
            self._events.append(
                {"kind": "dead", "group": group, "step": step})
        self._dead_active.add(group)

    def drain_events(self) -> List[dict]:
        """Fault activations logged since the last drain — the trace
        feeder (``serving/engine.py`` forwards these to the flight
        recorder as ``fault.*`` instants).  Each event carries the step
        it fired at, so drain timing cannot skew the record."""
        new = self._events[self._drained:]
        self._drained = len(self._events)
        return new

    @property
    def touches_health(self) -> bool:
        """True when the plan needs heartbeats fed to a health monitor."""
        return bool(self.dead or self.slow)

    def heartbeats(self, step: int, num_groups: int) -> List[float]:
        """Synthetic per-group step times for ``step`` (what an external
        monitor would report): ``inf`` for a dead group past its fault
        step, ``factor * baseline`` for slow groups, baseline otherwise.
        Evicted dead groups (``mark_recovered``) drop out of the layout,
        so the list always matches the CURRENT group count."""
        t = [self.baseline_s] * num_groups
        for g, f in self.slow:
            if g < num_groups and g not in self._recovered:
                t[g] = f * self.baseline_s
        for g, s in self.dead:
            if g in self._recovered or g >= num_groups:
                continue
            if step >= s:
                self._activate_dead(g, step)
            if g in self._dead_active:
                t[g] = float("inf")
        return t

    def active_dead(self, step: int) -> Optional[int]:
        """The (first) dead group whose fault is live at ``step`` —
        sticky: once triggered it fires at every step (including steps
        before S replayed by a snapshot-resumed retry) until the engine
        evicts the group (``mark_recovered``)."""
        for g, s in self.dead:
            if g in self._recovered:
                continue
            if step >= s or g in self._dead_active:
                self._activate_dead(g, step)
                return g
        return None

    def mark_recovered(self, group: int) -> None:
        """The engine evicted ``group``: its dead/slow faults stop firing
        (the hardware left the ring; surviving groups re-index)."""
        self._recovered.add(group)

    def corrupt_fires(self, step: int) -> bool:
        """Fire-once check: True exactly the first time ``step`` is hit
        (a retried batch replays the step with a clean wire — the
        corruption was transient, as on real links)."""
        if step in self.corrupt and step not in self._corrupt_fired:
            self._corrupt_fired.add(step)
            self._events.append({"kind": "corrupt", "step": step})
            return True
        return False


def parse_fault_plan(spec) -> Optional[ServingFaultPlan]:
    """CLI/engine entry: None passes through, strings parse, plans are
    taken as-is."""
    if spec is None:
        return None
    if isinstance(spec, ServingFaultPlan):
        return spec
    return ServingFaultPlan.parse(spec)
