"""Sharded, atomic, restartable checkpoints (pure numpy + msgpack index).

Layout:
  <dir>/step_000123/
      meta.json            # step, arch, mesh/sharding metadata, tree spec
      shard_00000.npz      # this host's addressable leaf shards
  <dir>/LATEST             # atomic pointer (written last)

Guarantees:
  * atomic: written to step_X.tmp-<nonce>/ then os.rename'd; LATEST is
    updated only after the rename, so a crash mid-save never corrupts the
    restore path.
  * sharded: each host writes only its addressable shard of every leaf
    (here: host 0 writes everything; the addressable-slice logic is the
    same code path).
  * elastic: meta.json stores the *logical* shapes + PartitionSpecs, so
    ``runtime/elastic.py`` can restore onto a different mesh.
  * retention: keep_last prunes old steps after a successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs.clock import wall_stamp_s


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    extra_meta: Optional[Dict[str, Any]] = None,
    keep_last: int = 3,
) -> str:
    """Atomic save; returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    paths = _tree_paths(tree)
    arrays = {}
    for i, leaf in enumerate(leaves):
        arrays[f"leaf_{i:05d}"] = np.asarray(leaf)
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    meta = {
        "step": step,
        "paths": paths,
        "shapes": [list(np.shape(np.asarray(l))) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "time": wall_stamp_s(),  # epoch stamp on purpose (not a duration)
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.rename(tmp, final)
    # pointer last => restore never sees a partial save
    latest_tmp = os.path.join(ckpt_dir, f".LATEST.tmp-{uuid.uuid4().hex[:8]}")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _retain(ckpt_dir, keep_last)
    return final


def _retain(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and ".tmp" not in d
    )
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like``; validates layout."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "shard_00000.npz"))
    leaves, treedef = _flatten(tree_like)
    exp_paths = _tree_paths(tree_like)
    if meta["paths"] != exp_paths:
        raise ValueError(
            "checkpoint tree structure mismatch "
            f"(ckpt has {len(meta['paths'])} leaves, expected {len(exp_paths)})"
        )
    out = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i:05d}"]
        want = tuple(np.shape(np.asarray(leaf))) if hasattr(leaf, "shape") else None
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(
                f"leaf {exp_paths[i]}: shape {arr.shape} != expected {want}"
            )
        out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), meta


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; blocks on overlap."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra_meta=None) -> None:
        self.wait()
        # device->host copy happens here (synchronously) so the train loop
        # can mutate its arrays; the disk write is off-thread.
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra_meta, self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
