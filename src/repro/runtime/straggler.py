"""Straggler mitigation for LP serving: adaptive partition sizing.

LP's unit of work is *patches*, so a slow device (thermal throttling, a
noisy neighbour, a degraded ICI link) can be compensated by shrinking its
core region and growing everyone else's — the blend machinery is already
built for unequal partitions.  We keep an EMA of per-group step times and
re-plan core sizes proportional to measured speed, re-planning only when
the imbalance exceeds a threshold (re-planning forces an XLA recompile for
the uniform-window engine, so it is rate-limited).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.partition import PartitionPlan, _finalize


@dataclasses.dataclass
class StragglerState:
    num_partitions: int
    ema_alpha: float = 0.3
    rebalance_threshold: float = 0.15   # re-plan when >15% imbalance
    # optional obs.MetricsRegistry (duck-typed): the imbalance gauge and
    # slowest-group index flow out per observe() round
    metrics: object = None
    _ema: Optional[np.ndarray] = None

    def observe(self, step_times: Sequence[float]) -> None:
        t = np.asarray(step_times, dtype=np.float64)
        if len(t) != self.num_partitions:
            # group count changed without evict() — restart the EMA on
            # the new layout rather than broadcasting stale history
            self.num_partitions = len(t)
            self._ema = None
        if self._ema is None:
            self._ema = t
        else:
            self._ema = self.ema_alpha * t + (1 - self.ema_alpha) * self._ema
        if self.metrics is not None:
            from repro.obs import metrics as obsm

            s = self.speeds
            imb = float((s.max() - s.min()) / s.max()) if s.max() else 0.0
            self.metrics.set(obsm.STRAGGLER_IMBALANCE, imb)
            self.metrics.set("straggler.slowest_group", self.slowest)

    @property
    def speeds(self) -> np.ndarray:
        """Relative speed per group (1/time), normalized to mean 1."""
        if self._ema is None:
            return np.ones(self.num_partitions)
        s = 1.0 / np.maximum(self._ema, 1e-9)
        return s / s.mean()

    def needs_rebalance(self) -> bool:
        s = self.speeds
        return bool((s.max() - s.min()) / s.max() > self.rebalance_threshold)

    @property
    def slowest(self) -> int:
        """Index of the slowest group (largest step-time EMA)."""
        if self._ema is None:
            return 0
        return int(np.argmax(self._ema))

    def evict(self, group: int) -> None:
        """Drop ``group`` from the tracked layout after an applied
        eviction: the EMA row is removed so surviving groups keep their
        history under their NEW indices and the next ``observe`` expects
        ``num_partitions - 1`` step times."""
        if not 0 <= group < self.num_partitions:
            raise ValueError(f"group {group} not in [0, {self.num_partitions})")
        self.num_partitions -= 1
        if self._ema is not None:
            self._ema = np.delete(self._ema, group)

    def propose_group_eviction(
        self, mesh_shape, slowdown_factor: float = 2.0
    ):
        """Mid-request eviction proposal for the hybrid ``(M, T)`` mesh.

        Core re-sizing (:func:`plan_weighted_partition`) absorbs mild
        imbalance, but a group that is ``>= slowdown_factor`` slower than
        the median (dying host, broken ICI link) should be dropped from
        the LP ring entirely: returns ``(evicted_group, new_mesh_shape)``
        with ``M - 1`` groups, or ``None`` when no group is that far
        gone.  The caller applies it with
        ``runtime.elastic.replan_lp_compiler`` — which guarantees the
        compiled-step cache never reuses an entry for the old mesh shape
        and codec residual state resets exactly once — and then calls
        :meth:`evict` so this monitor tracks the shrunken ring.
        """
        if self._ema is None or mesh_shape[0] <= 2:
            return None
        worst = self.slowest
        med = float(np.median(np.delete(self._ema, worst)))
        if med <= 0 or float(self._ema[worst]) < slowdown_factor * med:
            return None
        return worst, (mesh_shape[0] - 1,) + tuple(mesh_shape[1:])


def plan_weighted_partition(
    extent: int,
    patch: int,
    overlap_ratio: float,
    speeds: Sequence[float],
    dim: int = 0,
) -> PartitionPlan:
    """Patch-aligned partition with core sizes proportional to speed.

    Largest-remainder apportionment of N patches over K groups; every
    group keeps >= 1 patch.  Overlap O scales with the *average* core size
    (same r semantics as the uniform plan)."""
    K = len(speeds)
    N = extent // patch
    if N < K:
        raise ValueError(f"N={N} patches < K={K} groups")
    s = np.clip(np.asarray(speeds, dtype=np.float64), 1e-3, None)
    quota = s / s.sum() * N
    base = np.maximum(np.floor(quota).astype(int), 1)
    # fix rounding to sum exactly N (largest remainders first)
    while base.sum() > N:
        base[np.argmax(base)] -= 1
    rem = quota - np.floor(quota)
    order = np.argsort(-rem)
    i = 0
    while base.sum() < N:
        base[order[i % K]] += 1
        i += 1
    L_avg = max(int(math.ceil(N / K)), 1)
    O = math.floor(L_avg * overlap_ratio)
    core_start, core_end = [], []
    pos = 0
    for k in range(K):
        core_start.append(pos)
        core_end.append(pos + int(base[k]))
        pos += int(base[k])
    assert pos == N
    return _finalize(dim, extent, patch, K, overlap_ratio, L_avg, O,
                     core_start, core_end)
