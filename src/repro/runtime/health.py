"""Group health for the LP serving ring: slow is not dead.

``runtime/straggler.StragglerState`` sees only finite step times, so its
EMA can flag a *slow* group (rebalance, eventually evict at the 2×-median
threshold) but can never notice a group that stopped reporting at all —
a dead host looks like "no new observation" and the stale EMA keeps it
healthy forever.  :class:`GroupHealthMonitor` generalizes the monitor
with **heartbeat deadlines**:

  * every ``observe()`` is one heartbeat round; a group whose entry is
    missing (``None`` / ``inf`` / ``nan``) or beyond its current
    deadline scores a *miss*, everything else feeds the wrapped EMA;
  * a miss does not kill: the group gets ``max_misses`` retry rounds,
    each with a backoff-extended deadline (``deadline × backoff^misses``
    — transient hiccups, a GC pause, a link retrain get time to clear);
  * only after the retry budget is exhausted is the group **dead**:
    :meth:`propose` then returns an immediate eviction proposal with
    ``reason="dead"``, bypassing the EMA's 2×-median slow test.  Slow
    proposals still come from the wrapped
    ``StragglerState.propose_group_eviction`` (``reason="slow"``).

The monitor never evicts below 2 LP groups (same floor as the straggler
EMA: a 1-group "ring" is not LP), and :meth:`evict` re-maps indices the
same way ``StragglerState.evict`` does, so misses follow their group to
its new index.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .straggler import StragglerState


@dataclasses.dataclass(frozen=True)
class EvictionProposal:
    """A concrete shrink proposal: drop ``group``, rebuild at
    ``new_mesh_shape`` (LP axis one smaller, tp untouched)."""

    group: int
    new_mesh_shape: Tuple[int, ...]
    reason: str                      # "dead" | "slow"


@dataclasses.dataclass
class GroupHealthMonitor:
    """Heartbeat-deadline health on top of the straggler EMA."""

    num_groups: int
    deadline_factor: float = 4.0     # miss when t > factor × median EMA
    max_misses: int = 2              # retry rounds before declaring death
    backoff: float = 1.5             # deadline growth per missed round
    default_deadline_s: float = 30.0  # before any EMA history exists
    straggler: StragglerState = None  # type: ignore[assignment]
    # optional obs.MetricsRegistry (duck-typed): heartbeat misses and
    # the dead-group gauge flow out per observe() round
    metrics: object = None
    _misses: np.ndarray = None        # type: ignore[assignment]
    _dead: set = dataclasses.field(default_factory=set)

    def __post_init__(self):
        if self.straggler is None:
            self.straggler = StragglerState(self.num_groups,
                                            metrics=self.metrics)
        elif self.metrics is not None and self.straggler.metrics is None:
            self.straggler.metrics = self.metrics
        if self._misses is None:
            self._misses = np.zeros(self.num_groups, dtype=np.int64)

    # ---------------------------------------------------------- heartbeats
    def deadline_s(self, group: int) -> float:
        """Current per-step deadline for ``group``: the fleet-median EMA
        times ``deadline_factor``, backoff-extended by the group's missed
        rounds so far (bounded retry: each miss buys the next round more
        slack, until the budget runs out)."""
        ema = self.straggler._ema
        base = self.default_deadline_s if ema is None else \
            self.deadline_factor * float(np.median(ema))
        return base * self.backoff ** int(self._misses[group])

    def observe(self, step_times: Sequence[Optional[float]]) -> None:
        """One heartbeat round.  Missing (None/inf/nan) or
        deadline-breaking entries count a miss; on-time entries clear
        the miss counter and feed the EMA.  A missed group feeds the
        fleet median instead of its (possibly infinite) reading: misses
        are judged by the retry counter, not the EMA, so a single
        deadline break must neither poison the median with infinities
        nor trip the EMA's 2×-median *slow* eviction before the miss
        budget has run out (dead-vs-slow stay separate verdicts)."""
        t = [math.inf if x is None else float(x) for x in step_times]
        if len(t) != self.num_groups:
            # layout changed without evict(): restart, like the EMA does
            self.num_groups = len(t)
            self._misses = np.zeros(len(t), dtype=np.int64)
            self._dead = set()
        missed = [not math.isfinite(x) or x > self.deadline_s(g)
                  for g, x in enumerate(t)]
        finite = [x for x, m in zip(t, missed) if not m]
        neutral = float(np.median(finite)) if finite else self.default_deadline_s
        feed = [neutral if m else x for x, m in zip(t, missed)]
        self.straggler.observe(feed)
        for g, m in enumerate(missed):
            if m:
                self._misses[g] += 1
                if self._misses[g] > self.max_misses:
                    self._dead.add(g)
            else:
                self._misses[g] = 0
                self._dead.discard(g)
        if self.metrics is not None:
            from repro.obs import metrics as obsm

            for g, m in enumerate(missed):
                if m:
                    self.metrics.inc(obsm.HEARTBEAT_MISSES, group=str(g))
            self.metrics.set(obsm.DEAD_GROUPS, len(self._dead))

    def mark_recovered(self, group: int) -> None:
        """External recovery signal: ``group`` came back (host restart,
        link re-trained, replica re-attached).  Clears its miss counter
        and dead verdict so the next heartbeat round judges it fresh —
        deadlines drop back to the un-backed-off base.  The EMA row is
        deliberately NOT reset: a recovered group that is still slow
        should keep tripping the straggler test (dead and slow stay
        separate verdicts, in both directions)."""
        if not 0 <= group < self.num_groups:
            raise ValueError(
                f"group {group} not in [0, {self.num_groups})")
        self._misses[group] = 0
        self._dead.discard(group)
        if self.metrics is not None:
            from repro.obs import metrics as obsm

            self.metrics.set(obsm.DEAD_GROUPS, len(self._dead))

    # ----------------------------------------------------------- proposals
    def dead_groups(self) -> List[int]:
        return sorted(self._dead)

    def propose(self, mesh_shape,
                slowdown_factor: float = 2.0) -> Optional[EvictionProposal]:
        """Dead first, slow second.  ``None`` when the ring is healthy or
        already at the 2-group floor (matching
        ``StragglerState.propose_group_eviction``)."""
        new_shape = (mesh_shape[0] - 1,) + tuple(mesh_shape[1:])
        if self._dead and mesh_shape[0] > 2:
            return EvictionProposal(min(self._dead), new_shape, "dead")
        prop = self.straggler.propose_group_eviction(
            mesh_shape, slowdown_factor=slowdown_factor)
        if prop is None:
            return None
        return EvictionProposal(prop[0], prop[1], "slow")

    def evict(self, group: int) -> None:
        """Apply an eviction: drop the group's miss row and re-map the
        survivors' indices (delegating the EMA row to the straggler)."""
        if not 0 <= group < self.num_groups:
            raise ValueError(
                f"group {group} not in [0, {self.num_groups})")
        self.straggler.evict(group)
        self.num_groups -= 1
        self._misses = np.delete(self._misses, group)
        self._dead = {g - 1 if g > group else g
                      for g in self._dead if g != group}
