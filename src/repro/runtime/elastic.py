"""Elastic scaling: restore a checkpoint onto a different device count /
mesh shape.

Checkpoints store *logical* (unsharded) arrays + tree structure, so
re-sharding is a placement decision, not a data transformation: we rebuild
PartitionSpecs for the new mesh and device_put each leaf.  Works for both
scale-down (16 -> 8 devices) and scale-up.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.distributed.sharding import param_specs
from .checkpoint import restore


def reshard_tree(tree: Any, mesh: Mesh, parallel: ParallelConfig) -> Any:
    """Place a host tree onto ``mesh`` under the standard sharding rules."""
    specs = param_specs(tree, parallel)
    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        tree,
        specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )


def replan_lp_compiler(compiler, new_mesh_shape, forward=None,
                       forward_factory=None, recorder=None) -> bool:
    """Mid-request elastic re-plan of a live LP step compiler.

    Retargets ``compiler`` (a ``core/lp_step.LPStepCompiler``) at a new
    ``(lp, tp)`` mesh shape — straggler-group eviction
    (``runtime.straggler.StragglerState.propose_group_eviction``), a
    failed host, or a scale-up.  The lp-axis size becomes the new K.

    Contract (regression-tested in tests/test_replan.py):

    * the full plan geometry is part of the step-cache key, so no step
      compiled for the old mesh shape is ever reused;
    * the compiler's ``plan_epoch`` bump makes an in-flight
      ``lp_denoise`` loop reset codec residual state exactly once at the
      next step boundary (old state shapes are garbage on the new plan);
    * a compiler whose ``forward`` hook or ``forward_factory`` (the
      scheduled-codec variant) is mesh-bound (the SPMD engines close
      over a jax ``Mesh`` whose lp axis must equal K) MUST be given a
      re-bound hook/factory built on the shrunken/grown mesh whenever K
      changes — the old one would reject the new plan at trace time.
      This function raises immediately instead of letting that happen
      mid-denoise.  Simulate-path compilers (no ``forward``, no
      ``forward_factory``) need nothing.

    ``recorder`` (``repro.obs.FlightRecorder``, optional) gets an
    ``elastic.replan`` instant when the re-plan actually changes the
    compiler (the epoch bump the in-flight denoise will observe).
    """
    new_mesh_shape = tuple(new_mesh_shape)
    if new_mesh_shape[0] != compiler.num_partitions:
        if compiler.forward is not None and forward is None:
            raise ValueError(
                "re-planning the lp-axis size of a mesh-bound compiler "
                "needs a re-bound forward hook (the old hook closes over "
                f"a mesh with lp={compiler.num_partitions}, new plan "
                f"wants lp={new_mesh_shape[0]})"
            )
        if compiler.forward_factory is not None and forward_factory is None:
            raise ValueError(
                "re-planning the lp-axis size of a schedule compiler "
                "whose forward_factory is mesh-bound needs a re-bound "
                "factory (the old one binds hooks to a mesh with "
                f"lp={compiler.num_partitions}, new plan wants "
                f"lp={new_mesh_shape[0]})"
            )
    changed = compiler.replan(
        num_partitions=new_mesh_shape[0],
        mesh_shape=new_mesh_shape,
        forward=forward,
        forward_factory=forward_factory,
    )
    if changed and recorder is not None:
        recorder.instant("elastic.replan", cat="elastic",
                         new_mesh_shape=list(new_mesh_shape),
                         epoch=compiler.plan_epoch)
    return changed



def restore_elastic(
    ckpt_dir: str,
    tree_like: Any,
    mesh: Mesh,
    parallel: ParallelConfig,
    step: Optional[int] = None,
):
    """Restore + re-shard in one move; returns (tree_on_mesh, meta)."""
    tree, meta = restore(ckpt_dir, tree_like, step=step)
    return reshard_tree(tree, mesh, parallel), meta
