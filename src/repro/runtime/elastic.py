"""Elastic scaling: restore a checkpoint onto a different device count /
mesh shape.

Checkpoints store *logical* (unsharded) arrays + tree structure, so
re-sharding is a placement decision, not a data transformation: we rebuild
PartitionSpecs for the new mesh and device_put each leaf.  Works for both
scale-down (16 -> 8 devices) and scale-up.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.distributed.sharding import param_specs
from .checkpoint import restore


def reshard_tree(tree: Any, mesh: Mesh, parallel: ParallelConfig) -> Any:
    """Place a host tree onto ``mesh`` under the standard sharding rules."""
    specs = param_specs(tree, parallel)
    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        tree,
        specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )


def restore_elastic(
    ckpt_dir: str,
    tree_like: Any,
    mesh: Mesh,
    parallel: ParallelConfig,
    step: Optional[int] = None,
):
    """Restore + re-shard in one move; returns (tree_on_mesh, meta)."""
    tree, meta = restore(ckpt_dir, tree_like, step=step)
    return reshard_tree(tree, mesh, parallel), meta
