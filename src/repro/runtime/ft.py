"""Fault-tolerant training driver: heartbeat-style failure detection
(simulated), automatic restart from the last checkpoint, bounded retries.

On a real cluster, failure shows up as a collective timing out or the
coordinator losing a host; here failures are injected as exceptions from a
``FailureInjector`` so the restart logic is exercised end-to-end in tests.
The driver guarantees:

  * training state after recovery == state replayed from the checkpoint
    step (data pipeline is random-access by step, so no data is skipped
    or double-counted);
  * at most ``max_restarts`` recoveries before surfacing the failure;
  * checkpoint cadence bounds lost work to ``ckpt_every`` steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax

from .checkpoint import AsyncCheckpointer, latest_step, restore


class DeviceFailure(RuntimeError):
    """Simulated device/host loss."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given steps (each fires once)."""

    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise DeviceFailure(f"injected device failure at step {step}")


@dataclasses.dataclass
class RunReport:
    final_step: int
    restarts: int
    losses: Dict[int, float]


def run_training(
    train_step: Callable,
    init_state: Callable[[], Any],      # () -> (params, opt_state)
    batch_for_step: Callable[[int], Any],
    num_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    injector: Optional[FailureInjector] = None,
    keep_last: int = 3,
) -> RunReport:
    """Run ``num_steps``, surviving injected failures via restart."""
    ckpt = AsyncCheckpointer(ckpt_dir, keep_last=keep_last)
    restarts = 0
    losses: Dict[int, float] = {}

    while True:
        # ---- (re)start: restore or init
        start = latest_step(ckpt_dir)
        if start is None:
            params, opt_state = init_state()
            step = 0
        else:
            params, opt_state = init_state()
            (params, opt_state), meta = restore(
                ckpt_dir, (params, opt_state), step=start
            )
            step = start
        try:
            import jax.numpy as jnp

            while step < num_steps:
                if injector is not None:
                    injector.check(step)
                batch = batch_for_step(step)
                params, opt_state, metrics = train_step(
                    params, opt_state, batch, jnp.int32(step)
                )
                losses[step] = float(metrics["loss"])
                step += 1
                if step % ckpt_every == 0 or step == num_steps:
                    ckpt.save(step, (params, opt_state), {"note": "auto"})
            ckpt.wait()
            return RunReport(final_step=step, restarts=restarts, losses=losses)
        except DeviceFailure:
            restarts += 1
            ckpt.wait()
            if restarts > max_restarts:
                raise
