"""Deterministic synthetic data pipeline, sharded per-host.

Production layout: each host generates only its addressable shard of the
global batch (seeded by (global_seed, step, host_id) so restarts are
exactly reproducible and elastic re-scales re-partition cleanly).  On CPU
tests there is one host and the global batch materializes locally.

Token streams follow a Zipf(1.2) unigram draw — enough structure for loss
curves to move during example training runs without any external data.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLMStream:
    """Infinite deterministic (tokens, labels) stream for one host."""

    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int,
                 data_cfg: DataConfig = DataConfig(),
                 host_id: int = 0, num_hosts: int = 1):
        if batch % num_hosts != 0:
            raise ValueError(f"global batch {batch} % hosts {num_hosts} != 0")
        self.cfg = cfg
        self.local_batch = batch // num_hosts
        self.seq_len = seq_len
        self.data_cfg = data_cfg
        self.host_id = host_id

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.data_cfg.seed, step, self.host_id)
        )

    def batch_at(self, step: int) -> Dict[str, Any]:
        """Batch for a given step — random access enables exact restart."""
        rng = self._rng(step)
        V = max(self.cfg.vocab_size, 2)
        # Zipf over the vocab, clipped into range
        toks = rng.zipf(self.data_cfg.zipf_a,
                        size=(self.local_batch, self.seq_len + 1))
        toks = np.minimum(toks - 1, V - 1).astype(np.int32)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.asarray(
                rng.normal(0, 0.02,
                           (self.local_batch, self.cfg.num_vision_tokens,
                            self.cfg.d_model)).astype(np.float32)
            ).astype(jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.asarray(
                rng.normal(0, 0.02,
                           (self.local_batch, self.cfg.encoder_seq,
                            self.cfg.d_model)).astype(np.float32)
            ).astype(jnp.dtype(self.cfg.dtype))
        return batch

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def latent_noise(key, shape: ShapeConfig, channels: int,
                 dtype=jnp.float32) -> jnp.ndarray:
    """z_T ~ N(0, I) for VDM generation."""
    t_lat = (shape.num_frames - 1) // 4 + 1
    return jax.random.normal(
        key, (shape.global_batch, t_lat, shape.height // 8, shape.width // 8,
              channels), dtype,
    )
