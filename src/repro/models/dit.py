"""WAN2.1-style video DiT — the paper's denoising network f(.).

Latent z: (B, T_lat, H_lat, W_lat, C).  3D-patchified with (p_T, p_H, p_W)
into tokens, processed by DiT blocks (self-attention over all patch tokens,
cross-attention to the encoded text prompt, SwiGLU FFN) with adaLN timestep
modulation, then unpatchified back to a noise prediction of z's shape.

This is the f(.) that LP calls on *sub-latents*: the model is fully shape-
polymorphic over (T_lat, H_lat, W_lat) as long as they are patch-aligned,
which is exactly what the patch-aligned partitioning (paper §3.3)
guarantees.  RoPE uses 3D axial frequencies computed from *global* patch
coordinates, so a sub-latent sees the same positional code it would see
inside the full latent (pass ``origin`` = its offset).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from .scan_util import pscan
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed import actctx
from .attention import attention_chunked
from .layers import (
    dense,
    dense_init,
    layernorm,
    layernorm_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_embedding,
)
from .transformer import stack_init


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def dit_block_init(key, cfg: ArchConfig):
    ka, kc, km, km2 = jax.random.split(key, 4)
    d = cfg.d_model
    dt = _dt(cfg)
    def qkvo(k):
        kq, kk, kv, ko = jax.random.split(k, 4)
        return {
            "q": dense_init(kq, d, cfg.num_heads * cfg.head_dim, dt),
            "k": dense_init(kk, d, cfg.num_heads * cfg.head_dim, dt),
            "v": dense_init(kv, d, cfg.num_heads * cfg.head_dim, dt),
            "o": dense_init(ko, cfg.num_heads * cfg.head_dim, d, dt),
        }
    return {
        "self_attn": qkvo(ka),
        "cross_attn": qkvo(kc),
        "cross_norm": layernorm_init(d),
        "mlp": mlp_init(km, d, cfg.d_ff, dt),
        # adaLN: 6 modulation vectors from the time embedding.  Gate rows
        # (g1, g2) start at 1 so a random-init model already has active
        # self-attention mixing — a trained DiT's operating point, and
        # what makes the LP-vs-centralized quality proxy meaningful.
        "ada": {"w": jnp.zeros((cfg.time_embed_dim, 6 * d), dt)},
        "ada_b": jnp.zeros((6, d), jnp.float32).at[2].set(1.0).at[5].set(1.0),
    }


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    dt = _dt(cfg)
    pt, ph, pw = cfg.patch_sizes
    patch_elems = pt * ph * pw * cfg.latent_channels
    ks = jax.random.split(key, 6)
    return {
        "patch_embed": dense_init(ks[0], patch_elems, d, dt),
        "text_proj": dense_init(ks[1], cfg.context_dim, d, dt),
        "time_mlp": {
            "w1": dense_init(ks[2], 256, cfg.time_embed_dim, jnp.float32),
            "w2": dense_init(ks[3], cfg.time_embed_dim, cfg.time_embed_dim, jnp.float32),
        },
        "blocks": stack_init(ks[4], cfg.num_layers, lambda k: dit_block_init(k, cfg)),
        "final_norm": layernorm_init(d),
        "final_ada": {"w": jnp.zeros((cfg.time_embed_dim, 2 * d), dt)},
        "head": dense_init(ks[5], d, patch_elems, dt),
    }


def _patchify(z: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, Tuple[int, int, int]]:
    """(B,T,H,W,C) -> (B, N_tokens, patch_elems) + patch-grid dims."""
    B, T, H, W, C = z.shape
    pt, ph, pw = cfg.patch_sizes
    nt, nh, nw = T // pt, H // ph, W // pw
    z = z.reshape(B, nt, pt, nh, ph, nw, pw, C)
    z = z.transpose(0, 1, 3, 5, 2, 4, 6, 7)
    return z.reshape(B, nt * nh * nw, pt * ph * pw * C), (nt, nh, nw)


def _unpatchify(tok: jnp.ndarray, grid, cfg: ArchConfig, out_shape):
    B = tok.shape[0]
    nt, nh, nw = grid
    pt, ph, pw = cfg.patch_sizes
    C = cfg.latent_channels
    z = tok.reshape(B, nt, nh, nw, pt, ph, pw, C)
    z = z.transpose(0, 1, 4, 2, 5, 3, 6, 7)
    return z.reshape(out_shape)


def _axial_rope(q, grid, origin, head_dim, theta=10_000.0):
    """3D axial RoPE over (t, h, w) patch coordinates (global coords)."""
    from .layers import rope_frequencies

    nt, nh, nw = grid
    ot, oh, ow = origin
    # split head_dim into 3 axial parts (multiples of 2)
    d_t = (head_dim // 3) & ~1
    d_h = (head_dim // 3) & ~1
    d_w = head_dim - d_t - d_h
    coords = [
        (jnp.arange(nt) + ot, d_t),
        (jnp.arange(nh) + oh, d_h),
        (jnp.arange(nw) + ow, d_w),
    ]
    angles = []
    for ax, (pos, dd) in enumerate(coords):
        freqs = jnp.asarray(rope_frequencies(dd, theta), jnp.float32)
        a = pos[:, None].astype(jnp.float32) * freqs  # (n, dd/2)
        shape = [1, 1, 1, dd // 2]
        shape[ax] = a.shape[0]
        a = a.reshape(shape)
        a = jnp.broadcast_to(a, (nt, nh, nw, dd // 2))
        angles.append(a)
    ang = jnp.concatenate(angles, axis=-1).reshape(1, nt * nh * nw, 1, head_dim // 2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(q.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(q.dtype)


def _attn(params, x, cfg, grid=None, origin=(0, 0, 0), context=None,
          kv_chunk: int = 4096):
    """Bidirectional (DiT) self- or cross-attention."""
    B, S, _ = x.shape
    H, D = cfg.num_heads, cfg.head_dim
    src = x if context is None else context
    Skv = src.shape[1]
    q = dense(params["q"], x).reshape(B, S, H, D)
    k = dense(params["k"], src).reshape(B, Skv, H, D)
    v = dense(params["v"], src).reshape(B, Skv, H, D)
    if context is None and grid is not None:
        q = _axial_rope(q, grid, origin, D)
        k = _axial_rope(k, grid, origin, D)
    # sequence-parallel attention inside LP windows: 12 heads don't divide
    # a 16-way TP axis, so shard query tokens instead (§Perf C)
    q = actctx.shard_attn_q(q)
    k = actctx.shard_attn_kv(k)
    v = actctx.shard_attn_kv(v)
    qp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kp = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
    out = attention_chunked(q, k, v, qp, kp, causal=False, kv_chunk=kv_chunk)
    out = actctx.shard_attn_out(out.reshape(B, S, H * D))
    return dense(params["o"], out)


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def forward(
    params,
    z: jnp.ndarray,                    # (B, T, H, W, C) noisy latent
    t: jnp.ndarray,                    # (B,) diffusion timestep
    context: jnp.ndarray,              # (B, L_ctx, context_dim) text embeds
    cfg: ArchConfig,
    origin: Tuple[int, int, int] = (0, 0, 0),   # global patch offset (LP!)
    kv_chunk: int = 4096,
    remat: bool = False,
) -> jnp.ndarray:
    """Noise prediction f(z_t, t, c) with the same shape as ``z``."""
    B = z.shape[0]
    tok, grid = _patchify(z, cfg)
    x = dense(params["patch_embed"], tok.astype(_dt(cfg)))
    ctx = dense(params["text_proj"], context.astype(_dt(cfg)))

    temb = sinusoidal_embedding(t.astype(jnp.float32), 256)
    temb = dense(params["time_mlp"]["w2"],
                 jax.nn.silu(dense(params["time_mlp"]["w1"], temb)))
    temb = jax.nn.silu(temb)                                   # (B, time_dim)

    def body(h, blk):
        mods = dense(blk["ada"], temb).reshape(B, 6, cfg.d_model) + blk["ada_b"][None]
        s1, b1, g1, s2, b2, g2 = [mods[:, i].astype(h.dtype) for i in range(6)]
        hn = _modulate(rmsnorm({"scale": jnp.ones(cfg.d_model)}, h), b1, s1)
        h = h + g1[:, None, :] * _attn(
            blk["self_attn"], hn, cfg, grid, origin, kv_chunk=kv_chunk
        )
        h = h + _attn(
            blk["cross_attn"],
            layernorm(blk["cross_norm"], h), cfg, context=ctx,
            kv_chunk=kv_chunk,
        )
        hn = _modulate(rmsnorm({"scale": jnp.ones(cfg.d_model)}, h), b2, s2)
        h = h + g2[:, None, :] * mlp(blk["mlp"], hn)
        return actctx.shard_batch(h), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = pscan(body_fn, x, params["blocks"])

    fmods = dense(params["final_ada"], temb).reshape(B, 2, cfg.d_model)
    shift, scale = fmods[:, 0].astype(x.dtype), fmods[:, 1].astype(x.dtype)
    x = _modulate(layernorm(params["final_norm"], x), shift, scale)
    out = dense(params["head"], x)
    return _unpatchify(out, grid, cfg, z.shape).astype(z.dtype)
