"""Mixture-of-Experts layer: top-k router + capacity-bounded sort dispatch.

Dispatch is sort-based (no (T, E, C) one-hot materialization): token-expert
pairs are bucketed by expert with a static per-expert capacity C, experts
run as a batched (E, C, d) matmul, and results scatter back weighted by the
router probabilities.  Expert tensors carry a leading E axis that
``distributed/sharding.py`` shards over the tensor-parallel mesh axis
(expert parallelism); tokens stay sharded over the data axes.

Capacity per expert: C = ceil(T * top_k / E * capacity_factor); overflow
tokens are dropped (standard Switch behaviour) — the router's auxiliary
load-balancing loss keeps drops rare in training.

Padded EP: when E doesn't divide the EP axis (granite-moe's 40 experts over
16 devices), configs pad E up (40 -> 48) and the router never routes to
padding experts (their logits are -inf via the router kernel's zero init +
explicit mask).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, truncated_normal_init


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    num_experts: int,
    dtype=jnp.bfloat16,
    num_padding_experts: int = 0,
):
    E = num_experts + num_padding_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d_model, E, jnp.float32),
        "wi": {"w": truncated_normal_init(k1, (E, d_model, d_ff), 1.0, dtype)},
        "wg": {"w": truncated_normal_init(k2, (E, d_model, d_ff), 1.0, dtype)},
        "wo": {"w": truncated_normal_init(k3, (E, d_ff, d_model), 1.0, dtype)},
    }


def moe_apply(
    params,
    x: jnp.ndarray,                  # (B, S, d)
    num_experts: int,                # real experts (excl. padding)
    top_k: int,
    capacity_factor: float = 1.25,
    router_noise: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E_total = params["wi"]["w"].shape[0]
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), params["router"]["w"]
    )
    if E_total > num_experts:  # padding experts are unroutable
        pad_mask = jnp.arange(E_total) >= num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    if router_noise is not None:
        logits = logits + router_noise
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)          # (T, k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # --- auxiliary load-balancing loss (Switch Transformer eq. 4)
    me = probs.mean(axis=0)                              # (E,)
    ce = jnp.zeros(E_total).at[top_e[:, 0]].add(1.0) / T
    aux = num_experts * jnp.sum(me * ce)

    # --- sort-based dispatch with static capacity
    C = int(math.ceil(T * top_k / num_experts * capacity_factor))
    flat_e = top_e.reshape(-1)                           # (T*k,)
    flat_w = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_e)                          # stable bucket sort
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]
    # position of each pair within its expert bucket
    counts = jnp.zeros(E_total, jnp.int32).at[e_sorted].add(1)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * top_k) - offsets[e_sorted]
    keep = pos_in_e < C                                  # capacity drop
    slot = jnp.where(keep, pos_in_e, C)                  # C = overflow slot

    # scatter tokens into (E, C+1, d); the +1 row swallows overflow
    buf = jnp.zeros((E_total, C + 1, d), x.dtype)
    buf = buf.at[e_sorted, slot].set(xt[t_sorted])
    buf = buf[:, :C, :]

    # --- batched expert FFN (E axis shards over the EP mesh axis)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, params["wg"]["w"],
                   preferred_element_type=jnp.float32)
    ) * jnp.einsum("ecd,edf->ecf", buf, params["wi"]["w"],
                   preferred_element_type=jnp.float32)
    y_e = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), params["wo"]["w"],
                     preferred_element_type=jnp.float32)  # (E, C, d) fp32

    # --- combine: gather back and weight
    pad_row = jnp.zeros((E_total, 1, d), y_e.dtype)
    y_pad = jnp.concatenate([y_e, pad_row], axis=1)      # (E, C+1, d)
    gathered = y_pad[e_sorted, slot]                     # (T*k, d)
    weighted = gathered * (w_sorted * keep)[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[t_sorted].add(weighted)
    return out.reshape(B, S, d).astype(x.dtype), aux
