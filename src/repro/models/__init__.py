"""Model zoo: unified init/forward/decode API across all families.

``build(cfg)`` returns a ``Model`` with:
  init(key)                          -> params
  forward(params, batch, **kw)       -> (hidden or noise-pred, aux)
  loss(params, batch)                -> scalar NLL (LM families)
  init_cache(batch, max_len)         -> decode cache (LM families)
  decode(params, token, cache, pos)  -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import dit, encdec, frontends, transformer
from .transformer import cross_entropy_chunked


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    forward: Callable
    loss: Optional[Callable] = None
    init_cache: Optional[Callable] = None
    decode: Optional[Callable] = None


def build(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "hybrid", "ssm"):
        def loss_fn(params, batch, remat=False, kv_chunk=2048):
            hidden, aux = transformer.forward(
                params, batch["tokens"], cfg,
                vision_embeds=batch.get("vision_embeds"),
                kv_chunk=kv_chunk, remat=remat,
            )
            nll = cross_entropy_chunked(params, hidden, batch["labels"], cfg)
            return nll + 0.01 * aux

        return Model(
            cfg=cfg,
            init=lambda key: transformer.init_params(key, cfg),
            forward=lambda p, batch, **kw: transformer.forward(
                p, batch["tokens"], cfg,
                vision_embeds=batch.get("vision_embeds"), **kw
            ),
            loss=loss_fn,
            init_cache=lambda b, m: transformer.init_cache(cfg, b, m),
            decode=lambda p, tok, cache, pos: transformer.decode_step(
                p, tok, cache, pos, cfg
            ),
        )
    if fam == "audio":
        def fwd(params, batch, **kw):
            enc = encdec.encode(params, batch["frames"], cfg, **kw)
            hid = encdec.decode_forward(params, batch["tokens"], enc, cfg, **kw)
            return hid, jnp.float32(0.0)

        def loss_fn(params, batch, remat=False, kv_chunk=2048):
            hid, _ = fwd(params, batch, kv_chunk=kv_chunk)
            return cross_entropy_chunked(
                {"embed": params["embed"]},
                hid,
                batch["labels"],
                dataclasses.replace(cfg, tie_embeddings=True),
            )

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            forward=fwd,
            loss=loss_fn,
            init_cache=lambda b, m: encdec.init_cache(cfg, b, m),
            decode=lambda p, tok, cache, pos, enc: encdec.decode_step(
                p, tok, cache, pos, enc, cfg
            ),
        )
    if fam == "vdm":
        return Model(
            cfg=cfg,
            init=lambda key: dit.init_params(key, cfg),
            forward=lambda p, batch, **kw: (
                dit.forward(p, batch["latent"], batch["t"], batch["context"],
                            cfg, **kw),
                jnp.float32(0.0),
            ),
        )
    raise ValueError(f"unknown family {fam!r}")
