"""Shared building blocks: params as plain pytrees + pure apply functions.

Conventions (used by ``distributed/sharding.py`` to assign PartitionSpecs):
  * projection kernels are dicts ``{"w": (in, out)}`` named ``q|k|v|o|wi|wg|wo``
  * embeddings are ``{"emb": (vocab, d)}``
  * norm scales are ``{"scale": (d,)}``
  * expert kernels carry a leading expert axis ``(E, in, out)``
All matmuls accumulate in fp32 (``preferred_element_type``) and cast back.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    """He/variance-scaling truncated-normal initializer (fan-in)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = math.sqrt(scale / fan_in)
    # match flax's truncated normal stddev correction
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std / 0.87962566).astype(dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16, scale: float = 1.0):
    return {"w": truncated_normal_init(key, (in_dim, out_dim), scale, dtype)}


def dense(params, x: jnp.ndarray) -> jnp.ndarray:
    w = params["w"]
    y = jnp.einsum("...i,io->...o", x, w, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"emb": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["emb"], tokens, axis=0)


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    """Logits via the (possibly tied) embedding table."""
    y = jnp.einsum(
        "...d,vd->...v", x, params["emb"], preferred_element_type=jnp.float32
    )
    return y  # keep fp32 for a stable softmax/loss


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def mlp_init(key, d: int, ff: int, dtype=jnp.bfloat16):
    """SwiGLU MLP (gate + up + down)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d, ff, dtype),
        "wg": dense_init(k2, d, ff, dtype),
        "wo": dense_init(k3, ff, d, dtype),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(dense(params["wg"], x)) * dense(params["wi"], x)
    return dense(params["wo"], h)


# ----------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------- sinusoidal embeddings
def sinusoidal_embedding(t: jnp.ndarray, dim: int, max_period: float = 10_000.0):
    """Diffusion timestep / position embedding.  t: (...,) -> (..., dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half) / half)
    args = t.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


# ----------------------------------------------------- depthwise causal conv
def causal_conv1d_init(key, channels: int, width: int, dtype=jnp.bfloat16):
    return {
        "w": truncated_normal_init(key, (width, channels), 1.0, dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(params, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (batch, seq, channels)."""
    w = params["w"]  # (width, channels)
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    windows = jnp.stack(
        [pad[:, i : i + x.shape[1], :] for i in range(width)], axis=0
    )  # (width, b, s, c)
    y = jnp.einsum("wbsc,wc->bsc", windows.astype(jnp.float32), w.astype(jnp.float32))
    return (y + params["b"].astype(jnp.float32)).astype(x.dtype)


def causal_conv1d_update(params, x_t: jnp.ndarray, conv_state: jnp.ndarray):
    """Single-token decode update.  x_t: (b, c); state: (b, width-1, c)."""
    w = params["w"]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (b,width,c)
    y = jnp.einsum(
        "bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32)
    ) + params["b"].astype(jnp.float32)
    return y.astype(x_t.dtype), window[:, 1:, :]
