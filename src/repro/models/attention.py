"""Grouped-query attention with memory-safe chunked online softmax.

The default path scans over KV chunks with a running (max, sum, acc) —
the flash-attention recurrence in pure jnp — so 32k prefill and 500k decode
never materialize an S x S score matrix.  ``kernels/flash_attention``
provides the Pallas TPU kernel with the same semantics (swapped in via
``use_pallas``); ``attention_dense`` is the O(S^2)-memory oracle used by
tests and small models.

Supports: causal, sliding-window (h2o-danube), bidirectional (encoders,
DiT), GQA head grouping, and single-token decode against a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import actctx
from .layers import dense, dense_init
from .scan_util import pscan

NEG_INF = -1.0e30


def gqa_init(key, d_model: int, num_heads: int, num_kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16, out_dim: Optional[int] = None):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "k": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "v": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "o": dense_init(ko, num_heads * head_dim, out_dim or d_model, dtype),
    }


def _mask_bias(q_pos, kv_pos, causal: bool, window: int, kv_len=None):
    """(..., Sq, Skv) additive bias: 0 where attendable, NEG_INF elsewhere."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    # int32-max marks padded KV slots (see attention_chunked) — always masked
    ok = kp < jnp.iinfo(jnp.int32).max
    ok = jnp.broadcast_to(
        ok, q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1])
    )
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    if kv_len is not None:
        ok &= kp < kv_len[..., None, None]
    return jnp.where(ok, 0.0, NEG_INF)


def attention_dense(
    q: jnp.ndarray,           # (B, Sq, H, D)
    k: jnp.ndarray,           # (B, Skv, KV, D)
    v: jnp.ndarray,           # (B, Skv, KV, D)
    q_positions: jnp.ndarray,     # (B, Sq)
    kv_positions: jnp.ndarray,    # (B, Skv)
    causal: bool = True,
    window: int = 0,
    kv_len: Optional[jnp.ndarray] = None,   # (B,) valid cache length
) -> jnp.ndarray:
    """Reference attention, O(Sq*Skv) memory."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(float(D))
    bias = _mask_bias(q_positions, kv_positions, causal, window, kv_len)
    scores = scores + bias[:, None, None, :, :]
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
    kv_chunk: int = 2048,
) -> jnp.ndarray:
    """Online-softmax attention scanning over KV chunks (flash recurrence)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if Skv <= kv_chunk:
        return attention_dense(
            q, k, v, q_positions, kv_positions, causal, window, kv_len
        )
    n_chunks = -(-Skv // kv_chunk)
    pad = n_chunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded positions get an out-of-range marker so masking kills them
        kv_positions = jnp.pad(
            kv_positions, ((0, 0), (0, pad)), constant_values=jnp.iinfo(jnp.int32).max
        )
    kc = k.reshape(B, n_chunks, kv_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)

    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(float(D))

    def step(carry, chunk):
        m, l, acc = carry
        k_i, v_i, pos_i = chunk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_i.astype(jnp.float32)) * scale
        bias = _mask_bias(q_positions, pos_i, causal, window, kv_len)
        s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, D), jnp.float32)
    (m, l, acc), _ = pscan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def attention(
    q, k, v, q_positions, kv_positions,
    causal: bool = True,
    window: int = 0,
    kv_len=None,
    kv_chunk: int = 2048,
    use_pallas: bool = False,
    pallas_interpret: bool = True,
):
    """Dispatch: Pallas flash kernel (TPU target) or chunked jnp."""
    if use_pallas:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.flash_attention(
            q, k, v, q_positions, kv_positions,
            causal=causal, window=window, kv_len=kv_len,
            interpret=pallas_interpret,
        )
    return attention_chunked(
        q, k, v, q_positions, kv_positions, causal, window, kv_len, kv_chunk
    )


def gqa_apply(
    params,
    x: jnp.ndarray,                 # (B, S, d)
    positions: jnp.ndarray,         # (B, S)
    rope_theta: float,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    causal: bool = True,
    window: int = 0,
    kv_source: Optional[jnp.ndarray] = None,      # cross-attention context
    kv_positions: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    kv_chunk: int = 2048,
):
    """Self- or cross-attention block (projections + attention + out proj)."""
    from .layers import apply_rope

    B, S, _ = x.shape
    src = x if kv_source is None else kv_source
    Skv = src.shape[1]
    q = dense(params["q"], x).reshape(B, S, num_heads, head_dim)
    k = dense(params["k"], src).reshape(B, Skv, num_kv_heads, head_dim)
    v = dense(params["v"], src).reshape(B, Skv, num_kv_heads, head_dim)
    if kv_positions is None:
        kv_positions = positions if kv_source is None else (
            jnp.broadcast_to(jnp.arange(Skv)[None, :], (B, Skv))
        )
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_positions, rope_theta)
    q = actctx.shard_attn_q(q)
    k = actctx.shard_attn_kv(k)
    v = actctx.shard_attn_kv(v)
    out = attention(
        q, k, v, positions, kv_positions,
        causal=causal, window=window, kv_chunk=kv_chunk,
    )
    out = actctx.shard_attn_out(out.reshape(B, S, num_heads * head_dim))
    return dense(params["o"], out)


def decode_attention(
    params,
    x_t: jnp.ndarray,               # (B, 1, d)
    cache_k: jnp.ndarray,           # (B, S_max, KV, D)
    cache_v: jnp.ndarray,
    position: jnp.ndarray,          # (B,) current index
    rope_theta: float,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    window: int = 0,
    use_rope: bool = True,
    kv_chunk: int = 8192,
):
    """One-token decode: project, update cache at ``position``, attend.

    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    from .layers import apply_rope

    B = x_t.shape[0]
    q = dense(params["q"], x_t).reshape(B, 1, num_heads, head_dim)
    k = dense(params["k"], x_t).reshape(B, 1, num_kv_heads, head_dim)
    v = dense(params["v"], x_t).reshape(B, 1, num_kv_heads, head_dim)
    pos2d = position[:, None]
    if use_rope:
        q = apply_rope(q, pos2d, rope_theta)
        k = apply_rope(k, pos2d, rope_theta)

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, p: jax.lax.dynamic_update_slice_in_dim(cb, nb, p, 0)
        )(c, new, position)

    cache_k = upd(cache_k, k)
    cache_v = upd(cache_v, v)
    S_max = cache_k.shape[1]
    if 0 < window < S_max:
        # sliding-window decode only ever attends to the last `window`
        # positions: slice them out of the cache so attention reads
        # O(window) instead of O(S_max) — a 128x traffic cut for
        # h2o-danube's 4096-window at the 500k-token cell (§Perf).
        start = jnp.clip(position + 1 - window, 0, S_max - window)
        win_k = jax.vmap(
            lambda cb, s: jax.lax.dynamic_slice_in_dim(cb, s, window, 0)
        )(cache_k, start)
        win_v = jax.vmap(
            lambda cb, s: jax.lax.dynamic_slice_in_dim(cb, s, window, 0)
        )(cache_v, start)
        kv_pos = start[:, None] + jnp.arange(window)[None, :]
        out = attention_chunked(
            q, win_k, win_v, pos2d, kv_pos,
            causal=False, window=window, kv_len=position + 1,
            kv_chunk=kv_chunk,
        )
    else:
        kv_pos = jnp.broadcast_to(jnp.arange(S_max)[None, :], (B, S_max))
        out = attention_chunked(
            q, cache_k, cache_v, pos2d, kv_pos,
            causal=False, window=window, kv_len=position + 1,
            kv_chunk=kv_chunk,
        )
    y = dense(params["o"], out.reshape(B, 1, num_heads * head_dim))
    return y, cache_k, cache_v
