"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) + sLSTM (scalar
memory, recurrent).

mLSTM is a gated linear recurrence

    C_t = f_t C_{t-1} + i_t k_t (x) v_t          (matrix memory, n x p)
    n_t = f_t n_{t-1} + i_t k_t                  (normalizer state)
    y_t = (q_t . C_t) / max(|q_t . n_t|, 1)

so the prefill/train path reuses ``ssm.gated_linear_scan`` with
``log_decay = logsigmoid(f~)`` and ``scale = exp(i~)`` (exponential input
gating, fp32).  The single-token decode path keeps the paper's max-state
stabilizer.  sLSTM has data-dependent *recurrent* connections (h_{t-1}
feeds the gates), which genuinely cannot be parallelized over time — it
runs as a lax.scan, matching the xLSTM paper's own characterization.

Ratio: every ``slstm_every``-th block is sLSTM, the rest mLSTM (7:1 in
xLSTM-1.3b, per arXiv:2405.04517).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from .scan_util import pscan

from .layers import (
    causal_conv1d,
    causal_conv1d_init,
    dense,
    dense_init,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
)
from .ssm import gated_linear_scan

PF_MLSTM = 2  # up-projection factor


# ------------------------------------------------------------------ mLSTM
def mlstm_init(key, d_model: int, num_heads: int, dtype=jnp.bfloat16):
    di = PF_MLSTM * d_model
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_init(d_model),
        "up": dense_init(ks[0], d_model, 2 * di, dtype),
        "conv": causal_conv1d_init(ks[1], di, 4, dtype),
        "q": dense_init(ks[2], di, di, dtype),
        "k": dense_init(ks[3], di, di, dtype),
        "gates": dense_init(ks[4], di, 2 * num_heads, jnp.float32),
        "gate_bias": jnp.concatenate(
            [jnp.zeros(num_heads), jnp.linspace(3.0, 6.0, num_heads)]
        ).astype(jnp.float32),
        "cell_norm": rmsnorm_init(di),
        "down": dense_init(ks[5], di, d_model, dtype),
    }


def mlstm_apply(params, x: jnp.ndarray, num_heads: int, chunk: int = 128):
    """x: (B, S, d).  Chunk-parallel mLSTM block forward."""
    b, s, _ = x.shape
    h = num_heads
    res = x
    xn = rmsnorm(params["norm"], x)
    a, g = jnp.split(dense(params["up"], xn), 2, axis=-1)     # (b,s,di) each
    di = a.shape[-1]
    dh = di // h
    ac = jax.nn.silu(causal_conv1d(params["conv"], a))
    q = dense(params["q"], ac).reshape(b, s, h, dh)
    k = dense(params["k"], ac).reshape(b, s, h, dh) / jnp.sqrt(float(dh))
    v = a.reshape(b, s, h, dh)                                 # value from a
    gates = dense(params["gates"], ac.astype(jnp.float32)) + params["gate_bias"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)                # (b,s,h)
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    i_scale = jnp.exp(jnp.clip(i_raw.astype(jnp.float32), -10.0, 10.0))
    # matrix memory: y = q . C  with C_t = f C + i k (x) v
    y = gated_linear_scan(v, log_f, i_scale, k, q, chunk=chunk)   # (b,s,h,dh)
    # normalizer: n_t = f n + i k ; denom = max(|q.n|, 1)
    ones = jnp.ones((b, s, h, 1), v.dtype)
    qn = gated_linear_scan(ones, log_f, i_scale, k, q, chunk=chunk)[..., 0]
    y = y / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(params["cell_norm"], y) * jax.nn.silu(g)
    return res + dense(params["down"], y)


def mlstm_init_cache(batch: int, d_model: int, num_heads: int, dtype=jnp.float32):
    di = PF_MLSTM * d_model
    dh = di // num_heads
    return {
        "conv": jnp.zeros((batch, 3, di), dtype),
        "C": jnp.zeros((batch, num_heads, dh, dh), dtype),
        "n": jnp.zeros((batch, num_heads, dh), dtype),
        "m": jnp.full((batch, num_heads), -1e30, dtype),
    }


def mlstm_decode(params, x_t: jnp.ndarray, cache: dict, num_heads: int):
    """Single-token mLSTM with max-state stabilization (xLSTM eq. 15)."""
    from .layers import causal_conv1d_update

    b = x_t.shape[0]
    h = num_heads
    res = x_t
    xn = rmsnorm(params["norm"], x_t)
    a, g = jnp.split(dense(params["up"], xn)[:, 0], 2, axis=-1)  # (b, di)
    di = a.shape[-1]
    dh = di // h
    ac, conv_state = causal_conv1d_update(params["conv"], a, cache["conv"])
    ac = jax.nn.silu(ac)
    q = dense(params["q"], ac[:, None])[:, 0].reshape(b, h, dh)
    k = dense(params["k"], ac[:, None])[:, 0].reshape(b, h, dh) / jnp.sqrt(float(dh))
    v = a.reshape(b, h, dh)
    gates = dense(params["gates"], ac[:, None].astype(jnp.float32))[:, 0] + params["gate_bias"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)                  # (b,h)
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    m_new = jnp.maximum(log_f + cache["m"], i_raw)
    f_eff = jnp.exp(log_f + cache["m"] - m_new)
    i_eff = jnp.exp(i_raw - m_new)
    C = cache["C"] * f_eff[..., None, None] + i_eff[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = cache["n"] * f_eff[..., None] + i_eff[..., None] * k
    num = jnp.einsum("bhd,bhdp->bhp", q.astype(jnp.float32), C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    y = y.reshape(b, 1, di).astype(x_t.dtype)
    y = rmsnorm(params["cell_norm"], y) * jax.nn.silu(g)[:, None]
    out = res + dense(params["down"], y)
    return out, {"conv": conv_state, "C": C, "n": n, "m": m_new}


# ------------------------------------------------------------------ sLSTM
def slstm_init(key, d_model: int, num_heads: int, dtype=jnp.bfloat16):
    dh = d_model // num_heads
    ks = jax.random.split(key, 4)
    rec = (
        jax.random.normal(ks[1], (4, num_heads, dh, dh)) / jnp.sqrt(float(dh))
    ).astype(jnp.float32)
    ff = -(-int(d_model * 4 / 3) // 128) * 128  # shard-friendly
    return {
        "norm": rmsnorm_init(d_model),
        "wx": dense_init(ks[0], d_model, 4 * d_model, dtype),  # z i f o
        "rec": rec,
        "group_norm": layernorm_init(d_model),
        "ffn": {
            "wi": dense_init(ks[2], d_model, ff, dtype),
            "wg": dense_init(ks[2], d_model, ff, dtype),
            "wo": dense_init(ks[3], ff, d_model, dtype),
        },
        "ffn_norm": rmsnorm_init(d_model),
    }


def _slstm_cell(params, xz, xi, xf, xo, state, num_heads):
    """One recurrent step.  x*: (b, h, dh); state: (c, n, m, h_prev)."""
    c, n, m, h_prev = state
    rec = params["rec"]  # (4, h, dh, dh)
    rz = jnp.einsum("bhd,hde->bhe", h_prev, rec[0])
    ri = jnp.einsum("bhd,hde->bhe", h_prev, rec[1]).mean(-1)
    rf = jnp.einsum("bhd,hde->bhe", h_prev, rec[2]).mean(-1)
    ro = jnp.einsum("bhd,hde->bhe", h_prev, rec[3])
    z = jnp.tanh(xz + rz)
    i_raw = xi.mean(-1) + ri                     # (b, h) scalar-per-head gates
    f_raw = xf.mean(-1) + rf
    o = jax.nn.sigmoid(xo + ro)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    f_eff = jnp.exp(log_f + m - m_new)[..., None]
    i_eff = jnp.exp(i_raw - m_new)[..., None]
    c_new = f_eff * c + i_eff * z
    n_new = f_eff * n + i_eff
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return (c_new, n_new, m_new, h_new)


def slstm_apply(params, x: jnp.ndarray, num_heads: int):
    """x: (B, S, d) — sequential scan over time (inherently recurrent)."""
    b, s, d = x.shape
    h = num_heads
    dh = d // h
    res = x
    xn = rmsnorm(params["norm"], x)
    gates_x = dense(params["wx"], xn).astype(jnp.float32)      # (b,s,4d)
    xz, xi, xf, xo = jnp.split(gates_x, 4, axis=-1)
    shaped = [t.reshape(b, s, h, dh).transpose(1, 0, 2, 3) for t in (xz, xi, xf, xo)]
    state0 = tuple(
        jnp.zeros((b, h, dh), jnp.float32) if k != 2 else jnp.full((b, h), -1e30)
        for k in range(4)
    )
    state0 = (state0[0], state0[1], jnp.full((b, h), -1e30), state0[3])

    def step(state, xs):
        new = _slstm_cell(params, xs[0], xs[1], xs[2], xs[3], state, num_heads)
        return new, new[3]

    _, hs = pscan(step, state0, tuple(shaped))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = layernorm(params["group_norm"], y)
    x1 = res + y
    # gated FFN (PF 4/3)
    f = params["ffn"]
    xf2 = rmsnorm(params["ffn_norm"], x1)
    hmid = jax.nn.silu(dense(f["wg"], xf2)) * dense(f["wi"], xf2)
    return x1 + dense(f["wo"], hmid)


def slstm_init_cache(batch: int, d_model: int, num_heads: int):
    dh = d_model // num_heads
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {
        "c": z, "n": z, "m": jnp.full((batch, num_heads), -1e30), "h": z,
    }


def slstm_decode(params, x_t: jnp.ndarray, cache: dict, num_heads: int):
    b, _, d = x_t.shape
    h, dh = num_heads, d // num_heads
    res = x_t
    xn = rmsnorm(params["norm"], x_t)
    gates_x = dense(params["wx"], xn)[:, 0].astype(jnp.float32)
    xz, xi, xf, xo = [t.reshape(b, h, dh) for t in jnp.split(gates_x, 4, -1)]
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, hnew = _slstm_cell(params, xz, xi, xf, xo, state, num_heads)
    y = hnew.reshape(b, 1, d).astype(x_t.dtype)
    y = layernorm(params["group_norm"], y)
    x1 = res + y
    f = params["ffn"]
    xf2 = rmsnorm(params["ffn_norm"], x1)
    hmid = jax.nn.silu(dense(f["wg"], xf2)) * dense(f["wi"], xf2)
    out = x1 + dense(f["wo"], hmid)
    return out, {"c": c, "n": n, "m": m, "h": hnew}
