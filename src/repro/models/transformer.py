"""Unified decoder-LM stacks for the assigned architectures.

One entry point, four family-specific stacks, all scan-over-layers (stacked
per-layer params -> single-layer HLO, MaxText-style) so 126-layer models
compile in seconds:

* ``dense``/``moe``/``vlm``: GQA attention (full or sliding-window) +
  SwiGLU MLP or MoE; vision-language models consume stub patch embeddings
  merged into the token stream.
* ``hybrid`` (zamba2): groups of Mamba2 blocks with one *shared* attention
  block invoked per group through per-invocation LoRA adapters.
* ``ssm`` (xlstm): groups of 7 mLSTM blocks + 1 sLSTM block.

Each stack provides forward (train/prefill), cache init, and one-token
decode.  Loss never materializes (B, S, V) logits — cross-entropy runs in
sequence chunks (vocab tables up to 256k).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from .scan_util import pscan

from repro.configs.base import ArchConfig
from repro.distributed import actctx
from .attention import decode_attention, gqa_apply, gqa_init
from .layers import (
    dense,
    dense_init,
    embed,
    embedding_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from .moe import moe_apply, moe_init
from .ssm import (
    mamba2_apply,
    mamba2_decode,
    mamba2_init,
    mamba2_init_cache,
)
from .xlstm import (
    mlstm_apply,
    mlstm_decode,
    mlstm_init,
    mlstm_init_cache,
    slstm_apply,
    slstm_decode,
    slstm_init,
    slstm_init_cache,
)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def stack_init(key, n: int, init_fn):
    """vmap a per-layer init over n split keys -> stacked params."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


# =============================================================== dense / moe
def _ep_padding(cfg: ArchConfig, ep_degree: int = 16) -> int:
    """Pad experts up to a multiple of the EP axis (granite's 40 -> 48)."""
    if cfg.num_experts % ep_degree == 0:
        return 0
    return ep_degree - cfg.num_experts % ep_degree


def lm_block_init(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    ka, km = jax.random.split(key)
    p = {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": gqa_init(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dt
        ),
        "mlp_norm": rmsnorm_init(cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(
            km, cfg.d_model, cfg.d_ff_expert, cfg.num_experts, dt,
            num_padding_experts=_ep_padding(cfg),
        )
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, dt)
    return p


def lm_block_apply(cfg: ArchConfig, params, x, positions, kv_chunk: int = 2048):
    window = cfg.window if cfg.attn_type == "swa" else 0
    h = gqa_apply(
        params["attn"],
        rmsnorm(params["attn_norm"], x, cfg.norm_eps),
        positions,
        cfg.rope_theta,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.head_dim,
        causal=True,
        window=window,
        kv_chunk=kv_chunk,
    )
    x = x + h
    xin = rmsnorm(params["mlp_norm"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_apply(
            params["moe"], xin, cfg.num_experts, cfg.experts_top_k,
            cfg.capacity_factor,
        )
    else:
        y, aux = mlp(params["mlp"], xin), jnp.float32(0.0)
    return x + y, aux


def lm_block_decode(cfg: ArchConfig, params, x_t, cache, position):
    window = cfg.window if cfg.attn_type == "swa" else 0
    h, ck, cv = decode_attention(
        params["attn"],
        rmsnorm(params["attn_norm"], x_t, cfg.norm_eps),
        cache["k"],
        cache["v"],
        position,
        cfg.rope_theta,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.head_dim,
        window=window,
    )
    x_t = x_t + h
    xin = rmsnorm(params["mlp_norm"], x_t, cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe_apply(
            params["moe"], xin, cfg.num_experts, cfg.experts_top_k,
            cfg.capacity_factor,
        )
    else:
        y = mlp(params["mlp"], xin)
    return x_t + y, {"k": ck, "v": cv}


# ================================================================== hybrid
def _zamba_groups(cfg: ArchConfig) -> int:
    assert cfg.num_layers % cfg.attn_every == 0, "layers must group evenly"
    return cfg.num_layers // cfg.attn_every


def zamba_shared_init(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    ka, km = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": gqa_init(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dt
        ),
        "mlp_norm": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dt),
    }


def zamba_lora_init(key, cfg: ArchConfig):
    """Per-invocation LoRA on the shared block's q/k/v projections."""
    dt = _dtype(cfg)
    out = {}
    for i, nm in enumerate(("q", "k", "v")):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        heads = cfg.num_heads if nm == "q" else cfg.num_kv_heads
        out[nm] = {
            "a": dense_init(k1, cfg.d_model, cfg.lora_rank, dt),
            "b": {"w": jnp.zeros((cfg.lora_rank, heads * cfg.head_dim), dt)},
        }
    return out


def _lora_adapted_attn(shared_attn, lora):
    """Shared projections + low-rank per-invocation deltas."""
    adapted = dict(shared_attn)
    for nm in ("q", "k", "v"):
        w = shared_attn[nm]["w"] + (
            lora[nm]["a"]["w"] @ lora[nm]["b"]["w"]
        ).astype(shared_attn[nm]["w"].dtype)
        adapted[nm] = {"w": w}
    return adapted


def zamba_group_apply(cfg, mamba_stack, shared, lora_g, x, positions, kv_chunk):
    """attn_every Mamba2 blocks (inner scan) + one shared-attn invocation."""

    def mamba_body(h, layer_params):
        return actctx.shard_batch(h + mamba2_apply(layer_params, h, cfg)), None

    x, _ = pscan(mamba_body, x, mamba_stack)
    attn_params = _lora_adapted_attn(shared["attn"], lora_g)
    h = gqa_apply(
        attn_params,
        rmsnorm(shared["attn_norm"], x, cfg.norm_eps),
        positions,
        cfg.rope_theta,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.head_dim,
        causal=True,
        kv_chunk=kv_chunk,
    )
    x = x + h
    x = x + mlp(shared["mlp"], rmsnorm(shared["mlp_norm"], x, cfg.norm_eps))
    return x


# ================================================================== top level
def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    k_emb, k_layers, k_extra, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.padded_vocab_size, cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["layers"] = stack_init(
            k_layers, cfg.num_layers, lambda k: lm_block_init(k, cfg)
        )
        if fam == "vlm":
            params["vision_proj"] = dense_init(k_extra, cfg.d_model, cfg.d_model, dt)
    elif fam == "hybrid":
        g = _zamba_groups(cfg)
        params["mamba"] = stack_init(
            k_layers,
            g * cfg.attn_every,
            lambda k: mamba2_init(
                k, cfg.d_model, cfg.ssm_state, cfg.ssm_headdim,
                cfg.ssm_expand, cfg.ssm_conv, cfg.ssm_groups, dt,
            ),
        )
        # reshape leading axis (L,) -> (groups, attn_every)
        params["mamba"] = jax.tree.map(
            lambda a: a.reshape(g, cfg.attn_every, *a.shape[1:]), params["mamba"]
        )
        params["shared"] = zamba_shared_init(k_extra, cfg)
        params["lora"] = stack_init(
            k_head, g, lambda k: zamba_lora_init(k, cfg)
        )
    elif fam == "ssm":  # xlstm
        n_s = cfg.num_layers // cfg.slstm_every
        n_m_per_group = cfg.slstm_every - 1
        params["mlstm"] = stack_init(
            k_layers,
            n_s * n_m_per_group,
            lambda k: mlstm_init(k, cfg.d_model, cfg.num_heads, dt),
        )
        params["mlstm"] = jax.tree.map(
            lambda a: a.reshape(n_s, n_m_per_group, *a.shape[1:]), params["mlstm"]
        )
        params["slstm"] = stack_init(
            k_extra, n_s, lambda k: slstm_init(k, cfg.d_model, cfg.num_heads, dt)
        )
    else:
        raise ValueError(f"init_params: unsupported family {fam!r}")
    if not cfg.tie_embeddings:
        params["lm_head"] = embedding_init(k_head, cfg.padded_vocab_size, cfg.d_model, dt)
    return params


def _merge_vision(params, x, vision_embeds):
    """VLM stub frontend: precomputed patch embeddings replace the first
    num_vision_tokens positions of the sequence."""
    v = dense(params["vision_proj"], vision_embeds).astype(x.dtype)
    nv = v.shape[1]
    return jnp.concatenate([v, x[:, nv:, :]], axis=1)


def forward(
    params,
    tokens: jnp.ndarray,                    # (B, S) int32
    cfg: ArchConfig,
    vision_embeds: Optional[jnp.ndarray] = None,
    kv_chunk: int = 2048,
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward -> (final hidden (B,S,d), aux_loss)."""
    B, S = tokens.shape
    x = actctx.shard_batch(embed(params["embed"], tokens))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if cfg.family == "vlm":
        if vision_embeds is None:
            raise ValueError("vlm forward needs vision_embeds")
        x = _merge_vision(params, x, vision_embeds)
    aux = jnp.float32(0.0)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, layer_params):
            h, a = carry
            h2, a2 = lm_block_apply(cfg, layer_params, h, positions, kv_chunk)
            return (actctx.shard_batch(h2), a + a2), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = pscan(body_fn, (x, aux), params["layers"])
    elif cfg.family == "hybrid":
        def body(h, xs):
            mamba_g, lora_g = xs
            h2 = zamba_group_apply(
                cfg, mamba_g, params["shared"], lora_g, h, positions, kv_chunk
            )
            return actctx.shard_batch(h2), None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = pscan(body_fn, x, (params["mamba"], params["lora"]))
    elif cfg.family == "ssm":
        def body(h, xs):
            mlstm_g, slstm_g = xs

            def mbody(hh, lp):
                return mlstm_apply(lp, hh, cfg.num_heads), None

            h, _ = pscan(mbody, h, mlstm_g)
            h = slstm_apply(slstm_g, h, cfg.num_heads)
            return actctx.shard_batch(h), None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = pscan(body_fn, x, (params["mlstm"], params["slstm"]))
    else:
        raise ValueError(cfg.family)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def logits_fn(params, hidden: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, hidden)
    if cfg.padded_vocab_size != cfg.vocab_size:  # mask vocab padding
        pad = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits


def cross_entropy_chunked(
    params, hidden: jnp.ndarray, labels: jnp.ndarray, cfg: ArchConfig,
    seq_chunk: int = 512,
) -> jnp.ndarray:
    """Mean token NLL without materializing (B, S, V) logits."""
    B, S, D = hidden.shape
    n = -(-S // seq_chunk)
    pad = n * seq_chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, n, seq_chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, seq_chunk).transpose(1, 0, 2)

    def chunk_loss(carry, xs):
        h, l = xs
        logits = logits_fn(params, h, cfg)                  # (B, c, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1
        )[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        nll = (logz - gold) * valid
        tot, cnt = carry
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = pscan(
        chunk_loss, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0)


# ================================================================ decode path
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dt = _dtype(cfg)
    kv_shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    if cfg.family in ("dense", "moe", "vlm"):
        L = cfg.num_layers
        return {
            "k": jnp.zeros((L, *kv_shape), dt),
            "v": jnp.zeros((L, *kv_shape), dt),
        }
    if cfg.family == "hybrid":
        g = _zamba_groups(cfg)
        m = mamba2_init_cache(batch, cfg)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None, None], (g, cfg.attn_every, *a.shape)
                ),
                m,
            ),
            "k": jnp.zeros((g, *kv_shape), dt),
            "v": jnp.zeros((g, *kv_shape), dt),
        }
    if cfg.family == "ssm":
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        mc = mlstm_init_cache(batch, cfg.d_model, cfg.num_heads)
        sc = slstm_init_cache(batch, cfg.d_model, cfg.num_heads)
        return {
            "mlstm": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None, None], (n_s, n_m, *a.shape)), mc
            ),
            "slstm": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_s, *a.shape)), sc
            ),
        }
    raise ValueError(cfg.family)


def decode_step(
    params,
    token: jnp.ndarray,          # (B, 1) int32
    cache: Dict[str, Any],
    position: jnp.ndarray,       # (B,) int32 current write index
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step -> (logits (B, 1, V) fp32, new cache)."""
    x = embed(params["embed"], token)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, xs):
            layer_params, ck, cv = xs
            h2, newc = lm_block_decode(
                cfg, layer_params, h, {"k": ck, "v": cv}, position
            )
            return h2, (newc["k"], newc["v"])

        x, (nk, nv) = pscan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    elif cfg.family == "hybrid":
        def body(h, xs):
            mamba_g, lora_g, mcache_g, ck, cv = xs

            def mbody(carry, mx):
                hh, = carry
                lp, mc = mx
                out, newmc = mamba2_decode(lp, hh, mc, cfg)
                return (hh + out,), newmc

            (h,), new_mc = pscan(mbody, (h,), (mamba_g, mcache_g))
            attn_params = _lora_adapted_attn(params["shared"]["attn"], lora_g)
            a, nk, nv = decode_attention(
                attn_params,
                rmsnorm(params["shared"]["attn_norm"], h, cfg.norm_eps),
                ck, cv, position,
                cfg.rope_theta, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            )
            h = h + a
            h = h + mlp(
                params["shared"]["mlp"],
                rmsnorm(params["shared"]["mlp_norm"], h, cfg.norm_eps),
            )
            return h, (new_mc, nk, nv)

        x, (new_mc, nk, nv) = pscan(
            body, x,
            (params["mamba"], params["lora"], cache["mamba"], cache["k"], cache["v"]),
        )
        new_cache = {"mamba": new_mc, "k": nk, "v": nv}
    elif cfg.family == "ssm":
        def body(h, xs):
            mlstm_g, slstm_g, mcache_g, scache_g = xs

            def mbody(hh, mx):
                lp, mc = mx
                out, newmc = mlstm_decode(lp, hh, mc, cfg.num_heads)
                return out, newmc

            h, new_mc = pscan(mbody, h, (mlstm_g, mcache_g))
            h, new_sc = slstm_decode(slstm_g, h, scache_g, cfg.num_heads)
            return h, (new_mc, new_sc)

        x, (new_mc, new_sc) = pscan(
            body, x,
            (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]),
        )
        new_cache = {"mlstm": new_mc, "slstm": new_sc}
    else:
        raise ValueError(cfg.family)

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, h, cfg), new_cache
