"""Whisper-style encoder-decoder backbone (family "audio").

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed mel-frame embeddings (B, encoder_seq, d_model); the
encoder is a bidirectional transformer over them, the decoder a causal
transformer with cross-attention.  Whisper uses MHA (kv == heads) and
learned positions; we use sinusoidal positions for the encoder (as the
original does) and RoPE-free learned-position decoding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from .scan_util import pscan

from repro.configs.base import ArchConfig
from repro.distributed import actctx
from .attention import decode_attention, gqa_apply, gqa_init
from .layers import (
    dense,
    embed,
    embedding_init,
    layernorm,
    layernorm_init,
    mlp,
    mlp_init,
    sinusoidal_embedding,
    unembed,
)
from .transformer import stack_init


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def enc_block_init(key, cfg: ArchConfig):
    ka, km = jax.random.split(key)
    return {
        "attn_norm": layernorm_init(cfg.d_model),
        "attn": gqa_init(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                         cfg.head_dim, _dt(cfg)),
        "mlp_norm": layernorm_init(cfg.d_model),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, _dt(cfg)),
    }


def dec_block_init(key, cfg: ArchConfig):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "self_norm": layernorm_init(cfg.d_model),
        "self_attn": gqa_init(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                              cfg.head_dim, _dt(cfg)),
        "cross_norm": layernorm_init(cfg.d_model),
        "cross_attn": gqa_init(kc, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                               cfg.head_dim, _dt(cfg)),
        "mlp_norm": layernorm_init(cfg.d_model),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, _dt(cfg)),
    }


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    k_e, k_d, k_emb, k_pos = jax.random.split(key, 4)
    return {
        "embed": embedding_init(k_emb, cfg.padded_vocab_size, cfg.d_model, _dt(cfg)),
        "dec_pos": embedding_init(k_pos, 8192, cfg.d_model, _dt(cfg)),
        "encoder": stack_init(k_e, cfg.encoder_layers,
                              lambda k: enc_block_init(k, cfg)),
        "decoder": stack_init(k_d, cfg.num_layers,
                              lambda k: dec_block_init(k, cfg)),
        "enc_final": layernorm_init(cfg.d_model),
        "dec_final": layernorm_init(cfg.d_model),
    }


def encode(params, frames: jnp.ndarray, cfg: ArchConfig,
           kv_chunk: int = 2048) -> jnp.ndarray:
    """frames: (B, S_enc, d) stub frontend embeddings -> encoder states."""
    B, S, _ = frames.shape
    pos = jnp.arange(S)
    x = frames + sinusoidal_embedding(pos, cfg.d_model)[None].astype(frames.dtype)
    positions = jnp.broadcast_to(pos[None, :], (B, S))

    def body(h, layer):
        a = gqa_apply(
            layer["attn"], layernorm(layer["attn_norm"], h), positions,
            cfg.rope_theta, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            causal=False, use_rope=False, kv_chunk=kv_chunk,
        )
        h = h + a
        h = h + mlp(layer["mlp"], layernorm(layer["mlp_norm"], h))
        return actctx.shard_batch(h), None

    x, _ = pscan(body, x, params["encoder"])
    return layernorm(params["enc_final"], x)


def decode_forward(
    params, tokens: jnp.ndarray, enc_states: jnp.ndarray, cfg: ArchConfig,
    kv_chunk: int = 2048,
) -> jnp.ndarray:
    """Teacher-forced decoder -> hidden states (B, S, d)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    x = x + embed(params["dec_pos"], jnp.arange(S) % 8192)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(h, layer):
        a = gqa_apply(
            layer["self_attn"], layernorm(layer["self_norm"], h), positions,
            cfg.rope_theta, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            causal=True, use_rope=False, kv_chunk=kv_chunk,
        )
        h = h + a
        c = gqa_apply(
            layer["cross_attn"], layernorm(layer["cross_norm"], h), positions,
            cfg.rope_theta, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            causal=False, use_rope=False, kv_source=enc_states,
            kv_chunk=kv_chunk,
        )
        h = h + c
        h = h + mlp(layer["mlp"], layernorm(layer["mlp_norm"], h))
        return actctx.shard_batch(h), None

    x, _ = pscan(body, x, params["decoder"])
    return layernorm(params["dec_final"], x)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    kv = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kv, _dt(cfg)), "v": jnp.zeros(kv, _dt(cfg))}


def decode_step(
    params, token: jnp.ndarray, cache: Dict[str, Any],
    position: jnp.ndarray, enc_states: jnp.ndarray, cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode with self-attn KV cache; cross-attn reads encoder
    states directly (they are small and static)."""
    B = token.shape[0]
    x = embed(params["embed"], token)
    x = x + embed(params["dec_pos"], position[:, None] % 8192)
    S_enc = enc_states.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(S_enc)[None, :], (B, S_enc))

    def body(h, xs):
        layer, ck, cv = xs
        a, nk, nv = decode_attention(
            layer["self_attn"], layernorm(layer["self_norm"], h),
            ck, cv, position, cfg.rope_theta,
            cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, use_rope=False,
        )
        h = h + a
        c = gqa_apply(
            layer["cross_attn"], layernorm(layer["cross_norm"], h),
            position[:, None], cfg.rope_theta,
            cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            causal=False, use_rope=False, kv_source=enc_states,
            kv_positions=enc_pos,
        )
        h = h + c
        h = h + mlp(layer["mlp"], layernorm(layer["mlp_norm"], h))
        return h, (nk, nv)

    x, (nk, nv) = pscan(body, x, (params["decoder"], cache["k"], cache["v"]))
    h = layernorm(params["dec_final"], x)
    logits = unembed(params["embed"], h)
    if cfg.padded_vocab_size != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits, {"k": nk, "v": nv}
