"""Scan wrapper with a global "cost mode" switch.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, ignoring the trip
count — so scan-over-layers (and kv-chunk / SSD-chunk / microbatch scans)
make FLOPs/bytes under-report by orders of magnitude.  For §Roofline,
``analysis/roofline.py`` re-lowers every cell in *cost mode*: scans fully
unrolled on polynomially scaled-down (num_layers, seq_len) configs, then
extrapolates exactly (every term is affine in L and at most quadratic in
S).  The production lowering keeps rolled loops (small HLO, fast compile,
true memory_analysis).

All model/step code must call ``pscan`` instead of ``jax.lax.scan``.
"""
from __future__ import annotations

import os
from typing import Any

import jax

_UNROLL = False


def set_unroll(flag: bool) -> None:
    global _UNROLL
    _UNROLL = flag


def unrolling() -> bool:
    return _UNROLL or os.environ.get("REPRO_UNROLL_SCANS", "") == "1"


def pscan(f, init, xs, length=None, **kw):
    if unrolling():
        kw = dict(kw)
        kw["unroll"] = True
    return jax.lax.scan(f, init, xs, length=length, **kw)
