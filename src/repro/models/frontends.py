"""Stub modality frontends (per assignment: [audio]/[vlm] entries specify
the transformer BACKBONE only; the frontend provides precomputed frame /
patch embeddings).

These generate deterministic synthetic embeddings for smoke tests and the
matching ShapeDtypeStructs for the dry-run (``launch/dryrun.input_specs``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def audio_frames(key, batch: int, cfg: ArchConfig) -> jnp.ndarray:
    """Whisper conv-frontend output: (B, encoder_seq, d_model)."""
    return (
        jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model)) * 0.02
    ).astype(jnp.dtype(cfg.dtype))


def vision_patches(key, batch: int, cfg: ArchConfig) -> jnp.ndarray:
    """InternViT patch embeddings projected to d_model: (B, N_vis, d)."""
    return (
        jax.random.normal(key, (batch, cfg.num_vision_tokens, cfg.d_model)) * 0.02
    ).astype(jnp.dtype(cfg.dtype))


def text_context(key, batch: int, cfg: ArchConfig) -> jnp.ndarray:
    """Encoded text prompt for the VDM (umT5 stub): (B, L_ctx, ctx_dim)."""
    return (
        jax.random.normal(key, (batch, cfg.context_len, cfg.context_dim)) * 0.02
    ).astype(jnp.float32)
