"""Mamba2 (SSD) blocks — chunked parallel scan for training/prefill,
recurrent state update for decode.

Per head h (P = headdim, N = state size):
    S_t = exp(A * dt_t) S_{t-1} + dt_t * B_t (x) x_t         (state update)
    y_t = C_t . S_t + D * x_t                                 (readout)

The chunked (SSD) algorithm splits the sequence into chunks of Q tokens:
intra-chunk terms use the masked quadratic form, inter-chunk terms carry
chunk summaries through a scan — O(S Q) work with O(S/Q) sequential steps.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from .scan_util import pscan

from .layers import (
    causal_conv1d,
    causal_conv1d_init,
    causal_conv1d_update,
    dense,
    dense_init,
    rmsnorm,
    rmsnorm_init,
)


def mamba2_init(key, d_model: int, state: int, headdim: int, expand: int = 2,
                conv_width: int = 4, groups: int = 1, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    heads = d_inner // headdim
    k_in, k_conv, k_out, k_dt = jax.random.split(key, 4)
    # in_proj emits [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (heads)]
    d_proj = 2 * d_inner + 2 * groups * state + heads
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 reference)
    u = jax.random.uniform(k_dt, (heads,))
    dt0 = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "in_proj": dense_init(k_in, d_model, d_proj, dtype),
        "conv": causal_conv1d_init(k_conv, d_inner + 2 * groups * state, conv_width, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(k_out, d_inner, d_model, dtype),
    }


def gated_linear_scan(x, log_decay, scale, B, C, chunk: int = 64,
                      factorized: bool = True):
    """Chunked scan for the gated linear recurrence

        S_t = exp(log_decay_t) S_{t-1} + scale_t * B_t (x) x_t
        y_t = C_t . S_t

    shared by Mamba2/SSD (log_decay = dt*A, scale = dt) and mLSTM
    (log_decay = logsigmoid(f), scale = exp(i)).  x: (b,s,h,p),
    log_decay/scale: (b,s,h), B,C: (b,s,g,n) with g | h.  Returns (b,s,h,p).

    Two intra-chunk formulations (§Perf iteration 1, EXPERIMENTS.md):

    * ``factorized=False`` — the textbook SSD form: materializes the decay
      tensor exp(cum_i - cum_j) of shape (b, nc, Q, Q, h).  For zamba2
      (h=80, Q=128) that is terabytes of HBM traffic per layer.
    * ``factorized=True`` — exp(cum_i - cum_j) = exp(cum_i - c) *
      exp(c - cum_j) with the per-chunk center c = (max+min)/2, so the
      (i, j) coupling reduces to the *group*-level C.B Gram matrix
      (b, nc, Q, Q, g) — h/g times smaller (80x for zamba2's g=1) — and
      two rank-1 per-token scalings.  Exponent args are clipped at +-60
      (clipped entries have decay ~e^-60: zero anyway); centering keeps
      the worst realistic |arg| ~ Q*max|dt*A|/2, which bounds chunk size
      (64 default: |arg| <= 52 for dt<=0.1, A>=-16).

    Group-level einsums never materialize B/C repeated to h heads
    (another h/g-fold traffic saving in the summaries/readout).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # reshape to (b, nc, Q, ...); heads split as (g, rep)
    xq = x.reshape(b, nc, chunk, g, rep, p).astype(jnp.float32)
    dtq = scale.reshape(b, nc, chunk, g, rep).astype(jnp.float32)
    Bq = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cq = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    a = log_decay.reshape(b, nc, chunk, g, rep).astype(jnp.float32)
    cum = jnp.cumsum(a, axis=2)                   # within-chunk cumulative
    total = cum[:, :, -1]                         # (b,nc,g,rep)

    Lmask = jnp.tril(jnp.ones((chunk, chunk), bool))
    if factorized:
        center = 0.5 * (cum.max(axis=2, keepdims=True)
                        + cum.min(axis=2, keepdims=True))
        a_i = jnp.exp(jnp.clip(cum - center, -60.0, 60.0))
        b_j = jnp.exp(jnp.clip(center - cum, -60.0, 60.0))
        cb = jnp.einsum("bcign,bcjgn->bcijg", Cq, Bq)        # (b,nc,Q,Q,g)
        cb = jnp.where(Lmask[None, None, :, :, None], cb, 0.0)
        v = xq * (dtq * b_j)[..., None]                      # (b,nc,Q,g,r,p)
        y_intra = jnp.einsum("bcijg,bcjgrp->bcigrp", cb, v)
        y_intra = y_intra * a_i[..., None]
    else:
        diff = cum[:, :, :, None] - cum[:, :, None, :]       # (b,nc,i,j,g,r)
        decay = jnp.where(Lmask[None, None, :, :, None, None],
                          jnp.exp(diff), 0.0)
        cb = jnp.einsum("bcign,bcjgn->bcijg", Cq, Bq)
        dx = dtq[..., None] * xq
        y_intra = jnp.einsum("bcijgr,bcijg,bcjgrp->bcigrp", decay, cb, dx)

    # --- chunk summaries: S_c = sum_j exp(total - cum_j) dt_j B_j (x) x_j
    w = jnp.exp(total[:, :, None] - cum)           # (b,nc,Q,g,rep)
    state_c = jnp.einsum("bcjgn,bcjgr,bcjgrp->bcgrnp", Bq, w * dtq, xq)

    # --- inter-chunk recurrence: S_c_in = exp(total_{c-1}) S_{c-1}_in + ...
    def scan_fn(S_prev, inp):
        tot_c, Sc = inp
        S_in = S_prev  # state *entering* this chunk
        S_out = jnp.exp(tot_c)[..., None, None] * S_prev + Sc
        return S_out, S_in

    S0 = jnp.zeros((b, g, rep, n, p), jnp.float32)
    _, S_in = pscan(
        scan_fn,
        S0,
        (total.transpose(1, 0, 2, 3), state_c.transpose(1, 0, 2, 3, 4, 5)),
    )
    S_in = S_in.transpose(1, 0, 2, 3, 4, 5)        # (b,nc,g,rep,n,p)

    # --- inter-chunk readout: y[i] += C_i . (exp(cum_i) S_in)
    y_inter = jnp.einsum("bcign,bcgrnp->bcigrp", Cq, S_in)
    y_inter = y_inter * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)
    return y[:, :s]


def mamba2_apply(params, x: jnp.ndarray, cfg, chunk: int = 64) -> jnp.ndarray:
    """Full-sequence forward.  x: (B, S, d_model).

    REPRO_SSD_NAIVE=1 selects the pre-optimization textbook SSD path
    (chunk 128, materialized per-head decay) — kept for §Perf A/B
    measurement and as a numerical cross-check."""
    import os

    naive = os.environ.get("REPRO_SSD_NAIVE", "") == "1"
    if naive:
        chunk = 128
    b, s, _ = x.shape
    heads = params["A_log"].shape[0]
    p = cfg.ssm_headdim
    g, n = cfg.ssm_groups, cfg.ssm_state
    d_inner = heads * p
    proj = dense(params["in_proj"], x)
    z, xbc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * g * n], axis=-1
    )
    xbc = jax.nn.silu(causal_conv1d(params["conv"], xbc))
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )  # (b,s,h)
    A = -jnp.exp(params["A_log"])
    y = gated_linear_scan(
        xin.reshape(b, s, heads, p),
        dt * A[None, None, :],
        dt,
        B.reshape(b, s, g, n),
        C.reshape(b, s, g, n),
        chunk=chunk,
        factorized=not naive,
    )
    y = y + params["D"][None, None, :, None] * xin.reshape(b, s, heads, p).astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y)


def mamba2_init_cache(batch: int, cfg, dtype=jnp.float32):
    heads = cfg.ssm_expand * cfg.d_model // cfg.ssm_headdim
    conv_ch = cfg.ssm_expand * cfg.d_model + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, heads, cfg.ssm_state, cfg.ssm_headdim), dtype),
    }


def mamba2_decode(params, x_t: jnp.ndarray, cache: dict, cfg):
    """Single-token recurrent update.  x_t: (B, 1, d_model)."""
    b = x_t.shape[0]
    heads = params["A_log"].shape[0]
    p, g, n = cfg.ssm_headdim, cfg.ssm_groups, cfg.ssm_state
    d_inner = heads * p
    proj = dense(params["in_proj"], x_t)[:, 0]       # (b, d_proj)
    z, xbc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * g * n], axis=-1
    )
    xbc, conv_state = causal_conv1d_update(params["conv"], xbc, cache["conv"])
    xbc = jax.nn.silu(xbc)
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,h)
    A = -jnp.exp(params["A_log"])
    xin_h = xin.reshape(b, heads, p).astype(jnp.float32)
    Bh = jnp.repeat(B.reshape(b, g, n), heads // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C.reshape(b, g, n), heads // g, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])                  # (b,h)
    S = cache["ssm"] * decay[..., None, None] + (
        dt[..., None, None] * Bh[..., :, None] * xin_h[..., None, :]
    )  # (b,h,n,p)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, S) + params["D"][None, :, None] * xin_h
    y = y.reshape(b, 1, d_inner).astype(x_t.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z)[:, None, :])
    out = dense(params["out_proj"], y)
    return out, {"conv": conv_state, "ssm": S}
