"""Trace-time activation-sharding context.

Model code is mesh-agnostic; the step builders (dry-run, train/serve
drivers) activate this context so the batch dimension of activations is
pinned to the data axes throughout the network.  Without the pin, GSPMD
may choose a parameter-stationary layout and **replicate activations**
across the data axis (observed on zamba2 train: per-device residual
stacks at global-batch size — §Perf A, EXPERIMENTS.md).

Usage:
    with actctx.batch_axes(("pod", "data")):
        lowered = jax.jit(step).lower(...)
Inside model code: ``x = actctx.shard_batch(x)`` (no-op when inactive).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_AXES: Optional[Tuple[str, ...]] = None
_ATTN_SEQ: Optional[str] = None


@contextlib.contextmanager
def batch_axes(axes: Optional[Tuple[str, ...]],
               attn_seq: Optional[str] = None):
    global _AXES, _ATTN_SEQ
    prev, prev_seq = _AXES, _ATTN_SEQ
    _AXES = tuple(axes) if axes else None
    _ATTN_SEQ = attn_seq
    try:
        yield
    finally:
        _AXES, _ATTN_SEQ = prev, prev_seq


def active() -> bool:
    return _AXES is not None


def shard_batch(x):
    """Constrain dim 0 of ``x`` to the data axes (no-op outside context).

    When sequence-parallel attention is active, rank-3+ hiddens
    (B, S, ...) stay sequence-sharded over the tp axis at layer
    boundaries too — re-gathering the sequence every layer costs an
    all-gather of the full activation per layer (261 GB/step on llama4
    prefill, §Perf B iteration 2)."""
    if _AXES is None or x.ndim == 0:
        return x
    if _ATTN_SEQ is not None and x.ndim >= 3:
        spec = P(_AXES, _ATTN_SEQ, *([None] * (x.ndim - 2)))
    else:
        spec = P(_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_attn_q(q):
    """Sequence-parallel attention for head counts that don't divide the
    TP degree (llama4's 40H/8KV over 16): shard the *query sequence* over
    the tp axis and replicate KV there — otherwise GSPMD partial-shards
    the score contraction and all-reduces quadratic (B,G,Sq,Skv) tensors
    (observed 2 TB/step on llama4 prefill — §Perf B).  q: (B, S, H, D)."""
    if _ATTN_SEQ is None:
        return q
    return jax.lax.with_sharding_constraint(
        q, P(_AXES, _ATTN_SEQ, None, None))


def shard_attn_kv(kv):
    if _ATTN_SEQ is None:
        return kv
    return jax.lax.with_sharding_constraint(
        kv, P(_AXES, None, None, None))


def shard_attn_out(out):
    """(B, S, H*D) attention output, still sequence-sharded."""
    if _ATTN_SEQ is None:
        return out
    return jax.lax.with_sharding_constraint(
        out, P(_AXES, _ATTN_SEQ, None))
