"""Explicit collective patterns the partitioner can't be trusted to find.

``seq_parallel_decode_attention``: flash-decode for batch=1 long-context —
the KV cache is sharded over a mesh axis along *sequence*; each shard
computes a partial softmax (max, sum, weighted values) and the combine is
two tiny psums.  This converts an idle data axis into K-fold attention
parallelism for the 500k-token cells (§Perf optimization for zamba2 /
h2o-danube long_500k).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def _local_partial(q, k, v, kv_pos, position, window: int):
    """Per-shard partial attention.  q: (B,1,H,D); k/v: (B,S_loc,KV,D);
    kv_pos: (B, S_loc) global positions of this shard's slots.
    Returns (m (B,KV,G), l (B,KV,G), acc (B,KV,G,D))."""
    B, _, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(float(D))
    ok = kv_pos <= position[:, None]
    if window > 0:
        ok &= kv_pos > (position[:, None] - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return m, l, acc


def seq_parallel_decode_attention(
    q: jnp.ndarray,            # (B, 1, H, D) current-token query (RoPE'd)
    k_local: jnp.ndarray,      # (B, S_local, KV, D) this shard's KV slice
    v_local: jnp.ndarray,
    kv_pos_local: jnp.ndarray, # (B, S_local) global positions (incl. new tok)
    position: jnp.ndarray,     # (B,) current decode index
    axis_name: str,
    window: int = 0,
) -> jnp.ndarray:
    """Flash-decode combine across a sequence-sharded cache.

    Communication: 2 psums of (B, KV, G) + one of (B, KV, G, D) —
    O(B*H*D) bytes, independent of context length."""
    B, _, H, D = q.shape
    m, l, acc = _local_partial(q, k_local, v_local, kv_pos_local, position, window)
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    acc_glob = jax.lax.psum(acc * corr[..., None], axis_name)
    out = acc_glob / jnp.maximum(l_glob, 1e-37)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)
