"""Explicit collective patterns the partitioner can't be trusted to find.

``seq_parallel_decode_attention``: flash-decode for batch=1 long-context —
the KV cache is sharded over a mesh axis along *sequence*; each shard
computes a partial softmax (max, sum, weighted values) and the combine is
two tiny psums.  This converts an idle data axis into K-fold attention
parallelism for the 500k-token cells (§Perf optimization for zamba2 /
h2o-danube long_500k).

``halo_spec`` / ``halo_exchange``: the LP fast-path collective.  Instead of
psumming a full global-latent-sized buffer per denoising step (every
position is owned by exactly one rank's core, yet the psum ships all of
them K ways), each rank sends its neighbors only the **overlap slabs** of
its weighted prediction via ``ppermute``, accumulates received slabs into
its core slice, and the replicated latent is reassembled from an
all-gather of core slices.  Wire bytes drop from 2(K-1)/K * S_z per device
to ~(K-1)/K * S_z + halo slabs (see ``core/comm_model.comm_lp_halo``).

All halo geometry is static Python derived from the uniform partition
plan, including the edge-clamped windows that can reach cores at offset
|d| >= 2 when the overlap ratio is large — the transfer schedule is exact,
not a nearest-neighbor approximation.

``wire_shard_slice`` / ``wire_unshard``: the hierarchy-aware wire split.
On a 2D ``(lp, tp)`` mesh every tp rank holds a replica of each slab, so
shipping the full slab on all T parallel lp rings moves T identical
copies across the (slow) inter-group links.  Sharding the wire over the
tp axis — each tp rank ppermutes only its 1/T chunk, receivers reassemble
with one intra-group all-gather — cuts inter-group bytes T-fold at the
price of a cheap intra-group collective.  The split is a pure transport
rearrangement (flatten, zero-pad to T equal chunks, concatenate back),
so sharded and unsharded engines are bit-identical.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def _local_partial(q, k, v, kv_pos, position, window: int):
    """Per-shard partial attention.  q: (B,1,H,D); k/v: (B,S_loc,KV,D);
    kv_pos: (B, S_loc) global positions of this shard's slots.
    Returns (m (B,KV,G), l (B,KV,G), acc (B,KV,G,D))."""
    B, _, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(float(D))
    ok = kv_pos <= position[:, None]
    if window > 0:
        ok &= kv_pos > (position[:, None] - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return m, l, acc


def seq_parallel_decode_attention(
    q: jnp.ndarray,            # (B, 1, H, D) current-token query (RoPE'd)
    k_local: jnp.ndarray,      # (B, S_local, KV, D) this shard's KV slice
    v_local: jnp.ndarray,
    kv_pos_local: jnp.ndarray, # (B, S_local) global positions (incl. new tok)
    position: jnp.ndarray,     # (B,) current decode index
    axis_name: str,
    window: int = 0,
) -> jnp.ndarray:
    """Flash-decode combine across a sequence-sharded cache.

    Communication: 2 psums of (B, KV, G) + one of (B, KV, G, D) —
    O(B*H*D) bytes, independent of context length."""
    B, _, H, D = q.shape
    m, l, acc = _local_partial(q, k_local, v_local, kv_pos_local, position, window)
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    acc_glob = jax.lax.psum(acc * corr[..., None], axis_name)
    out = acc_glob / jnp.maximum(l_glob, 1e-37)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ------------------------------------------------------- wire sharding
def wire_shard_len(n_elems: int, shard_size: int) -> int:
    """Per-rank chunk length of an ``n_elems`` flat wire split
    ``shard_size`` ways (last chunk zero-padded)."""
    return -(-n_elems // shard_size)


def wire_shard_slice(x: jnp.ndarray, shard_rank: jnp.ndarray,
                     shard_size: int) -> jnp.ndarray:
    """This rank's 1/T chunk of a flat view of ``x``.

    ``shard_rank`` is the traced tp-axis index; the chunk length is the
    static ``wire_shard_len`` so every rank ships a uniform shape (the
    tail chunk carries zero padding).  Flattening keeps the split exact
    for any slab shape and any wire dtype, including int4's packed last
    axis.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    s = wire_shard_len(n, shard_size)
    if s * shard_size != n:
        flat = jnp.pad(flat, (0, s * shard_size - n))
    return jax.lax.dynamic_slice_in_dim(flat, shard_rank * s, s, 0)


def wire_unshard(chunks: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Reassemble a ``(T, s)`` stack of gathered chunks into the logical
    wire of ``shape`` (drops the tail padding).  Exact inverse of T
    ``wire_shard_slice`` calls."""
    n = 1
    for d in shape:
        n *= d
    return chunks.reshape(-1)[:n].reshape(shape)


def wire_unshard_rows(chunks: jnp.ndarray,
                      shape: Tuple[int, ...]) -> jnp.ndarray:
    """Reassemble a ``(T, K, s)`` stack of gathered chunk *columns* (one
    tp gather of a K-row lp gather) into the ``(K,) + shape`` wire
    table, dropping each row's tail padding — the batched
    :func:`wire_unshard`."""
    K = chunks.shape[1]
    n = 1
    for d in shape:
        n *= d
    return jnp.swapaxes(chunks, 0, 1).reshape(K, -1)[:, :n].reshape(
        (K,) + tuple(shape)
    )


def _id(x):
    return x


def sharded_ppermute(
    x: jnp.ndarray,
    axis_name: str,
    perm,
    shard_axis: str,
    shard_size: int,
    pin=_id,
) -> jnp.ndarray:
    """One ppermute with the payload sharded over ``shard_axis``: each
    shard rank ships its 1/T chunk across ``axis_name``, then an
    intra-group all-gather reassembles the full message at the
    receiver.  ``pin`` (the codec layer's optimization barrier) wraps
    every tensor entering/leaving a collective so compact wire dtypes
    survive XLA's simplifier.  This is THE sharded point-to-point
    transport — every engine routes through here so the byte model and
    the compiled HLO can never diverge per call site."""
    chunk = wire_shard_slice(x, jax.lax.axis_index(shard_axis), shard_size)
    got = jax.lax.ppermute(pin(chunk), axis_name, perm)
    chunks = jax.lax.all_gather(pin(got), shard_axis, axis=0, tiled=False)
    return wire_unshard(pin(chunks), x.shape)


def sharded_all_gather(
    x: jnp.ndarray,
    axis_name: str,
    shard_axis: str,
    shard_size: int,
    pin=_id,
) -> jnp.ndarray:
    """Ring all-gather over ``axis_name`` with each contribution sharded
    over ``shard_axis``: the slow-tier gather moves ``(K, 1/T chunk)``,
    one intra-group all-gather collects the chunk columns, and every
    device reassembles the full ``(K,) + x.shape`` table locally.  The
    sharded twin of ``jax.lax.all_gather(x, axis_name)``."""
    chunk = wire_shard_slice(x, jax.lax.axis_index(shard_axis), shard_size)
    lp = jax.lax.all_gather(pin(chunk), axis_name, axis=0, tiled=False)
    tp = jax.lax.all_gather(pin(lp), shard_axis, axis=0, tiled=False)
    return wire_unshard_rows(pin(tp), x.shape)


# ------------------------------------------------------------ halo exchange
@dataclasses.dataclass(frozen=True)
class HaloTransfer:
    """One ``ppermute`` round: every rank ``j`` with a nonempty overlap
    between its window and the core of rank ``j + offset`` sends that slab.

    Slabs are padded to ``length`` (the max over senders) because ppermute
    requires a uniform shape; ``src_len`` masks the padding to zero before
    the send.  All positions are *latent units* — ``src_start`` in the
    sender's window coordinates, ``dst_start`` in the receiver's core
    coordinates.  Ranks without a peer at this offset send a zero slab that
    no one receives and receive ppermute's implicit zeros.
    """

    offset: int
    length: int
    perm: Tuple[Tuple[int, int], ...]
    src_start: Tuple[int, ...]
    src_len: Tuple[int, ...]
    dst_start: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static transfer schedule for halo-exchange LP reconstruction."""

    num_partitions: int
    window: int
    extent: int
    starts: Tuple[int, ...]
    core_start: Tuple[int, ...]
    core_end: Tuple[int, ...]
    core_pad: int                      # max core length (all-gather shard)
    transfers: Tuple[HaloTransfer, ...]

    @property
    def core_len(self) -> Tuple[int, ...]:
        return tuple(e - s for s, e in zip(self.core_start, self.core_end))

    @property
    def max_transfer(self) -> int:
        return max((t.length for t in self.transfers), default=0)

    @property
    def pad(self) -> int:
        """Zero-padding a window buffer needs so every slab slice is
        in-bounds (dynamic_slice clamping would silently corrupt data)."""
        return max(self.core_pad, self.max_transfer)


def halo_spec(plan) -> HaloSpec:
    """Build the exact transfer schedule from a uniform-window plan.

    ``plan`` needs ``num_partitions``, ``window``, ``extent``, ``starts``,
    ``core_start``, ``core_end`` (``core/uniform.UniformPlan``).  For every
    rank pair (j, k) the slab is ``window_j ∩ core_k``; pairs are grouped
    by offset ``k - j`` so each group is one ppermute.  Interior ranks only
    talk to +-1 neighbors; clamped edge windows at large overlap ratios
    produce the occasional |offset| >= 2 round, which stays exact here.
    """
    K = plan.num_partitions
    core_len = [plan.core_end[k] - plan.core_start[k] for k in range(K)]
    transfers = []
    for d in [x for x in range(-(K - 1), K) if x != 0]:
        pairs = []
        for j in range(K):
            k = j + d
            if not 0 <= k < K:
                continue
            lo = max(plan.starts[j], plan.core_start[k])
            hi = min(plan.starts[j] + plan.window, plan.core_end[k])
            if hi > lo:
                pairs.append((j, k, lo, hi))
        if not pairs:
            continue
        length = max(hi - lo for (_, _, lo, hi) in pairs)
        src_start, src_len, dst_start = [0] * K, [0] * K, [0] * K
        perm = []
        for j, k, lo, hi in pairs:
            perm.append((j, k))
            src_start[j] = lo - plan.starts[j]
            src_len[j] = hi - lo
            dst_start[k] = lo - plan.core_start[k]
        transfers.append(HaloTransfer(
            offset=d, length=length, perm=tuple(perm),
            src_start=tuple(src_start), src_len=tuple(src_len),
            dst_start=tuple(dst_start),
        ))
    return HaloSpec(
        num_partitions=K,
        window=plan.window,
        extent=plan.extent,
        starts=tuple(plan.starts),
        core_start=tuple(plan.core_start),
        core_end=tuple(plan.core_end),
        core_pad=max(core_len),
        transfers=tuple(transfers),
    )


def halo_exchange(
    wpred: jnp.ndarray,
    spec: HaloSpec,
    rank: jnp.ndarray,
    axis_name: str,
    eager_sends: bool = False,
    shard_axis: Optional[str] = None,
    shard_size: int = 1,
) -> jnp.ndarray:
    """Cross-rank reduction of overlapping window predictions, halo-only.

    ``wpred``: this rank's *weighted* prediction with the partition dim
    first, zero-padded at the end by at least ``spec.pad`` rows.  ``rank``
    is the traced lp-axis index.  Returns a ``(core_pad + max_transfer,
    ...)`` accumulator whose first ``core_len[rank]`` rows hold the full
    sum over every rank's contribution to this rank's core positions
    (unnormalized); rows beyond that are garbage by construction.

    Communication: one ppermute of slab size per transfer round — O(halo)
    bytes instead of the O(S_z) psum of the naive reconstruction.

    ``eager_sends`` issues every ppermute round up front, before any
    accumulation: the rounds carry no data dependence on each other, so
    XLA's async collective scheduler can start them all while the local
    own-core copy (and, on the hybrid mesh, the tail of the intra-group
    Phi_m forward that produces late rows of ``wpred``) is still in
    flight.  The default ordering interleaves send/accumulate per round,
    which serializes the rounds through the accumulator chain.

    ``shard_axis`` / ``shard_size`` (the hybrid mesh's tp axis and size)
    shard every slab over the tp axis: each tp rank ppermutes only its
    1/T chunk across the group boundary and the receiver reassembles
    the slab with one intra-group all-gather before depositing.  Slab
    values are tp-replicated on the hybrid mesh, so the result is
    bit-identical to the unsharded exchange — only the wire layout
    changes (inter-group bytes drop T-fold).
    """
    K = spec.num_partitions
    acc_len = spec.core_pad + spec.max_transfer
    trail = (1,) * (wpred.ndim - 1)
    acc = jnp.zeros((acc_len,) + wpred.shape[1:], wpred.dtype)
    sharded = shard_axis is not None and shard_size > 1

    def send(t: HaloTransfer) -> jnp.ndarray:
        slab = jax.lax.dynamic_slice_in_dim(
            wpred, jnp.asarray(t.src_start)[rank], t.length, 0
        )
        valid = jnp.arange(t.length) < jnp.asarray(t.src_len)[rank]
        slab = slab * valid.reshape((t.length,) + trail).astype(slab.dtype)
        if sharded:
            return sharded_ppermute(slab, axis_name, t.perm, shard_axis,
                                    shard_size)
        return jax.lax.ppermute(slab, axis_name, t.perm)

    def deposit(acc, t: HaloTransfer, got: jnp.ndarray) -> jnp.ndarray:
        dst = jnp.asarray(t.dst_start)[rank]
        cur = jax.lax.dynamic_slice_in_dim(acc, dst, t.length, 0)
        return jax.lax.dynamic_update_slice_in_dim(acc, cur + got, dst, 0)

    received = [send(t) for t in spec.transfers] if eager_sends else None
    # own window -> own core (no communication)
    own_off = jnp.asarray([spec.core_start[k] - spec.starts[k] for k in range(K)])
    own = jax.lax.dynamic_slice_in_dim(wpred, own_off[rank], spec.core_pad, 0)
    acc = jax.lax.dynamic_update_slice_in_dim(acc, own, 0, 0)
    for ti, t in enumerate(spec.transfers):
        got = received[ti] if eager_sends else send(t)
        acc = deposit(acc, t, got)
    return acc
