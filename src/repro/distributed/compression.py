"""Gradient compression for the slow cross-pod axis.

Scheme: bf16 all-reduce with fp32 error feedback — gradients cross the
inter-pod links in bf16, and the quantization residual is carried and
re-injected so the *accumulated* update stays unbiased (EF14).

This module is now a thin wrapper: the error-feedback round-trip has
been generalized into the wire-codec subsystem
(``comm.residual.ef_roundtrip`` over any ``comm.codecs.Codec``), which
also powers the residual-compressed halo exchange for LP serving.  The
original gradient API is kept for the training path.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.codecs import Bf16Codec
from repro.comm.residual import ef_roundtrip

_BF16 = Bf16Codec()


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One leaf: add residual, round-trip through bf16, new residual."""
    return ef_roundtrip(_BF16, g, err)


def compressed_psum(grads, err_state, axis_name: Optional[str]):
    """psum gradients over ``axis_name`` in bf16 with error feedback.

    With axis_name=None (single-pod) this is a pure local round-trip —
    still applied so numerics are identical across pod counts.
    Returns (reduced_grads_fp32, new_err_state).
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    sent, new_err = [], []
    for g, e in zip(flat_g, flat_e):
        s, ne = compress_decompress(g, e)
        sent.append(s)
        new_err.append(ne)
    if axis_name is not None:
        sent = [jax.lax.pmean(s, axis_name) for s in sent]
    return jax.tree.unflatten(treedef, sent), jax.tree.unflatten(treedef, new_err)
