"""Gradient compression for the slow cross-pod axis (beyond-paper,
per-assignment distributed-optimization tricks).

Scheme: bf16 all-reduce with fp32 error feedback.  Gradients are cast to
bf16 before crossing the inter-pod links (halving the bytes of the
dominant collective); the quantization residual is kept host-side and
added back into the next step's gradient, so the *accumulated* update is
unbiased (error-feedback / EF14 construction).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One leaf: add residual, round-trip through bf16, new residual."""
    corrected = g.astype(jnp.float32) + err
    sent = corrected.astype(jnp.bfloat16)          # what crosses the pod link
    back = sent.astype(jnp.float32)
    return back, corrected - back


def compressed_psum(grads, err_state, axis_name: Optional[str]):
    """psum gradients over ``axis_name`` in bf16 with error feedback.

    With axis_name=None (single-pod) this is a pure local round-trip —
    still applied so numerics are identical across pod counts.
    Returns (reduced_grads_fp32, new_err_state).
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    sent, new_err = [], []
    for g, e in zip(flat_g, flat_e):
        s, ne = compress_decompress(g, e)
        sent.append(s)
        new_err.append(ne)
    if axis_name is not None:
        sent = [jax.lax.pmean(s, axis_name) for s in sent]
    return jax.tree.unflatten(treedef, sent), jax.tree.unflatten(treedef, new_err)
