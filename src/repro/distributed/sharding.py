"""Parameter and batch PartitionSpec rules (FSDP / TP / EP / vocab-parallel).

Specs are assigned by key-path pattern over the param pytree, producing a
matching pytree of ``PartitionSpec``.  Stacked-layer leading axes (scan over
layers) are padded with ``None`` on the left automatically.

Logical mapping (mesh axes "pod", "data", "model"):
  * batch / LP groups   -> ("pod", "data")
  * tensor parallel     -> "model"   (heads, d_ff, vocab, experts)
  * FSDP (ZeRO-3)       -> "data"    (optional; on for training & big-model
                                       serving so 405B-class fits HBM)

Baseline philosophy: only *boundary* shardings (params + inputs + outputs)
are pinned; internal activation layout is left to GSPMD.  §Perf iterations
add explicit constraints where the partitioner misbehaves.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ParallelConfig

# (regex on '/'-joined path, spec for the TRAILING dims)
# fsdp and tp placeholders resolved against the ParallelConfig.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / unembedding: vocab over tp (vocab-parallel logits)
    (r"(^|/)embed/emb$", ("tp", "fsdp")),
    (r"(^|/)lm_head/emb$", ("tp", "fsdp")),
    (r"(^|/)dec_pos/emb$", (None, None)),
    # MoE first — the generic wi/wg/wo rules below would shadow these
    # (experts over tp = expert parallelism, FSDP inside each expert)
    (r"/moe/router/w$", ("fsdp", None)),
    (r"/moe/(wi|wg)/w$", ("tp", "fsdp", None)),
    (r"/moe/wo/w$", ("tp", None, "fsdp")),
    # attention projections
    (r"/(q|k|v)/w$", ("fsdp", "tp")),
    (r"/o/w$", ("tp", "fsdp")),
    # dense MLP
    (r"/(wi|wg)/w$", ("fsdp", "tp")),
    (r"/wo/w$", ("tp", "fsdp")),
    # zamba2 LoRA adapters
    (r"/lora.*/a/w$", ("fsdp", None)),
    (r"/lora.*/b/w$", (None, "tp")),
    # mamba2: keep the fused in_proj output replicated (mixed z|x|B|C|dt
    # splits don't align with shard boundaries — §Perf candidate), shard
    # the inner->model projection input over tp
    (r"/in_proj/w$", ("fsdp", None)),
    (r"/out_proj/w$", (None, "fsdp")),
    # xLSTM
    (r"/up/w$", ("fsdp", "tp")),
    (r"/down/w$", ("tp", "fsdp")),
    (r"/wx/w$", ("fsdp", "tp")),
    (r"/gates/w$", (None, None)),
    (r"/rec$", (None, None, None)),
    # DiT
    (r"/patch_embed/w$", (None, "tp")),
    (r"/text_proj/w$", (None, "tp")),
    (r"/head/w$", ("tp", None)),
    (r"/ada/w$", (None, "tp")),
    (r"/time_mlp/w[12]/w$", (None, None)),
    # vision stub projection
    (r"/vision_proj/w$", ("fsdp", "tp")),
)


def _resolve(ax: Optional[str], parallel: ParallelConfig) -> Optional[Any]:
    if ax == "tp":
        return parallel.tp_axis
    if ax == "fsdp":
        return parallel.fsdp_axis
    return ax


def spec_for_path(path: str, ndim: int, parallel: ParallelConfig) -> P:
    for pat, trailing in _RULES:
        if re.search(pat, path):
            axes = [_resolve(a, parallel) for a in trailing]
            if len(axes) > ndim:
                axes = axes[len(axes) - ndim :]
            pad = [None] * (ndim - len(axes))
            return P(*pad, *axes)
    return P(*([None] * ndim))  # scalars / norms / biases replicate


def _path_of(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_or_shapes, parallel: ParallelConfig):
    """Pytree of PartitionSpec matching ``params_or_shapes`` (arrays or
    ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: spec_for_path(_path_of(kp), leaf.ndim, parallel),
        params_or_shapes,
    )


def param_shardings(params_or_shapes, parallel: ParallelConfig, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_or_shapes, parallel),
        is_leaf=lambda x: isinstance(x, P),
    )


def _dp(parallel: ParallelConfig, mesh: Mesh):
    axes = tuple(a for a in parallel.dp_axes if a in mesh.axis_names)
    return axes if axes else None


def batch_specs(kind: str, parallel: ParallelConfig, mesh: Mesh, cfg: ArchConfig):
    """Input PartitionSpecs per workload kind (pytree matching the batch)."""
    dp = _dp(parallel, mesh)
    if kind == "train":
        spec = {"tokens": P(dp, None), "labels": P(dp, None)}
        if cfg.family == "vlm":
            spec["vision_embeds"] = P(dp, None, None)
        if cfg.family == "audio":
            spec["frames"] = P(dp, None, None)
        return spec
    if kind == "prefill":
        spec = {"tokens": P(dp, None)}
        if cfg.family == "vlm":
            spec["vision_embeds"] = P(dp, None, None)
        if cfg.family == "audio":
            spec["frames"] = P(dp, None, None)
        return spec
    if kind == "decode":
        return {"token": P(dp, None), "position": P(dp)}
    if kind == "vdm_generate":
        # latent replicated over the LP axis (slicing is local); context too
        return {"latent": P(), "t": P(), "context": P()}
    raise ValueError(kind)


def cache_specs(cfg: ArchConfig, parallel: ParallelConfig, mesh: Mesh,
                seq_axis: Optional[str] = None, kv_mode: str = "kv"):
    """KV/state-cache PartitionSpecs.

    Layout (L, B, S, KV, D): batch over dp axes, kv heads over tp (or
    head_dim when KV doesn't divide the tp degree — ``kv_mode="dim"``).
    For long-context batch=1 decode, ``seq_axis`` shards the *sequence*
    dim of attention caches instead (sequence-parallel decode)."""
    dp = _dp(parallel, mesh)
    tp = parallel.tp_axis

    def kv_spec(ndim: int) -> P:
        # (..., B, S, KV, D)
        kv_ax, d_ax = (tp, None) if kv_mode == "kv" else (None, tp)
        if seq_axis is not None:
            trail = (None, seq_axis, kv_ax, d_ax)
        else:
            trail = (dp, None, kv_ax, d_ax)
        pad = [None] * (ndim - 4)
        return P(*pad, *trail)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "audio"):
        return {"k": kv_spec(5), "v": kv_spec(5)}
    if fam == "hybrid":
        return {
            "mamba": {
                # (g, attn_every, B, ...) conv/ssm states: batch over dp
                "conv": P(None, None, dp, None, None),
                "ssm": P(None, None, dp, None, None, None),
            },
            "k": kv_spec(5),
            "v": kv_spec(5),
        }
    if fam == "ssm":
        return {
            "mlstm": {
                "conv": P(None, None, dp, None, None),
                "C": P(None, None, dp, None, None, None),
                "n": P(None, None, dp, None, None),
                "m": P(None, None, dp, None),
            },
            "slstm": {
                "c": P(None, dp, None, None),
                "n": P(None, dp, None, None),
                "m": P(None, dp, None),
                "h": P(None, dp, None, None),
            },
        }
    raise ValueError(fam)
