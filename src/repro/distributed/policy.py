"""Per-(arch x shape) parallelization policy.

Decides dp/fsdp/tp/remat/microbatch/optimizer for each dry-run cell, using
napkin memory math against the v5e budget (16 GB HBM/chip):

* train: always FSDP (ZeRO-3) over "data"; Adafactor + per-layer remat +
  microbatch accumulation for >=100B-param models (Adam fp32 moments for
  405B are ~3.2 TB — they cannot fit a 256-chip pod).
* serve: TP over "model"; FSDP also on when bf16 params / 16 > ~12 GB
  (weight-gathered serving for 405B-class).
* decode caches shard KV-heads over "model" when divisible, else head_dim.
"""
from __future__ import annotations

import jax

from repro.configs.base import ArchConfig, ParallelConfig, ShapeConfig

GiB = 1024**3


def count_params(cfg: ArchConfig, model=None) -> int:
    """Exact param count via eval_shape on init (no allocation).

    Pure-python product — jnp.prod would overflow int32 on 5e9-element
    expert tensors (llama4's (128, 5120, 8192) stacks)."""
    import math

    from repro import models

    model = model or models.build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(shapes))


def active_params(cfg: ArchConfig, total: int) -> int:
    """Active params per token (MoE: top_k of num_experts experts)."""
    if not cfg.is_moe:
        return total
    expert = 3 * cfg.d_model * cfg.d_ff_expert
    pad = cfg.num_experts + (
        0 if cfg.num_experts % 16 == 0 else 16 - cfg.num_experts % 16
    )
    all_experts = cfg.num_layers * pad * expert
    active_experts = cfg.num_layers * cfg.experts_top_k * expert
    return total - all_experts + active_experts


def plan_parallel(
    cfg: ArchConfig, shape: ShapeConfig, multi_pod: bool = False,
    n_params: int = 0,
) -> ParallelConfig:
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    n = n_params or count_params(cfg)
    big = n > 100e9
    param_bytes = 2 * n  # bf16

    if shape.kind == "train":
        dp_size = 16 * (2 if multi_pod else 1)
        per_replica = max(shape.global_batch // dp_size, 1)
        # target <= 2 sequences per device per microbatch for 100B-class
        micro = 1
        if big:
            micro = max(per_replica // 1, 1)
        elif n > 10e9:
            micro = max(per_replica // 4, 1)
        return ParallelConfig(
            dp_axes=dp_axes,
            fsdp_axis="data",
            remat="full" if n > 1.5e9 else "none",
            microbatch=micro,
            optimizer="adafactor" if big else "adamw",
        )

    # serving
    fsdp = "data" if param_bytes / 16 > 12 * GiB else None
    seq_axis = None
    return ParallelConfig(
        dp_axes=dp_axes,
        fsdp_axis=fsdp,
        remat="none",
        microbatch=1,
        optimizer="adamw",
        seq_axis=seq_axis,
    )


def cache_head_or_dim(cfg: ArchConfig, tp_size: int = 16) -> str:
    """Shard decode caches over KV heads when divisible, else head_dim."""
    return "kv" if cfg.num_kv_heads % tp_size == 0 else "dim"
