"""Hierarchical hybrid parallelism (paper supplementary §11).

Cluster of K devices partitioned into M disjoint groups (Eq. 42);
inter-group LP partitions the latent across groups with the same
patch-aligned overlapping machinery (K -> M in Eqs. 7-10), and each group
runs an arbitrary intra-group operator Phi_m (Eq. 43) — NMP / TP / PP /
plain jit — as a black box over its sub-latent.

Two compositions live here:

* :func:`lp_forward_halo_hybrid` — the production engine on a 2D
  ``(lp, tp)`` mesh: the PR 1 halo schedule (overlap-slab ppermutes +
  core all-gather, full ``comm/`` codec support including residual state)
  runs over the **group axis**, while each group executes the
  tensor-parallel DiT forward as a black-box Phi_m over the ``tp`` axis.
  The halo ppermute rounds are issued eagerly (no data dependence between
  rounds) so XLA's async collective scheduler can overlap them with the
  tail of the intra-group forward.
* :func:`hybrid_forward` — the single-process reference composition
  (explicit Phi_m list, paper-exact partitions) used by tests and the
  hybrid example, plus the :class:`GroupLayout` bookkeeping of Eq. 42.

Mesh contract for the SPMD engine (see docs/hybrid_lp_tp.md):

* the mesh has an LP **group** axis of size M == plan.num_partitions and
  a **tp** axis of size T >= 1 (extra axes are tolerated and treated as
  replicated);
* ``z`` is replicated everywhere; ``denoise_fn`` runs per device inside
  the manual (shard_map) region and may use any ``tp_axis`` collectives
  internally (Megatron psums, CFG-pair gathers, ...), but must return the
  same value on every tp rank of a group (end with a tp reduction);
* every LP collective names only ``lp_axis``, so each tp rank exchanges
  with its same-tp peer in the neighbor groups — per-device wire bytes
  are exactly the 1D halo model (``comm_model.comm_lp_halo_hybrid``),
  independent of T.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from .lp_step import lp_forward
from .partition import PartitionPlan, plan_partition
from .uniform import UniformPlan


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """K devices -> M disjoint groups (Eq. 42 constraints)."""

    num_devices: int
    num_groups: int
    groups: Tuple[Tuple[int, ...], ...]

    def validate(self) -> None:
        seen = set()
        for g in self.groups:
            assert g, "empty group"
            assert not (seen & set(g)), "groups must be disjoint"
            seen |= set(g)
        assert seen == set(range(self.num_devices)), "groups must cover G"


def make_groups(num_devices: int, num_groups: int) -> GroupLayout:
    if num_devices % num_groups != 0:
        raise ValueError(f"K={num_devices} must split into M={num_groups}")
    per = num_devices // num_groups
    groups = tuple(
        tuple(range(m * per, (m + 1) * per)) for m in range(num_groups)
    )
    layout = GroupLayout(num_devices, num_groups, groups)
    layout.validate()
    return layout


def hybrid_forward(
    intra_group_ops: Sequence[Callable[[jnp.ndarray], jnp.ndarray]],
    z: jnp.ndarray,
    extent_axis: int,
    patch: int,
    overlap_ratio: float,
) -> jnp.ndarray:
    """One hybrid LP forward: inter-group partition -> Phi_m per group ->
    position-aware reconstruction.  ``intra_group_ops[m]`` is Phi_m
    (Eq. 43) — any parallel denoiser for group m's sub-latent."""
    M = len(intra_group_ops)
    plan: PartitionPlan = plan_partition(
        z.shape[extent_axis], patch, M, overlap_ratio
    )
    op_iter = iter(intra_group_ops)

    def dispatch(sub):
        return next(op_iter)(sub)

    return lp_forward(dispatch, z, plan, extent_axis)


# ------------------------------------------------------- 2D-mesh SPMD engine
@dataclasses.dataclass(frozen=True)
class HybridMeshSpec:
    """Group-axis halo schedule bound to a concrete ``(lp, tp)`` mesh.

    ``halo`` is the plain 1D ``distributed.collectives.HaloSpec`` over the
    M groups — the wire schedule is T-independent because every transfer
    names only the lp axis (each tp rank talks to its same-tp peer).
    """

    lp_axis: str
    tp_axis: Optional[str]
    num_groups: int                 # M — lp-axis size == plan partitions
    tp_size: int                    # T — 1 when no tp axis on the mesh
    halo: "HaloSpec"                # group-axis transfer schedule

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return (self.num_groups, self.tp_size)


def hybrid_halo_spec(
    plan: UniformPlan, mesh: Mesh, lp_axis: str = "data",
    tp_axis: Optional[str] = "model",
) -> HybridMeshSpec:
    """Validate the 2D-mesh contract and build the group-axis halo spec."""
    from repro.distributed.collectives import halo_spec

    M = plan.num_partitions
    if lp_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no lp axis {lp_axis!r}: {mesh.axis_names}")
    if mesh.shape[lp_axis] != M:
        raise ValueError(
            f"lp axis {lp_axis!r} has size {mesh.shape[lp_axis]}, plan has "
            f"M={M} groups"
        )
    tp = 1
    if tp_axis is not None and tp_axis in mesh.axis_names:
        tp = mesh.shape[tp_axis]
    else:
        tp_axis = None
    return HybridMeshSpec(
        lp_axis=lp_axis, tp_axis=tp_axis, num_groups=M, tp_size=tp,
        halo=halo_spec(plan),
    )


def lp_forward_halo_hybrid(
    denoise_fn: Callable[[jnp.ndarray], jnp.ndarray],
    z: jnp.ndarray,
    plan: UniformPlan,
    axis: int,
    mesh: Mesh,
    lp_axis: str = "data",
    tp_axis: Optional[str] = "model",
    codec=None,
    codec_state=None,
    eager_sends: bool = True,
    wire_shard: bool = False,
    nan_guard: bool = False,
):
    """Hybrid LP×TP halo forward on a 2D ``(lp, tp)`` mesh.

    Same reconstruction math as ``core/spmd.lp_forward_halo`` — slice the
    group window, run Phi_m, trapezoid-weight, exchange only the overlap
    slabs over the **group axis**, normalize the own core analytically and
    all-gather the disjoint cores — but composed with tensor parallelism:

    * ``denoise_fn`` is the black-box intra-group operator Phi_m (Eq. 43).
      It runs per device inside the manual region and may issue any
      ``tp_axis`` collectives (Megatron-style psums,
      :func:`tp_cfg_combine`, ...).  Its output must be tp-replicated
      within the group.
    * Every LP collective (ppermute rounds + core all-gather) names only
      ``lp_axis``: wire bytes per device are exactly the 1D halo/codec
      model (T-independent); group-aggregate bytes are T x that, carried
      on T parallel lp rings.
    * ``eager_sends`` (default on) issues all ppermute rounds before any
      accumulation so the halo wires can overlap the tail of the DiT
      forward and each other under async collective scheduling.

    ``codec`` / ``codec_state`` behave as in ``lp_forward_halo``: any
    ``comm.codecs`` codec compresses every wire payload; residual codecs
    take state with a leading lp-axis dim (``comm.wire.
    init_halo_wire_state``) and the call returns ``(latent, new_state)``.
    State is sharded ``P(lp_axis)`` — replicated over tp, which stays
    consistent because the codec arithmetic is deterministic and its
    inputs are tp-replicated by the Phi_m contract.

    ``wire_shard`` turns on the hierarchy-aware wire: every LP payload
    (halo slabs and core-gather contributions, coded or not) is split
    over the tp axis so each tp rank ships only its 1/T chunk across
    the group boundary, followed by a cheap intra-group all-gather to
    reassemble the message before it is consumed.  Inter-group bytes
    drop T-fold (``comm_model.comm_lp_halo_sharded``); values — and
    residual codec state, which is computed from full slabs identically
    on every tp rank — are bit-equal to the unsharded engine.  A no-op
    on meshes without a tp axis (T == 1).

    ``nan_guard`` arms the wire-decode NaN/Inf guard (see
    ``core/spmd.lp_forward_halo``): corrupted messages fall back to the
    stale slab / zeros instead of propagating into the latent — the
    serving engine's default (docs/fault_tolerance.md).

    Implementation: ``spmd.lp_forward_halo`` already names only
    ``lp_axis`` in its collectives, so the hybrid engine IS that
    function behind the validated 2D-mesh contract
    (:func:`hybrid_halo_spec`) plus the eager-send default — one body to
    maintain, verified per-engine by the conformance matrix.
    """
    mspec = hybrid_halo_spec(plan, mesh, lp_axis, tp_axis)  # validate
    from .spmd import lp_forward_halo

    shard_axis = mspec.tp_axis if (wire_shard and mspec.tp_size > 1) else None
    return lp_forward_halo(
        denoise_fn, z, plan, axis, mesh, lp_axis,
        codec=codec, codec_state=codec_state, eager_sends=eager_sends,
        shard_axis=shard_axis, nan_guard=nan_guard,
    )


# ------------------------------------------------ intra-group Phi_m helpers
def tp_cfg_branch(tp_axis: str) -> jnp.ndarray:
    """This device's CFG branch (0 = cond, 1 = uncond) on the tp axis.

    Ranks alternate branches (``rank % 2``).  This extracts exactly
    **2-way** parallelism — the CFG pair is the only axis being split —
    so it pays off at T == 2; at larger T the extra ranks recompute a
    branch redundantly (correct, but wasted FLOPs).  For T > 2 compose
    real tensor parallelism inside the forward (Megatron psums over
    ``tp_axis``) instead of, or in addition to, the CFG split.
    """
    return jax.lax.axis_index(tp_axis) % 2


def tp_cfg_combine(pred_branch: jnp.ndarray, tp_axis: str,
                   guidance) -> jnp.ndarray:
    """Gather the CFG pair computed on alternating tp ranks and combine.

    Each tp rank computed ONE guidance branch of the window prediction
    (halving the per-device DiT batch vs the batched-CFG replication at
    T == 2; see :func:`tp_cfg_branch` for the T > 2 caveat); the pair is
    reunited with one intra-group all-gather — a window-sized wire on
    the fast intra-group links, never crossing the group axis.  Only
    rows 0 and 1 of the gathered stack are read, so redundant branches
    on T > 2 ranks are ignored.  Output is tp-replicated, satisfying
    the Phi_m contract.
    """
    from repro.diffusion.cfg import cfg_combine

    stack = jax.lax.all_gather(pred_branch, tp_axis, axis=0, tiled=False)
    return cfg_combine(stack[0], stack[1], guidance)
