"""Hierarchical hybrid parallelism (paper supplementary §11).

Cluster of K devices partitioned into M disjoint groups (Eq. 42);
inter-group LP partitions the latent across groups with the same
patch-aligned overlapping machinery (K -> M in Eqs. 7-10), and each group
runs an arbitrary intra-group operator Phi_m (Eq. 43) — NMP / TP / PP /
plain jit — as a black box over its sub-latent.

On the production mesh this is realized by the GSPMD LP engine with the
"data" axis as the group axis and "model" as the intra-group TP axis
(launch/dryrun._vdm_lp_step); this module provides the explicit reference
composition + the group-assignment bookkeeping used by tests and the
hybrid example.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax.numpy as jnp

from .lp_step import lp_forward
from .partition import PartitionPlan, plan_partition


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """K devices -> M disjoint groups (Eq. 42 constraints)."""

    num_devices: int
    num_groups: int
    groups: Tuple[Tuple[int, ...], ...]

    def validate(self) -> None:
        seen = set()
        for g in self.groups:
            assert g, "empty group"
            assert not (seen & set(g)), "groups must be disjoint"
            seen |= set(g)
        assert seen == set(range(self.num_devices)), "groups must cover G"


def make_groups(num_devices: int, num_groups: int) -> GroupLayout:
    if num_devices % num_groups != 0:
        raise ValueError(f"K={num_devices} must split into M={num_groups}")
    per = num_devices // num_groups
    groups = tuple(
        tuple(range(m * per, (m + 1) * per)) for m in range(num_groups)
    )
    layout = GroupLayout(num_devices, num_groups, groups)
    layout.validate()
    return layout


def hybrid_forward(
    intra_group_ops: Sequence[Callable[[jnp.ndarray], jnp.ndarray]],
    z: jnp.ndarray,
    extent_axis: int,
    patch: int,
    overlap_ratio: float,
) -> jnp.ndarray:
    """One hybrid LP forward: inter-group partition -> Phi_m per group ->
    position-aware reconstruction.  ``intra_group_ops[m]`` is Phi_m
    (Eq. 43) — any parallel denoiser for group m's sub-latent."""
    M = len(intra_group_ops)
    plan: PartitionPlan = plan_partition(
        z.shape[extent_axis], patch, M, overlap_ratio
    )
    op_iter = iter(intra_group_ops)

    def dispatch(sub):
        return next(op_iter)(sub)

    return lp_forward(dispatch, z, plan, extent_axis)
