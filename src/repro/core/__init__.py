"""Latent Parallelism (LP) — the paper's primary contribution.

Pipeline per denoising step (paper Fig. 3):
  schedule.rotation_dim     -> which dim to partition (Eq. 3)
  partition.plan_partition  -> patch-aligned overlapping slices (Eqs. 7-10)
  <parallel denoising>      -> per-device DiT forward on sub-latents (Eq. 4)
  weights / reconstruct     -> position-aware stitching (Eqs. 11-17)

``lp_step`` is the single-host reference engine, ``spmd`` the shard_map
production engine, ``uniform`` the fixed-shape window variant SPMD needs,
``comm_model`` the §7 analytic cost model, ``hybrid`` the §11 inter-group
LP + intra-group model parallelism composition.
"""
from .schedule import (  # noqa: F401
    DIM_NAMES,
    HEIGHT,
    TEMPORAL,
    WIDTH,
    rotation_dim,
    rotation_schedule,
    usable_dims,
)
from .partition import (  # noqa: F401
    PartitionPlan,
    extract,
    plan_partition,
    plan_partition_balanced,
)
from .weights import blend_weight_1d, global_normalizer, partition_weights  # noqa: F401
from .reconstruct import reconstruct  # noqa: F401
from .uniform import UniformPlan, expansion_factor, plan_uniform  # noqa: F401
from .lp_step import (  # noqa: F401
    DenoiseSnapshot,
    LPStepCompiler,
    lp_denoise,
    lp_denoise_reference,
    lp_forward,
    lp_forward_uniform,
)
from . import comm_model  # noqa: F401
