"""Analytic communication-overhead model (paper §7 + TPU-SPMD variants).

Reproduces the paper's closed forms:

    C_NMP = 2 T (K-1) S_H                                   (Eq. 22)
    C_PP  = 2 T (K-1) S_H                                   (Eq. 23)
    C_LP  = 4 T sum_{k>=2} S_sub^(k)                        (Eq. 27)
    R     ~ 2 gamma(r,K) / K * (S_z / S_H)                  (Eq. 31)
    C_hyb ~ 2 T S_H' (K - M)                                (Eq. 53)

plus models the paper measures but does not derive (HP ~ tensor-parallel
collectives inside DiT blocks) and the TPU-SPMD LP variant (one ring
all-reduce of the weighted predictions per step; scatter is free because
the latent is replicated along the lp axis).

Everything returns **bytes**.  ``bytes_per_el`` defaults to 4 (the paper's
fp32 transfers; WAN2.1 inference moves fp32 latents/noise between devices).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from .partition import plan_partition
from .schedule import rotation_dim, usable_dims


@dataclasses.dataclass(frozen=True)
class VDMCommConfig:
    """Workload geometry for the communication model."""

    latent_dims: Tuple[int, int, int]   # (T_lat, H_lat, W_lat)
    latent_channels: int                # C
    patch_sizes: Tuple[int, int, int]   # (p_T, p_H, p_W)
    d_model: int                        # DiT hidden width
    num_blocks: int                     # DiT depth
    text_len: int = 512                 # encoded prompt length (context)
    num_steps: int = 60                 # T (denoising iterations)
    cfg_passes: int = 2                 # conditional + unconditional
    bytes_per_el: int = 4               # fp32 on the wire (paper setup)

    @property
    def latent_elems(self) -> int:
        t, h, w = self.latent_dims
        return t * h * w * self.latent_channels

    @property
    def latent_bytes(self) -> int:
        """S_z."""
        return self.latent_elems * self.bytes_per_el

    @property
    def num_tokens(self) -> int:
        t, h, w = self.latent_dims
        pt, ph, pw = self.patch_sizes
        return (t // pt) * (h // ph) * (w // pw)

    @property
    def activation_bytes(self) -> int:
        """S_H: the hidden activation crossing a DiT block boundary."""
        return self.num_tokens * self.d_model * self.bytes_per_el


def comm_nmp(cfg: VDMCommConfig, K: int) -> int:
    """Eq. 22: every CFG pass crosses K-1 boundaries carrying S_H."""
    return cfg.cfg_passes * cfg.num_steps * (K - 1) * cfg.activation_bytes


def comm_pp(cfg: VDMCommConfig, K: int) -> int:
    """Eq. 23: pipelining overlaps transfers but moves the same bytes."""
    return comm_nmp(cfg, K)


def comm_tp(cfg: VDMCommConfig, K: int, collectives_per_block: int = 2) -> int:
    """Tensor-parallel (the paper's HP is FSDP+xDiT; TP collectives dominate).

    Per DiT block: ``collectives_per_block`` ring all-reduces of the hidden
    activation (attention out-proj + MLP down-proj).  Ring all-reduce wire
    bytes across the group = 2 (K-1) S per collective.
    """
    per_allreduce = 2 * (K - 1) * cfg.activation_bytes
    return (
        cfg.num_steps
        * cfg.cfg_passes
        * cfg.num_blocks
        * collectives_per_block
        * per_allreduce
    )


def comm_hp_xdit(cfg: VDMCommConfig, K: int) -> int:
    """The paper's HP baseline (WAN's FSDP + xDiT), calibrated.

    xDiT's patch-level pipelining (PipeFusion) communicates *latent-scale*
    tensors per step, not per-block activations.  Paper Table 1 fits
    ``3 * S_z`` per worker per step and ``7 * S_z`` for the master to
    <0.5% for both 49- and 81-frame settings (891.21 MB and 1439.65 MB per
    worker respectively); we adopt that empirical per-step accounting:

        C_HP = T * S_z * (7 + 3 * (K - 1))
    """
    return cfg.num_steps * cfg.latent_bytes * (7 + 3 * (K - 1))


def _sub_latent_bytes(cfg: VDMCommConfig, K: int, r: float, dim: int) -> Tuple[int, ...]:
    """S_sub^(k) for the paper-exact partition along ``dim``."""
    extent = cfg.latent_dims[dim]
    plan = plan_partition(extent, cfg.patch_sizes[dim], K, r, dim)
    other = cfg.latent_elems // extent
    return tuple(sz * other * cfg.bytes_per_el for sz in plan.sizes)


def comm_lp_hub(
    cfg: VDMCommConfig,
    K: int,
    r: float,
    scatter_gather_factor: int = 2,
) -> int:
    """Eq. 27 with the true rotating geometry (exact, not the Eq. 28 approx).

    Master scatters K-1 sub-latents and gathers K-1 predictions; the paper
    multiplies by 2 for the CFG passes (``scatter_gather_factor``).  Each
    step's S_sub depends on the rotation dimension, so we sum the actual
    schedule rather than assuming balance.
    """
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    total = 0
    for i in range(1, cfg.num_steps + 1):
        dim = rotation_dim(i, dims)
        subs = _sub_latent_bytes(cfg, K, r, dim)
        step = 2 * sum(subs[1:])  # scatter + gather, workers only (Eq. 26)
        total += scatter_gather_factor * step
    return total


def comm_lp_measured(cfg: VDMCommConfig, K: int, r: float) -> int:
    """LP as the paper's system *measures* it (Table 1 per-GPU accounting).

    The implementation batches the CFG passes on-device, so sub-latents are
    scattered once and predictions gathered once per step.  Workers tally
    send+recv (2 * S_sub each); the master row tallies its sends only
    (sum_{k>=2} S_sub).  Total = 3 * T * sum_{k>=2} S_sub, which matches
    Table 1 to a few percent for both r=0.5 and r=1.0 (the paper's Eq. 26
    theory doubles this by charging CFG twice).
    """
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    total = 0
    for i in range(1, cfg.num_steps + 1):
        dim = rotation_dim(i, dims)
        subs = _sub_latent_bytes(cfg, K, r, dim)
        total += 3 * sum(subs[1:])
    return total


def comm_lp_spmd(cfg: VDMCommConfig, K: int, r: float) -> int:
    """TPU-SPMD LP: latent replicated on the lp axis => scatter is local.

    Reconstruction = one ring all-reduce of the (weight-masked, scattered)
    prediction buffer of size S_z per step; CFG is combined locally before
    the reduce, so the factor-2 of Eq. 26 disappears.  Wire bytes per step
    across the group = 2 (K-1)/K * S_z * K = 2 (K-1) S_z.
    """
    per_step = 2 * (K - 1) * cfg.latent_bytes
    return cfg.num_steps * per_step


def _halo_plan(cfg: VDMCommConfig, K: int, r: float, dim: int):
    from .uniform import plan_uniform

    return plan_uniform(cfg.latent_dims[dim], cfg.patch_sizes[dim], K, r, dim)


def _row_bytes(cfg: VDMCommConfig, dim: int) -> int:
    """Bytes of one latent-unit slab orthogonal to ``dim``."""
    return (cfg.latent_elems // cfg.latent_dims[dim]) * cfg.bytes_per_el


def lp_halo_step_collectives(
    cfg: VDMCommConfig, K: int, r: float, dim: int
) -> dict:
    """Per-device collective payloads of ONE halo LP step along ``dim``.

    Accounted the way ``analysis/hlo_analyzer.py`` measures compiled HLO:
    each collective contributes its **output shape** bytes.  The halo step
    lowers to one all-gather of the padded core slice — output is the
    gathered (K, core_pad) stack — plus one collective-permute per
    transfer round with a slab-shaped output.  Cross-checked against the
    dry-run HLO in tests/test_fast_lp_step.py.
    """
    from repro.distributed.collectives import halo_spec

    spec = halo_spec(_halo_plan(cfg, K, r, dim))
    row = _row_bytes(cfg, dim)
    return {
        "all-gather": K * spec.core_pad * row,
        "collective-permute": sum(t.length * row for t in spec.transfers),
    }


def comm_lp_halo(cfg: VDMCommConfig, K: int, r: float = 0.5) -> int:
    """Halo-exchange LP (``core/spmd.lp_forward_halo``): group wire bytes.

    Per step, reconstruction is (a) a ring all-gather of the padded core
    slices — every rank's core_pad shard crosses K-1 links — and (b) the
    ppermute halo rounds, where each scheduled (src, dst) pair moves one
    padded slab.  No buffer of size S_z ever crosses the wire:

        C_halo_step = K (K-1) core_pad row  +  sum_t |perm_t| len_t row

    vs the psum engine's ``2 (K-1) S_z`` (``comm_lp_spmd``).  The overlap
    slabs scale with O ~ r L ~ r D/K, so the advantage grows with K.
    """
    from repro.distributed.collectives import halo_spec

    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    per_dim = {}
    for dim in dims:
        spec = halo_spec(_halo_plan(cfg, K, r, dim))
        row = _row_bytes(cfg, dim)
        ag = K * (K - 1) * spec.core_pad * row
        pp = sum(len(t.perm) * t.length * row for t in spec.transfers)
        per_dim[dim] = ag + pp
    return sum(
        per_dim[rotation_dim(i, dims)] for i in range(1, cfg.num_steps + 1)
    )


def lp_halo_codec_step_collectives(
    cfg: VDMCommConfig, K: int, r: float, dim: int, codec="int8"
) -> dict:
    """Per-device collective payloads of ONE codec'd halo LP step.

    Same HLO output-shape accounting as :func:`lp_halo_step_collectives`
    but through a ``comm.codecs`` codec: every ppermute round ships the
    coded slab (``codec.bits`` per element) plus its per-slab scale
    meta, and the core all-gather ships K coded core slices plus K
    scales.  Matches ``analysis/hlo_analyzer`` on the compiled HLO
    exactly (the codecs pin their wire dtype to the collectives).
    """
    from repro.comm.codecs import get_codec
    from repro.distributed.collectives import halo_spec

    codec = get_codec(codec)
    spec = halo_spec(_halo_plan(cfg, K, r, dim))
    row_el = cfg.latent_elems // cfg.latent_dims[dim]  # elems per latent row
    pp = sum(
        codec.wire_bytes(t.length * row_el) for t in spec.transfers
    )
    ag = K * codec.wire_bytes(spec.core_pad * row_el)
    return {"all-gather": ag, "collective-permute": pp}


def _halo_codec_group_bytes_per_dim(
    cfg: VDMCommConfig, K: int, r: float, codec
) -> dict:
    """Group wire bytes of ONE codec'd halo step, per rotation dim.

    The single per-dim formula every halo byte model composes: each
    rank's coded core slice (+ scale meta) crosses K-1 links in the
    ring all-gather, and each scheduled ppermute pair moves one coded
    slab (+ meta).  Shared by :func:`comm_lp_halo_codec` (fixed codec)
    and :func:`lp_halo_scheduled_segments` (per-step codecs) so the
    "scheduled == sum of fixed-codec steps" exact-match contract can
    never drift between the two.
    """
    from repro.comm.codecs import get_codec
    from repro.distributed.collectives import halo_spec

    codec = get_codec(codec)
    out = {}
    for dim in usable_dims(cfg.latent_dims, cfg.patch_sizes, K):
        spec = halo_spec(_halo_plan(cfg, K, r, dim))
        row_el = cfg.latent_elems // cfg.latent_dims[dim]
        ag = K * (K - 1) * codec.wire_bytes(spec.core_pad * row_el)
        pp = sum(
            len(t.perm) * codec.wire_bytes(t.length * row_el)
            for t in spec.transfers
        )
        out[dim] = ag + pp
    return out


def comm_lp_halo_codec(
    cfg: VDMCommConfig, K: int, r: float = 0.5, codec="int8"
) -> int:
    """Codec-compressed halo LP: group wire bytes over the full schedule.

    :func:`comm_lp_halo` with every payload squeezed through a wire
    codec (``core/spmd.lp_forward_halo(..., codec=...)``).  With int8
    this is ~4x below the fp32 halo path — and the residual variants
    spend the same bytes on a temporally-delta-coded payload, so the
    quality cost shrinks without moving more data.
    """
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    per_dim = _halo_codec_group_bytes_per_dim(cfg, K, r, codec)
    return sum(
        per_dim[rotation_dim(i, dims)] for i in range(1, cfg.num_steps + 1)
    )


def comm_lp_halo_scheduled(
    cfg: VDMCommConfig, K: int, r: float, step_codecs: Sequence[str]
) -> int:
    """Sigma-scheduled halo LP: group wire bytes over a per-step codec
    assignment.

    ``step_codecs[i]`` names the wire codec of forward pass ``i + 1``
    (the ``policy/`` layer resolves sigma thresholds against the
    sampler's trajectory; this model is deliberately sigma-blind).  The
    step count is ``len(step_codecs)`` — it overrides ``cfg.num_steps``
    so a resolved schedule can never silently disagree with the model.
    Each step moves exactly the bytes of the fixed-codec halo step on
    its rotation dim (:func:`comm_lp_halo_codec` per-dim terms): a
    segment boundary changes which codec encodes, not the message
    layout, so per-segment totals are sums of fixed-codec step bytes —
    the property the conformance suite and
    ``benchmarks/codec_schedule.py`` check against measured HLO.
    """
    return sum(
        seg["wire_bytes"] for seg in
        lp_halo_scheduled_segments(cfg, K, r, step_codecs)
    )


def lp_halo_scheduled_segments(
    cfg: VDMCommConfig, K: int, r: float, step_codecs: Sequence[str]
) -> Tuple[dict, ...]:
    """Per-segment byte breakdown of :func:`comm_lp_halo_scheduled`.

    One entry per contiguous same-codec step run: ``{"codec", "start",
    "stop", "wire_bytes", "per_dim"}`` with 1-indexed inclusive step
    bounds and ``per_dim`` the single-step group bytes per rotation dim
    (each must match the measured HLO of the fixed-codec engine
    exactly).
    """
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    per_dim_by_codec: dict = {}

    def per_dim(codec_name: str) -> dict:
        if codec_name not in per_dim_by_codec:
            per_dim_by_codec[codec_name] = \
                _halo_codec_group_bytes_per_dim(cfg, K, r, codec_name)
        return per_dim_by_codec[codec_name]

    segments = []
    for i, name in enumerate(step_codecs, start=1):
        if segments and segments[-1]["codec"] == name:
            segments[-1]["stop"] = i
            segments[-1]["wire_bytes"] += per_dim(name)[rotation_dim(i, dims)]
        else:
            segments.append({
                "codec": name, "start": i, "stop": i,
                "wire_bytes": per_dim(name)[rotation_dim(i, dims)],
                "per_dim": dict(per_dim(name)),
            })
    return tuple(segments)


def lp_halo_sharded_step_collectives(
    cfg: VDMCommConfig, M: int, T: int, r: float, dim: int, codec="fp32"
) -> dict:
    """Per-device collective payloads of ONE wire-sharded hybrid step.

    The hierarchy-aware wire (``core/hybrid.lp_forward_halo_hybrid(...,
    wire_shard=True)``): every coded payload is chunked T ways over the
    tp axis, each tp rank ships only its chunk across the group
    boundary, and one intra-group all-gather reassembles the message.
    Same HLO output-shape accounting as
    :func:`lp_halo_codec_step_collectives`, split into the two link
    tiers:

    * ``inter`` (lp-axis collectives, replica groups of size M): one
      collective-permute of the (ceil-padded) 1/T chunk + the full meta
      per transfer round, and the core all-gather of M chunks + M metas.
    * ``intra`` (tp-axis all-gathers, replica groups of size T): the
      (T, chunk) reassembly per transfer round and the (T, M, chunk)
      core reassembly.  The Phi_m all-reduce (TP psums) is charged to
      the intra-group model (``comm_tp``), never here.

    Per device, ``inter`` is ~1/T of the unsharded hybrid step (exact up
    to chunk ceil-padding and the T-replicated meta): the T-fold
    inter-group saving ``BENCH_wire_shard.json`` gates.
    """
    from repro.comm.codecs import get_codec
    from repro.distributed.collectives import halo_spec, wire_shard_len

    if T < 2:
        raise ValueError(f"wire sharding needs a tp axis of size >= 2, T={T}")
    codec = get_codec(codec)
    spec = halo_spec(_halo_plan(cfg, M, r, dim))
    row_el = cfg.latent_elems // cfg.latent_dims[dim]
    C = cfg.latent_channels
    db = codec.wire_dtype_bytes
    pp_inter = 0
    tp_intra = 0
    for t in spec.transfers:
        s = wire_shard_len(codec.wire_elems(t.length * row_el, C), T)
        pp_inter += s * db + codec.meta_bytes
        tp_intra += T * s * db
    s_core = wire_shard_len(codec.wire_elems(spec.core_pad * row_el, C), T)
    ag_inter = M * s_core * db + M * codec.meta_bytes
    tp_intra += T * M * s_core * db
    return {
        "inter": {"collective-permute": pp_inter, "all-gather": ag_inter},
        "intra": {"all-gather": tp_intra},
    }


def _halo_sharded_group_bytes_per_dim(
    cfg: VDMCommConfig, M: int, T: int, r: float, codec
) -> dict:
    """Group wire bytes of ONE wire-sharded hybrid step, per rotation
    dim, split by link tier.

    Ring accounting mirrors :func:`_halo_codec_group_bytes_per_dim`:
    every scheduled ppermute pair moves one chunk (+ full meta) on each
    of the T lp rings, each device's core chunk (+ meta) crosses M-1
    links of its lp ring, and each intra-group reassembly moves every
    contribution across T-1 links of its tp ring (M tp rings per mesh).
    """
    from repro.comm.codecs import get_codec
    from repro.distributed.collectives import halo_spec, wire_shard_len

    codec = get_codec(codec)
    C = cfg.latent_channels
    db = codec.wire_dtype_bytes
    out = {}
    for dim in usable_dims(cfg.latent_dims, cfg.patch_sizes, M):
        spec = halo_spec(_halo_plan(cfg, M, r, dim))
        row_el = cfg.latent_elems // cfg.latent_dims[dim]
        inter = intra = 0
        for t in spec.transfers:
            s = wire_shard_len(codec.wire_elems(t.length * row_el, C), T)
            inter += T * len(t.perm) * (s * db + codec.meta_bytes)
            intra += M * T * (T - 1) * s * db
        s_core = wire_shard_len(codec.wire_elems(spec.core_pad * row_el, C), T)
        inter += T * M * (M - 1) * (s_core * db + codec.meta_bytes)
        intra += M * T * (T - 1) * M * s_core * db
        out[dim] = (inter, intra)
    return out


def comm_lp_halo_sharded(
    cfg: VDMCommConfig,
    M: int,
    T: int,
    r: float = 0.5,
    codec="fp32",
    step_codecs: Optional[Sequence[str]] = None,
) -> dict:
    """Wire-sharded hybrid LP×TP halo engine: group wire bytes over the
    full denoise, split into ``{"inter", "intra", "total"}``.

    The T-fold contrast with :func:`comm_lp_halo_hybrid` (whose group
    bytes are ``T x`` the 1D model because every tp rank ships the full
    slab on its own lp ring): here the T rings carry disjoint 1/T
    chunks, so ``inter`` collapses back to ~the 1D model (+ T-replicated
    meta + ceil padding) and the delta moves to ``intra`` — the
    trade the two-tier autotuner prices with ``inter_gbps`` /
    ``intra_gbps``.  ``step_codecs`` (one codec name per forward pass,
    as in :func:`comm_lp_halo_scheduled`) overrides the fixed ``codec``
    and ``cfg.num_steps``.
    """
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, M)
    if step_codecs is None:
        step_codecs = [codec] * cfg.num_steps
    per_dim_by_codec: dict = {}

    def per_dim(name):
        key = name if isinstance(name, str) else name.name
        if key not in per_dim_by_codec:
            per_dim_by_codec[key] = _halo_sharded_group_bytes_per_dim(
                cfg, M, T, r, name)
        return per_dim_by_codec[key]

    inter = intra = 0
    for i, name in enumerate(step_codecs, start=1):
        a, b = per_dim(name)[rotation_dim(i, dims)]
        inter += a
        intra += b
    return {"inter": inter, "intra": intra, "total": inter + intra}


def lp_halo_wire_profile(
    cfg: VDMCommConfig,
    M: int,
    T: int,
    r: float,
    step_codecs: Sequence[str],
    wire_shard: bool = False,
) -> dict:
    """Per-device wire bytes of a whole denoise, split by link tier.

    The quantity the two-tier autotuner turns into wire *time*: on a
    torus the T lp rings (and the M tp rings) are disjoint physical
    links, so per-device bytes — not group aggregates — are the
    time-like measure.  Unsharded: the per-device step payloads are the
    1D codec'd halo model on every tier-1 (inter-group) link and the
    intra tier carries nothing of LP's.  Sharded: the per-device split
    of :func:`lp_halo_sharded_step_collectives`.

    Returns ``{"inter", "intra", "hidden"}``.  ``hidden`` is the
    displaced-halo tier: for a ``displaced:*`` step that is NOT the
    first of its (rotation-dim x codec) run, the step consumes the
    previous step's slabs already in the carry, so its inter-group
    collective-permute bytes overlap the local compute instead of
    gating the step — they are moved from ``inter`` (exposed) to
    ``hidden``.  First-of-run steps stay fully exposed (the dim-rotation
    flush forces them synchronous), and the core all-gather is always
    exposed (the step cannot finish without the fresh cores).  The HLO
    contract is over ``inter + hidden``: displaced mode changes WHEN
    bytes gate the step, never how many cross the wire — the compiled
    collectives are identical per collective per tier.
    """
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, M)
    cache: dict = {}

    def step(name, dim):
        key = (name if isinstance(name, str) else name.name, dim)
        if key not in cache:
            if wire_shard:
                d = lp_halo_sharded_step_collectives(cfg, M, T, r, dim,
                                                     codec=name)
                cache[key] = (d["inter"]["collective-permute"],
                              d["inter"]["all-gather"],
                              sum(d["intra"].values()))
            else:
                d = lp_halo_codec_step_collectives(cfg, M, r, dim,
                                                   codec=name)
                cache[key] = (d["collective-permute"], d["all-gather"], 0)
        return cache[key]

    inter = intra = hidden = 0
    prev_run = None
    for i, name in enumerate(step_codecs, start=1):
        key = name if isinstance(name, str) else name.name
        dim = rotation_dim(i, dims)
        pp, ag, b = step(name, dim)
        run = (dim, key)
        if key.startswith("displaced") and run == prev_run:
            hidden += pp          # slab ppermutes overlap the compute
            inter += ag
        else:
            inter += pp + ag      # first-of-run / synchronous: all exposed
        intra += b
        prev_run = run
    return {"inter": inter, "intra": intra, "hidden": hidden}


def lp_halo_hybrid_step_collectives(
    cfg: VDMCommConfig, M: int, T: int, r: float, dim: int, codec="fp32"
) -> dict:
    """Per-device collective payloads of ONE hybrid LP×TP halo step.

    On the 2D ``(lp=M, tp=T)`` mesh every LP collective names only the
    group axis, so each device's halo payloads are **identical to the 1D
    codec'd halo step over M partitions** — T-independent by
    construction.  This is the exact analytic-bytes contract the hybrid
    engine is tested against: the all-gather / collective-permute entries
    of the compiled 2D-mesh HLO (``analysis/hlo_analyzer`` accounting)
    must match these numbers exactly; any all-reduce in that HLO belongs
    to the intra-group Phi_m (TP psums) and is charged to the intra-group
    model (``comm_tp``), not to LP.
    """
    if T < 1:
        raise ValueError(f"tp size T={T} must be >= 1")
    return lp_halo_codec_step_collectives(cfg, M, r, dim, codec=codec)


def comm_lp_halo_hybrid(
    cfg: VDMCommConfig, M: int, T: int, r: float = 0.5, codec="fp32"
) -> int:
    """Hybrid LP×TP halo engine: group wire bytes over the full schedule.

    §11 composition on an ``(M, T)`` mesh
    (``core/hybrid.lp_forward_halo_hybrid``): the inter-group halo
    schedule runs once per tp rank — T parallel lp rings, each moving the
    1D codec'd halo bytes — so the group aggregate is ``T x
    comm_lp_halo_codec(M)`` while per-device bytes (and therefore wire
    *time* on a torus, where the T rings are disjoint physical links)
    stay exactly at the 1D model.  Intra-group Phi_m traffic (TP psums,
    CFG-pair gathers) is intentionally excluded: Phi_m is a black box
    whose cost is the caller's intra-group model (``comm_tp`` /
    ``comm_nmp`` on the sub-latent, cf. Eq. 50).
    """
    if T < 1:
        raise ValueError(f"tp size T={T} must be >= 1")
    return T * comm_lp_halo_codec(cfg, M, r, codec=codec)


def comm_lp_gspmd_codec(cfg: VDMCommConfig, K: int, r: float,
                        codec="int8") -> int:
    """GSPMD stacked engine with a wire codec: bytes are UNCHANGED.

    ``lp_forward_gspmd(..., codec=...)`` round-trips every window through
    the codec before the stacked reduce (value-faithful to a codec'd
    wire), but the reduce the partitioner emits still ships f32 — GSPMD
    has no reduce-then-decode hook.  Kept as an explicit model so
    benchmark tables can show WHY the halo family is the codec path:
    same quality cost as the codec'd halo engine, zero byte savings.
    """
    from repro.comm.codecs import get_codec

    get_codec(codec)  # validate the name
    return comm_lp_spmd(cfg, K, r)


def collective_wire_bytes(kind: str, payload_bytes: float, K: int) -> float:
    """HLO output-shape payload -> ring wire bytes per device.

    ``hlo_analyzer`` reports collective payloads as output sizes; on a ring
    an all-reduce moves 2 (K-1)/K of its buffer per device, an all-gather
    (K-1)/K of its *gathered* output, and a collective-permute exactly its
    payload.  Used to reconcile measured HLO bytes with the analytic
    ``comm_lp_*`` wire models.
    """
    if kind == "all-reduce":
        return 2.0 * (K - 1) / K * payload_bytes
    if kind in ("all-gather", "reduce-scatter"):
        return (K - 1) / K * payload_bytes
    if kind == "collective-permute":
        return float(payload_bytes)
    raise ValueError(f"unknown collective kind {kind!r}")


def comm_hybrid(
    cfg: VDMCommConfig,
    K: int,
    M: int,
    r: float,
    intra: str = "nmp",
    wire_shard: bool = False,
) -> int:
    """§11: inter-group LP across M groups + intra-group NMP/TP (Eq. 50).

    ``S_H'`` is the activation of a 1/M sub-latent.  Exact inter-group term
    (rotating geometry with M partitions) + intra-group term per group.

    ``wire_shard`` models the hierarchy-aware wire on the paper's hub
    topology: every inter-group sub-latent transfer is striped over the
    group's ``k_m`` members (each member's NIC carries 1/k_m, so the
    per-link inter bytes drop k_m-fold even though the group total
    crossing the boundary is unchanged — the hub ships each sub-latent
    once either way), and the intra-group total honestly charges the
    reassembly all-gather: each striped transfer's chunks cross k_m - 1
    intra links per member, adding ``(k_m - 1)/k_m x`` the inter term
    alongside the NMP/TP collectives.  This is the accounting
    ``benchmarks/table1_comm.py`` reports so wire-shard rows include
    the gather term instead of pretending the reassembly is free.
    """
    if K % M != 0:
        raise ValueError(f"K={K} must divide into M={M} groups")
    k_m = K // M
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, M)
    inter = 0
    for i in range(1, cfg.num_steps + 1):
        dim = rotation_dim(i, dims)
        subs = _sub_latent_bytes(cfg, M, r, dim)
        inter += 2 * 2 * sum(subs[1:])
    # Intra-group activation: tokens of the (average) extended sub-latent.
    gamma_tokens = 0.0
    for i in range(1, cfg.num_steps + 1):
        dim = rotation_dim(i, dims)
        subs = _sub_latent_bytes(cfg, M, r, dim)
        gamma_tokens += sum(subs) / (M * cfg.latent_bytes)
    gamma = gamma_tokens / cfg.num_steps
    act_sub = int(cfg.activation_bytes * gamma)
    if intra == "nmp":
        intra_total = M * cfg.cfg_passes * cfg.num_steps * (k_m - 1) * act_sub
    elif intra == "tp":
        intra_total = (
            M
            * cfg.num_steps
            * cfg.cfg_passes
            * cfg.num_blocks
            * 2
            * 2
            * (k_m - 1)
            * act_sub
        )
    else:
        raise ValueError(f"unknown intra-group strategy {intra!r}")
    if wire_shard and k_m > 1:
        # the reassembly gather: every striped inter transfer's chunks
        # cross k_m - 1 intra links per member before Phi_m can run
        intra_total += inter * (k_m - 1) // k_m
    return inter + intra_total


def gamma_factor(cfg: VDMCommConfig, K: int, r: float) -> float:
    """gamma(r, K) = S_ext / S_z averaged over the rotation (Eq. 19)."""
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    tot = 0.0
    for i in range(1, cfg.num_steps + 1):
        dim = rotation_dim(i, dims)
        tot += sum(_sub_latent_bytes(cfg, K, r, dim)) / cfg.latent_bytes
    return tot / cfg.num_steps


def reduction_vs_nmp(cfg: VDMCommConfig, K: int, r: float) -> float:
    """1 - C_LP / C_NMP (the paper's headline 'up to 97%')."""
    return 1.0 - comm_lp_hub(cfg, K, r) / comm_nmp(cfg, K)


def wan21_comm_config(
    num_frames: int,
    height: int = 480,
    width: int = 832,
    num_steps: int = 60,
    bytes_per_el: int = 4,
) -> VDMCommConfig:
    """WAN2.1-1.3B geometry (paper §5.1): VAE stride (4, 8, 8), C=16,
    patchify (1, 2, 2), d_model 1536, 30 DiT blocks."""
    t_lat = (num_frames - 1) // 4 + 1
    return VDMCommConfig(
        latent_dims=(t_lat, height // 8, width // 8),
        latent_channels=16,
        patch_sizes=(1, 2, 2),
        d_model=1536,
        num_blocks=30,
        num_steps=num_steps,
        bytes_per_el=bytes_per_el,
    )
