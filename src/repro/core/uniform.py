"""Uniform-window partition variant for SPMD execution (TPU adaptation).

XLA SPMD requires identical shapes on every rank, but the paper's Eq. 8
clips edge partitions (`max(0, ...)`, `min(N, ...)`) to *different* sizes.
Instead of padding + masking, every rank slices a fixed-size window of
``W = L + 2*O`` patches whose *start* is clamped into range:

    start_k = clamp(core_start_k - O, 0, N - W)

Edge ranks therefore see extra valid context on their clipped side (a
superset of the paper's context — quality can only improve; see DESIGN.md
§2).  Blend ramps span the full distance from the core edge to the window
edge so the trapezoids of neighboring ranks still sum consistently, and the
global normalizer remains an analytic function of geometry.

Cores are assigned with the *balanced* scheme so all ranks do useful work
even when N is barely >= K (e.g. 21 latent frames over 16 devices).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

from .partition import PartitionPlan, plan_partition_balanced
from .weights import blend_weight_1d


@dataclasses.dataclass(frozen=True)
class UniformPlan:
    """K equal-size windows with per-rank core bounds and blend deltas."""

    dim: int
    extent: int                    # D_d (latent units); must be patch-aligned
    patch: int
    num_partitions: int
    overlap_ratio: float
    window: int                    # window size, latent units (same all ranks)
    starts: Tuple[int, ...]        # s_k, latent units
    core_start: Tuple[int, ...]    # latent units, global coords
    core_end: Tuple[int, ...]
    delta_start: Tuple[int, ...]   # front ramp lengths (latent units)
    delta_end: Tuple[int, ...]     # rear ramp lengths

    @property
    def ends(self) -> Tuple[int, ...]:
        return tuple(s + self.window for s in self.starts)

    def weight_1d(self, k: int) -> np.ndarray:
        return blend_weight_1d(self.window, self.delta_start[k], self.delta_end[k])

    def normalizer(self) -> np.ndarray:
        z = np.zeros(self.extent, dtype=np.float32)
        for k in range(self.num_partitions):
            s = self.starts[k]
            z[s : s + self.window] += self.weight_1d(k)
        assert (z > 0).all(), "uncovered positions in uniform plan"
        return z

    def validate(self) -> None:
        K = self.num_partitions
        assert len(self.starts) == K
        covered = np.zeros(self.extent, dtype=bool)
        core_covered = np.zeros(self.extent, dtype=bool)
        for k in range(K):
            s, e = self.starts[k], self.starts[k] + self.window
            assert 0 <= s and e <= self.extent, (s, e, self.extent)
            assert s <= self.core_start[k] <= self.core_end[k] <= e
            covered[s:e] = True
            core_covered[self.core_start[k] : self.core_end[k]] = True
        assert covered.all() and core_covered.all()


def plan_uniform(
    extent: int, patch: int, num_partitions: int, overlap_ratio: float, dim: int = 0
) -> UniformPlan:
    """Build the uniform-window plan from a balanced core assignment."""
    if extent % patch != 0:
        raise ValueError(
            f"SPMD uniform partitioning requires patch-aligned extents "
            f"(extent={extent}, patch={patch}); pad the latent first"
        )
    base: PartitionPlan = plan_partition_balanced(
        extent, patch, num_partitions, overlap_ratio, dim
    )
    N = base.num_patches
    K = num_partitions
    L = base.core_patches
    O = base.overlap_patches
    Wp = min(N, L + 2 * O)  # window in patches
    starts, core_s, core_e, d_s, d_e = [], [], [], [], []
    for k in range(K):
        a, b = base.core_start[k], base.core_end[k]
        s = min(max(0, a - O), N - Wp)
        starts.append(s * patch)
        core_s.append(a * patch)
        core_e.append(b * patch)
        d_s.append((a - s) * patch)
        d_e.append((s + Wp - b) * patch)
    plan = UniformPlan(
        dim=dim,
        extent=extent,
        patch=patch,
        num_partitions=K,
        overlap_ratio=overlap_ratio,
        window=Wp * patch,
        starts=tuple(starts),
        core_start=tuple(core_s),
        core_end=tuple(core_e),
        delta_start=tuple(d_s),
        delta_end=tuple(d_e),
    )
    plan.validate()
    return plan


def expansion_factor(plan: UniformPlan) -> float:
    """gamma(r, K) = S_ext / S_z (paper Eq. 19) for the uniform plan."""
    return plan.num_partitions * plan.window / plan.extent
