"""LP denoising engines: reference loop + the compiled fast path.

One LP forward pass = dynamic rotating partition -> parallel denoising ->
position-aware latent reconstruction (paper §3.2 workflow, Fig. 3).

Two loop drivers live here:

* :func:`lp_denoise_reference` — the original eager loop.  The denoiser
  for step ``i`` is a fresh Python closure with the timestep baked in, so
  nothing is (or can be) cached across steps.  Kept as the semantics
  oracle and the benchmark baseline.
* :func:`lp_denoise` + :class:`LPStepCompiler` — the production path.
  Timestep, scheduler scalars, and conditioning are **traced arguments**,
  so one jitted step function serves every timestep that shares a rotation
  dim; the compiled-step cache is keyed on (latent geometry, rotation dim,
  K, r, uniform, arg signatures) and ``z`` is donated.  Consecutive
  same-dim steps fuse into one ``lax.scan``.  A T-step denoise compiles at
  most once per rotation dim (<= 3 traces) instead of T times.

The production SPMD engines (``core/spmd.py``) plug in via the
``forward`` hook; both are cross-checked in tests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from contextlib import nullcontext
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .partition import PartitionPlan, extract, plan_partition
from .reconstruct import reconstruct
from .schedule import rotation_dim, usable_dims
from .uniform import UniformPlan, plan_uniform

# Reference-engine denoiser: maps a sub-latent (same rank as the latent)
# to its noise prediction of identical shape, timestep baked in.
DenoiseFn = Callable[[jnp.ndarray], jnp.ndarray]

# Fast-path denoiser: (window, t, *extras) -> pred, where ``t`` is a traced
# f32 scalar and ``extras`` carry traced conditioning (text context, CFG
# scale, ...).  CFG lives *inside* the fn (paper Eq. 4).
DenoiseStepFn = Callable[..., jnp.ndarray]


@dataclasses.dataclass
class DenoiseSnapshot:
    """Mid-denoise recovery point, recorded at dim-rotation / codec-
    segment boundaries.

    Pass one to :func:`lp_denoise` (the serving engine keeps one per
    batch attempt): after every completed scan run — a maximal stretch
    of same-dim, same-codec-segment steps — the latent and the step
    index are recorded here, and a later :func:`lp_denoise` call with
    the same snapshot resumes from that boundary instead of ``z_T``,
    bounding lost work to at most one dim-run.

    Why ``(z, step)`` is the WHOLE state: residual-codec wire state is
    re-zeroed at exactly these boundaries (dim switch, segment switch,
    re-plan — see ``LPStepCompiler.init_codec_state``), so the codec
    state to resume with is definitionally the fresh init the resumed
    run performs anyway — a boundary resume replays the fault-free
    arithmetic bit-for-bit.  ``z`` is kept as a HOST copy: it must
    survive both buffer donation by the next compiled step and the loss
    of the device that failed.
    """

    step: int = 0                       # last completed denoise step
    z: Optional[np.ndarray] = None      # host-resident latent at ``step``
    plan_epoch: int = 0                 # compiler epoch when recorded
    boundaries: int = 0                 # records taken (monitoring)
    resumes: int = 0                    # times a denoise resumed from here

    def record(self, step: int, z, plan_epoch: int = 0) -> None:
        self.step = int(step)
        self.z = np.asarray(z)
        self.plan_epoch = int(plan_epoch)
        self.boundaries += 1

    def clear(self) -> None:
        self.step, self.z, self.plan_epoch = 0, None, 0


def lp_forward(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: PartitionPlan,
    axis: int,
) -> jnp.ndarray:
    """One LP forward pass with a prebuilt (paper-exact) partition plan."""
    preds = []
    for k in range(plan.num_partitions):
        sub = extract(z, plan, k, axis)
        pred = denoise_fn(sub)
        if pred.shape != sub.shape:
            raise ValueError(
                f"denoise_fn changed the sub-latent shape: {sub.shape} -> {pred.shape}"
            )
        preds.append(pred)
    return reconstruct(preds, plan, axis)


def lp_forward_uniform(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: UniformPlan,
    axis: int,
    use_kernel: Optional[bool] = None,
) -> jnp.ndarray:
    """One LP forward pass on uniform windows, batched with vmap.

    This mirrors what every SPMD rank does: slice a fixed-size window,
    denoise, weight, scatter-add; here the K ranks are a vmapped leading
    axis and the reduction runs through ``spmd.blend_windows`` (which on
    TPU dispatches the fused Pallas stitch kernel — ``use_kernel``
    overrides the backend default).
    """
    from .spmd import blend_windows, stack_windows

    windows = stack_windows(z, plan, axis)
    preds = jax.vmap(denoise_fn)(windows)
    return blend_windows(preds, plan, axis, use_kernel=use_kernel).astype(z.dtype)


# ------------------------------------------------------------ compiled path
def _abstract_sig(tree: Any) -> Tuple:
    """Hashable (treedef, shapes/dtypes) signature of a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(tree)
    return (
        treedef,
        tuple((jnp.shape(l), jnp.result_type(l).name) for l in leaves),
    )


class LPStepCompiler:
    """LRU cache of jitted LP step functions.

    One entry per ``(z geometry, rotation dim, scan length, K, r, uniform,
    scalars/extras signature)``.  The built step takes ``(z, t, scalars,
    extras)`` with everything but the static partition geometry traced, and
    donates ``z`` so the latent updates in place across the T-step loop.

    ``forward`` overrides the per-step LP engine, e.g.
    ``lambda fn, z, plan, axis: lp_forward_halo(fn, z, plan, axis, mesh)``
    to run the halo-exchange collective inside the compiled step.

    ``codec`` (a ``comm.codecs`` name or instance) compresses the LP
    wire payloads.  Stateless codecs (bf16/int8/int4) only change the
    per-step forward; residual codecs carry state (previous decoded
    slabs + error-feedback carries) which this cache threads through the
    ``lax.scan`` carry — never through re-traced closures — so a T-step
    denoise still compiles at most once per rotation dim.  With a codec
    and no custom ``forward``, steps run through
    ``comm.wire.simulate_halo_forward`` (the single-process mirror of
    the halo collective; pass a mesh-bound ``forward`` for real SPMD,
    stateful hooks take/return ``(pred, state)``).

    ``mesh_shape`` records the ``(lp, tp)`` mesh the ``forward`` hook is
    bound to (e.g. ``(M, T)`` for the hybrid engine).  It is part of the
    cache key together with the full partition geometry ``(K, r)``, so a
    mid-request :meth:`replan` — straggler eviction, elastic mesh change
    — can NEVER be served a stale entry compiled for the old mesh shape.

    ``schedule`` (a ``policy.CodecSchedule`` or spec string) varies the
    wire codec over the denoise: ``lp_denoise`` resolves the sigma
    thresholds against the sampler's trajectory and runs each (dim-run x
    codec-segment) as its own ``lax.scan``, passing the segment codec to
    :meth:`step_fn` per call.  The segment codec is part of the cache
    key, residual state is created fresh per segment (reset exactly once
    at every boundary), and compiles stay <= 3 x num_segments per
    denoise.  ``forward_factory`` is the scheduled twin of ``forward``:
    called with each segment's codec, it returns the mesh-bound hook for
    that codec (stateless hooks take ``(fn, z, plan, axis)``, stateful
    ones ``(fn, z, plan, axis, state)`` and return ``(pred, state)``).
    """

    def __init__(
        self,
        denoise_fn: DenoiseStepFn,
        update_fn: Callable[[jnp.ndarray, jnp.ndarray, Any], jnp.ndarray],
        num_partitions: int,
        overlap_ratio: float,
        patch_sizes: Sequence[int],
        spatial_axes: Sequence[int] = (1, 2, 3),
        uniform: bool = False,
        forward: Optional[Callable] = None,
        use_kernel: Optional[bool] = None,
        donate: bool = True,
        maxsize: int = 32,
        codec=None,
        mesh_shape: Optional[Tuple[int, ...]] = None,
        schedule=None,
        forward_factory: Optional[Callable] = None,
        wire_shard: bool = False,
        nan_guard: bool = False,
    ):
        self.denoise_fn = denoise_fn
        self.update_fn = update_fn
        self.num_partitions = num_partitions
        self.overlap_ratio = overlap_ratio
        self.patch_sizes = tuple(patch_sizes)
        self.spatial_axes = tuple(spatial_axes)
        self.uniform = uniform
        self.forward = forward
        self.use_kernel = use_kernel
        self.donate = donate
        self.maxsize = maxsize
        self.mesh_shape = None if mesh_shape is None else tuple(mesh_shape)
        self.forward_factory = forward_factory
        # records whether the bound forward hooks run the tp-sharded
        # wire (core/hybrid.lp_forward_halo_hybrid(wire_shard=True));
        # part of the cache key so a replan that swaps the hook for a
        # differently-wired one can never be served a stale entry
        self.wire_shard = bool(wire_shard)
        # arm the wire decode NaN/Inf guard on the simulate mirror
        # (mesh-bound hooks carry their own flag).  Fixed for the
        # compiler's lifetime — identity on finite wires, so it is NOT
        # part of the cache key
        self.nan_guard = bool(nan_guard)
        if schedule is not None:
            from repro.policy.schedule import parse_schedule

            schedule = parse_schedule(schedule)
            if codec is not None:
                raise ValueError(
                    "pass codec= (fixed) or schedule= (sigma-varying), "
                    "not both"
                )
            if forward is not None and forward_factory is None:
                raise ValueError(
                    "a codec schedule cannot run through a fixed "
                    "forward= hook (it is bound to one codec and would "
                    "silently ignore the segments) — pass a "
                    "forward_factory that binds the hook per segment "
                    "codec"
                )
            if not uniform and forward_factory is None:
                raise ValueError(
                    "codec schedules need the uniform-window halo "
                    "geometry (uniform=True) or a forward_factory hook"
                )
        self.schedule = schedule
        if codec is not None:
            from repro.comm.codecs import get_codec

            codec = get_codec(codec)
            if not uniform and forward is None:
                raise ValueError(
                    "wire codecs need the uniform-window halo geometry "
                    "(uniform=True) or a custom forward hook"
                )
        self.codec = codec
        self._cache: "OrderedDict[Tuple, Callable]" = OrderedDict()
        self.compiles = 0
        self.hits = 0
        # re-planning bookkeeping: the epoch bumps on every geometry
        # change so in-flight loops (lp_denoise) reset codec residual
        # state exactly once at the next step boundary; state_inits
        # counts init_codec_state calls (regression-tested).
        self.plan_epoch = 0
        self.state_inits = 0

    def replan(
        self,
        num_partitions: Optional[int] = None,
        overlap_ratio: Optional[float] = None,
        mesh_shape: Optional[Tuple[int, ...]] = None,
        forward: Optional[Callable] = None,
        forward_factory: Optional[Callable] = None,
        wire_shard: Optional[bool] = None,
    ) -> bool:
        """Mid-request re-plan: swap the partition geometry / mesh shape.

        Safe to call from a ``lp_denoise`` ``step_hook`` (straggler- or
        elasticity-triggered): the full geometry ``(K, r, mesh_shape)``
        is part of the step-cache key, so entries compiled for the old
        plan can never be hit again (they age out of the LRU), and the
        ``plan_epoch`` bump makes the in-flight denoise loop re-derive
        its rotation dims and re-zero codec residual state exactly once
        — old-geometry state shapes would be garbage on the new plan.
        Returns True when anything actually changed.
        """
        if wire_shard is not None and bool(wire_shard) != self.wire_shard:
            # a mesh-bound hook closes over its wire layout: flipping
            # the flag without re-binding would key (and report) the new
            # wire while executing the old one — same stale-hook hazard
            # replan_lp_compiler raises for on a K change.  Checked
            # before any mutation so a raise leaves the plan untouched.
            if (self.forward is not None and forward is None) or \
                    (self.forward_factory is not None and
                     forward_factory is None):
                raise ValueError(
                    "changing wire_shard on a compiler with a bound "
                    "forward hook needs a re-bound forward= / "
                    "forward_factory= in the same replan call"
                )
        changed = False
        if num_partitions is not None and num_partitions != self.num_partitions:
            self.num_partitions = num_partitions
            changed = True
        if overlap_ratio is not None and overlap_ratio != self.overlap_ratio:
            self.overlap_ratio = overlap_ratio
            changed = True
        if mesh_shape is not None and tuple(mesh_shape) != self.mesh_shape:
            self.mesh_shape = tuple(mesh_shape)
            changed = True
        if forward is not None and forward is not self.forward:
            # a new mesh needs a re-bound collective hook
            self.forward = forward
            changed = True
        if forward_factory is not None and \
                forward_factory is not self.forward_factory:
            self.forward_factory = forward_factory
            changed = True
        if wire_shard is not None and bool(wire_shard) != self.wire_shard:
            self.wire_shard = bool(wire_shard)
            changed = True
        if changed:
            self.plan_epoch += 1
        return changed

    @property
    def stateful(self) -> bool:
        return self.codec is not None and self.codec.stateful

    def _codec_for(self, codec):
        """Per-call codec resolution: ``None`` means the compiler's own
        fixed codec (legacy behaviour); segment codecs come in as Codec
        instances (or names) from the schedule-resolved denoise loop."""
        if codec is None:
            return self.codec
        from repro.comm.codecs import get_codec

        return get_codec(codec)

    # ------------------------------------------------------------- plans
    def _plan(self, dim: int, extent: int):
        if self.uniform:
            return plan_uniform(
                extent, self.patch_sizes[dim], self.num_partitions,
                self.overlap_ratio, dim,
            )
        return plan_partition(
            extent, self.patch_sizes[dim], self.num_partitions,
            self.overlap_ratio, dim,
        )

    def _forward(self, fn: DenoiseFn, z, plan, axis, codec=None):
        codec = self._codec_for(codec)
        if self.forward_factory is not None and codec is not None:
            return self.forward_factory(codec)(fn, z, plan, axis)
        if self.forward is not None:
            return self.forward(fn, z, plan, axis)
        if codec is not None:
            from repro.comm.wire import simulate_halo_forward

            return simulate_halo_forward(fn, z, plan, axis, codec,
                                         nan_guard=self.nan_guard)
        if self.uniform:
            return lp_forward_uniform(fn, z, plan, axis, use_kernel=self.use_kernel)
        return lp_forward(fn, z, plan, axis)

    def _forward_stateful(self, fn: DenoiseFn, z, plan, axis, state,
                          codec=None):
        """Codec-state-threading forward: returns (pred, new_state)."""
        codec = self._codec_for(codec)
        if self.forward_factory is not None:
            return self.forward_factory(codec)(fn, z, plan, axis, state)
        if self.forward is not None:
            return self.forward(fn, z, plan, axis, state)
        from repro.comm.wire import simulate_halo_forward

        return simulate_halo_forward(fn, z, plan, axis, codec, state,
                                     nan_guard=self.nan_guard)

    def init_codec_state(self, dim: int, z: jnp.ndarray, codec=None):
        """Zeroed residual-codec state for (rotation dim, latent geometry).

        ``lp_denoise`` creates this fresh at the start of every same-dim,
        same-codec-segment scan run (temporal deltas are only meaningful
        between consecutive steps along one rotation dim, and a segment
        boundary switches the wire protocol) — which also guarantees no
        codec state leaks across serving requests."""
        codec = self._codec_for(codec)
        if codec is None or not codec.stateful:
            return None
        from repro.comm.wire import init_halo_wire_state
        from repro.distributed.collectives import halo_spec

        self.state_inits += 1
        axis = self.spatial_axes[dim]
        plan = self._plan(dim, z.shape[axis])
        rest = tuple(s for i, s in enumerate(z.shape) if i != axis)
        return init_halo_wire_state(codec, halo_spec(plan), rest)

    # ------------------------------------------------------------- build
    def step_fn(
        self, dim: int, z: jnp.ndarray, n: int, scalars: Any, extras: Tuple,
        codec=None,
    ) -> Callable:
        codec = self._codec_for(codec)
        key = (
            dim, n, tuple(z.shape), jnp.result_type(z).name,
            _abstract_sig(scalars), _abstract_sig(extras),
            None if codec is None else codec.name,
            # full plan geometry + epoch: a mid-request replan (new K/r,
            # new mesh shape, re-bound forward hook) can never be served
            # an entry compiled for the old plan
            self.num_partitions, self.overlap_ratio, self.mesh_shape,
            self.wire_shard, self.plan_epoch,
        )
        cached = self._cache.get(key)
        if cached is not None:
            self._cache.move_to_end(key)
            self.hits += 1
            return cached
        axis = self.spatial_axes[dim]
        plan = self._plan(dim, z.shape[axis])
        den, upd = self.denoise_fn, self.update_fn

        if codec is not None and codec.stateful:
            # codec state rides the scan carry next to z — the step stays
            # one compiled function per (rotation dim, codec segment)
            if n == 1:
                def step(zc, st, t, sc, extras):
                    pred, st = self._forward_stateful(
                        lambda w: den(w, t, *extras), zc, plan, axis, st,
                        codec,
                    )
                    return upd(zc, pred, sc), st
            else:
                def step(zc, st, ts, scs, extras):
                    def body(carry, x):
                        zb, s = carry
                        t, sc = x
                        pred, s = self._forward_stateful(
                            lambda w: den(w, t, *extras), zb, plan, axis, s,
                            codec,
                        )
                        return (upd(zb, pred, sc), s), None
                    (out, st), _ = jax.lax.scan(body, (zc, st), (ts, scs))
                    return out, st
        elif n == 1:
            def step(zc, t, sc, extras):
                pred = self._forward(
                    lambda w: den(w, t, *extras), zc, plan, axis, codec
                )
                return upd(zc, pred, sc)
        else:
            def step(zc, ts, scs, extras):
                def body(zb, x):
                    t, sc = x
                    pred = self._forward(
                        lambda w: den(w, t, *extras), zb, plan, axis, codec
                    )
                    return upd(zb, pred, sc), None
                out, _ = jax.lax.scan(body, zc, (ts, scs))
                return out

        fn = jax.jit(step, donate_argnums=(0,) if self.donate else ())
        self._cache[key] = fn
        if len(self._cache) > self.maxsize:
            self._cache.popitem(last=False)
        self.compiles += 1
        return fn


def lp_denoise(
    denoise_fn: Optional[DenoiseStepFn],
    z_T: jnp.ndarray,
    sampler,
    num_steps: int,
    num_partitions: int,
    overlap_ratio: float,
    patch_sizes: Sequence[int],
    spatial_axes: Sequence[int],
    uniform: bool = False,
    extras: Tuple = (),
    compiler: Optional[LPStepCompiler] = None,
    fuse_scan: bool = True,
    step_hook: Optional[Callable[[int], None]] = None,
    codec=None,
    schedule=None,
    snapshot: Optional[DenoiseSnapshot] = None,
    recorder=None,
) -> jnp.ndarray:
    """Full T-step LP denoising on the compiled fast path.

    ``denoise_fn(window, t, *extras)`` takes the timestep (and any
    conditioning in ``extras``) as traced arguments; ``sampler`` provides
    ``timestep(i)`` / ``step_scalars(i)`` / ``update(z, pred, scalars)``
    (see ``diffusion/sampler.py``).  Pass a prebuilt ``compiler`` to reuse
    compiled steps across calls (the serving engine does, across batches);
    otherwise one is created for this call — either way a run traces at
    most once per rotation dim.  ``step_hook(i)`` fires outside the
    compiled region (fault injection, straggler accounting); setting it
    disables scan fusion so the hook really does run between steps.

    ``codec`` compresses LP wire payloads; ``schedule`` (a
    ``policy.CodecSchedule`` or spec string, mutually exclusive with
    ``codec``) varies the codec over sigma — both are ignored when
    ``compiler`` is given (the compiler owns the policy then).  A
    schedule is resolved against the sampler's sigma trajectory and
    executed as **segmented scans**: every (rotation-dim run x codec
    segment) is one compiled step / one ``lax.scan``, so a T-step
    denoise compiles at most ``3 x num_segments`` times.  Residual-codec
    state is zeroed at every rotation-dim switch, at every codec-segment
    boundary (exactly once per boundary), and at every mid-request
    re-plan (exactly once), and discarded at the end of the call:
    temporal deltas only span consecutive same-dim, same-segment steps —
    whether fused into one scan or stepped through a hook — and state
    can never leak across calls (or serving requests).  A ``step_hook``
    may call ``compiler.replan(...)`` (straggler / elastic re-planning):
    the next step re-derives its rotation dims and compiles against the
    new geometry; stale cache entries for the old plan are unreachable.

    ``snapshot`` (a :class:`DenoiseSnapshot`) arms boundary
    checkpointing: the latent is recorded (host copy) after every
    completed run — dim switch, codec-segment switch, or re-plan — and
    a call whose snapshot already holds a recorded step resumes from it
    (skipping steps ``<= snapshot.step``) instead of starting at
    ``z_T``.  The serving engine's failed-batch retry rides this: lost
    work is bounded by one dim-run, and because boundaries are exactly
    where residual codec state is re-zeroed, a boundary resume replays
    the fault-free arithmetic bit-for-bit.  A resume after a re-plan is
    fine too — the snapshot holds the full (geometry-independent)
    latent, and the resumed steps re-derive dims from the compiler's
    current K.

    ``recorder`` (a ``repro.obs.FlightRecorder``; duck-typed so core
    never imports obs) wraps every compiled dispatch in a trace span +
    ``jax.profiler.TraceAnnotation`` and feeds the run/step latency
    histograms.  It is pure host state — NEVER passed into the jitted
    step and never part of the compile cache key — so enabling it can
    change neither compile counts nor numerics
    (``benchmarks/obs_overhead.py`` gates both).  Per-step wire bytes
    are NOT probed here: the serving engine derives them by replaying
    ``comm_model`` (``repro.obs.account``) against the executed
    geometry.  Note the spans block on the dispatched value, so device
    work is attributed to its own span instead of the next one.
    """
    if step_hook is not None:
        fuse_scan = False
    comp = compiler
    if comp is None:
        if denoise_fn is None:
            raise ValueError("need denoise_fn when no compiler is given")
        comp = LPStepCompiler(
            denoise_fn, sampler.update, num_partitions, overlap_ratio,
            patch_sizes, spatial_axes, uniform=uniform, codec=codec,
            schedule=schedule,
        )

    # Resolve the (possibly absent) codec schedule to one codec per
    # forward pass.  ``None`` entries mean "the compiler's fixed codec"
    # — the legacy path, bit-identical to pre-schedule behaviour.
    active_schedule = comp.schedule
    if active_schedule is not None:
        from repro.comm.codecs import get_codec as _get_codec
        from repro.policy.schedule import trajectory_sigmas

        _sigmas = trajectory_sigmas(sampler, num_steps)
        step_codecs = [
            _get_codec(n) for n in active_schedule.step_codecs(_sigmas)
        ]
    else:
        step_codecs = [None] * num_steps

    def _codec_key(c):
        return None if c is None else c.name

    def _stateful(c):
        return comp.stateful if c is None else c.stateful

    def _dims():
        # from the compiler's CURRENT geometry: a step_hook may replan K
        # mid-request (runtime/straggler + runtime/elastic)
        dims = usable_dims(
            [z_T.shape[comp.spatial_axes[d]] for d in range(3)],
            comp.patch_sizes,
            comp.num_partitions,
        )
        if not dims:
            raise ValueError(
                f"no latent dim has >= {comp.num_partitions} patches; reduce K"
            )
        return dims

    dims = _dims()
    start = 0
    if snapshot is not None and snapshot.z is not None and snapshot.step > 0:
        # resume from the last boundary: fresh device buffer from the
        # host copy (donation-safe; the snapshot itself is untouched, so
        # a second resume from the same boundary also works)
        start = min(int(snapshot.step), num_steps)
        snapshot.resumes += 1
        if recorder is not None:
            recorder.record_resume(start)
        z = jnp.asarray(snapshot.z).astype(z_T.dtype)
    else:
        # private copy: the first step donates its input buffer, and the
        # caller's z_T must survive the call
        z = jnp.array(z_T, copy=True) if comp.donate else jnp.asarray(z_T)

    if fuse_scan:
        # group consecutive same-dim, same-codec-segment steps into
        # scan-fused runs; codec state is zeroed per run (consecutive
        # runs switch dims or cross a segment boundary, and neither
        # dim-foreign nor protocol-foreign state may carry over)
        runs: list = []
        for i in range(1, num_steps + 1):
            dim = rotation_dim(i, dims)
            ck = _codec_key(step_codecs[i - 1])
            if runs and runs[-1][0] == (dim, ck):
                runs[-1][1].append(i)
            else:
                runs.append(((dim, ck), [i]))
        for (dim, ck), idxs in runs:
            # resume support: runs at or before the snapshot boundary are
            # already done.  (A run can straddle ``start`` only when the
            # snapshot was taken under a different geometry — e.g. an
            # eviction changed the usable dims — the leftover steps run
            # as a sub-run with fresh state, which error feedback
            # absorbs.)
            idxs = [i for i in idxs if i > start]
            if not idxs:
                continue
            seg_codec = step_codecs[idxs[0] - 1]
            stateful = _stateful(seg_codec)
            ts = [np.float32(sampler.timestep(i)) for i in idxs]
            scs = [sampler.step_scalars(i) for i in idxs]
            st = comp.init_codec_state(dim, z, seg_codec) if stateful else None
            ck_name = ck or getattr(comp.codec, "name", "none")
            span = (nullcontext() if recorder is None else
                    recorder.device_span("denoise.run", dim=dim,
                                         codec=ck_name, start=idxs[0],
                                         stop=idxs[-1], n=len(idxs),
                                         epoch=comp.plan_epoch))
            t0 = time.perf_counter()
            with span:
                if len(idxs) == 1:
                    fn = comp.step_fn(dim, z, 1, scs[0], extras,
                                      codec=seg_codec)
                    if stateful:
                        z, _ = fn(z, st, ts[0], scs[0], extras)
                    else:
                        z = fn(z, ts[0], scs[0], extras)
                else:
                    ts_arr = jnp.asarray(np.stack(ts))
                    scs_arr = jax.tree.map(
                        lambda *xs: jnp.asarray(np.stack(xs)), *scs
                    )
                    fn = comp.step_fn(dim, z, len(idxs), scs_arr, extras,
                                      codec=seg_codec)
                    if stateful:
                        z, _ = fn(z, st, ts_arr, scs_arr, extras)
                    else:
                        z = fn(z, ts_arr, scs_arr, extras)
                if recorder is not None:
                    jax.block_until_ready(z)
            if recorder is not None:
                recorder.record_run(idxs[0], idxs[-1],
                                    time.perf_counter() - t0,
                                    dim=dim, codec=ck_name,
                                    epoch=comp.plan_epoch)
            if snapshot is not None and idxs[-1] < num_steps:
                snapshot.record(idxs[-1], z, comp.plan_epoch)
                if recorder is not None:
                    recorder.record_snapshot(idxs[-1])
        return z

    # Unfused (step_hook) path: one compiled step per call, codec state
    # carried across consecutive same-dim, same-segment steps (temporal
    # deltas stay meaningful between steps) and reset on a dim switch, a
    # codec-segment boundary, or a re-plan.  The hook may call
    # ``comp.replan(...)``: the epoch bump re-derives the rotation dims
    # and resets residual state exactly once — old state shapes would be
    # garbage on the new plan.
    cur_state = None
    cur_dim = None
    cur_codec_key = None
    cur_epoch = comp.plan_epoch
    for i in range(start + 1, num_steps + 1):
        if step_hook is not None:
            step_hook(i)
        if comp.plan_epoch != cur_epoch:      # mid-request re-plan
            cur_epoch = comp.plan_epoch
            dims = _dims()
            cur_state, cur_dim = None, None
            if recorder is not None:
                recorder.record_replan(i, comp.num_partitions, cur_epoch)
            if snapshot is not None and i - 1 >= max(start, 1):
                # a re-plan is a boundary too (state re-zeroes here):
                # record the pre-replan latent so a failure during the
                # first post-replan step resumes right before it.  The
                # ``i == start + 1`` case (a replan firing on the FIRST
                # resumed step) must re-record too: ``z`` equals the
                # snapshot's latent then, but the record re-stamps the
                # boundary with the NEW epoch — a second fault resumes
                # from a boundary whose epoch matches the geometry its
                # replay will re-derive, never a pre-replan stamp.
                snapshot.record(i - 1, z, cur_epoch)
                if recorder is not None:
                    recorder.record_snapshot(i - 1)
        dim = rotation_dim(i, dims)
        seg_codec = step_codecs[i - 1]
        ck = _codec_key(seg_codec)
        stateful = _stateful(seg_codec)
        t = np.float32(sampler.timestep(i))
        sc = sampler.step_scalars(i)
        if stateful and (cur_state is None or dim != cur_dim
                         or ck != cur_codec_key):
            cur_state = comp.init_codec_state(dim, z, seg_codec)
        cur_dim, cur_codec_key = dim, ck
        ck_name = ck or getattr(comp.codec, "name", "none")
        span = (nullcontext() if recorder is None else
                recorder.device_span("denoise.step", dim=dim, step=i,
                                     codec=ck_name, epoch=comp.plan_epoch))
        t0 = time.perf_counter()
        with span:
            fn = comp.step_fn(dim, z, 1, sc, extras, codec=seg_codec)
            if stateful:
                z, cur_state = fn(z, cur_state, t, sc, extras)
            else:
                z = fn(z, t, sc, extras)
            if recorder is not None:
                jax.block_until_ready(z)
        if recorder is not None:
            recorder.record_run(i, i, time.perf_counter() - t0,
                                dim=dim, codec=ck_name,
                                epoch=comp.plan_epoch)
        if snapshot is not None and i < num_steps:
            nxt = rotation_dim(i + 1, dims)
            nxt_ck = _codec_key(step_codecs[i])
            if nxt != dim or nxt_ck != ck:    # step i ends a run
                snapshot.record(i, z, comp.plan_epoch)
                if recorder is not None:
                    recorder.record_snapshot(i)
    return z


# ---------------------------------------------------------- reference loop
def lp_denoise_reference(
    denoise_fn_for_step: Callable[[int, int], DenoiseFn],
    z_T: jnp.ndarray,
    scheduler_update: Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray],
    num_steps: int,
    num_partitions: int,
    overlap_ratio: float,
    patch_sizes: Sequence[int],
    spatial_axes: Sequence[int],
    uniform: bool = False,
) -> jnp.ndarray:
    """The original eager T-step loop (paper Fig. 3, Eqs. 3-6).

    ``denoise_fn_for_step(i, dim)`` returns the guided denoiser for forward
    pass ``i`` (1-indexed) with the timestep baked into the closure;
    ``scheduler_update(z, pred, i)`` is S(.) of Eq. 6.  Every step builds a
    fresh closure, so nothing caches — this is the semantics oracle the
    compiled path is tested against, and the benchmark baseline.
    """
    dims = usable_dims(
        [z_T.shape[spatial_axes[d]] for d in range(3)],
        patch_sizes,
        num_partitions,
    )
    if not dims:
        raise ValueError(
            f"no latent dim has >= {num_partitions} patches; reduce K"
        )
    z = z_T
    for i in range(1, num_steps + 1):
        dim = rotation_dim(i, dims)
        axis = spatial_axes[dim]
        fn = denoise_fn_for_step(i, dim)
        if uniform:
            plan = plan_uniform(
                z.shape[axis], patch_sizes[dim], num_partitions, overlap_ratio, dim
            )
            pred = lp_forward_uniform(fn, z, plan, axis)
        else:
            plan = plan_partition(
                z.shape[axis], patch_sizes[dim], num_partitions, overlap_ratio, dim
            )
            pred = lp_forward(fn, z, plan, axis)
        z = scheduler_update(z, pred, i)
    return z
