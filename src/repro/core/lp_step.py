"""Single-host LP reference engine (paper §3.2 workflow, Fig. 3).

One LP forward pass = dynamic rotating partition -> parallel denoising ->
position-aware latent reconstruction.  This module is the *reference*
implementation: partitions are the paper-exact variable-size slices, the
"parallel" denoising is a Python loop (or a vmap for uniform windows), and
reconstruction is the scatter-add of ``core/reconstruct.py``.

The production SPMD engine (``core/spmd.py``) computes identical math with
shard_map + one psum; both are cross-checked in tests.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .partition import PartitionPlan, extract, plan_partition
from .reconstruct import reconstruct
from .schedule import rotation_dim, usable_dims
from .uniform import UniformPlan, plan_uniform

# denoise_fn maps a sub-latent (same rank as the latent) to its noise
# prediction of identical shape.  CFG is expected to live *inside* the fn
# (paper Eq. 4: each partition computes its own guided prediction).
DenoiseFn = Callable[[jnp.ndarray], jnp.ndarray]


def lp_forward(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: PartitionPlan,
    axis: int,
) -> jnp.ndarray:
    """One LP forward pass with a prebuilt (paper-exact) partition plan."""
    preds = []
    for k in range(plan.num_partitions):
        sub = extract(z, plan, k, axis)
        pred = denoise_fn(sub)
        if pred.shape != sub.shape:
            raise ValueError(
                f"denoise_fn changed the sub-latent shape: {sub.shape} -> {pred.shape}"
            )
        preds.append(pred)
    return reconstruct(preds, plan, axis)


def lp_forward_uniform(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: UniformPlan,
    axis: int,
) -> jnp.ndarray:
    """One LP forward pass on uniform windows, batched with vmap.

    This mirrors what every SPMD rank does: slice a fixed-size window,
    denoise, weight, scatter-add; here the K ranks are a vmapped leading
    axis and the psum is a sum over it.
    """
    K = plan.num_partitions
    windows = jnp.stack(
        [
            jax.lax.dynamic_slice_in_dim(z, plan.starts[k], plan.window, axis)
            for k in range(K)
        ]
    )
    preds = jax.vmap(denoise_fn)(windows)
    acc = jnp.zeros(
        z.shape[:axis] + (plan.extent,) + z.shape[axis + 1 :], dtype=jnp.float32
    )
    for k in range(K):
        w = plan.weight_1d(k)
        shape = [1] * z.ndim
        shape[axis] = plan.window
        wk = jnp.asarray(w).reshape(shape)
        idx = [slice(None)] * z.ndim
        idx[axis] = slice(plan.starts[k], plan.starts[k] + plan.window)
        acc = acc.at[tuple(idx)].add(preds[k].astype(jnp.float32) * wk)
    norm_shape = [1] * z.ndim
    norm_shape[axis] = plan.extent
    zn = jnp.asarray(plan.normalizer()).reshape(norm_shape)
    return (acc / zn).astype(z.dtype)


def lp_denoise(
    denoise_fn_for_step: Callable[[int, int], DenoiseFn],
    z_T: jnp.ndarray,
    scheduler_update: Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray],
    num_steps: int,
    num_partitions: int,
    overlap_ratio: float,
    patch_sizes: Sequence[int],
    spatial_axes: Sequence[int],
    uniform: bool = False,
) -> jnp.ndarray:
    """Full T-step LP denoising loop (paper Fig. 3, Eqs. 3-6).

    ``denoise_fn_for_step(i, dim)`` returns the guided denoiser for forward
    pass ``i`` (1-indexed); ``scheduler_update(z, pred, i)`` is S(.) of
    Eq. 6.  ``spatial_axes`` maps dim 0/1/2 (T/H/W) to axes of ``z``.
    """
    dims = usable_dims(
        [z_T.shape[spatial_axes[d]] for d in range(3)],
        patch_sizes,
        num_partitions,
    )
    if not dims:
        raise ValueError(
            f"no latent dim has >= {num_partitions} patches; reduce K"
        )
    z = z_T
    for i in range(1, num_steps + 1):
        dim = rotation_dim(i, dims)
        axis = spatial_axes[dim]
        fn = denoise_fn_for_step(i, dim)
        if uniform:
            plan = plan_uniform(
                z.shape[axis], patch_sizes[dim], num_partitions, overlap_ratio, dim
            )
            pred = lp_forward_uniform(fn, z, plan, axis)
        else:
            plan = plan_partition(
                z.shape[axis], patch_sizes[dim], num_partitions, overlap_ratio, dim
            )
            pred = lp_forward(fn, z, plan, axis)
        z = scheduler_update(z, pred, i)
    return z
