"""SPMD LP engine — Latent Parallelism on a TPU mesh axis.

TPU adaptation of the paper's master/scatter-gather workflow (DESIGN.md §2):

* the latent is **replicated** along the lp mesh axis, so the "dynamic
  rotating partition" (scatter) is a *local slice* — zero communication;
* each rank denoises its uniform window (paper Eq. 4), weights it with its
  trapezoid mask (Eq. 12), and scatters it into a zero global buffer;
* "latent reconstruction" (Eqs. 15-17) is a single ``psum`` over the lp
  axis followed by a local divide with the analytically known normalizer
  (Eq. 16 needs no communication — weights depend on geometry only).

Two formulations compute identical math:

* :func:`stack_windows` / :func:`blend_windows` — pure functions used with
  GSPMD: stack the K windows on a leading axis sharded over the lp axis and
  let the partitioner place the slice / reduce.  Composes transparently
  with tensor-parallel sharding constraints inside the denoiser.
* :func:`lp_forward_shard_map` — explicit shard_map: guarantees the
  collective schedule (one psum of latent size per step) independent of
  partitioner heuristics.  Used by the serving engine and the dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

from .uniform import UniformPlan

DenoiseFn = Callable[[jnp.ndarray], jnp.ndarray]


# --------------------------------------------------------------- pure math
def stack_windows(z: jnp.ndarray, plan: UniformPlan, axis: int) -> jnp.ndarray:
    """(K, ..., window, ...) stack of the K uniform windows of ``z``."""
    return jnp.stack(
        [
            jax.lax.dynamic_slice_in_dim(z, plan.starts[k], plan.window, axis)
            for k in range(plan.num_partitions)
        ]
    )


def window_weights(plan: UniformPlan) -> np.ndarray:
    """(K, window) trapezoid masks, float32."""
    return np.stack([plan.weight_1d(k) for k in range(plan.num_partitions)])


def blend_windows(
    preds: jnp.ndarray, plan: UniformPlan, axis: int,
    use_kernel: bool | None = None,
) -> jnp.ndarray:
    """Position-aware reconstruction of stacked window predictions.

    ``preds``: (K, ...) with the partition dim at ``axis`` of each element
    (i.e. ``axis + 1`` of the stacked tensor).  The sum over the leading K
    axis is what GSPMD lowers to a reduce over the lp mesh axis.

    ``use_kernel=None`` auto-selects the fused Pallas stitch kernel
    (``kernels/latent_blend``) on TPU — one pass over the output instead
    of the K+2 latent-sized HBM round trips of the jnp scatter-add below.
    Off-TPU the kernel only runs in (slow, Python) interpret mode, so it
    stays opt-in there (tests force it on small shapes).
    """
    K = plan.num_partitions
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels import ops

        interpret = jax.default_backend() != "tpu"
        p = jnp.moveaxis(preds, axis + 1, 1)        # (K, W, rest...)
        rest = p.shape[2:]
        flat = int(np.prod(rest)) if rest else 1
        out = ops.latent_blend(
            p.reshape(K, plan.window, flat),
            jnp.asarray(window_weights(plan)),
            jnp.asarray(plan.normalizer()),
            plan.starts, plan.window, plan.extent,
            interpret=interpret,
        )
        return jnp.moveaxis(out.reshape((plan.extent,) + rest), 0, axis)
    w = jnp.asarray(window_weights(plan))  # (K, window)
    wshape = [1] * (preds.ndim - 1)
    wshape[axis] = plan.window
    weighted = preds.astype(jnp.float32) * w.reshape((K, *wshape))
    out_shape = list(preds.shape[1:])
    out_shape[axis] = plan.extent
    zero = jnp.zeros(out_shape, jnp.float32)
    starts = jnp.asarray(plan.starts)

    def scatter(buf, pred_k, start_k):
        return jax.lax.dynamic_update_slice_in_dim(buf, pred_k, start_k, axis)

    scattered = jax.vmap(scatter, in_axes=(None, 0, 0))(zero, weighted, starts)
    acc = scattered.sum(axis=0)
    norm_shape = [1] * acc.ndim
    norm_shape[axis] = plan.extent
    norm = jnp.asarray(plan.normalizer()).reshape(norm_shape)
    return (acc / norm).astype(preds.dtype)


def blend_windows_coded(
    preds: jnp.ndarray, plan: UniformPlan, axis: int,
    codec="int8", use_kernel: bool | None = None,
) -> jnp.ndarray:
    """Blend stacked window predictions that crossed a quantized wire.

    Each of the K window predictions is round-tripped through the codec
    exactly as the stacked engine would ship it (one per-slab scale per
    window).  For int8 the round trip is fully fused on TPU: a two-phase
    Pallas quantize (``kernels/wire_codec.int8_quantize``) and a
    dequantize+blend kernel (``dequant_blend``) that never materializes
    the dequantized f32 windows in HBM.  Other codecs decode and reuse
    :func:`blend_windows`.
    """
    from repro.comm.codecs import get_codec

    codec = get_codec(codec)
    K = plan.num_partitions
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if codec.name == "int8" and use_kernel:
        from repro.kernels import ops

        interpret = jax.default_backend() != "tpu"
        p = jnp.moveaxis(preds, axis + 1, 1)         # (K, W, rest...)
        rest = p.shape[2:]
        flat = int(np.prod(rest)) if rest else 1
        p = p.reshape(K, plan.window, flat)
        wires, scales = [], []
        for k in range(K):
            wire, scale = ops.int8_quantize(p[k], interpret=interpret)
            wires.append(wire)
            scales.append(scale[0, 0])
        out = ops.dequant_blend(
            jnp.stack(wires), jnp.stack(scales),
            jnp.asarray(window_weights(plan)),
            jnp.asarray(plan.normalizer()),
            plan.starts, plan.window, plan.extent,
            interpret=interpret, out_dtype=preds.dtype,
        )
        return jnp.moveaxis(out.reshape((plan.extent,) + rest), 0, axis)
    # vmapped over the stacked axis (one per-slab scale per window): under
    # GSPMD this keeps the axis sharded over the lp axis — a per-k Python
    # loop of dynamic slices would force an all-gather of the stack
    roundtripped = jax.vmap(
        lambda p: codec.decode(*codec.encode(p), p.shape)
    )(preds).astype(preds.dtype)
    return blend_windows(roundtripped, plan, axis, use_kernel=use_kernel)


def lp_forward_stacked(
    denoise_fn: DenoiseFn, z: jnp.ndarray, plan: UniformPlan, axis: int
) -> jnp.ndarray:
    """Full LP forward in stacked form: slice -> vmap(denoise) -> blend.

    Under jit with the stacked axis sharded over the lp mesh axis, each
    device runs exactly one window; without a mesh this is the vmapped
    reference (tested against ``lp_forward_uniform``).
    """
    windows = stack_windows(z, plan, axis)
    preds = jax.vmap(denoise_fn)(windows)
    # jnp form: this function's point is GSPMD composability (stacked axis
    # sharded over the lp mesh axis) — the partitioner needs the visible
    # scatter-sum, not an opaque kernel
    return blend_windows(preds, plan, axis, use_kernel=False)


# ------------------------------------------------------------- GSPMD engine
def lp_forward_gspmd(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: UniformPlan,
    axis: int,
    mesh: Mesh,
    lp_axis: str = "data",
    codec=None,
) -> jnp.ndarray:
    """LP forward with GSPMD sharding constraints on the stacked axis.

    ``codec`` routes the stacked reduce through
    :func:`blend_windows_coded`: every window prediction is round-tripped
    through the wire codec (vmapped over the sharded stacked axis, one
    per-slab scale per window) before the scatter-sum, so the engine's
    output is bit-faithful to what a codec'd wire would deliver instead
    of silently shipping f32 values.  Note the *transfer* the partitioner
    emits still carries f32 (a psum must reduce decoded values — GSPMD
    offers no hook to reduce-then-decode), which is exactly why the halo
    family, not GSPMD, is the production codec path; see
    ``comm_model.comm_lp_gspmd_codec``.  Stateless codecs only (residual
    state needs the explicit halo schedule).

    Caveat (jax 0.4.x): the legacy partitioner lowers the stacked-axis
    reduce to an all-reduce over EVERY device when the mesh has additional
    (replicated) axes, multiplying the result by their product — execute
    this engine on a single-axis mesh there (compile-only analysis, e.g.
    the dry-run, is unaffected by values).  Meshes with Auto axis types
    (jax >= 0.5) lower it correctly.
    """
    if codec is not None:
        from repro.comm.codecs import get_codec

        codec = get_codec(codec)
        if codec.stateful:
            raise ValueError(
                f"codec {codec.name!r} is stateful; the GSPMD engine only "
                "supports stateless codecs (use the halo engines)"
            )
        if codec.name == "fp32":
            codec = None
    windows = stack_windows(z, plan, axis)
    spec = [None] * windows.ndim
    spec[0] = lp_axis
    windows = jax.lax.with_sharding_constraint(
        windows, NamedSharding(mesh, P(*spec))
    )
    preds = jax.vmap(denoise_fn)(windows)
    preds = jax.lax.with_sharding_constraint(
        preds, NamedSharding(mesh, P(*spec))
    )
    # jnp form always: the partitioner must see the scatter-sum to lower
    # it to a reduce over the lp axis (an opaque kernel would force an
    # all-gather of the stacked windows instead)
    if codec is not None:
        out = blend_windows_coded(preds, plan, axis, codec=codec,
                                  use_kernel=False)
    else:
        out = blend_windows(preds, plan, axis, use_kernel=False)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


# --------------------------------------------------------- shard_map engine
def lp_forward_shard_map(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: UniformPlan,
    axis: int,
    mesh: Mesh,
    lp_axis: str = "data",
) -> jnp.ndarray:
    """Explicit per-device LP forward: slice local -> denoise -> psum.

    ``z`` replicated along ``lp_axis``; the only collective is one psum of
    the global-latent-sized buffer (comm_model.comm_lp_spmd's 2(K-1)/K S_z
    wire bytes per device).  The lp axis size must equal K.
    """
    K = plan.num_partitions
    if mesh.shape[lp_axis] != K:
        raise ValueError(
            f"lp axis {lp_axis!r} has size {mesh.shape[lp_axis]}, plan has K={K}"
        )
    starts = jnp.asarray(plan.starts)
    weights = jnp.asarray(window_weights(plan))  # (K, window)
    norm = jnp.asarray(plan.normalizer())

    other_axes = tuple(n for n in mesh.axis_names if n != lp_axis)

    def per_device(z_rep: jnp.ndarray) -> jnp.ndarray:
        k = jax.lax.axis_index(lp_axis)
        start = starts[k]
        window = jax.lax.dynamic_slice_in_dim(z_rep, start, plan.window, axis)
        pred = denoise_fn(window).astype(jnp.float32)
        wshape = [1] * pred.ndim
        wshape[axis] = plan.window
        pred = pred * weights[k].reshape(wshape)
        out_shape = list(z_rep.shape)
        buf = jnp.zeros(out_shape, jnp.float32)
        buf = jax.lax.dynamic_update_slice_in_dim(buf, pred, start, axis)
        buf = jax.lax.psum(buf, lp_axis)  # latent reconstruction (Eq. 15)
        nshape = [1] * buf.ndim
        nshape[axis] = plan.extent
        return (buf / norm.reshape(nshape)).astype(z_rep.dtype)

    # Replicated in/out along every axis; the denoiser may use other axes
    # (e.g. tensor parallelism over "model") internally.
    fn = compat.shard_map(
        per_device,
        mesh=mesh,
        in_specs=P(),
        out_specs=P(),
        check_vma=False,
    )
    return fn(z)


# ------------------------------------------------------- engine selection
LP_IMPLS = ("auto", "gspmd", "shard_map", "halo", "halo_hybrid")


def select_lp_impl(num_partitions: int, tp: int = 1) -> str:
    """Resolve ``lp_impl="auto"`` to a concrete SPMD engine.

    The halo schedule's wire bytes are ``K(K-1) core_pad row + Σ_t
    |perm_t| len_t row`` vs the psum's ``2(K-1) S_z``
    (``comm_model.comm_lp_halo`` vs ``comm_lp_spmd``): at K=2 the
    edge-clamped windows span nearly the whole extent and halo is
    break-even, so keep the psum engine there; from K>=3 the overlap
    slabs shrink like r·D/K and halo wins at any r<=1 (ROADMAP, PR 1
    measurements — strictly better for K>=4 on every benchmark config).

    ``tp`` is the intra-group tensor-parallel degree: on a 2D ``(lp,
    tp)`` mesh the break-even is unchanged (both engines' per-device
    wire bytes are T-independent — each tp rank runs the lp collective
    on its own ring), but the halo family must be the *hybrid* engine
    (``core/hybrid.lp_forward_halo_hybrid``), whose eager-send ordering
    lets the halo rounds overlap the tail of the intra-group forward.
    """
    if num_partitions <= 2:
        return "shard_map"
    return "halo_hybrid" if tp > 1 else "halo"


# ---------------------------------------------------------- halo-exchange
def lp_forward_halo(
    denoise_fn: DenoiseFn,
    z: jnp.ndarray,
    plan: UniformPlan,
    axis: int,
    mesh: Mesh,
    lp_axis: str = "data",
    codec=None,
    codec_state=None,
    eager_sends: bool = False,
    shard_axis: Optional[str] = None,
    nan_guard: bool = False,
):
    """Halo-exchange LP forward: the fast-path collective schedule.

    Same math as :func:`lp_forward_shard_map`, but reconstruction never
    materializes (or psums) a global-latent-sized buffer.  Each rank:

    1. slices + denoises its window and applies its trapezoid weights;
    2. exchanges only the **overlap slabs** with the ranks whose cores its
       window touches (``distributed.collectives.halo_exchange`` —
       ppermute rounds of O(overlap) bytes);
    3. normalizes its own core slice with the analytic ``Z(x)``;
    4. all-gathers the core slices (disjoint cover of the latent) and
       reassembles the replicated output locally.

    Wire bytes per device ~ (K-1)/K * S_z + halo slabs, vs the psum's
    2 (K-1)/K * S_z (``comm_model.comm_lp_halo`` vs ``comm_lp_spmd``);
    there is no all-reduce in the compiled HLO at all.

    ``codec`` (a ``comm.codecs`` name or instance) additionally squeezes
    every wire payload — ppermute slabs and the core all-gather — through
    a wire codec (``comm_model.comm_lp_halo_codec`` for the byte model).
    Residual codecs are stateful: pass ``codec_state`` from
    ``comm.wire.init_halo_wire_state`` (leading lp-axis dim) and this
    returns ``(latent, new_state)`` instead of just the latent — the
    compiled-step cache threads it through the ``lax.scan`` carry.

    All collectives name only ``lp_axis``, so the engine composes with
    extra mesh axes for free: the denoiser may use them internally (the
    hybrid LP×TP engine, ``core/hybrid.lp_forward_halo_hybrid``, is this
    function behind a validated 2D-mesh contract).  ``eager_sends``
    issues every ppermute round before any accumulation (see
    ``distributed.collectives.halo_exchange``) so async collective
    scheduling can overlap the rounds with the tail of the denoiser.

    ``shard_axis`` (hybrid meshes: the tp axis) shards every wire
    payload — halo slabs and core-gather contributions — over that
    axis: each tp rank ships only its 1/T chunk across the (slow)
    inter-group lp links and the full message is reassembled with a
    cheap intra-group all-gather.  Requires the denoiser output (and
    hence every slab) to be replicated along ``shard_axis``, which the
    hybrid Phi_m contract guarantees; the result is bit-identical to
    the unsharded engine (``comm_model.comm_lp_halo_sharded`` for the
    two-tier byte model).

    ``nan_guard`` arms the codec decode guard (``comm.wire._finite_or``):
    a corrupted wire message (NaN/Inf after decode) is replaced by the
    rank-local stale slab (residual codecs) or dropped to zeros
    (stateless) instead of poisoning the latent — elementwise selects
    only, so wire bytes and healthy-path values are unchanged.  A no-op
    without a codec (there is no decode to guard).
    """
    from repro.distributed.collectives import (
        halo_exchange,
        halo_spec,
        sharded_all_gather,
    )

    K = plan.num_partitions
    if mesh.shape[lp_axis] != K:
        raise ValueError(
            f"lp axis {lp_axis!r} has size {mesh.shape[lp_axis]}, plan has K={K}"
        )
    shard_size = 1
    if shard_axis is not None:
        if shard_axis not in mesh.axis_names:
            raise ValueError(
                f"shard axis {shard_axis!r} not on mesh: {mesh.axis_names}"
            )
        if shard_axis == lp_axis:
            # sharding over the transfer axis itself would reassemble
            # chunks of DIFFERENT senders' slabs — shapes all line up,
            # values silently wrong
            raise ValueError(
                f"shard axis must differ from the lp axis ({lp_axis!r}): "
                "wire chunks are reassembled across the shard axis after "
                "the lp transfer"
            )
        shard_size = mesh.shape[shard_axis]
        if shard_size == 1:
            shard_axis = None  # degenerate: nothing to shard over
    spec = halo_spec(plan)
    core_len = spec.core_len
    starts = jnp.asarray(plan.starts)
    weights = jnp.asarray(window_weights(plan))  # (K, window)
    # Per-rank core slice of the analytic normalizer, padded with ones so
    # the division is a no-op on the garbage rows beyond core_len[k].
    norm = plan.normalizer()
    norm_core = np.ones((K, spec.core_pad), np.float32)
    for k in range(K):
        norm_core[k, : core_len[k]] = norm[plan.core_start[k] : plan.core_end[k]]
    norm_core = jnp.asarray(norm_core)

    if codec is not None:
        from repro.comm.codecs import get_codec

        codec = get_codec(codec)
        if codec.stateful and codec_state is None:
            raise ValueError(
                f"codec {codec.name!r} is stateful: pass codec_state from "
                "comm.wire.init_halo_wire_state"
            )

    def _weighted_window(z_rep, k):
        window = jax.lax.dynamic_slice_in_dim(z_rep, starts[k], plan.window, axis)
        pred = denoise_fn(window).astype(jnp.float32)
        wshape = [1] * pred.ndim
        wshape[axis] = plan.window
        wpred = pred * weights[k].reshape(wshape)
        wpred = jnp.moveaxis(wpred, axis, 0)
        return jnp.pad(wpred, [(0, spec.pad)] + [(0, 0)] * (wpred.ndim - 1))

    def _reassemble(gathered, dtype):
        out = jnp.zeros((plan.extent,) + gathered.shape[2:], gathered.dtype)
        for j in range(K):  # cores tile [0, extent): static local reassembly
            out = jax.lax.dynamic_update_slice_in_dim(
                out, gathered[j, : core_len[j]], plan.core_start[j], 0
            )
        return jnp.moveaxis(out, 0, axis).astype(dtype)

    def _core_gather_raw(core: jnp.ndarray) -> jnp.ndarray:
        """Uncoded core all-gather, wire-sharded when shard_axis is set:
        each tp rank gathers only its 1/T chunk over the lp ring, then
        one intra-group all-gather reassembles the (K, core_pad) table."""
        if shard_axis is None:
            return jax.lax.all_gather(core, lp_axis, axis=0, tiled=False)
        return sharded_all_gather(core, lp_axis, shard_axis, shard_size)

    if codec is None:
        def per_device(z_rep: jnp.ndarray) -> jnp.ndarray:
            k = jax.lax.axis_index(lp_axis)
            wpred = _weighted_window(z_rep, k)
            acc = halo_exchange(wpred, spec, k, lp_axis,
                                eager_sends=eager_sends,
                                shard_axis=shard_axis,
                                shard_size=shard_size)
            nshape = (spec.core_pad,) + (1,) * (acc.ndim - 1)
            core = (acc[: spec.core_pad] / norm_core[k].reshape(nshape)).astype(
                z_rep.dtype
            )
            return _reassemble(_core_gather_raw(core), z_rep.dtype)

        fn = compat.shard_map(
            per_device,
            mesh=mesh,
            in_specs=P(),
            out_specs=P(),
            check_vma=False,
        )
        return fn(z)

    from repro.comm.wire import (
        compressed_core_gather,
        compressed_halo_exchange,
    )

    if not codec.stateful:
        def per_device_codec(z_rep: jnp.ndarray) -> jnp.ndarray:
            k = jax.lax.axis_index(lp_axis)
            wpred = _weighted_window(z_rep, k)
            acc, _ = compressed_halo_exchange(wpred, spec, k, lp_axis,
                                              codec, {},
                                              eager_sends=eager_sends,
                                              shard_axis=shard_axis,
                                              shard_size=shard_size,
                                              nan_guard=nan_guard)
            nshape = (spec.core_pad,) + (1,) * (acc.ndim - 1)
            core = acc[: spec.core_pad] / norm_core[k].reshape(nshape)
            gathered, _ = compressed_core_gather(core, k, lp_axis, codec, {},
                                                 K, shard_axis=shard_axis,
                                                 shard_size=shard_size,
                                                 nan_guard=nan_guard)
            return _reassemble(gathered, z_rep.dtype)

        fn = compat.shard_map(
            per_device_codec,
            mesh=mesh,
            in_specs=P(),
            out_specs=P(),
            check_vma=False,
        )
        return fn(z)

    def per_device_stateful(z_rep: jnp.ndarray, state):
        k = jax.lax.axis_index(lp_axis)
        st = jax.tree.map(lambda s: s[0], state)  # drop the lp-axis dim
        wpred = _weighted_window(z_rep, k)
        acc, st = compressed_halo_exchange(wpred, spec, k, lp_axis, codec, st,
                                           eager_sends=eager_sends,
                                           shard_axis=shard_axis,
                                           shard_size=shard_size,
                                           nan_guard=nan_guard)
        nshape = (spec.core_pad,) + (1,) * (acc.ndim - 1)
        core = acc[: spec.core_pad] / norm_core[k].reshape(nshape)
        gathered, st = compressed_core_gather(core, k, lp_axis, codec, st, K,
                                              shard_axis=shard_axis,
                                              shard_size=shard_size,
                                              nan_guard=nan_guard)
        out = _reassemble(gathered, z_rep.dtype)
        return out, jax.tree.map(lambda s: s[None], st)

    fn = compat.shard_map(
        per_device_stateful,
        mesh=mesh,
        in_specs=(P(), P(lp_axis)),
        out_specs=(P(), P(lp_axis)),
        check_vma=False,
    )
    return fn(z, codec_state)
