"""Patch-aligned overlapping partition (paper §3.3, Eqs. 7-10).

Partitioning happens in *patch space*: the DiT patchify sizes
``(p_T, p_H, p_W)`` define the atomic units, and partition boundaries always
land on patch boundaries so no visual patch is cut in half.

Two planners are provided:

* :func:`plan_partition` — the paper-exact scheme (Eqs. 7-10):
  ``L = ceil(N/K)`` core patches per partition, ``O = floor(L*r)`` overlap
  patches, extended bounds clipped to ``[0, N)``.
* :func:`plan_partition_balanced` — a beyond-paper variant distributing
  ``N mod K`` leftover patches one-per-partition, avoiding the paper
  formula's empty partitions when ``N`` is close to ``K`` (e.g. 21 latent
  frames over 16 devices).  Used by the SPMD engine.

All geometry is static Python/numpy — partitioning never traces.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Static geometry of a K-way patch-aligned overlapping partition.

    All bounds are half-open ``[start, end)``.  ``core_*`` / ``ext_*`` are in
    patch space, ``lat_*`` in latent space (patch index * patch size, with
    the final partition absorbing any remainder ``D mod p``).
    """

    dim: int                      # which latent dim (0=T, 1=H, 2=W)
    extent: int                   # D_d: latent size along dim
    patch: int                    # p_d: patch size along dim
    num_partitions: int           # K
    overlap_ratio: float          # r
    num_patches: int              # N_d = floor(D_d / p_d)
    core_patches: int             # L  (paper; max core size for balanced)
    overlap_patches: int          # O
    core_start: Tuple[int, ...]   # alpha_k, patch space
    core_end: Tuple[int, ...]     # beta_k
    ext_start: Tuple[int, ...]    # alpha'_k
    ext_end: Tuple[int, ...]      # beta'_k
    lat_start: Tuple[int, ...]    # s_k, latent space
    lat_end: Tuple[int, ...]      # e_k

    @property
    def sizes(self) -> Tuple[int, ...]:
        """ell_k = e_k - s_k, latent units."""
        return tuple(e - s for s, e in zip(self.lat_start, self.lat_end))

    @property
    def core_lat_start(self) -> Tuple[int, ...]:
        return tuple(a * self.patch for a in self.core_start)

    @property
    def core_lat_end(self) -> Tuple[int, ...]:
        # A core ending at the last patch absorbs the remainder D mod p, so
        # the latent tail is always inside some core region.
        return tuple(
            self.extent if b == self.num_patches else b * self.patch
            for b in self.core_end
        )

    @property
    def delta_start(self) -> Tuple[int, ...]:
        """Front overlap lengths (latent units), Eq. 11."""
        return tuple(
            c - s for c, s in zip(self.core_lat_start, self.lat_start)
        )

    @property
    def delta_end(self) -> Tuple[int, ...]:
        """Rear overlap lengths (latent units), Eq. 11."""
        return tuple(e - c for c, e in zip(self.core_lat_end, self.lat_end))

    def validate(self) -> None:
        assert len(self.lat_start) == self.num_partitions
        covered = np.zeros(self.extent, dtype=bool)
        for s, e in zip(self.lat_start, self.lat_end):
            assert 0 <= s <= e <= self.extent, (s, e, self.extent)
            covered[s:e] = True
        assert covered.all(), "partition does not cover the latent extent"
        for s, e, a, b in zip(
            self.lat_start, self.lat_end, self.core_lat_start, self.core_lat_end
        ):
            assert s <= a <= b <= e, "core region must lie inside the partition"


def _finalize(
    dim: int,
    extent: int,
    patch: int,
    K: int,
    r: float,
    L: int,
    O: int,
    core_start: List[int],
    core_end: List[int],
) -> PartitionPlan:
    N = extent // patch
    ext_start = [max(0, a - O) for a in core_start]
    ext_end = [min(N, b + O) for b in core_end]
    lat_start = [a * patch for a in ext_start]
    lat_end = [b * patch for b in ext_end]
    # Absorb the remainder D mod p into any partition touching the last patch
    # (the paper assumes p | D; real latents are padded but we stay general).
    for k in range(K):
        if ext_end[k] == N:
            lat_end[k] = extent
    plan = PartitionPlan(
        dim=dim,
        extent=extent,
        patch=patch,
        num_partitions=K,
        overlap_ratio=r,
        num_patches=N,
        core_patches=L,
        overlap_patches=O,
        core_start=tuple(core_start),
        core_end=tuple(core_end),
        ext_start=tuple(ext_start),
        ext_end=tuple(ext_end),
        lat_start=tuple(lat_start),
        lat_end=tuple(lat_end),
    )
    plan.validate()
    return plan


def plan_partition(
    extent: int, patch: int, num_partitions: int, overlap_ratio: float, dim: int = 0
) -> PartitionPlan:
    """Paper-exact partition (Eqs. 7-10).

    ``alpha_k = (k-1) * L``, ``beta_k = alpha_k + L`` with
    ``L = ceil(N / K)``; extended bounds clipped to ``[0, N)``.  ``beta_k``
    is additionally clamped to ``N`` so trailing partitions stay valid when
    ``K * L > N`` (the paper's formula leaves them dangling past the array).
    """
    K, r = num_partitions, overlap_ratio
    if K < 1:
        raise ValueError(f"need at least one partition, got K={K}")
    if not 0.0 <= r <= max(0, K - 1):
        raise ValueError(f"overlap ratio must be in [0, K-1], got r={r}")
    N = extent // patch
    if N < 1:
        raise ValueError(f"latent extent {extent} shorter than one patch {patch}")
    L = math.ceil(N / K)
    O = math.floor(L * r)
    core_start = [min((k - 1) * L, N) for k in range(1, K + 1)]
    core_end = [min(a + L, N) for a in core_start]
    return _finalize(dim, extent, patch, K, r, L, O, core_start, core_end)


def plan_partition_balanced(
    extent: int, patch: int, num_partitions: int, overlap_ratio: float, dim: int = 0
) -> PartitionPlan:
    """Balanced cores: the first ``N mod K`` partitions take ``ceil(N/K)``
    patches, the rest ``floor(N/K)``.  Every partition is non-empty when
    ``N >= K``.  Overlap ``O`` uses the max core size, matching the paper's
    ``O = floor(L * r)`` scaling."""
    K, r = num_partitions, overlap_ratio
    if K < 1:
        raise ValueError(f"need at least one partition, got K={K}")
    if not 0.0 <= r <= max(0, K - 1):
        raise ValueError(f"overlap ratio must be in [0, K-1], got r={r}")
    N = extent // patch
    if N < K:
        raise ValueError(
            f"balanced partition needs at least one patch per partition "
            f"(N={N} < K={K}); drop this dim from the rotation instead"
        )
    base, extra = divmod(N, K)
    L = base + (1 if extra else 0)
    O = math.floor(L * r)
    core_start, core_end = [], []
    pos = 0
    for k in range(K):
        size = base + (1 if k < extra else 0)
        core_start.append(pos)
        core_end.append(pos + size)
        pos += size
    assert pos == N
    return _finalize(dim, extent, patch, K, r, L, O, core_start, core_end)


def slice_bounds(plan: PartitionPlan, k: int) -> Tuple[int, int]:
    """Latent-space bounds ``[s_k, e_k)`` of partition ``k`` (0-indexed)."""
    return plan.lat_start[k], plan.lat_end[k]


def extract(z, plan: PartitionPlan, k: int, axis: int):
    """``z_t^(k) = z_t[R_k]`` (Eq. 10): slice partition ``k`` along ``axis``."""
    s, e = slice_bounds(plan, k)
    idx = [slice(None)] * z.ndim
    idx[axis] = slice(s, e)
    return z[tuple(idx)]
