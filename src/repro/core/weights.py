"""Position-aware blend weights (paper §3.4, Eqs. 11-12).

For partition ``k`` with extent ``[s_k, e_k)`` (length ``ell_k``), core
region ``[alpha_k * p, beta_k * p)``, front overlap ``Delta_start`` and rear
overlap ``Delta_end``:

    W_j = j / Delta_start                for 0 <= j < Delta_start
        = 1                              for Delta_start <= j < ell - Delta_end
        = (ell - j) / Delta_end          for ell - Delta_end <= j < ell

Weights are deterministic functions of partition *geometry* only.  That
matters on TPU: every device can compute the **global** normalizer
``Z(x) = sum_k I_k(x) * W_k(x)`` (Eq. 16) analytically, so reconstruction
needs a single all-reduce of the weighted predictions instead of shipping
weights across devices.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .partition import PartitionPlan


def blend_weight_1d(length: int, delta_start: int, delta_end: int) -> np.ndarray:
    """Trapezoid weights for one partition (Eq. 12), as float32 numpy.

    Ramp up over ``[0, delta_start)``, flat 1 over the core, ramp down over
    ``[length - delta_end, length)``.  ``delta == 0`` means no ramp on that
    side (boundary partitions clipped by Eq. 8).
    """
    if length < 1:
        return np.zeros((0,), dtype=np.float32)
    if delta_start + delta_end > length:
        raise ValueError(
            f"overlaps ({delta_start}+{delta_end}) exceed partition length {length}"
        )
    j = np.arange(length, dtype=np.float32)
    w = np.ones(length, dtype=np.float32)
    if delta_start > 0:
        ramp = j[:delta_start] / float(delta_start)
        w[:delta_start] = ramp
    if delta_end > 0:
        tail = (float(length) - j[length - delta_end :]) / float(delta_end)
        w[length - delta_end :] = tail
    return w


def partition_weights(plan: PartitionPlan) -> Tuple[np.ndarray, ...]:
    """Per-partition 1-D weight masks ``W^(k)`` along the partition dim."""
    out = []
    for k in range(plan.num_partitions):
        ell = plan.lat_end[k] - plan.lat_start[k]
        out.append(blend_weight_1d(ell, plan.delta_start[k], plan.delta_end[k]))
    return tuple(out)


def global_normalizer(plan: PartitionPlan) -> np.ndarray:
    """``Z(x) = sum_k I_k(x) W^(k)_{pi_k(x)}`` (Eq. 16) over the full extent.

    Computed from geometry alone — no communication.  Positive everywhere
    (every position is in at least one core or adjacent ramp).
    """
    z = np.zeros(plan.extent, dtype=np.float32)
    for k, w in enumerate(partition_weights(plan)):
        s, e = plan.lat_start[k], plan.lat_end[k]
        z[s:e] += w
    if not (z > 0).all():
        raise AssertionError("normalizer has zero entries — uncovered positions")
    return z
