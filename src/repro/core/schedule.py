"""Dynamic rotating partition schedule (paper Eq. 3).

At forward pass ``i`` (1-indexed; ``i = T + 1 - t`` for diffusion timestep
``t`` counting down from ``T``) the partitioning dimension is

    d_i = M[(i - 1) mod 3 + 1]

where ``M`` maps 1, 2, 3 to temporal, height, width.  Rotation guarantees
2-completeness of the receptive field (paper supplementary Thm. 1): any two
consecutive steps partition along different dimensions, so information
reaches the whole latent within two steps.
"""
from __future__ import annotations

from typing import Sequence, Tuple

#: Canonical order of latent dimensions, matching the paper's M(.) mapping.
DIM_NAMES: Tuple[str, str, str] = ("temporal", "height", "width")
TEMPORAL, HEIGHT, WIDTH = 0, 1, 2


def rotation_dim(i: int, dims: Sequence[int] = (TEMPORAL, HEIGHT, WIDTH)) -> int:
    """Partition dimension for the ``i``-th forward pass (1-indexed).

    ``dims`` restricts the rotation cycle (e.g. a latent whose temporal
    extent is too small to split K ways rotates over height/width only).
    The paper's Eq. 3 is the default ``dims=(0, 1, 2)`` case.
    """
    if i < 1:
        raise ValueError(f"forward pass index is 1-indexed, got {i}")
    if not dims:
        raise ValueError("rotation requires at least one dimension")
    return dims[(i - 1) % len(dims)]


def rotation_schedule(
    num_steps: int, dims: Sequence[int] = (TEMPORAL, HEIGHT, WIDTH)
) -> Tuple[int, ...]:
    """Partition dimension for every forward pass of a ``num_steps`` run."""
    return tuple(rotation_dim(i, dims) for i in range(1, num_steps + 1))


def usable_dims(
    latent_dims: Sequence[int],
    patch_sizes: Sequence[int],
    num_partitions: int,
    dims: Sequence[int] = (TEMPORAL, HEIGHT, WIDTH),
) -> Tuple[int, ...]:
    """Dims with at least one patch per partition (``N_d >= K``).

    The paper evaluates K=4 GPUs where every dimension qualifies; at K=16 a
    short temporal extent (e.g. 13 latent frames for a 3 s video) cannot be
    split 16 ways, so the rotation cycle drops it.  Dropping a dim preserves
    2-completeness as long as >= 2 dims remain (consecutive steps still
    partition along different dimensions).
    """
    out = []
    for d in dims:
        n_patches = latent_dims[d] // patch_sizes[d]
        if n_patches >= num_partitions:
            out.append(d)
    return tuple(out)
