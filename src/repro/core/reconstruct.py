"""Position-aware latent reconstruction (paper §3.4, Eqs. 13-17).

Given the K local noise predictions and the partition plan, compute

    A(x) = sum_k I_k(x) * W^(k)_{pi_k(x)} * pred_k[pi_k(x)]     (Eq. 15)
    Z(x) = sum_k I_k(x) * W^(k)_{pi_k(x)}                       (Eq. 16)
    F(x) = A(x) / Z(x)                                          (Eq. 17)

This module is the single-host reference: a Python loop over partitions with
scatter-adds.  The SPMD engine (``core/spmd.py``) computes the same math with
one ``psum`` over the mesh axis; the Pallas kernel (``kernels/latent_blend``)
fuses weighting + accumulation for the TPU hot path.  All three are tested
against each other.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .partition import PartitionPlan
from .weights import global_normalizer, partition_weights


def _shape_weight(w: np.ndarray, ndim: int, axis: int) -> jnp.ndarray:
    """Broadcast a 1-D weight along ``axis`` of an ``ndim``-rank tensor."""
    shape = [1] * ndim
    shape[axis] = w.shape[0]
    return jnp.asarray(w).reshape(shape)


def reconstruct(
    preds: Sequence[jnp.ndarray],
    plan: PartitionPlan,
    axis: int,
    accumulate_dtype=jnp.float32,
) -> jnp.ndarray:
    """Stitch K local predictions into the global prediction (Eq. 17).

    ``preds[k]`` has the shape of partition ``k``'s sub-latent; all other
    axes must agree.  Accumulation runs in ``accumulate_dtype`` (fp32 by
    default — bf16 overlap sums lose ~2 bits of mantissa at seams).
    """
    if len(preds) != plan.num_partitions:
        raise ValueError(
            f"got {len(preds)} predictions for K={plan.num_partitions}"
        )
    ref = preds[0]
    out_shape = list(ref.shape)
    out_shape[axis] = plan.extent
    acc = jnp.zeros(out_shape, dtype=accumulate_dtype)
    weights = partition_weights(plan)
    for k, pred in enumerate(preds):
        s, e = plan.lat_start[k], plan.lat_end[k]
        if pred.shape[axis] != e - s:
            raise ValueError(
                f"partition {k}: prediction extent {pred.shape[axis]} != "
                f"plan extent {e - s} along axis {axis}"
            )
        w = _shape_weight(weights[k], pred.ndim, axis)
        idx = [slice(None)] * pred.ndim
        idx[axis] = slice(s, e)
        acc = acc.at[tuple(idx)].add(pred.astype(accumulate_dtype) * w)
    z = _shape_weight(global_normalizer(plan), acc.ndim, axis)
    return (acc / z).astype(ref.dtype)
