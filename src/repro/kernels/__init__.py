"""Pallas TPU kernels for the compute hot-spots LP exercises:

  flash_attention — the DiT/LM attention inner loop (MXU-tiled online
                    softmax; the dominant FLOPs of every forward)
  latent_blend    — LP's position-aware reconstruction (Eqs. 15-17) in a
                    single fused pass
  guidance_update — CFG combine + scheduler step epilogue, fused
  mamba_ssd       — chunked SSD scan with VMEM-resident recurrent state
                    (the zamba2 hybrid's dominant traffic, §Perf A4)

Each ships with a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``; tests sweep shapes/dtypes in interpret mode (CPU container;
TPU v5e is the lowering target).
"""
from . import ops, ref  # noqa: F401
