"""Pallas TPU kernels for the wire-codec hot paths.

Two fused kernels extend ``latent_blend`` (the LP stitch kernel) to the
quantized wire:

* :func:`int8_quantize` — per-slab max-abs scale + symmetric int8
  quantization in one ``pallas_call``: a two-phase grid first reduces
  ``max|x|`` into SMEM scratch (phase 0 sweeps the row blocks), then
  quantizes every block with the final scale (phase 1).  The jnp encode
  path reads the slab twice from HBM (amax reduce, then quantize); here
  each block is only re-streamed once with no intermediate f32 buffer.

* :func:`dequant_blend` — position-aware latent reconstruction
  (``latent_blend``'s Eqs. 15-17 math) fused with the int8 dequantize:
  quantized window predictions (K, W, F) + per-window scales go straight
  to the blended output without ever materializing the dequantized f32
  windows in HBM (K latent-sized round trips saved on top of
  latent_blend's fusion).

Grid layouts mirror ``latent_blend``: F is blocked, K (or the phase) is
the innermost grid dim so VMEM scratch accumulates across it.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ------------------------------------------------------------- quantize
def _quant_kernel(x_ref, wire_ref, scale_ref, amax_ref, *,
                  qmax: int, nb: int):
    phase = pl.program_id(0)
    ib = pl.program_id(1)

    @pl.when((phase == 0) & (ib == 0))
    def _init():
        amax_ref[0] = 0.0

    @pl.when(phase == 0)
    def _scan():
        amax_ref[0] = jnp.maximum(
            amax_ref[0], jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))
        )

    @pl.when(phase == 1)
    def _quantize():
        scale = jnp.maximum(amax_ref[0], 1e-20) / qmax
        q = jnp.clip(
            jnp.round(x_ref[...].astype(jnp.float32) / scale), -qmax, qmax
        )
        wire_ref[...] = q.astype(jnp.int8)

        @pl.when(ib == nb - 1)
        def _emit_scale():
            scale_ref[0, 0] = scale


@functools.partial(jax.jit, static_argnames=("qmax", "blk_r", "interpret"))
def int8_quantize(
    x: jnp.ndarray,            # (R, F) rows to quantize as ONE slab
    qmax: int = 127,
    blk_r: int = 256,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused per-slab max-abs + int8 quantize: (wire (R, F) int8,
    scale (1, 1) f32).  Matches ``comm.codecs.IntCodec(bits=8).encode``
    bit-for-bit (same scale floor, same rounding)."""
    R, F = x.shape
    blk_r = min(blk_r, R)
    pr = -R % blk_r
    if pr:
        # zero rows never win the max-abs and quantize to 0: safe padding
        x = jnp.pad(x, ((0, pr), (0, 0)))
    nb = (R + pr) // blk_r
    kernel = functools.partial(_quant_kernel, qmax=qmax, nb=nb)
    wire, scale = pl.pallas_call(
        kernel,
        grid=(2, nb),
        in_specs=[pl.BlockSpec((blk_r, F), lambda ph, ib: (ib, 0))],
        out_specs=[
            pl.BlockSpec((blk_r, F), lambda ph, ib: (ib, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R + pr, F), jnp.int8),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(x)
    return wire[:R], scale


# --------------------------------------------------------- dequant+blend
def _dequant_blend_kernel(wire_ref, scale_ref, w_ref, norm_ref, o_ref,
                          acc_ref, *, starts: Tuple[int, ...], window: int,
                          num_k: int):
    ikk = pl.program_id(1)

    @pl.when(ikk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    scale = scale_ref[0]
    pred = wire_ref[0].astype(jnp.float32) * scale     # fused dequantize
    w = w_ref[0, :]                                    # (W,)
    contrib = pred * w[:, None]

    def add_at(s):
        cur = pl.load(acc_ref, (pl.ds(s, window), slice(None)))
        pl.store(acc_ref, (pl.ds(s, window), slice(None)), cur + contrib)

    branches = [functools.partial(add_at, s) for s in starts]
    jax.lax.switch(ikk, branches)

    @pl.when(ikk == num_k - 1)
    def _finish():
        z = norm_ref[0, :]                             # (E,)
        o_ref[...] = (acc_ref[...] / z[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("starts", "window", "extent", "blk_f",
                              "interpret", "out_dtype"),
)
def dequant_blend(
    wire: jnp.ndarray,         # (K, W, F) int8 quantized window preds
    scales: jnp.ndarray,       # (K,) f32 per-window dequant scales
    weights: jnp.ndarray,      # (K, W) trapezoid masks
    normalizer: jnp.ndarray,   # (E,)
    starts: Tuple[int, ...],   # static per-partition offsets
    window: int,
    extent: int,
    blk_f: int = 512,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """out[x, f] = (sum_k W_k[x-s_k] * scale_k * wire[k, x-s_k, f]) / Z[x]
    in one pass — the quantized-wire twin of ``latent_blend``."""
    K, W, F = wire.shape
    assert W == window and len(starts) == K
    blk_f = min(blk_f, F)
    pf = -F % blk_f
    if pf:
        wire = jnp.pad(wire, ((0, 0), (0, 0), (0, pf)))
    nf = (F + pf) // blk_f
    kernel = functools.partial(
        _dequant_blend_kernel, starts=tuple(starts), window=window, num_k=K,
    )
    out = pl.pallas_call(
        kernel,
        grid=(nf, K),
        in_specs=[
            pl.BlockSpec((1, window, blk_f), lambda jf, kk: (kk, 0, jf)),
            pl.BlockSpec((1,), lambda jf, kk: (kk,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, window), lambda jf, kk: (kk, 0)),
            pl.BlockSpec((1, extent), lambda jf, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((extent, blk_f), lambda jf, kk: (0, jf)),
        out_shape=jax.ShapeDtypeStruct((extent, F + pf), out_dtype),
        scratch_shapes=[pltpu.VMEM((extent, blk_f), jnp.float32)],
        interpret=interpret,
    )(wire, scales, weights, normalizer[None, :])
    return out[:, :F]
