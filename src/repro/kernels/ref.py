"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attention_dense


def flash_attention_ref(q, k, v, q_positions, kv_positions,
                        causal=True, window=0):
    """O(S^2)-memory attention — the flash kernel oracle."""
    return attention_dense(q, k, v, q_positions, kv_positions, causal, window)


def latent_blend_ref(preds, weights, normalizer, starts, window, extent):
    """Scatter-add reconstruction (Eqs. 15-17), K+1 passes."""
    K, W, F = preds.shape
    acc = jnp.zeros((extent, F), jnp.float32)
    for kk in range(K):
        contrib = preds[kk].astype(jnp.float32) * weights[kk][:, None]
        acc = acc.at[starts[kk]:starts[kk] + window].add(contrib)
    return (acc / normalizer[:, None]).astype(preds.dtype)


def guidance_update_ref(z, cond, uncond, w, dt):
    """CFG combine + Euler step, unfused."""
    pred = uncond.astype(jnp.float32) + w * (
        cond.astype(jnp.float32) - uncond.astype(jnp.float32))
    return (z.astype(jnp.float32) + dt * pred).astype(z.dtype)


def mamba_ssd_ref(x, log_decay, scale, B, C):
    """Sequential gated linear recurrence (groups == 1) — SSD oracle."""
    from repro.models.ssm import gated_linear_scan

    return gated_linear_scan(
        x, log_decay, scale, B[:, :, None, :], C[:, :, None, :],
        chunk=32, factorized=False,
    )
