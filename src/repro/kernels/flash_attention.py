"""Pallas TPU flash attention (tiled online softmax, GQA, causal/SWA).

TPU-native tiling: the grid is (batch, q_head, q_blocks, kv_blocks) with
the kv dimension innermost — TPU executes the grid sequentially per core,
so the (m, l, acc) online-softmax carry lives in VMEM scratch across the
kv sweep.  Block shapes keep the MXU fed ((bq x D) @ (D x bk) with D, bq,
bk multiples of the 128-lane registers) and the working set in VMEM:

    q block   (bq, D)    bf16/f32
    k/v block (bk, D)
    acc       (bq, D)    f32 scratch
    m, l      (bq, 128)  f32 scratch (lane-padded)

GQA is handled in the BlockSpec index_map (q head h reads kv head h//G) —
no KV replication in HBM.  Causal masking uses position tensors (LP
sub-latents and decode steps have non-trivial global positions); when
``causal`` and positions are block-contiguous, fully-masked kv blocks are
skipped via ``pl.when`` on the grid indices (upper-triangle skip: ~2x
fewer matmuls at long S).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30
LANES = 128


def _kernel(
    q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref,   # inputs
    o_ref,                                        # output
    acc_ref, m_ref, l_ref,                        # VMEM scratch
    *, causal: bool, window: int, blk_q: int, blk_k: int,
    num_kv_blocks: int, skip_upper: bool,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_pos_ref[0, :]                       # (bq,)
    kv_pos = kv_pos_ref[0, :]                     # (bk,)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) / math.sqrt(d)                           # (bq, bk)
        ok = (kv_pos[None, :] < jnp.iinfo(jnp.int32).max)
        if causal:
            ok = ok & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    if skip_upper and causal:
        # contiguous positions: kv block strictly after q block -> all masked
        iq = pl.program_id(2)
        q_end = (iq + 1) * blk_q - 1
        k_start = ik * blk_k
        pl.when(k_start <= q_end)(compute)
    else:
        compute()

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret",
                     "skip_upper"),
)
def flash_attention(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Skv, KV, D)
    v: jnp.ndarray,
    q_positions: jnp.ndarray,  # (B, Sq) int32
    kv_positions: jnp.ndarray, # (B, Skv) int32
    causal: bool = True,
    window: int = 0,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = True,
    skip_upper: bool = False,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)

    # pad sequences to block multiples; padded kv slots get int32-max
    # positions (always masked), padded q rows are dropped at the end
    pq = -Sq % blk_q
    pk = -Skv % blk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pk)),
                               constant_values=jnp.iinfo(jnp.int32).max)
    Sq_p, Skv_p = Sq + pq, Skv + pk
    nq, nk = Sq_p // blk_q, Skv_p // blk_k

    qt = q.transpose(0, 2, 1, 3)       # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)       # (B, KV, Skv, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        num_kv_blocks=nk, skip_upper=skip_upper,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, blk_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),      # acc
            pltpu.VMEM((blk_q, LANES), jnp.float32),  # m (lane-padded)
            pltpu.VMEM((blk_q, LANES), jnp.float32),  # l
        ],
        interpret=interpret,
    )(q_positions, kv_positions, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
