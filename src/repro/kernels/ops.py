"""jit'd public wrappers for the Pallas kernels.

``interpret=True`` (default here) runs the kernel bodies in Python on CPU
for validation; on a real TPU pass ``interpret=False``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .mamba_ssd import mamba_ssd as _ssd
from .guidance_update import guidance_update as _guidance
from .latent_blend import latent_blend as _blend
from .wire_codec import dequant_blend as _dequant_blend
from .wire_codec import int8_quantize as _int8_quantize


def flash_attention(q, k, v, q_positions, kv_positions, *, causal=True,
                    window=0, kv_len=None, blk_q=128, blk_k=128,
                    interpret=True, skip_upper=False):
    if kv_len is not None:
        # fold the valid-length mask into kv positions (int32-max = masked)
        kv_positions = jnp.where(
            kv_positions < kv_len[:, None], kv_positions,
            jnp.iinfo(jnp.int32).max,
        )
    return _flash(q, k, v, q_positions.astype(jnp.int32),
                  kv_positions.astype(jnp.int32), causal=causal,
                  window=window, blk_q=blk_q, blk_k=blk_k,
                  interpret=interpret, skip_upper=skip_upper)


def latent_blend(preds, weights, normalizer, starts: Tuple[int, ...],
                 window: int, extent: int, *, blk_f=512, interpret=True):
    return _blend(preds, weights, normalizer, tuple(int(s) for s in starts),
                  window, extent, blk_f=blk_f, interpret=interpret)


def int8_quantize(x, *, qmax=127, blk_r=256, interpret=True):
    """(wire int8, scale (1,1)) — fused per-slab max-abs + quantize."""
    return _int8_quantize(x, qmax=qmax, blk_r=blk_r, interpret=interpret)


def dequant_blend(wire, scales, weights, normalizer, starts: Tuple[int, ...],
                  window: int, extent: int, *, blk_f=512, interpret=True,
                  out_dtype=None):
    """Fused int8 dequantize + position-aware blend (latent_blend twin)."""
    import jax.numpy as _jnp

    return _dequant_blend(
        wire, scales.reshape(-1), weights, normalizer,
        tuple(int(s) for s in starts), window, extent, blk_f=blk_f,
        interpret=interpret,
        out_dtype=out_dtype if out_dtype is not None else _jnp.float32,
    )


def guidance_update(z, cond, uncond, w: float, dt: float, *,
                    blk=65536, interpret=True):
    return _guidance(z, cond, uncond, float(w), float(dt), blk=blk,
                     interpret=interpret)


def mamba_ssd(x, log_decay, scale, B, C, *, chunk=64, head_block=8,
              interpret=True):
    return _ssd(x, log_decay, scale, B, C, chunk=chunk,
                head_block=head_block, interpret=interpret)
