"""Pallas TPU kernel: fused CFG combine + flow-matching scheduler update.

The per-step epilogue of the diffusion loop is pure elementwise traffic:

    pred   = uncond + w * (cond - uncond)        (CFG, Eq. 2)
    z_next = z + dt * pred                       (Euler step, Eq. 6)

Composed naively that is 4 latent-sized HBM reads + 2 writes; fused it is
3 reads + 1 write (~1.7x less traffic on a memory-bound step).  Tiled
over flattened latent blocks, everything in one VMEM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, cond_ref, uncond_ref, o_ref, *, w: float, dt: float):
    z = z_ref[...].astype(jnp.float32)
    c = cond_ref[...].astype(jnp.float32)
    u = uncond_ref[...].astype(jnp.float32)
    pred = u + w * (c - u)
    o_ref[...] = (z + dt * pred).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("w", "dt", "blk", "interpret"))
def guidance_update(
    z: jnp.ndarray,
    cond: jnp.ndarray,
    uncond: jnp.ndarray,
    w: float,
    dt: float,
    blk: int = 65536,
    interpret: bool = True,
) -> jnp.ndarray:
    shape = z.shape
    flat = z.size
    blk = min(blk, flat)
    pad = -flat % blk
    def prep(a):
        a = a.reshape(-1)
        return jnp.pad(a, (0, pad)) if pad else a
    zf, cf, uf = prep(z), prep(cond), prep(uncond)
    n = zf.size // blk
    out = pl.pallas_call(
        functools.partial(_kernel, w=w, dt=dt),
        grid=(n,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(zf.shape, z.dtype),
        interpret=interpret,
    )(zf, cf, uf)
    return out[:flat].reshape(shape)
