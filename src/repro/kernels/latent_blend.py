"""Pallas TPU kernel: fused position-aware latent reconstruction
(paper Eqs. 15-17 — the LP stitching hot path).

Computes, for the uniform-window plan,

    out[x, f] = ( sum_k W_k[x - s_k] * preds[k, x - s_k, f] ) / Z[x]

in ONE pass over the output: the jnp reference materializes K weighted
scatter buffers + an fp32 accumulator (K+2 latent-sized HBM round trips);
the kernel keeps the accumulator tile in VMEM and writes each output tile
once.

Layout: preds (K, W, F) where the partition dim is dim 1 and F flattens
every other latent dim.  Grid (F_blocks, K) — K innermost so the output
tile accumulates across partitions in VMEM scratch:

    preds block (1, W, bf)      weights row (1, W)
    out block   (E, bf)         acc scratch (E, bf) f32

Starts are static (partition geometry is compile-time), so the scatter
offset per k is a constant-indexed dynamic slice.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(preds_ref, w_ref, norm_ref, o_ref, acc_ref, *,
            starts: Tuple[int, ...], window: int, num_k: int):
    ikk = pl.program_id(1)

    @pl.when(ikk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pred = preds_ref[0].astype(jnp.float32)          # (W, bf)
    w = w_ref[0, :]                                   # (W,)
    contrib = pred * w[:, None]
    # static scatter offset per partition index
    def add_at(s):
        cur = pl.load(acc_ref, (pl.ds(s, window), slice(None)))
        pl.store(acc_ref, (pl.ds(s, window), slice(None)), cur + contrib)

    branches = [functools.partial(add_at, s) for s in starts]
    jax.lax.switch(ikk, branches)

    @pl.when(ikk == num_k - 1)
    def _finish():
        z = norm_ref[0, :]                            # (E,)
        o_ref[...] = (acc_ref[...] / z[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("starts", "window", "extent", "blk_f",
                              "interpret"),
)
def latent_blend(
    preds: jnp.ndarray,        # (K, W, F)
    weights: jnp.ndarray,      # (K, W) trapezoid masks
    normalizer: jnp.ndarray,   # (E,)
    starts: Tuple[int, ...],   # static per-partition offsets
    window: int,
    extent: int,
    blk_f: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    K, W, F = preds.shape
    assert W == window and len(starts) == K
    blk_f = min(blk_f, F)
    pf = -F % blk_f
    if pf:
        preds = jnp.pad(preds, ((0, 0), (0, 0), (0, pf)))
    nf = (F + pf) // blk_f
    kernel = functools.partial(
        _kernel, starts=tuple(starts), window=window, num_k=K,
    )
    out = pl.pallas_call(
        kernel,
        grid=(nf, K),
        in_specs=[
            pl.BlockSpec((1, window, blk_f), lambda jf, kk: (kk, 0, jf)),
            pl.BlockSpec((1, window), lambda jf, kk: (kk, 0)),
            pl.BlockSpec((1, extent), lambda jf, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((extent, blk_f), lambda jf, kk: (0, jf)),
        out_shape=jax.ShapeDtypeStruct((extent, F + pf), preds.dtype),
        scratch_shapes=[pltpu.VMEM((extent, blk_f), jnp.float32)],
        interpret=interpret,
    )(preds, weights, normalizer[None, :])
    return out[:, :F]
