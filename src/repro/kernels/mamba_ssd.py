"""Pallas TPU kernel: chunked Mamba2/SSD scan with VMEM-resident state.

The XLA chunk-scan (ssm.gated_linear_scan) must round-trip the recurrent
state S (heads x n x p — 1.3 GB for zamba2 at batch 16) through HBM on
every 64-token chunk: §Perf A4 measured ~19 TB/step of pure state traffic.
This kernel keeps S in VMEM scratch across the chunk sweep:

    grid = (batch, head_blocks, num_chunks)   # chunks innermost
    scratch: S (hb, n, p) f32 — persists across the chunk dimension,
             reset at chunk 0

Per chunk (all in VMEM): cumulative decays, the factorized intra-chunk
form (same math as gated_linear_scan(factorized=True): group-level C·B^T
Gram + rank-1 exp scalings, exponents clipped at ±60 with per-chunk
centering), inter-chunk readout against S, then the state update.

HBM traffic per chunk = read x/decay/scale/B/C once + write y once —
state never leaves VMEM.  Assumes ssm_groups == 1 (zamba2's config);
B/C are shared across every head block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, dt_ref, b_ref, c_ref, y_ref, s_ref, *,
            num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, hb, p)
    a = a_ref[0, 0].astype(jnp.float32)        # (Q, hb)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, hb)
    Bc = b_ref[0, 0].astype(jnp.float32)       # (Q, n)
    Cc = c_ref[0, 0].astype(jnp.float32)       # (Q, n)
    Q = x.shape[0]

    cum = jnp.cumsum(a, axis=0)                # (Q, hb)
    total = cum[-1, :]                         # (hb,)

    # inter-chunk: y += exp(cum) * (C . S_in)
    y_inter = jnp.einsum("qn,hnp->qhp", Cc, s_ref[...],
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, :, None]

    # intra-chunk (factorized, ±60-clipped centered exponents)
    center = 0.5 * (cum.max(axis=0) + cum.min(axis=0))      # (hb,)
    a_i = jnp.exp(jnp.clip(cum - center[None, :], -60.0, 60.0))
    b_j = jnp.exp(jnp.clip(center[None, :] - cum, -60.0, 60.0))
    cb = jnp.einsum("in,jn->ij", Cc, Bc,
                    preferred_element_type=jnp.float32)      # (Q, Q)
    mask = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    cb = cb * mask
    v = x * (dt * b_j)[:, :, None]                           # (Q, hb, p)
    y_intra = jnp.einsum("ij,jhp->ihp", cb, v,
                         preferred_element_type=jnp.float32)
    y_intra = y_intra * a_i[:, :, None]

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(total) S + sum_j exp(total-cum_j) dt_j B_j x_j
    w = jnp.exp(total[None, :] - cum) * dt                   # (Q, hb)
    s_new = jnp.einsum("qn,qhp->hnp", Bc, w[:, :, None] * x,
                       preferred_element_type=jnp.float32)
    s_ref[...] = jnp.exp(total)[:, None, None] * s_ref[...] + s_new


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "head_block", "interpret"),
)
def mamba_ssd(
    x: jnp.ndarray,           # (b, s, h, p)
    log_decay: jnp.ndarray,   # (b, s, h)
    scale: jnp.ndarray,       # (b, s, h)
    B: jnp.ndarray,           # (b, s, n)   (groups == 1)
    C: jnp.ndarray,           # (b, s, n)
    chunk: int = 64,
    head_block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    b, s, h, p = x.shape
    n = B.shape[-1]
    hb = min(head_block, h)
    assert h % hb == 0, (h, hb)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded positions: zero input, zero decay (exp(0)=1 keeps state)
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    xq = x.reshape(b, nc, chunk, h, p)
    aq = log_decay.reshape(b, nc, chunk, h)
    dq = scale.reshape(b, nc, chunk, h)
    Bq = B.reshape(b, nc, chunk, n)
    Cq = C.reshape(b, nc, chunk, n)

    grid = (b, h // hb, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hb, p),
                         lambda ib, ih, ic: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, chunk, hb),
                         lambda ib, ih, ic: (ib, ic, 0, ih)),
            pl.BlockSpec((1, 1, chunk, hb),
                         lambda ib, ih, ic: (ib, ic, 0, ih)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hb, p),
                               lambda ib, ih, ic: (ib, ic, 0, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, chunk, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((hb, n, p), jnp.float32)],
        interpret=interpret,
    )(xq, aq, dq, Bq, Cq)
    return out.reshape(b, sp, h, p)[:, :s]
