"""Chrome-trace / Perfetto flight recorder (host-side, monotonic).

Spans are recorded as complete ("X") events with microsecond ``ts`` /
``dur`` from :mod:`repro.obs.clock`, point events as instants ("i"),
and numeric series as counters ("C") — the JSON schema Perfetto and
``chrome://tracing`` load directly (open https://ui.perfetto.dev and
drop the file in).  Recording is append-to-a-list cheap: no locks, no
I/O until :meth:`TraceRecorder.write`; the recorder must NEVER be
visible to jit (it is plain host state, so it cannot enter a cache
key — ``benchmarks/obs_overhead.py`` gates both properties).

``device_span`` additionally enters a ``jax.profiler.TraceAnnotation``
so that when a device profile is captured (``jax.profiler.trace``),
the host spans line up with the device timeline under the same names.
"""
from __future__ import annotations

import json
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from .clock import perf_us

TRACE_SCHEMA = "repro-obs-trace-v1"

# Span/instant taxonomy (docs/observability.md) — categories group the
# Perfetto tracks: serve (request lifecycle), denoise (compiled step
# path), policy (plan resolution), elastic (replan/evict), fault
# (injected drills), wire (derived byte attribution), dryrun (lowering).
CATEGORIES = ("serve", "denoise", "policy", "elastic", "fault", "wire",
              "dryrun", "obs")


def _jsonable(v: Any) -> Any:
    """Recursive JSON-safe copy: numpy scalars/arrays -> python,
    tuples -> lists, anything exotic -> repr."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()          # numpy scalar
    if hasattr(v, "tolist"):
        return v.tolist()        # numpy array
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    return repr(v)


def _clean(args: Dict[str, Any]) -> Dict[str, Any]:
    return {k: _jsonable(v) for k, v in args.items()}


class TraceRecorder:
    """Accumulates Chrome-trace events; serialises on demand."""

    def __init__(self, pid: int = 1, tid: int = 1) -> None:
        self.events: List[dict] = []
        self.pid = pid
        self.tid = tid

    # -- primitives -----------------------------------------------------
    def begin_span(self, name: str, cat: str = "serve",
                   **args: Any) -> float:
        """Manual span open; pair with :meth:`end_span`."""
        return perf_us()

    def end_span(self, name: str, t0_us: float, cat: str = "serve",
                 **args: Any) -> None:
        t1 = perf_us()
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": t0_us, "dur": t1 - t0_us,
            "pid": self.pid, "tid": self.tid,
            "args": _clean(args),
        })

    @contextmanager
    def span(self, name: str, cat: str = "serve", **args: Any):
        t0 = perf_us()
        try:
            yield
        finally:
            self.end_span(name, t0, cat=cat, **args)

    @contextmanager
    def device_span(self, name: str, cat: str = "denoise", **args: Any):
        """Span that also annotates the device timeline.

        ``jax.profiler.TraceAnnotation`` is ~free when no profiler
        session is active, and names the XLA activity when one is — so
        host spans and device slices share a vocabulary.
        """
        from jax.profiler import TraceAnnotation

        t0 = perf_us()
        try:
            with TraceAnnotation(name):
                yield
        finally:
            self.end_span(name, t0, cat=cat, **args)

    def complete(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "serve", **args: Any) -> None:
        """Complete ("X") event with caller-supplied timestamps.

        Used for events whose clock domain is not ``perf_us`` — e.g.
        per-request lifecycle spans stamped on the serving engine's
        (possibly virtual) clock.  ``dur_us`` is clamped at 0 so a
        degenerate stamp pair can never produce an invalid event.
        """
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": float(ts_us), "dur": max(0.0, float(dur_us)),
            "pid": self.pid, "tid": self.tid,
            "args": _clean(args),
        })

    def instant(self, name: str, cat: str = "serve", **args: Any) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": perf_us(),
            "pid": self.pid, "tid": self.tid,
            "args": _clean(args),
        })

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "serve") -> None:
        """Counter sample — Perfetto renders these as stacked series."""
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": perf_us(),
            "pid": self.pid, "tid": self.tid,
            "args": _clean(values),
        })

    # -- serialisation --------------------------------------------------
    def to_json(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


def validate_trace(doc: dict) -> List[str]:
    """Schema check for exported traces; returns a list of violations.

    Guarded by tier-1 tests so the on-disk format cannot drift without
    a deliberate schema bump: top-level ``traceEvents`` + the
    ``otherData.schema`` tag, and every event a well-formed Chrome
    trace phase with monotonic-microsecond ``ts``.
    """
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["trace document is not an object"]
    if doc.get("otherData", {}).get("schema") != TRACE_SCHEMA:
        errs.append(f"otherData.schema != {TRACE_SCHEMA!r}")
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return errs + ["traceEvents is not a list"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "C", "B", "E", "M"):
            errs.append(f"{where}: bad phase {ph!r}")
        for field in ("name", "ts", "pid", "tid"):
            if field not in ev:
                errs.append(f"{where}: missing {field!r}")
        if ph == "X" and (not isinstance(ev.get("dur"), (int, float))
                          or ev["dur"] < 0):
            errs.append(f"{where}: X event needs dur >= 0")
        if ev.get("cat") not in CATEGORIES:
            errs.append(f"{where}: unknown category {ev.get('cat')!r}")
        if "args" in ev:
            try:
                json.dumps(ev["args"])
            except TypeError:
                errs.append(f"{where}: args not JSON-serialisable")
            ua = ev["args"].get("unattributed_steps") \
                if isinstance(ev["args"], dict) else None
            if isinstance(ua, (int, float)) and ua > 0:
                # a reconciliation row that skipped steps means the wire
                # attribution has a hole — never "free" wire time
                errs.append(f"{where}: {ev.get('name')} has "
                            f"unattributed_steps={ua}")
    return errs
