"""Metrics registry: counters, gauges, histograms + snapshot exporters.

Names are dotted (``serve.queue_depth``); labels are sorted key=value
pairs so a (name, labels) series is stable across runs — the property
the trace-schema tests pin.  Export formats:

* **Prometheus text** (``.prom``/``.txt``): standard exposition format,
  dots mapped to underscores, histograms exported as ``_count`` /
  ``_sum`` plus p50/p99 ``{quantile=...}`` rows (summary-style);
  reservoir truncation is a separate ``<name>_dropped`` counter
  family (``_dropped`` is not a valid summary child series).
* **JSONL** (anything else): one JSON object per series, machine-
  diffable against ``comm_model`` outputs.

Histograms keep a bounded **reservoir** of samples (Algorithm R, a
deterministic per-registry PRNG): once a series passes ``hist_cap``,
each new sample replaces a uniformly random held one, so p50/p99 stay
unbiased estimates of the WHOLE stream instead of freezing on the
first ``hist_cap`` (warm-up) observations.  ``count`` / ``sum`` stay
exact running totals, and every snapshot row exports ``dropped`` (how
many observations exceed the held sample count) so truncation is
always visible.

Recording is a dict update — no locks, no I/O until snapshot time, and
never visible to jit.
"""
from __future__ import annotations

import json
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clock import wall_stamp_s

LabelKey = Tuple[Tuple[str, str], ...]

# Canonical metric names (docs/observability.md) — feeders use these
# constants so the schema cannot fork silently.
QUEUE_DEPTH = "serve.queue_depth"
BATCH_SIZE = "serve.batch_size"
REQUESTS = "serve.requests"
BATCHES = "serve.batches"
RESTARTS = "serve.restarts"
EVICTIONS = "serve.evictions"
BATCH_WALL_S = "serve.batch_wall_s"
STEP_LATENCY_S = "denoise.step_s"
RUN_WALL_S = "denoise.run_s"
COMPILES = "compiler.compiles"
WIRE_BYTES = "wire.bytes"
HEARTBEAT_MISSES = "health.heartbeat_misses"
DEAD_GROUPS = "health.dead_groups"
STRAGGLER_IMBALANCE = "straggler.imbalance"
FAULTS_INJECTED = "faults.injected"
SNAPSHOT_RESUMES = "snapshot.resumes"
SNAPSHOT_RECORDS = "snapshot.records"
PLAN_WIRE_BYTES = "policy.plan_wire_bytes"
PLAN_WIRE_TIME_MS = "policy.plan_wire_time_ms"
PLAN_SEGMENTS = "policy.plan_segments"
# request-lifecycle / SLO names (docs/observability.md, obs/slo.py)
QUEUE_WAIT_S = "serve.queue_wait_s"
E2E_LATENCY_S = "serve.e2e_latency_s"
BATCH_OCCUPANCY = "serve.batch_occupancy"
GOODPUT_RPS = "serve.goodput_rps"
SLO_VIOLATIONS = "serve.slo_violations"
# explicit backpressure: submit() rejected a request at the max_queue
# bound (serving/engine.QueueFull) — counted where it happened
REQUESTS_REJECTED = "serve.requests_rejected"
# replica-router family (serving/router.py, docs/observability.md) —
# fleet-level series; per-replica engine series reuse the serve.*
# names above with a {replica="<id>"} label
ROUTER_DISPATCHES = "router.dispatches"
ROUTER_SHED = "router.shed"
ROUTER_REDISPATCHES = "router.redispatches"
ROUTER_FAILED = "router.failed"
ROUTER_DEGRADE_STEPS = "router.degrade_steps"
ROUTER_RESTORE_STEPS = "router.restore_steps"
ROUTER_REPLICA_DEATHS = "router.replica_deaths"
ROUTER_QUEUE_DEPTH = "router.queue_depth"
ROUTER_HEALTHY_REPLICAS = "router.healthy_replicas"


def _labels(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Reservoir:
    """Bounded sample reservoir (Vitter's Algorithm R) with exact
    running ``seen`` / ``total``: quantiles come from a uniform sample
    of the whole stream, count/sum stay exact, and ``dropped`` exposes
    how many observations the reservoir is NOT holding."""

    __slots__ = ("vals", "seen", "total", "mn", "mx")

    def __init__(self) -> None:
        self.vals: List[float] = []
        self.seen = 0
        self.total = 0.0
        self.mn = float("inf")
        self.mx = float("-inf")

    def add(self, value: float, cap: int, rng: random.Random) -> None:
        self.seen += 1
        self.total += value
        self.mn = min(self.mn, value)
        self.mx = max(self.mx, value)
        if len(self.vals) < cap:
            self.vals.append(value)
            return
        j = rng.randrange(self.seen)
        if j < cap:
            self.vals[j] = value

    @property
    def dropped(self) -> int:
        return self.seen - len(self.vals)


class MetricsRegistry:
    """Counters/gauges/histograms keyed on (name, sorted labels)."""

    def __init__(self, hist_cap: int = 65536, seed: int = 0) -> None:
        self._counters: Dict[Tuple[str, LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, LabelKey], float] = {}
        self._hists: Dict[Tuple[str, LabelKey], _Reservoir] = {}
        self._hist_cap = hist_cap
        # one seeded PRNG for every reservoir: the same observation
        # sequence always yields the same held samples (replayable
        # snapshots under a fixed workload seed)
        self._rng = random.Random(seed)

    # -- recording ------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        key = (name, _labels(labels))
        self._counters[key] = self._counters.get(key, 0.0) + float(value)

    def set(self, name: str, value: float, **labels) -> None:
        self._gauges[(name, _labels(labels))] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        res = self._hists.setdefault((name, _labels(labels)), _Reservoir())
        res.add(float(value), self._hist_cap, self._rng)

    # -- reading --------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get((name, _labels(labels)), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        return self._gauges.get((name, _labels(labels)))

    def hist_values(self, name: str, **labels) -> List[float]:
        res = self._hists.get((name, _labels(labels)))
        return [] if res is None else list(res.vals)

    def hist_dropped(self, name: str, **labels) -> int:
        res = self._hists.get((name, _labels(labels)))
        return 0 if res is None else res.dropped

    @staticmethod
    def _quantiles(res: _Reservoir) -> Dict[str, float]:
        arr = np.asarray(res.vals, dtype=np.float64)
        return {
            "count": int(res.seen),          # exact stream length
            "sum": float(res.total),         # exact stream total
            "min": float(res.mn),            # exact stream extrema
            "max": float(res.mx),
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "dropped": int(res.dropped),     # samples not held
        }

    def snapshot(self) -> List[dict]:
        """Flat series list — the JSONL rows, sorted for stable diffs."""
        rows: List[dict] = []
        for (name, lk), v in self._counters.items():
            rows.append({"name": name, "type": "counter",
                         "labels": dict(lk), "value": v})
        for (name, lk), v in self._gauges.items():
            rows.append({"name": name, "type": "gauge",
                         "labels": dict(lk), "value": v})
        for (name, lk), res in self._hists.items():
            if res.vals:
                rows.append({"name": name, "type": "histogram",
                             "labels": dict(lk),
                             **self._quantiles(res)})
        rows.sort(key=lambda r: (r["name"], sorted(r["labels"].items())))
        return rows

    # -- exporters ------------------------------------------------------
    def to_jsonl(self) -> str:
        stamp = wall_stamp_s()
        return "\n".join(
            json.dumps({**row, "stamp_s": stamp})
            for row in self.snapshot()
        ) + "\n"

    def to_prometheus(self) -> str:
        def pname(name: str) -> str:
            return "repro_" + name.replace(".", "_").replace("-", "_")

        def escape(v: str) -> str:
            # exposition-format label escaping: backslash, quote, newline
            return (str(v).replace("\\", "\\\\").replace('"', '\\"')
                    .replace("\n", "\\n"))

        def fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
            parts = [f'{k}="{escape(v)}"' for k, v in sorted(labels.items())]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        lines: List[str] = []
        typed: set = set()  # one TYPE line per metric name
        # reservoir truncation per histogram series: NOT a valid
        # summary child series, so it gets its own counter family —
        # collected here and emitted after the main pass so every
        # family's samples stay contiguous under one TYPE line
        dropped: Dict[str, List[str]] = {}

        def type_line(n: str, kind: str) -> None:
            if n not in typed:
                typed.add(n)
                lines.append(f"# TYPE {n} {kind}")

        for row in self.snapshot():
            n = pname(row["name"])
            if row["type"] == "counter":
                type_line(n, "counter")
                lines.append(f"{n}{fmt_labels(row['labels'])} "
                             f"{row['value']}")
            elif row["type"] == "gauge":
                type_line(n, "gauge")
                lines.append(f"{n}{fmt_labels(row['labels'])} "
                             f"{row['value']}")
            else:  # histogram -> summary-style quantile rows
                type_line(n, "summary")
                for q, field in (("0.5", "p50"), ("0.99", "p99")):
                    extra = 'quantile="%s"' % q
                    lines.append(
                        f"{n}{fmt_labels(row['labels'], extra)} "
                        f"{row[field]}")
                lines.append(f"{n}_sum{fmt_labels(row['labels'])} "
                             f"{row['sum']}")
                lines.append(f"{n}_count{fmt_labels(row['labels'])} "
                             f"{row['count']}")
                dropped.setdefault(f"{n}_dropped", []).append(
                    f"{n}_dropped{fmt_labels(row['labels'])} "
                    f"{row['dropped']}")
        for fam in sorted(dropped):
            lines.append(f"# TYPE {fam} counter")
            lines.extend(dropped[fam])
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        """Format by extension: .prom/.txt -> Prometheus text, else JSONL."""
        text = (self.to_prometheus()
                if path.endswith((".prom", ".txt")) else self.to_jsonl())
        with open(path, "w") as f:
            f.write(text)
