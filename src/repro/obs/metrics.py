"""Metrics registry: counters, gauges, histograms + snapshot exporters.

Names are dotted (``serve.queue_depth``); labels are sorted key=value
pairs so a (name, labels) series is stable across runs — the property
the trace-schema tests pin.  Export formats:

* **Prometheus text** (``.prom``/``.txt``): standard exposition format,
  dots mapped to underscores, histograms exported as ``_count`` /
  ``_sum`` plus p50/p99 ``{quantile=...}`` rows (summary-style).
* **JSONL** (anything else): one JSON object per series, machine-
  diffable against ``comm_model`` outputs.

Recording is a dict update — no locks, no I/O until snapshot time, and
never visible to jit.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clock import wall_stamp_s

LabelKey = Tuple[Tuple[str, str], ...]

# Canonical metric names (docs/observability.md) — feeders use these
# constants so the schema cannot fork silently.
QUEUE_DEPTH = "serve.queue_depth"
BATCH_SIZE = "serve.batch_size"
REQUESTS = "serve.requests"
BATCHES = "serve.batches"
RESTARTS = "serve.restarts"
EVICTIONS = "serve.evictions"
BATCH_WALL_S = "serve.batch_wall_s"
STEP_LATENCY_S = "denoise.step_s"
RUN_WALL_S = "denoise.run_s"
COMPILES = "compiler.compiles"
WIRE_BYTES = "wire.bytes"
HEARTBEAT_MISSES = "health.heartbeat_misses"
DEAD_GROUPS = "health.dead_groups"
STRAGGLER_IMBALANCE = "straggler.imbalance"
FAULTS_INJECTED = "faults.injected"
SNAPSHOT_RESUMES = "snapshot.resumes"
SNAPSHOT_RECORDS = "snapshot.records"
PLAN_WIRE_BYTES = "policy.plan_wire_bytes"
PLAN_WIRE_TIME_MS = "policy.plan_wire_time_ms"
PLAN_SEGMENTS = "policy.plan_segments"


def _labels(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Counters/gauges/histograms keyed on (name, sorted labels)."""

    def __init__(self, hist_cap: int = 65536) -> None:
        self._counters: Dict[Tuple[str, LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, LabelKey], float] = {}
        self._hists: Dict[Tuple[str, LabelKey], List[float]] = {}
        self._hist_cap = hist_cap

    # -- recording ------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        key = (name, _labels(labels))
        self._counters[key] = self._counters.get(key, 0.0) + float(value)

    def set(self, name: str, value: float, **labels) -> None:
        self._gauges[(name, _labels(labels))] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        vals = self._hists.setdefault((name, _labels(labels)), [])
        if len(vals) < self._hist_cap:
            vals.append(float(value))

    # -- reading --------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get((name, _labels(labels)), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        return self._gauges.get((name, _labels(labels)))

    def hist_values(self, name: str, **labels) -> List[float]:
        return list(self._hists.get((name, _labels(labels)), []))

    @staticmethod
    def _quantiles(vals: Sequence[float]) -> Dict[str, float]:
        arr = np.asarray(vals, dtype=np.float64)
        return {
            "count": int(arr.size),
            "sum": float(arr.sum()),
            "min": float(arr.min()),
            "max": float(arr.max()),
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
        }

    def snapshot(self) -> List[dict]:
        """Flat series list — the JSONL rows, sorted for stable diffs."""
        rows: List[dict] = []
        for (name, lk), v in self._counters.items():
            rows.append({"name": name, "type": "counter",
                         "labels": dict(lk), "value": v})
        for (name, lk), v in self._gauges.items():
            rows.append({"name": name, "type": "gauge",
                         "labels": dict(lk), "value": v})
        for (name, lk), vals in self._hists.items():
            if vals:
                rows.append({"name": name, "type": "histogram",
                             "labels": dict(lk),
                             **self._quantiles(vals)})
        rows.sort(key=lambda r: (r["name"], sorted(r["labels"].items())))
        return rows

    # -- exporters ------------------------------------------------------
    def to_jsonl(self) -> str:
        stamp = wall_stamp_s()
        return "\n".join(
            json.dumps({**row, "stamp_s": stamp})
            for row in self.snapshot()
        ) + "\n"

    def to_prometheus(self) -> str:
        def pname(name: str) -> str:
            return "repro_" + name.replace(".", "_").replace("-", "_")

        def fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in sorted(labels.items())]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        lines: List[str] = []
        typed: set = set()  # one TYPE line per metric name

        def type_line(n: str, kind: str) -> None:
            if n not in typed:
                typed.add(n)
                lines.append(f"# TYPE {n} {kind}")

        for row in self.snapshot():
            n = pname(row["name"])
            if row["type"] == "counter":
                type_line(n, "counter")
                lines.append(f"{n}{fmt_labels(row['labels'])} "
                             f"{row['value']}")
            elif row["type"] == "gauge":
                type_line(n, "gauge")
                lines.append(f"{n}{fmt_labels(row['labels'])} "
                             f"{row['value']}")
            else:  # histogram -> summary-style quantile rows
                type_line(n, "summary")
                for q, field in (("0.5", "p50"), ("0.99", "p99")):
                    extra = 'quantile="%s"' % q
                    lines.append(
                        f"{n}{fmt_labels(row['labels'], extra)} "
                        f"{row[field]}")
                lines.append(f"{n}_sum{fmt_labels(row['labels'])} "
                             f"{row['sum']}")
                lines.append(f"{n}_count{fmt_labels(row['labels'])} "
                             f"{row['count']}")
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        """Format by extension: .prom/.txt -> Prometheus text, else JSONL."""
        text = (self.to_prometheus()
                if path.endswith((".prom", ".txt")) else self.to_jsonl())
        with open(path, "w") as f:
            f.write(text)
