"""Derived per-step wire accounting — replay ``comm_model``, don't probe.

The denoise steps run inside ``lax.scan``, so there is nothing to
instrument on the hot path: per-step wire bytes are *derived* by
replaying the analytic byte model against the geometry the engine
actually executed.  Because ``core/comm_model`` matches the compiled
HLO exactly per collective per tier (the repo-wide invariant every
conformance cell gates), the derived attribution is exact, not an
estimate.

The replay consumes a **geometry timeline** — ``[(from_step, K),
...]`` — recorded by the serving engine: one entry at batch start and
one per mid-request eviction (``shrink_hybrid_mesh`` replans change K
and therefore the rotation-dim sequence and halo plan of every later
step).  Step ``i`` is attributed under the geometry whose ``from_step``
is the largest one ``<= i`` — i.e. the geometry its *surviving*
execution used (snapshot-resumed retries re-run steps under the new
mesh; duplicated work from restarts is tracked by ``serve.restarts``,
not double-billed here).

All payloads are per-device, HLO output-shape accounted, per sample
(batch size 1) — the same basis as ``analysis/hlo_analyzer`` and the
``lp_halo_*`` models; records carry ``batch_size`` for scaling.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.comm_model import (
    VDMCommConfig,
    lp_halo_codec_step_collectives,
    lp_halo_sharded_step_collectives,
)
from repro.core.schedule import rotation_dim, usable_dims

HALO_IMPLS = ("halo", "halo_hybrid")


def step_wire_attribution(
    cfg: VDMCommConfig,
    K: int,
    r: float,
    dim: int,
    codec: str,
    tp: int = 1,
    wire_shard: bool = False,
    lp_impl: str = "halo",
) -> Dict[str, Dict[str, float]]:
    """Per-device payload bytes of ONE step, split by link tier.

    Halo family: the unsharded wire puts every LP collective on the
    inter-group (lp-axis) tier — per-device payloads are T-independent
    (``lp_halo_hybrid_step_collectives``) — while ``wire_shard`` splits
    them per :func:`lp_halo_sharded_step_collectives`.  The psum-family
    engines (``shard_map`` at K=2, and ``uniform``/``gspmd``, whose
    partitioned reduce ships the full latent) are one all-reduce of the
    S_z buffer per step, output-shape accounted like the HLO analyzer
    reports it.
    """
    if lp_impl in HALO_IMPLS:
        if wire_shard and tp >= 2:
            return lp_halo_sharded_step_collectives(
                cfg, K, tp, r, dim, codec=codec)
        d = lp_halo_codec_step_collectives(cfg, K, r, dim, codec=codec)
        return {"inter": dict(d), "intra": {}}
    # psum family: one latent-sized all-reduce per step, codec-blind
    # (comm_lp_spmd / comm_lp_gspmd_codec: GSPMD has no
    # reduce-then-decode hook, so codecs never shrink these bytes).
    return {"inter": {"all-reduce": float(cfg.latent_bytes)}, "intra": {}}


def attribute_denoise_steps(
    cfg: VDMCommConfig,
    r: float,
    step_codecs: Sequence[str],
    geometry: Sequence[Tuple[int, int]],
    tp: int = 1,
    wire_shard: bool = False,
    lp_impl: str = "halo",
    links=None,
    batch_size: int = 1,
) -> List[dict]:
    """Replay the byte model over a whole denoise -> per-step records.

    ``geometry`` is the engine's timeline ``[(from_step, K), ...]``
    (ascending ``from_step``; first entry must cover step 1).  Each
    K-epoch re-derives ``usable_dims`` and restarts nothing else — the
    rotation index is the global step ``i``, exactly as ``lp_denoise``
    computes it after a replan.  ``links`` (a ``policy.autotune
    .LinkModel``) prices each step's predicted wire time.

    Displaced codecs (``displaced:*``): ``inter_bytes`` stays the TOTAL
    inter payload (HLO-matching — the collectives are identical), and
    ``hidden_bytes`` records the slab-ppermute portion that overlaps
    compute on every step that is not the first of its (dim x codec x
    K) run — the same run-boundary rule ``lp_denoise`` uses to flush
    the stale carry.  ``pred_wire_time_ms`` prices only the EXPOSED
    bytes (``inter - hidden``).  Non-displaced codecs get
    ``hidden_bytes = 0`` and identical records to before.
    """
    if not geometry or geometry[0][0] > 1:
        raise ValueError(f"geometry timeline must start at step 1: "
                         f"{geometry!r}")
    epochs = sorted(geometry, key=lambda g: g[0])
    records: List[dict] = []
    cache: Dict[tuple, dict] = {}
    prev_run = None
    for i, codec in enumerate(step_codecs, start=1):
        epoch_idx, K = 0, epochs[0][1]
        for j, (start, k) in enumerate(epochs):
            if start <= i:
                epoch_idx, K = j, k
        dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
        dim = rotation_dim(i, dims)
        key = (K, dim, codec)
        if key not in cache:
            cache[key] = step_wire_attribution(
                cfg, K, r, dim, codec, tp=tp, wire_shard=wire_shard,
                lp_impl=lp_impl)
        tiers = cache[key]
        inter_b = float(sum(tiers.get("inter", {}).values()))
        intra_b = float(sum(tiers.get("intra", {}).values()))
        hidden_b = 0.0
        if (str(codec).startswith("displaced") and prev_run == key
                and lp_impl in HALO_IMPLS):
            hidden_b = float(tiers.get("inter", {})
                             .get("collective-permute", 0.0))
        prev_run = key
        rec = {
            "step": i,
            "dim": dim,
            "codec": codec,
            "K": K,
            "tp": tp,
            "wire_shard": bool(wire_shard and tp >= 2
                               and lp_impl in HALO_IMPLS),
            "lp_impl": lp_impl,
            "plan_epoch": epoch_idx,
            "batch_size": batch_size,
            "inter": {k: float(v) for k, v in
                      tiers.get("inter", {}).items()},
            "intra": {k: float(v) for k, v in
                      tiers.get("intra", {}).items()},
            "inter_bytes": inter_b,
            "intra_bytes": intra_b,
            "hidden_bytes": hidden_b,
        }
        if links is not None:
            rec["pred_wire_time_ms"] = links.wire_time_ms(
                inter_b - hidden_b, intra_b)
        records.append(rec)
    return records


def tier_for_group_size(group_size: int, M: int, T: int) -> str:
    """Map an HLO replica-group size to a link tier.

    ``hlo_analyzer.collective_group_bytes`` keys payloads as
    ``"all-gather[g]"`` where ``g`` is the replica-group size: on an
    ``(lp=M, tp=T)`` mesh, lp-axis collectives have groups of size M
    (inter tier) and tp-axis collectives groups of size T (intra).
    When M == T the group size alone cannot disambiguate — callers get
    ``"ambiguous"`` and should pick M != T meshes for exact-diff tests.
    """
    if M != T:
        if group_size == M:
            return "inter"
        if group_size == T:
            return "intra"
    elif group_size == M:
        return "ambiguous"
    return "unknown"


def tiered_collectives(
    collective_group_bytes: Dict[str, float], M: int, T: int
) -> List[dict]:
    """Unify dryrun's ``collectives_by_group`` into the wire schema.

    ``{"all-gather[3]": bytes, ...}`` -> sorted records of
    ``{"collective", "group_size", "tier", "bytes"}`` — the same
    vocabulary :func:`step_wire_attribution` emits, so a dry-run HLO
    measurement is machine-diffable against the ``comm_model`` replay.
    """
    out: List[dict] = []
    for key, nbytes in collective_group_bytes.items():
        if "[" in key and key.endswith("]"):
            kind, g = key[:-1].split("[", 1)
            group_size = int(g)
        else:  # ungrouped (single-mesh-axis) collective
            kind, group_size = key, M
        out.append({
            "collective": kind,
            "group_size": group_size,
            "tier": tier_for_group_size(group_size, M, T),
            "bytes": float(nbytes),
        })
    out.sort(key=lambda r: (r["tier"], r["collective"], r["group_size"]))
    return out


def reconcile_segments(
    records: Sequence[dict],
    measured: Sequence[dict],
) -> List[dict]:
    """Predicted vs measured wall time per codec segment.

    ``records`` are per-step attribution rows (with
    ``pred_wire_time_ms``); ``measured`` are run-span rows ``{"start",
    "stop", "wall_s"}`` from the trace.  Returns one row per measured
    run with the summed prediction over its step range — the
    calibration feedback that tells the autotuner whether its
    ``LinkModel`` gbps defaults match the deployed links.

    A measured step with no attribution record (or a record without a
    ``pred_wire_time_ms``) is NOT silently reconciled as zero-cost
    wire: it is counted in the row's ``unattributed_steps``, and
    ``validate_trace`` fails a trace whose reconciliation carries a
    nonzero count — a hole in the attribution is a bug in the feeder,
    not free bytes.
    """
    by_step = {r["step"]: r for r in records}
    out = []
    for m in measured:
        steps = range(int(m["start"]), int(m["stop"]) + 1)
        pred = 0.0
        unattributed = 0
        for s in steps:
            rec = by_step.get(s)
            if rec is None or "pred_wire_time_ms" not in rec:
                unattributed += 1
            else:
                pred += rec["pred_wire_time_ms"]
        row = {
            "start": int(m["start"]),
            "stop": int(m["stop"]),
            "codec": m.get("codec"),
            "dim": m.get("dim"),
            "measured_wall_ms": float(m["wall_s"]) * 1e3,
            "pred_wire_time_ms": pred,
            "unattributed_steps": unattributed,
        }
        if pred > 0 and not unattributed:
            row["measured_over_pred"] = row["measured_wall_ms"] / pred
        out.append(row)
    return out
