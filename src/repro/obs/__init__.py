"""Flight recorder + metrics plane for the LP serving path.

One object — :class:`FlightRecorder` — carries the whole observability
surface: Chrome-trace spans (:mod:`.trace`), a metrics registry
(:mod:`.metrics`), and derived per-step wire accounting
(:mod:`.account`).  Every feeder (serving engine, runtime health,
policy autotuner, launch CLIs) takes an *optional* recorder and calls
through the no-op-safe helpers here, so the instrumented and bare code
paths are the same code path.

Invariant: the recorder is host state only.  It is never passed into a
jitted function and never enters ``LPStepCompiler``'s cache key —
``benchmarks/obs_overhead.py`` gates 0 extra compiles and <= 3% step
latency with tracing on.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext
from typing import Any, Dict, List, Optional, Sequence

from . import metrics as M
from .account import (
    attribute_denoise_steps,
    reconcile_segments,
    step_wire_attribution,
    tier_for_group_size,
    tiered_collectives,
)
from .clock import perf_s, perf_us, wall_stamp_s
from .metrics import MetricsRegistry
from .slo import (
    SLO_REPORT_SCHEMA,
    SLOClass,
    SLOSpec,
    disposition,
    evaluate_slo,
    failures_from_trace,
    report_from_metrics_jsonl,
    rows_from_trace,
    shed_from_trace,
)
from .trace import TRACE_SCHEMA, TraceRecorder, validate_trace

__all__ = [
    "FlightRecorder", "MetricsRegistry", "TraceRecorder",
    "TRACE_SCHEMA", "validate_trace", "attribute_denoise_steps",
    "step_wire_attribution", "tiered_collectives",
    "tier_for_group_size", "reconcile_segments",
    "perf_s", "perf_us", "wall_stamp_s",
    "SLOSpec", "SLOClass", "SLO_REPORT_SCHEMA", "evaluate_slo",
    "rows_from_trace", "report_from_metrics_jsonl",
    "shed_from_trace", "failures_from_trace", "disposition",
]


class FlightRecorder:
    """Bundles a trace recorder + metrics registry behind safe helpers.

    Construct with ``trace=False`` or ``metrics=False`` to disable one
    plane; all helpers no-op cleanly on the disabled plane, so feeders
    never branch.
    """

    def __init__(self, trace: bool = True, metrics: bool = True,
                 links=None) -> None:
        self.trace: Optional[TraceRecorder] = (
            TraceRecorder() if trace else None)
        self.metrics: Optional[MetricsRegistry] = (
            MetricsRegistry() if metrics else None)
        if links is None:
            # the autotuner's two-tier defaults, so per-step predicted
            # wire time is priced without configuration (lazy import:
            # policy sits above obs in the layering)
            from repro.policy.autotune import DEFAULT_LINKS
            links = DEFAULT_LINKS
        self.links = links  # policy.autotune.LinkModel for pricing
        self.wire_steps: List[dict] = []    # per-step attribution rows
        self.plans: List[dict] = []         # resolved plan records
        self.measured_runs: List[dict] = []  # run-span wall times
        self.reconciliations: List[dict] = []  # predicted vs measured
        self.request_rows: List[dict] = []  # per-request lifecycle rows
        self.shed_rows: List[dict] = []     # admission-control sheds
        self.failed_rows: List[dict] = []   # terminal request failures

    # -- trace helpers (no-op when trace plane disabled) ---------------
    def span(self, name: str, cat: str = "serve", **args: Any):
        if self.trace is None:
            return nullcontext()
        return self.trace.span(name, cat=cat, **args)

    def device_span(self, name: str, cat: str = "denoise", **args: Any):
        if self.trace is None:
            return nullcontext()
        return self.trace.device_span(name, cat=cat, **args)

    def instant(self, name: str, cat: str = "serve", **args: Any) -> None:
        if self.trace is not None:
            self.trace.instant(name, cat=cat, **args)

    def counter_sample(self, name: str, values: Dict[str, float],
                       cat: str = "serve") -> None:
        if self.trace is not None:
            self.trace.counter(name, values, cat=cat)

    # -- metrics helpers (no-op when metrics plane disabled) -----------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        if self.metrics is not None:
            self.metrics.inc(name, value, **labels)

    def gauge(self, name: str, value: float, **labels) -> None:
        if self.metrics is not None:
            self.metrics.set(name, value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        if self.metrics is not None:
            self.metrics.observe(name, value, **labels)

    # -- composite feeders ---------------------------------------------
    def record_run(self, start: int, stop: int, wall_s: float,
                   dim: Optional[int] = None, codec: Optional[str] = None,
                   epoch: int = 0) -> None:
        """One compiled dispatch (a scan-fused run or a single step).

        The span itself is emitted by ``lp_denoise``'s ``device_span``;
        this records the measured wall for segment reconciliation and
        feeds the run/step latency histograms.  Steps inside a fused
        ``lax.scan`` are invisible individually, so the per-step sample
        is the run wall divided evenly — documented as derived in
        docs/observability.md.
        """
        n = max(1, int(stop) - int(start) + 1)
        self.measured_runs.append({
            "start": int(start), "stop": int(stop),
            "wall_s": float(wall_s), "dim": dim, "codec": codec,
            "epoch": int(epoch),
        })
        self.observe(M.RUN_WALL_S, wall_s)
        for _ in range(n):
            self.observe(M.STEP_LATENCY_S, wall_s / n)

    def record_snapshot(self, step: int) -> None:
        self.instant("snapshot.record", cat="serve", step=int(step))
        self.inc(M.SNAPSHOT_RECORDS)

    def record_resume(self, from_step: int) -> None:
        self.instant("snapshot.resume", cat="serve",
                     from_step=int(from_step))
        self.inc(M.SNAPSHOT_RESUMES)

    def record_replan(self, step: int, K: int, epoch: int) -> None:
        self.instant("plan.replan", cat="elastic", step=int(step),
                     K=int(K), epoch=int(epoch))

    def record_request(self, row: dict) -> None:
        """One completed request's lifecycle row (serving engine).

        The row carries the full stamp set (``submit_s`` / ``admit_s``
        / ``denoise_start_s`` / ``done_s`` on the engine's clock — the
        workload's *virtual* timeline under the load harness) plus
        ``priority``, batch identity, and the derived
        ``queue_wait_s`` / ``e2e_s``.  It is emitted verbatim as a
        ``request.lifecycle`` complete event so an offline evaluation
        (``obs.slo.rows_from_trace``) sees byte-identical inputs to
        the live one, and feeds the per-priority latency histograms.
        """
        self.request_rows.append(row)
        if self.trace is not None:
            self.trace.complete(
                "request.lifecycle",
                ts_us=float(row["submit_s"]) * 1e6,
                dur_us=(float(row["done_s"]) - float(row["submit_s"]))
                * 1e6,
                cat="serve", **row)
        priority = str(row.get("priority", "standard"))
        labels = {"priority": priority}
        if row.get("replica") is not None:
            labels["replica"] = str(row["replica"])
        self.observe(M.QUEUE_WAIT_S, row["queue_wait_s"], **labels)
        self.observe(M.E2E_LATENCY_S, row["e2e_s"], **labels)
        if row.get("violated"):
            self.inc(M.SLO_VIOLATIONS, **labels)

    def record_shed(self, row: dict) -> None:
        """One request shed by admission control (the replica router's
        load-shedding path — never the engine, which REJECTS at submit
        instead).  ``row`` carries ``request_id`` / ``priority`` /
        ``submit_s`` / ``shed_s`` / ``reason`` (+ queue depths); it is
        emitted verbatim as a ``request.shed`` instant so the offline
        SLO evaluation can reconstruct the disposition of every
        admitted request (the zero-lost-requests gate), and counts
        ``router.shed`` per priority."""
        self.shed_rows.append(row)
        self.instant("request.shed", cat="serve", **row)
        self.inc(M.ROUTER_SHED,
                 priority=str(row.get("priority", "standard")))

    def record_failed(self, row: dict) -> None:
        """One TERMINAL request failure (redispatch budget exhausted,
        or no live replica left).  Engine-level ``request.failed``
        instants are not terminal under a router — the router may still
        redispatch — so the router records its own row here with
        ``terminal=True``; offline disposition accounting keys on that
        flag.  Emitted verbatim as a ``request.failed`` instant and
        counted as ``router.failed`` per priority."""
        self.failed_rows.append(row)
        self.instant("request.failed", cat="serve", **row)
        self.inc(M.ROUTER_FAILED,
                 priority=str(row.get("priority", "standard")))

    def record_wire_steps(self, records: Sequence[dict]) -> None:
        """Attribution rows -> trace instants + tiered byte counters.

        ``hidden_bytes`` (the displaced-halo portion of ``inter_bytes``
        that overlaps compute, see ``account.attribute_denoise_steps``)
        rides the same instants and the by-tier counter — it is an
        attribution OF inter bytes, not an extra tier, so the collective
        byte counters (which gate HLO-exactness) are unchanged.
        """
        self.wire_steps.extend(records)
        for rec in records:
            self.instant("wire.step", cat="wire", **{
                k: rec[k] for k in
                ("step", "dim", "codec", "K", "inter_bytes", "intra_bytes",
                 "hidden_bytes") if k in rec
            })
            for tier in ("inter", "intra"):
                for coll, nbytes in rec.get(tier, {}).items():
                    self.inc(M.WIRE_BYTES, nbytes, tier=tier,
                             collective=coll)
        if records and self.trace is not None:
            tot_inter = sum(r["inter_bytes"] for r in records)
            tot_intra = sum(r["intra_bytes"] for r in records)
            tot_hidden = sum(r.get("hidden_bytes", 0.0) for r in records)
            self.counter_sample("wire.bytes_by_tier",
                                {"inter": tot_inter, "intra": tot_intra,
                                 "hidden": tot_hidden},
                                cat="wire")

    def record_reconciliations(self, rows: Sequence[dict]) -> None:
        """Predicted-vs-measured rows (``account.reconcile_segments``)
        -> ``wire.reconcile`` instants.  ``unattributed_steps`` travels
        with each row so ``validate_trace`` can fail a trace whose
        reconciliation silently skipped steps."""
        self.reconciliations.extend(rows)
        for row in rows:
            self.instant("wire.reconcile", cat="wire", **row)

    def record_plan(self, plan, candidates: Optional[Sequence[dict]] = None,
                    context: str = "serve") -> None:
        """A resolved ``StepPolicyPlan`` + the autotuner's ranked field."""
        row = {
            "context": context,
            "lp_impl": plan.lp_impl,
            "schedule": plan.schedule.spec,
            "wire_shard": bool(plan.wire_shard),
            "num_segments": plan.num_segments,
            "wire_bytes": float(plan.wire_bytes),
            "inter_bytes": float(plan.inter_bytes),
            "intra_bytes": float(plan.intra_bytes),
            "wire_time_ms": float(plan.wire_time_ms),
            "hidden_bytes": float(getattr(plan, "hidden_bytes", 0)),
        }
        if candidates is not None:
            row["candidates"] = list(candidates)
        self.plans.append(row)
        self.instant("policy.plan", cat="policy", **row)
        self.gauge(M.PLAN_WIRE_BYTES, plan.wire_bytes, context=context)
        self.gauge(M.PLAN_WIRE_TIME_MS, plan.wire_time_ms, context=context)
        self.gauge(M.PLAN_SEGMENTS, plan.num_segments, context=context)

    # -- export ---------------------------------------------------------
    def write_trace(self, path: str) -> None:
        if self.trace is not None:
            self.trace.write(path)

    def write_metrics(self, path: str) -> None:
        if self.metrics is not None:
            self.metrics.write(path)
