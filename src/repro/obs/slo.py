"""SLO specs + request-lifecycle evaluator for the serving load harness.

An :class:`SLOSpec` maps **priority classes** to deadlines: the
``--slo`` grammar is comma-separated ``priority:deadline_s[@target]``
entries, e.g. ``"interactive:2.0@0.999,standard:8,batch:30@0.9"`` —
``deadline_s`` is the end-to-end (submit -> done) latency bound and
``target`` the fraction of the class's requests that must meet it
(default 0.99).

:func:`evaluate_slo` turns per-request lifecycle rows (the stamps the
serving engine records — see ``LPServingEngine`` and
``FlightRecorder.record_request``) into a per-class report: request
count, queue-wait and e2e p50/p99, deadline violations + violation
rate, goodput (requests meeting their deadline per second, absolute
and per device), and SLO **burn rate** (violation rate over the error
budget ``1 - target``; burn > 1 means the budget is being spent faster
than the SLO allows).

The evaluator is deliberately *source-agnostic*: rows can come

* **live** from a :class:`~repro.obs.FlightRecorder`
  (``recorder.request_rows``),
* **offline** from a ``--trace-out`` artifact
  (:func:`rows_from_trace` extracts the ``request.lifecycle`` events),

and because violations/quantiles are always recomputed from the raw
stamps (never trusted from the producer), the offline report is
guaranteed to equal the live one for the same serve —
``benchmarks/serving_load.py`` gates that equality.  A coarser
aggregate-only report can also be rebuilt from a ``--metrics-out``
JSONL snapshot (:func:`report_from_metrics_jsonl`): per-class
quantiles survive, per-request recomputation does not.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from . import metrics as M

SLO_REPORT_SCHEMA = "repro-slo-report-v1"

#: Priority vocabulary the load harness ships by default.  The spec
#: grammar accepts any identifier — these are just the documented
#: classes the request-mix generator and docs use.
PRIORITY_CLASSES = ("interactive", "standard", "batch")

DEFAULT_SLO_SPEC = "interactive:30@0.99,standard:120@0.95,batch:600@0.9"


@dataclasses.dataclass(frozen=True)
class SLOClass:
    priority: str
    deadline_s: float
    target: float = 0.99          # fraction that must meet the deadline

    def __post_init__(self):
        if self.deadline_s <= 0:
            raise ValueError(
                f"SLO class {self.priority!r}: deadline must be > 0, "
                f"got {self.deadline_s}")
        if not 0.0 < self.target <= 1.0:
            raise ValueError(
                f"SLO class {self.priority!r}: target must be in (0, 1], "
                f"got {self.target}")

    @property
    def entry(self) -> str:
        tgt = f"@{self.target:g}" if self.target != 0.99 else ""
        return f"{self.priority}:{self.deadline_s:g}{tgt}"


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    classes: Dict[str, SLOClass]

    @classmethod
    def parse(cls, spec: "SLOSpec | str | None") -> "SLOSpec":
        """``"interactive:2.0@0.999,standard:8"`` -> :class:`SLOSpec`.

        ``None``/empty parses to :data:`DEFAULT_SLO_SPEC`.
        """
        if isinstance(spec, SLOSpec):
            return spec
        if spec is None or not str(spec).strip():
            spec = DEFAULT_SLO_SPEC
        classes: Dict[str, SLOClass] = {}
        for entry in str(spec).split(","):
            entry = entry.strip()
            if not entry:
                continue
            head, sep, tgt = entry.partition("@")
            name, colon, deadline = head.partition(":")
            name = name.strip()
            if not name or not colon or not deadline.strip():
                raise ValueError(
                    f"bad SLO entry {entry!r}: want "
                    "'priority:deadline_s[@target]'")
            if name in classes:
                raise ValueError(f"duplicate SLO class {name!r}")
            try:
                deadline_s = float(deadline)
                target = float(tgt) if sep else 0.99
            except ValueError as e:
                raise ValueError(f"bad SLO entry {entry!r}: {e}") from None
            classes[name] = SLOClass(name, deadline_s, target)
        if not classes:
            raise ValueError(f"SLO spec {spec!r} has no classes")
        return cls(classes)

    @property
    def spec(self) -> str:
        """Canonical round-trippable string form."""
        return ",".join(c.entry for c in self.classes.values())

    def get(self, priority: str) -> Optional[SLOClass]:
        return self.classes.get(priority)

    def deadline_for(self, priority: str) -> float:
        """Deadline for ``priority``; +inf when the class is unspeced
        (an unspeced class can never violate — it is still reported)."""
        c = self.classes.get(priority)
        return c.deadline_s if c is not None else math.inf


# ---------------------------------------------------------------- rows
def rows_from_trace(doc: dict) -> List[dict]:
    """Extract per-request lifecycle rows from an exported trace.

    The inverse of ``FlightRecorder.record_request``: every
    ``request.lifecycle`` complete event carries the full row in its
    ``args``, so an offline evaluation sees byte-identical inputs to
    the live one.
    """
    rows = []
    for ev in doc.get("traceEvents", []):
        if ev.get("name") == "request.lifecycle" and "args" in ev:
            rows.append(dict(ev["args"]))
    return rows


def shed_from_trace(doc: dict) -> List[dict]:
    """Extract ``request.shed`` rows from an exported trace — the
    inverse of ``FlightRecorder.record_shed``.  One row per request the
    router's admission control dropped; together with the lifecycle and
    terminal-failure rows these account for EVERY admitted request (the
    zero-lost-requests gate in ``benchmarks/router_resilience.py``)."""
    rows = []
    for ev in doc.get("traceEvents", []):
        if ev.get("name") == "request.shed" and "args" in ev:
            rows.append(dict(ev["args"]))
    return rows


def failures_from_trace(doc: dict, terminal_only: bool = True) -> List[dict]:
    """Extract ``request.failed`` rows from an exported trace.

    Two producers share the event name: the ENGINE emits one when a
    batch exhausts its restart budget (under a router that request may
    still be redispatched and complete elsewhere), and the ROUTER emits
    one with ``terminal=True`` when the redispatch budget is exhausted.
    ``terminal_only`` (the default) keeps only the router's terminal
    rows — the mirror of ``FlightRecorder.record_failed`` and the set
    disposition accounting needs."""
    rows = []
    for ev in doc.get("traceEvents", []):
        if ev.get("name") == "request.failed" and "args" in ev:
            args = dict(ev["args"])
            if terminal_only and not args.get("terminal"):
                continue
            rows.append(args)
    return rows


def disposition(completed_rows: Iterable[dict],
                shed_rows: Iterable[dict],
                failed_rows: Iterable[dict]) -> Dict[str, object]:
    """Account for every request's final disposition by ``request_id``.

    Precedence is ``completed > shed > failed``: a redispatched request
    may have left a non-terminal failure trail (or been shed from one
    replica's queue and re-admitted) before completing, and completion
    always wins.  Returns the per-outcome id sets plus counts; the
    zero-lost gate checks ``completed | shed | failed == admitted``."""
    completed = {int(r["request_id"]) for r in completed_rows}
    shed = {int(r["request_id"]) for r in shed_rows} - completed
    failed = ({int(r["request_id"]) for r in failed_rows}
              - completed - shed)
    return {
        "completed_ids": completed, "shed_ids": shed,
        "failed_ids": failed,
        "completed": len(completed), "shed": len(shed),
        "failed": len(failed),
        "accounted": len(completed) + len(shed) + len(failed),
    }


def _pct(vals: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals, dtype=np.float64), q))


# ----------------------------------------------------------- evaluator
def evaluate_slo(
    rows: Iterable[dict],
    spec: "SLOSpec | str | None" = None,
    num_devices: int = 1,
    recorder=None,
    shed_rows: Optional[Iterable[dict]] = None,
    failed_rows: Optional[Iterable[dict]] = None,
) -> dict:
    """Per-class SLO report from request-lifecycle rows.

    Violations and quantiles are recomputed here from the raw stamps
    (``submit_s`` / ``admit_s`` / ``done_s``), never read from the
    producer — the property that makes the offline (trace-replayed)
    report equal the live one.  Goodput counts only requests that met
    their class deadline, over the workload makespan
    (first submit -> last done); ``num_devices`` scales it to
    goodput-per-device.  When a ``recorder`` is passed, the canonical
    ``serve.goodput_rps`` gauges are published per class and in total.

    Router extensions (all additive — the report for a routerless
    serve is byte-identical to before): ``shed_rows`` /
    ``failed_rows`` (``FlightRecorder.shed_rows`` / ``failed_rows``
    live, :func:`shed_from_trace` / :func:`failures_from_trace`
    offline) add per-class ``shed`` / ``failed`` counts and a
    disposition block; lifecycle rows carrying a ``replica`` field add
    a per-replica section (count / violations / goodput on the shared
    makespan).
    """
    spec = SLOSpec.parse(spec)
    rows = list(rows)
    by_class: Dict[str, List[dict]] = {}
    for row in rows:
        by_class.setdefault(str(row.get("priority", "standard")),
                            []).append(row)

    report: dict = {
        "schema": SLO_REPORT_SCHEMA,
        "spec": spec.spec,
        "num_devices": int(num_devices),
        "requests": len(rows),
        "classes": {},
    }
    shed = None if shed_rows is None else list(shed_rows)
    failed = None if failed_rows is None else list(failed_rows)
    if shed is not None or failed is not None:
        disp = disposition(rows, shed or [], failed or [])
        report["disposition"] = {
            "completed": disp["completed"], "shed": disp["shed"],
            "failed": disp["failed"], "accounted": disp["accounted"],
        }
        if shed is not None:
            by_p: Dict[str, int] = {}
            for r in shed:
                p = str(r.get("priority", "standard"))
                by_p[p] = by_p.get(p, 0) + 1
            report["shed"] = {"total": len(shed),
                              "by_priority": dict(sorted(by_p.items()))}
        if failed is not None:
            by_p = {}
            for r in failed:
                p = str(r.get("priority", "standard"))
                by_p[p] = by_p.get(p, 0) + 1
            report["failed"] = {"total": len(failed),
                                "by_priority": dict(sorted(by_p.items()))}
    if not rows:
        report.update(makespan_s=0.0, goodput_rps=0.0,
                      goodput_per_device_rps=0.0, violations=0)
        return report

    t0 = min(float(r["submit_s"]) for r in rows)
    t1 = max(float(r["done_s"]) for r in rows)
    makespan = max(t1 - t0, 1e-12)
    total_good = 0
    total_violations = 0
    for priority in sorted(by_class):
        crows = by_class[priority]
        waits = [float(r["admit_s"]) - float(r["submit_s"]) for r in crows]
        e2es = [float(r["done_s"]) - float(r["submit_s"]) for r in crows]
        deadline = spec.deadline_for(priority)
        sclass = spec.get(priority)
        violations = sum(1 for e in e2es if e > deadline)
        good = len(crows) - violations
        total_good += good
        total_violations += violations
        violation_rate = violations / len(crows)
        entry = {
            "count": len(crows),
            "queue_wait_p50_s": _pct(waits, 50),
            "queue_wait_p99_s": _pct(waits, 99),
            "e2e_p50_s": _pct(e2es, 50),
            "e2e_p99_s": _pct(e2es, 99),
            "deadline_s": deadline if math.isfinite(deadline) else None,
            "target": sclass.target if sclass is not None else None,
            "violations": violations,
            "violation_rate": violation_rate,
            "goodput_rps": good / makespan,
            "goodput_per_device_rps": good / makespan / num_devices,
        }
        # burn rate: violation rate over the error budget (1 - target).
        # > 1.0 means the budget burns faster than the SLO allows; a
        # target of exactly 1.0 has no budget, so any violation is an
        # infinite burn (reported as null/None when clean).
        if sclass is None:
            entry["burn_rate"] = None
        elif sclass.target >= 1.0:
            entry["burn_rate"] = math.inf if violations else 0.0
        else:
            entry["burn_rate"] = violation_rate / (1.0 - sclass.target)
        report["classes"][priority] = entry

    report["makespan_s"] = makespan
    report["violations"] = total_violations
    report["goodput_rps"] = total_good / makespan
    report["goodput_per_device_rps"] = total_good / makespan / num_devices
    # per-replica section: only when rows carry a fleet identity (the
    # replica router stamps ``replica`` into every lifecycle row), so a
    # single-engine serve keeps the exact historical report schema
    if any(r.get("replica") is not None for r in rows):
        by_replica: Dict[str, List[dict]] = {}
        for row in rows:
            rid = row.get("replica")
            by_replica.setdefault(
                "unrouted" if rid is None else str(rid), []).append(row)
        replicas: Dict[str, dict] = {}
        for rid in sorted(by_replica):
            rrows = by_replica[rid]
            e2es = [float(r["done_s"]) - float(r["submit_s"])
                    for r in rrows]
            viol = sum(
                1 for r, e in zip(rrows, e2es)
                if e > spec.deadline_for(
                    str(r.get("priority", "standard"))))
            good = len(rrows) - viol
            replicas[rid] = {
                "count": len(rrows),
                "e2e_p50_s": _pct(e2es, 50),
                "e2e_p99_s": _pct(e2es, 99),
                "violations": viol,
                "goodput_rps": good / makespan,
            }
        report["replicas"] = replicas
    if recorder is not None:
        recorder.gauge(M.GOODPUT_RPS, report["goodput_rps"],
                       priority="_total")
        for priority, entry in report["classes"].items():
            recorder.gauge(M.GOODPUT_RPS, entry["goodput_rps"],
                           priority=priority)
    return report


def report_from_metrics_jsonl(text: str,
                              spec: "SLOSpec | str | None" = None) -> dict:
    """Aggregate-only report from a ``--metrics-out`` JSONL snapshot.

    The snapshot holds per-class histogram aggregates (not raw rows),
    so this rebuilds per-class p50/p99 and the live-counted
    ``serve.slo_violations`` — it cannot recompute violations or
    goodput from stamps.  Use the trace artifact
    (:func:`rows_from_trace` + :func:`evaluate_slo`) for the exact
    report; this one is for fleets that only ship metrics.
    """
    spec = SLOSpec.parse(spec)
    classes: Dict[str, dict] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        row = json.loads(line)
        priority = row.get("labels", {}).get("priority")
        if priority is None:
            continue
        entry = classes.setdefault(priority, {})
        if row["name"] == M.E2E_LATENCY_S:
            entry.update(count=row["count"], e2e_p50_s=row["p50"],
                         e2e_p99_s=row["p99"],
                         e2e_samples_dropped=row.get("dropped", 0))
        elif row["name"] == M.QUEUE_WAIT_S:
            entry.update(queue_wait_p50_s=row["p50"],
                         queue_wait_p99_s=row["p99"])
        elif row["name"] == M.SLO_VIOLATIONS:
            entry["violations"] = row["value"]
    for priority, entry in classes.items():
        deadline = spec.deadline_for(priority)
        entry["deadline_s"] = deadline if math.isfinite(deadline) else None
        entry.setdefault("violations", 0)
    return {
        "schema": SLO_REPORT_SCHEMA,
        "source": "metrics",
        "spec": spec.spec,
        "classes": classes,
    }


def format_report(report: dict) -> str:
    """Human-readable per-class table for CLI output."""
    lines = [f"SLO report ({report.get('requests', '?')} requests, "
             f"spec={report['spec']})"]
    for priority, e in sorted(report.get("classes", {}).items()):
        deadline = e.get("deadline_s")
        dl = f"{deadline:g}s" if deadline is not None else "-"
        burn = e.get("burn_rate")
        burn_s = ("inf" if burn == math.inf else
                  f"{burn:.2f}" if burn is not None else "-")
        lines.append(
            f"  {priority:<12} n={e.get('count', '?'):<4} "
            f"wait p50/p99={e.get('queue_wait_p50_s', float('nan')):.3f}/"
            f"{e.get('queue_wait_p99_s', float('nan')):.3f}s "
            f"e2e p50/p99={e.get('e2e_p50_s', float('nan')):.3f}/"
            f"{e.get('e2e_p99_s', float('nan')):.3f}s "
            f"deadline={dl} viol={e.get('violations', 0)} "
            f"burn={burn_s}"
            + (f" goodput={e['goodput_rps']:.3f}rps"
               if "goodput_rps" in e else ""))
    for rid, e in sorted(report.get("replicas", {}).items()):
        lines.append(
            f"  replica {rid:<4} n={e['count']:<4} "
            f"e2e p50/p99={e['e2e_p50_s']:.3f}/{e['e2e_p99_s']:.3f}s "
            f"viol={e['violations']} "
            f"goodput={e['goodput_rps']:.3f}rps")
    if "disposition" in report:
        d = report["disposition"]
        lines.append(
            f"  disposition: completed={d['completed']} "
            f"shed={d['shed']} failed={d['failed']} "
            f"(accounted={d['accounted']})")
    if "goodput_rps" in report:
        lines.append(
            f"  total: goodput={report['goodput_rps']:.3f}rps "
            f"({report['goodput_per_device_rps']:.3f}/device over "
            f"{report['num_devices']} devices), "
            f"makespan={report['makespan_s']:.2f}s, "
            f"violations={report['violations']}")
    return "\n".join(lines)
