"""One monotonic clock for every span, metric, and wall measurement.

Every host-side duration in the repo — engine ``batch_wall_s``, trace
span ``dur``, benchmark timers — must come from the same monotonic
source so they are mutually comparable and immune to NTP slews.
``time.time()`` is reserved for *stamps* (when did this snapshot get
written), never for durations.
"""
from __future__ import annotations

import time

__all__ = ["perf_s", "perf_us", "wall_stamp_s"]


def perf_s() -> float:
    """Monotonic seconds — the clock for all durations."""
    return time.perf_counter()


def perf_us() -> float:
    """Monotonic microseconds — Chrome-trace ``ts``/``dur`` units."""
    return time.perf_counter() * 1e6


def wall_stamp_s() -> float:
    """Wall-clock epoch seconds — for snapshot timestamps ONLY.

    Never subtract two of these; use :func:`perf_s` for durations.
    """
    return time.time()
