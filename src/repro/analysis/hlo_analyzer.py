"""Trip-count-aware analyzer for compiled (SPMD-partitioned) HLO text.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers programs.  This module parses the HLO module text into
computations, reads each while loop's trip count from its
``backend_config={"known_trip_count":{"n":...}}`` annotation (falling back
to the condition's compare-against-constant), and accumulates:

  * FLOPs: dot / convolution ops (inside fused computations too, since
    fusion doesn't change arithmetic), x trip counts.
  * HBM bytes: per-op operand+output sizes at *fusion boundaries* only
    (fused internals stay in registers/VMEM), x trips.
  * collective bytes: by kind, x trips.

Operands in HLO text are name references; shapes are resolved through the
per-computation SSA map (operands are always defined in the same
computation).  Validated against ``cost_analysis()`` on fully-unrolled
programs in tests/test_hlo_analyzer.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# Shape alternative one: tuple types — may contain `/*index=N*/` comments
# (note the `=`) but never parentheses, so `[^()]*` is the safe pattern.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(([^)]*)"
)
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+(\d+)')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# replica_groups={{0,2},{1,3}} (explicit) or =[2,4]<=[8]... (iota form:
# shape is [num_groups, group_size])
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _replica_group_size(line: str) -> Optional[int]:
    """Participant count of a collective's replica groups, if stated.

    On a 2D ``(lp, tp)`` mesh this is what tells the two link tiers
    apart: lp-axis collectives run in groups of size M, tp-axis ones in
    groups of size T (``collective-permute`` carries pairs, not groups —
    it returns None and every LP ppermute is inter-group by
    construction).
    """
    m = _RG_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x])
    m = _RG_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return None


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (elems, bytes) over every typed array in a shape string."""
    elems = tot = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


@dataclasses.dataclass
class OpRecord:
    name: str
    kind: str
    out_shape: str
    args: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpRecord]
    shapes: Dict[str, str]  # ssa name -> output shape string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if line and not line[0].isspace() and "{" in line:
            head = line.split("{")[0]
            if "(" in head and ("%" in head.split("(")[0] or head.startswith("ENTRY")):
                name = head.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
                cur = Computation(name=name, ops=[], shapes={})
                comps[name] = cur
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            rec = OpRecord(m.group(1), m.group(3), m.group(2), m.group(4), stripped)
            cur.ops.append(rec)
            cur.shapes[rec.name] = rec.out_shape
    return comps


def _operand_names(args: str) -> List[str]:
    return re.findall(r"%([\w.\-]+)", args)


def _trip_count(op: OpRecord, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return max(int(m.group(1)), 1)
    # fallback: condition compares induction var against a constant
    mc = re.search(r"condition=%?([\w.\-]+)", op.line)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        consts = {}
        for o in cond.ops:
            if o.kind == "constant":
                mm = re.search(r"constant\((-?\d+)\)", o.line)
                if mm:
                    consts[o.name] = int(mm.group(1))
        best = 0
        for o in cond.ops:
            if o.kind in ("compare", "fusion"):
                for nm in _operand_names(o.args):
                    if nm in consts:
                        best = max(best, consts[nm])
        if best:
            return best
    return 1


def _dot_flops(op: OpRecord, comp: Computation) -> int:
    out_elems, _ = _shape_elems_bytes(op.out_shape)
    m = re.search(r"rhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m:
        return 0
    cdims = _dims(m.group(1))
    names = _operand_names(op.args)
    if len(names) < 2:
        return 0
    rhs_shape = comp.shapes.get(names[1], "")
    sm = _SHAPE_RE.search(rhs_shape)
    if not sm:
        return 0
    rhs_dims = _dims(sm.group(2))
    k = 1
    for c in cdims:
        if c < len(rhs_dims):
            k *= rhs_dims[c]
    return 2 * out_elems * k


def _conv_flops(op: OpRecord, comp: Computation) -> int:
    out_elems, _ = _shape_elems_bytes(op.out_shape)
    names = _operand_names(op.args)
    if len(names) < 2:
        return 0
    sm = _SHAPE_RE.search(comp.shapes.get(names[1], ""))
    if not sm:
        return 0
    kernel = _dims(sm.group(2))
    k = 1
    for d in kernel[:-1]:
        k *= d
    return 2 * out_elems * k


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    # collective bytes keyed "kind[group_size]" (replica-group size, e.g.
    # "all-gather[4]") or bare "kind" when the op states no groups
    # (collective-permute) — the 2D-mesh inter/intra split
    collective_group_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def add(self, other: "Analysis", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult
        for k, v in other.collective_group_bytes.items():
            self.collective_group_bytes[k] = \
                self.collective_group_bytes.get(k, 0) + v * mult


def breakdown(hlo: str, top: int = 15):
    """Top HBM-traffic contributors (op kind + shape, trip-multiplied).
    The §Perf diagnosis tool."""
    import collections

    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    counter: Dict[Tuple[str, str], float] = collections.Counter()

    def walk(name: str, mult: float, depth=0) -> None:
        comp = comps.get(name)
        if comp is None or depth > 12:
            return
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                trips = _trip_count(op, comps)
                if mb:
                    walk(mb.group(1), mult * trips, depth + 1)
                continue
            if kind in _NO_HBM:
                continue
            _, out_b = _shape_elems_bytes(op.out_shape)
            if kind == "fusion":
                mcalls = re.search(r"calls=%?([\w.\-]+)", op.line)
                sub = comps.get(mcalls.group(1)) if mcalls else None
                if sub is not None and sub.ops and \
                        sub.ops[-1].kind == "dynamic-update-slice":
                    names = _operand_names(sub.ops[-1].args)
                    _, b = _shape_elems_bytes(
                        sub.shapes.get(names[1], "") if len(names) > 1 else "")
                    counter[("fusion(dus)", op.out_shape[:70])] += 2 * b * mult
                    continue
            if kind == "dynamic-update-slice":
                names = _operand_names(op.args)
                _, b = _shape_elems_bytes(
                    comp.shapes.get(names[1], "") if len(names) > 1 else "")
                nbytes = 2 * b
            elif kind == "scatter":
                names = _operand_names(op.args)
                _, b = _shape_elems_bytes(
                    comp.shapes.get(names[2], "") if len(names) > 2 else "")
                nbytes = 2 * b
            elif kind in ("dynamic-slice", "gather"):
                nbytes = 2 * out_b
            elif kind == "fusion":
                nbytes = out_b + _fusion_operand_bytes(op, comp, comps)
            else:
                in_b = 0
                for nm in _operand_names(op.args):
                    shp = comp.shapes.get(nm)
                    if shp:
                        _, bb = _shape_elems_bytes(shp)
                        in_b += bb
                nbytes = out_b + in_b
            counter[(kind, op.out_shape[:70])] += nbytes * mult

    walk(entry, 1.0)
    return counter.most_common(top)


def _fusion_operand_bytes(fusion_op: OpRecord, comp: Computation,
                          comps: Dict[str, Computation]) -> int:
    """Input traffic of a fusion op.

    Operands that the fused computation consumes ONLY through
    dynamic-slice ops are read at *slice* size, not buffer size — the
    pattern of a scan body reading one step's slice of its stacked xs
    (counting the full loop-invariant buffer per iteration overcounted
    xlstm's sLSTM scan by ~4 orders of magnitude)."""
    mcalls = re.search(r"calls=%?([\w.\-]+)", fusion_op.line)
    sub = comps.get(mcalls.group(1)) if mcalls else None
    operand_names = _operand_names(fusion_op.args)
    if sub is None:
        total = 0
        for nm in operand_names:
            shp = comp.shapes.get(nm)
            if shp:
                _, b = _shape_elems_bytes(shp)
                total += b
        return total
    # param index -> how it is consumed inside the fused computation
    params = [op for op in sub.ops if op.kind == "parameter"]
    slice_only: Dict[str, int] = {}   # param name -> slice bytes
    used_other = set()
    for op in sub.ops:
        if op.kind == "parameter":
            continue
        names = set(_operand_names(op.args))
        for p in params:
            if p.name in names:
                if op.kind == "dynamic-slice":
                    _, b = _shape_elems_bytes(op.out_shape)
                    slice_only[p.name] = slice_only.get(p.name, 0) + b
                else:
                    used_other.add(p.name)
    total = 0
    for i, nm in enumerate(operand_names):
        shp = comp.shapes.get(nm)
        if not shp:
            continue
        _, full = _shape_elems_bytes(shp)
        if i < len(params):
            pname = params[i].name
            if pname in slice_only and pname not in used_other:
                total += min(slice_only[pname], full)
                continue
        total += full
    return total


_CONTROL = ("while", "conditional")
_NO_HBM = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
           "while", "conditional", "after-all", "add-dependency")
_CALLERS = ("fusion", "call", "custom-call", "reduce", "map", "scatter",
            "sort", "reduce-window", "select-and-scatter", "all-reduce",
            "reduce-scatter")


def analyze(hlo: str) -> Analysis:
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = next((n for n in comps if "main" in n), next(iter(comps)))

    flops_memo: Dict[str, Analysis] = {}

    def called_flops(name: str) -> Analysis:
        """Arithmetic (+collectives) of a called computation, recursively."""
        if name in flops_memo:
            return flops_memo[name]
        flops_memo[name] = Analysis()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return flops_memo[name]
        a = Analysis()
        for op in comp.ops:
            _accumulate_op(a, op, comp, boundary=False)
        flops_memo[name] = a
        return a

    def _accumulate_op(a: Analysis, op: OpRecord, comp: Computation,
                       boundary: bool) -> None:
        kind = op.kind
        if kind == "dot":
            a.flops += _dot_flops(op, comp)
        elif kind == "convolution":
            a.flops += _conv_flops(op, comp)
        base = kind.replace("-start", "")
        if base in COLLECTIVES and not kind.endswith("-done"):
            _, nbytes = _shape_elems_bytes(op.out_shape)
            a.collective_bytes[base] = a.collective_bytes.get(base, 0) + nbytes
            a.collective_counts[base] = a.collective_counts.get(base, 0) + 1
            gs = _replica_group_size(op.line)
            gkey = base if gs is None else f"{base}[{gs}]"
            a.collective_group_bytes[gkey] = \
                a.collective_group_bytes.get(gkey, 0) + nbytes
        if kind == "while":
            mb = re.search(r"body=%?([\w.\-]+)", op.line)
            trips = _trip_count(op, comps)
            if mb and mb.group(1) in comps:
                a.add(walk(mb.group(1)), mult=trips)
            return
        if kind == "conditional":
            mbr = re.search(r"branch_computations=\{([^}]*)\}", op.line)
            if mbr:
                branches = [b.strip().lstrip("%") for b in mbr.group(1).split(",")]
                for br in branches:
                    if br in comps:
                        a.add(walk(br), mult=1.0 / max(len(branches), 1))
            return
        if kind in _CALLERS:
            mcalls = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.line)
            if mcalls and mcalls.group(1) in comps:
                a.add(called_flops(mcalls.group(1)))
        if boundary and kind not in _NO_HBM:
            _, out_b = _shape_elems_bytes(op.out_shape)
            # windowed / in-place ops move only the slice, not the buffer:
            # XLA updates dynamic-update-slice/scatter destinations in
            # place (aliasing), and dynamic-slice/gather read only the
            # window.  Counting full operands would overcount scan-output
            # stacking by the trip count.  A fusion whose ROOT is a DUS
            # (scan stacking fused with the producer) gets the same
            # treatment: traffic = produced slice, not the full buffer.
            if kind == "fusion":
                mcalls = re.search(r"calls=%?([\w.\-]+)", op.line)
                sub = comps.get(mcalls.group(1)) if mcalls else None
                if sub is not None and sub.ops and \
                        sub.ops[-1].kind == "dynamic-update-slice":
                    root = sub.ops[-1]
                    names = _operand_names(root.args)
                    upd = sub.shapes.get(names[1], "") if len(names) > 1 else ""
                    _, upd_b = _shape_elems_bytes(upd)
                    # read producer inputs (~slice-sized) + write the slice;
                    # the big destination buffer is aliased in place
                    a.hbm_bytes += 2 * upd_b
                    return
            if kind == "dynamic-update-slice":
                names = _operand_names(op.args)
                upd = comp.shapes.get(names[1], "") if len(names) > 1 else ""
                _, upd_b = _shape_elems_bytes(upd)
                a.hbm_bytes += 2 * upd_b
                return
            if kind == "scatter":
                names = _operand_names(op.args)
                upd = comp.shapes.get(names[2], "") if len(names) > 2 else ""
                _, upd_b = _shape_elems_bytes(upd)
                a.hbm_bytes += 2 * upd_b
                return
            if kind in ("dynamic-slice", "gather"):
                a.hbm_bytes += 2 * out_b
                return
            if kind == "fusion":
                a.hbm_bytes += out_b + _fusion_operand_bytes(op, comp, comps)
                return
            in_b = 0
            for nm in _operand_names(op.args):
                shp = comp.shapes.get(nm)
                if shp:
                    _, b = _shape_elems_bytes(shp)
                    in_b += b
            a.hbm_bytes += out_b + in_b

    walk_memo: Dict[str, Analysis] = {}

    def walk(name: str) -> Analysis:
        """Boundary-level walk (HBM accounting on) of a computation."""
        if name in walk_memo:
            return walk_memo[name]
        walk_memo[name] = Analysis()
        comp = comps.get(name)
        if comp is None:
            return walk_memo[name]
        a = Analysis()
        for op in comp.ops:
            _accumulate_op(a, op, comp, boundary=True)
        walk_memo[name] = a
        return a

    return walk(entry)
