"""HLO text parsing: collective operand/output byte accounting.

``cost_analysis()`` does not expose collective traffic, so §Roofline's
collective term comes from summing the shapes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
compiled (SPMD-partitioned) HLO.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  %all-reduce.5 = f32[4,26,6]{...} all-reduce(...)
#       ar.1 = (f32[128]{0}, f32[256]{0}) all-reduce-start(...)
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (output-shape accounting, skipping
    the -done halves of async pairs so nothing double-counts)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}(?:-start)?\(", hlo_text))
