"""Roofline analysis from compiled dry-run records (deliverable g).

Per (arch x shape x mesh) the dry-run JSON carries per-device, trip-count-
aware numbers (see analysis/hlo_analyzer.py):

  compute term    = MXU_FLOPs_per_device / peak_FLOPs
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = sum_ops wire_bytes_per_device(op) / ICI_bw

Wire amplification per collective kind on a ring/torus: all-reduce moves
2 (K-1)/K of its payload through each device (~2x), all-gather /
reduce-scatter / all-to-all ~(K-1)/K (~1x), collective-permute 1x.

MODEL_FLOPS (useful work) per device:
  train:    6 * N_active * tokens / chips      (fwd 2ND + bwd 4ND)
  prefill:  2 * N_active * tokens / chips
  decode:   2 * N_active * batch / chips       (one token per sequence)
  vdm:      2 * N * window_tokens * B * cfg_passes / chips  (one LP step)

The MODEL/HLO ratio exposes remat and redundant compute (e.g. remat'd
training reads ~8ND of HLO flops for 6ND of useful math).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_seconds(collectives: Dict[str, float]) -> float:
    wire = sum(_WIRE_FACTOR.get(k, 1.0) * v for k, v in collectives.items())
    return wire / ICI_BW


def model_flops_per_device(arch: str, shape_name: str, n_active: int,
                           chips: int) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len / chips
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len / chips
    if shape.kind == "decode":
        return 2.0 * n_active * shape.global_batch / chips
    if shape.kind == "vdm_generate":
        t_lat = (shape.num_frames - 1) // 4 + 1
        h_lat, w_lat = shape.height // 8, shape.width // 8
        pt, ph, pw = cfg.patch_sizes
        # useful work per LP step = the full latent denoised once per CFG
        # pass, spread over every chip (LP x TP): 2*N*tokens*2 / chips.
        # HLO flops above this reflect overlap windows (gamma), attention
        # quadratic terms, and any partitioner redundancy.
        tokens = (t_lat // pt) * (h_lat // ph) * (w_lat // pw)
        return 2.0 * n_active * tokens * shape.global_batch * 2 / chips
    raise ValueError(shape.kind)


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    roofline_fraction: float   # compute_s / max(term) — how close to ideal
    action: str

    def as_dict(self):
        return dataclasses.asdict(self)


_ACTIONS = {
    "compute": "reduce redundant compute (remat policy, fused attention, "
               "CFG batching) or raise arithmetic intensity per chip",
    "memory": "raise arithmetic intensity: fuse elementwise chains, larger "
              "matmul tiles, bf16 buffers, flash attention (no S^2 traffic)",
    "collective": "reshard to cut collective volume (different TP/FSDP "
                  "split, reduce-scatter instead of all-reduce, overlap "
                  "collectives with compute)",
}


def roofline_row(rec: Dict[str, Any], chips: Optional[int] = None) -> Optional[RooflineRow]:
    if rec.get("skipped") or "error" in rec or "flops" not in rec:
        return None
    chips = chips or (512 if rec["mesh"] == "2x16x16" else 256)
    comp = rec["flops"] / PEAK_FLOPS
    mem = rec.get("hbm_bytes", 0.0) / HBM_BW
    coll = collective_seconds(rec.get("collectives", {}))
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(
        rec["arch"], rec["shape"], rec.get("n_active_params", 0), chips
    )
    useful = mf / rec["flops"] if rec["flops"] else 0.0
    bound = max(terms.values())
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=comp, memory_s=mem, collective_s=coll,
        dominant=dominant,
        model_flops=mf, hlo_flops=rec["flops"], useful_ratio=useful,
        roofline_fraction=min(frac, 1.0),
        action=_ACTIONS[dominant],
    )


def build_table(records: List[Dict[str, Any]]) -> List[RooflineRow]:
    rows = []
    for rec in records:
        row = roofline_row(rec)
        if row is not None:
            rows.append(row)
    return rows


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.1%} |\n"
        )
    return "".join(out)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("records", help="dry-run JSON")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    with open(args.records) as f:
        records = json.load(f)
    rows = build_table(records)
    md = markdown_table(rows)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.as_dict() for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
