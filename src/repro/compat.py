"""JAX version compatibility shims.

The codebase is written against the modern JAX surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(axis_types=...)``, dict-returning
``Compiled.cost_analysis``).  Older jaxlibs (0.4.x) spell these differently;
everything version-sensitive is funneled through here so call sites stay on
the new names.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if AxisType is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(AxisType.Auto,) * len(tuple(axes)),
            )
        except TypeError:
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """Context manager activating ``mesh`` for bare-PartitionSpec lowering.

    ``jax.set_mesh`` on new JAX; on 0.4.x the Mesh object itself is the
    context manager that installs the resource environment.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (0.4.x).

    ``check_vma`` maps onto the old ``check_rep`` flag — both toggle the
    replication/varying-manual-axes checker.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def cost_analysis(compiled) -> Dict[str, Any]:
    """Normalize ``Compiled.cost_analysis()`` to a flat dict.

    Old jaxlibs return a one-element list of per-device dicts; new ones
    return the dict directly (or None when the backend has no analysis).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
