"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — jax locks the device count on first init, and
the dry-run needs to set XLA_FLAGS before that happens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 ("data", "model") or 2-pod 2x16x16 ("pod", "data",
    "model").  256 chips per pod (TPU v5e-256 topology)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with Auto axis types (tests, small runs)."""
    return compat.make_mesh(shape, axes)


def local_mesh(model: int = 1, data: Optional[int] = None):
    """Mesh over whatever devices exist (CPU tests: usually 1)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return make_mesh((data, model), ("data", "model"))
