"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — jax locks the device count on first init, and
the dry-run needs to set XLA_FLAGS before that happens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 ("data", "model") or 2-pod 2x16x16 ("pod", "data",
    "model").  256 chips per pod (TPU v5e-256 topology)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with Auto axis types (tests, small runs)."""
    return compat.make_mesh(shape, axes)


def local_mesh(model: int = 1, data: Optional[int] = None):
    """Mesh over whatever devices exist (CPU tests: usually 1)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str) -> Tuple[int, int]:
    """Parse a ``--mesh MxT`` CLI argument into ``(lp_groups, tp)``.

    ``M`` is the LP group-axis size (== K partitions of the inter-group
    plan), ``T`` the intra-group tensor-parallel degree; ``"4x2"`` ->
    ``(4, 2)``.  A bare ``"4"`` means no tp axis, ``(4, 1)``.
    """
    parts = spec.lower().replace("×", "x").split("x")
    if not 1 <= len(parts) <= 2:
        raise ValueError(f"--mesh wants MxT (e.g. 4x2), got {spec!r}")
    try:
        m = int(parts[0])
        t = int(parts[1]) if len(parts) == 2 else 1
    except ValueError as e:
        raise ValueError(f"--mesh wants MxT (e.g. 4x2), got {spec!r}") from e
    if m < 2 or t < 1:
        raise ValueError(f"--mesh needs M>=2 LP groups and T>=1, got {spec!r}")
    return m, t


def shrink_hybrid_mesh(mesh, evicted_group: int, tp: Optional[int] = None):
    """Rebuild an ``(M-1, T)`` hybrid mesh from the survivors of ``mesh``
    after LP group ``evicted_group`` died (its row of devices leaves the
    ring; every other group keeps its devices and tp layout, re-indexed).

    This is the mesh half of mid-request eviction on mesh-bound engines:
    the serving engine pairs it with a re-bound forward hook
    (``LPServingEngine._build_forward``) handed to
    ``runtime.elastic.replan_lp_compiler`` — see docs/fault_tolerance.md.
    ``tp``, when given, asserts the mesh's tp-axis size (a mismatch means
    the caller's bookkeeping has diverged from the mesh it is shrinking).
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = np.asarray(mesh.devices)
    if devs.ndim == 1:                      # 1D lp-only mesh -> (M, 1)
        devs = devs.reshape(-1, 1)
    if devs.ndim != 2:
        raise ValueError(
            f"shrink_hybrid_mesh wants an (M, T) hybrid mesh, got device "
            f"array of shape {devs.shape}"
        )
    m, t = devs.shape
    if tp is not None and t != tp:
        raise ValueError(f"mesh tp axis has size {t}, caller expected {tp}")
    if not 0 <= evicted_group < m:
        raise ValueError(f"evicted group {evicted_group} not in [0, {m})")
    if m <= 2:
        raise ValueError(
            f"cannot shrink a {m}-group LP ring below 2 groups "
            "(LP needs >= 2 partitions)"
        )
    survivors = np.delete(devs, evicted_group, axis=0)
    if len(mesh.axis_names) == 1:
        return Mesh(survivors.reshape(-1), mesh.axis_names)
    return Mesh(survivors, mesh.axis_names)


def make_hybrid_mesh(lp: int, tp: int = 1):
    """``(lp, tp)`` mesh named ("data", "model") over the first lp*tp
    devices — the hybrid LP x TP engine's layout.  Built directly from a
    reshaped device array so a mesh smaller than the host's device count
    works on every jax version (tests place K=3 rings on 8 fake CPUs).
    """
    import numpy as np
    from jax.sharding import Mesh

    n = lp * tp
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"mesh {lp}x{tp} needs {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(lp, tp), ("data", "model"))
