"""Training driver CLI.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 100 --batch 8 --seq 128 [--reduced] [--ckpt-dir DIR]

Uses the full substrate: sharded synthetic data, AdamW/adafactor,
fault-tolerant restart loop, async checkpoints.  On the CPU container the
reduced configs are the practical choice; full configs are exercised via
``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.data.pipeline import SyntheticLMStream
from repro.runtime.ft import run_training
from repro.train.loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = models.build(cfg)
    parallel = ParallelConfig(dp_axes=(), fsdp_axis=None)
    raw = make_train_step(model, parallel, peak_lr=args.lr,
                          total_steps=args.steps)
    step_fn = jax.jit(raw)
    data = SyntheticLMStream(cfg, batch=args.batch, seq_len=args.seq)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")

    def init_state():
        p = model.init(jax.random.PRNGKey(0))
        return p, raw.opt_init(p)

    report = run_training(step_fn, init_state, data.batch_at, args.steps,
                          ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"finished {report.final_step} steps; "
          f"loss {report.losses[0]:.4f} -> "
          f"{report.losses[max(report.losses)]:.4f}; ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
