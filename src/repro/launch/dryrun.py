import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The dry-run compiles against fake CPU devices by construction; pinning
# the platform (unless the caller overrides) skips jax's TPU runtime
# probe, which hangs for minutes on hosts with libtpu but no TPU.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract memory/cost/collective evidence.

MUST be the first import in the process (jax locks the device count on
first init) — hence the XLA_FLAGS assignment above everything else.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all

Per cell this produces:
  * compiled.memory_analysis()  -> bytes/device (proves it fits)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the compiled HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)
"""
import argparse
import dataclasses
import json
import sys
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import models
from repro.configs import get_config, get_shape, skip_reason, cells
from repro.configs.base import ArchConfig, ParallelConfig, ShapeConfig
from repro.distributed.policy import (
    active_params,
    cache_head_or_dim,
    count_params,
    plan_parallel,
)
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.obs.clock import perf_s
from repro.serving.serve_step import make_decode_step, make_prefill_step
from repro.train.loop import make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        out = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        if cfg.family == "vlm":
            out["vision_embeds"] = _sds((B, cfg.num_vision_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            out["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["vision_embeds"] = _sds((B, cfg.num_vision_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            out["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
        return out
    if shape.kind == "decode":
        out = {
            "token": _sds((B, 1), jnp.int32),
            "position": _sds((B,), jnp.int32),
        }
        if cfg.family == "audio":
            out["enc_states"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
        return out
    if shape.kind == "vdm_generate":
        t_lat = (shape.num_frames - 1) // 4 + 1
        h_lat, w_lat = shape.height // 8, shape.width // 8
        return {
            "latent": _sds((B, t_lat, h_lat, w_lat, cfg.latent_channels), dt),
            "t": _sds((B,), jnp.float32),
            "context": _sds((2 * B, cfg.context_len, cfg.context_dim), jnp.float32),
        }
    raise ValueError(shape.kind)


def _collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in compiled HLO."""
    from repro.analysis.hlo import collective_bytes

    return collective_bytes(hlo_text)


def _mem_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def _vdm_lp_step(cfg: ArchConfig, shape: ShapeConfig, mesh, parallel,
                 lp_impl: str = "gspmd", wire_codec: Optional[str] = None,
                 wire_shard: Optional[bool] = None,
                 eager_sends: Optional[bool] = None,
                 inject_fault: Optional[str] = None,
                 nan_guard: bool = False):
    """Build the jitted LP denoising step (one forward pass, dim=height)."""
    from repro.core import plan_uniform
    from repro.core.hybrid import lp_forward_halo_hybrid
    from repro.core.spmd import (
        lp_forward_gspmd,
        lp_forward_halo,
        lp_forward_shard_map,
        select_lp_impl,
    )
    from repro.diffusion.cfg import cfg_combine
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.models import dit

    K = mesh.shape["data"]
    tp = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") \
        else dict(mesh.shape).get("model", 1)
    if lp_impl == "auto":
        # comm-model break-even rule; a wire codec implies the halo
        # family (that's where the codec layer lives)
        if wire_codec not in (None, "fp32"):
            lp_impl = "halo_hybrid" if tp > 1 else "halo"
        else:
            lp_impl = select_lp_impl(K, tp)
    if wire_codec not in (None, "fp32") and lp_impl == "shard_map":
        raise ValueError(
            f"--wire-codec {wire_codec} needs the halo family (or gspmd's "
            f"value-faithful blend); got --lp-impl {lp_impl} (the measured "
            "HLO would be uncoded)"
        )
    if (wire_codec and str(wire_codec).startswith("displaced")
            and lp_impl not in ("halo", "halo_hybrid")):
        raise ValueError(
            f"--wire-codec {wire_codec} is a displaced halo codec, which "
            "needs carry-resident slab state — only the halo family keeps "
            f"one (psum/gspmd have no per-direction slab carry); got "
            f"--lp-impl {lp_impl}"
        )
    # hierarchy-aware wire defaults: eager sends + tp-sharded wire on
    # for hybrid meshes (the tp axis is what gets sharded over)
    if eager_sends is None:
        eager_sends = tp > 1
    if wire_shard is None:
        wire_shard = tp > 1 and lp_impl in ("halo", "halo_hybrid")
    if wire_shard and tp <= 1:
        raise ValueError(
            "--wire-shard shards the halo wire over the tp axis; this "
            "mesh has no tp ('model') axis of size >= 2"
        )
    if wire_shard and lp_impl not in ("halo", "halo_hybrid"):
        raise ValueError(
            f"--wire-shard needs the halo family (the sharded wire lives "
            f"there), got --lp-impl {lp_impl}"
        )
    # --inject-fault: dead/slow are runtime drills (no effect on a
    # single-step lowering); corrupt@S swaps the wire codec for its
    # NaN-poisoning wrapper so the guarded decode HLO can be inspected.
    corrupt_wire = False
    if inject_fault:
        from repro.runtime.faults import parse_fault_plan

        fplan = parse_fault_plan(inject_fault)
        if fplan.corrupt:
            if lp_impl not in ("halo", "halo_hybrid") or \
                    wire_codec in (None, "fp32"):
                raise ValueError(
                    "--inject-fault corrupt@S poisons the compressed halo "
                    "wire; it needs a halo-family --lp-impl with a "
                    "--wire-codec"
                )
            corrupt_wire = True
            # a poisoned wire is only survivable with the decode guard
            nan_guard = True
    h_lat = shape.height // 8
    plan = plan_uniform(h_lat, cfg.patch_sizes[1], K, parallel.overlap_ratio, dim=1)
    sampler = FlowMatchEuler(shape.num_steps)
    guidance = 5.0
    model = models.build(cfg)

    def step(params, batch):
        z, t, ctx = batch["latent"], batch["t"], batch["context"]
        b = z.shape[0]

        kv_chunk = int(os.environ.get("REPRO_DIT_KV_CHUNK", "4096"))
        # CFG-pair-on-pod is a GSPMD-only constraint: inside the explicit
        # shard_map/halo engines every mesh axis is manual, so bare-P
        # constraints cannot apply there.
        cfg_on_pod = "pod" in mesh.axis_names and lp_impl == "gspmd"

        def denoise(window):
            z2 = jnp.concatenate([window, window], axis=0)
            t2 = jnp.concatenate([t, t], axis=0)
            if cfg_on_pod:
                # DESIGN.md §2: the CFG pair (cond, uncond) maps onto the
                # pod axis — each pod computes one branch; only the
                # latent-sized combine crosses the slow inter-pod links
                z2 = jax.lax.with_sharding_constraint(
                    z2, P("pod", *([None] * (z2.ndim - 1))))
            pred = dit.forward(params, z2, t2, ctx, cfg, kv_chunk=kv_chunk)
            if cfg_on_pod:
                pred = jax.lax.with_sharding_constraint(
                    pred, P("pod", *([None] * (pred.ndim - 1))))
            return cfg_combine(pred[:b], pred[b:], guidance)

        def denoise_tp_cfg(window):
            # hybrid Phi_m at T=2: the two tp ranks take one CFG branch
            # each — half the DiT batch per device, pair reunited by one
            # intra-group all-gather (core/hybrid.tp_cfg_combine).  The
            # split is 2-way only, so larger T falls back to the batched
            # CFG denoiser (see the dispatch below).
            from repro.core.hybrid import tp_cfg_branch, tp_cfg_combine

            br = tp_cfg_branch("model")
            my_ctx = jax.lax.dynamic_slice_in_dim(
                ctx, br * ctx.shape[0] // 2, ctx.shape[0] // 2, 0
            )
            pred = dit.forward(params, window, t, my_ctx, cfg,
                               kv_chunk=kv_chunk)
            return tp_cfg_combine(pred, "model", guidance)

        if lp_impl == "shard_map":
            pred = lp_forward_shard_map(denoise, z, plan, 2, mesh, "data")
        elif lp_impl in ("halo", "halo_hybrid"):
            hybrid = lp_impl == "halo_hybrid"
            den = denoise_tp_cfg if (hybrid and tp == 2) else denoise
            if hybrid:
                def fwd(fn, zz, pl, ax, st=None, **kw):
                    return lp_forward_halo_hybrid(
                        fn, zz, pl, ax, mesh, "data", "model",
                        codec_state=st, eager_sends=eager_sends,
                        wire_shard=wire_shard, nan_guard=nan_guard, **kw)
            else:
                def fwd(fn, zz, pl, ax, st=None, **kw):
                    return lp_forward_halo(
                        fn, zz, pl, ax, mesh, "data",
                        codec_state=st, eager_sends=eager_sends,
                        shard_axis="model" if (wire_shard and tp > 1)
                        else None, nan_guard=nan_guard, **kw)
            if wire_codec in (None, "fp32"):
                pred = fwd(den, z, plan, 2)
            else:
                from repro.comm import get_codec, init_halo_wire_state
                from repro.distributed.collectives import halo_spec

                codec = get_codec(wire_codec)
                if corrupt_wire:
                    from repro.runtime.faults import CorruptingCodec

                    codec = CorruptingCodec.wrap(codec)
                if codec.stateful:
                    # single-step lowering: a zero carry inside the step
                    # (collective shapes are state-independent, which is
                    # what the dry run measures)
                    st = init_halo_wire_state(
                        codec, halo_spec(plan),
                        tuple(s for i, s in enumerate(z.shape) if i != 2),
                    )
                    pred, _ = fwd(den, z, plan, 2, st=st, codec=codec)
                else:
                    pred = fwd(den, z, plan, 2, codec=codec)
        else:
            pred = lp_forward_gspmd(denoise, z, plan, 2, mesh, "data",
                                    codec=wire_codec)
        return sampler.step(z, pred, 1)

    return step


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    lp_impl: str = "gspmd",
    mesh=None,
    wire_codec: Optional[str] = None,
    wire_shard: Optional[bool] = None,
    eager_sends: Optional[bool] = None,
    inject_fault: Optional[str] = None,
    wire_nan_guard: bool = False,
    recorder=None,
) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run record.

    ``recorder`` (``repro.obs.FlightRecorder``, optional) gets
    ``dryrun``-category spans around lower+compile plus the cell's
    ``wire_tiers`` bytes as ``wire.bytes`` counters — the same schema
    the serving engine's derived attribution uses, so measured HLO and
    ``comm_model`` replay are machine-diffable.
    """
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    reason = skip_reason(arch, shape_name)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "skipped": reason,
    }
    if reason:
        if recorder is not None:
            recorder.instant("dryrun.skip", cat="dryrun", arch=arch,
                             shape=shape_name, reason=reason)
        return rec

    t0 = perf_s()
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    # a caller-supplied --mesh overrides the production tag
    rec["mesh"] = "x".join(str(v) for v in dict(mesh.shape).values())
    model = models.build(cfg)
    n_params = count_params(cfg, model)
    parallel = plan_parallel(cfg, shape, multi_pod=multi_pod, n_params=n_params)
    rec["n_params"] = n_params
    rec["n_active_params"] = active_params(cfg, n_params)
    rec["parallel"] = {
        "fsdp": parallel.fsdp_axis, "remat": parallel.remat,
        "microbatch": parallel.microbatch, "optimizer": parallel.optimizer,
    }

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shapes, parallel)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    params_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_shapes, psh,
    )
    ispecs = input_specs(cfg, shape)

    from repro.distributed import actctx

    dp_for_ctx = tuple(a for a in parallel.dp_axes if a in mesh.axis_names)
    if shape.kind == "vdm_generate":
        # LP parallelizes over windows (the stacked vmap axis), not batch;
        # batch-dim constraints inside the DiT would pin the CFG pair (2)
        # to the 16-way data axis and break the shard_map manual region
        dp_for_ctx = ()
    # sequence-parallel attention when head counts don't divide TP
    tp_size = mesh.shape[parallel.tp_axis]
    attn_seq = None
    # trigger on *query* heads only: kv-head replication is handled
    # acceptably by GSPMD, but non-divisible q heads partial-shard the
    # score contraction (llama3 train regressed 616->3555s collective
    # when kv=8 triggered seq-par; q=128 divides fine — §Perf B note)
    if shape.kind in ("train", "prefill") and cfg.num_heads and             cfg.num_heads % tp_size != 0:
        attn_seq = parallel.tp_axis
    if shape.kind == "vdm_generate" and lp_impl == "gspmd" and             cfg.num_heads % tp_size:
        attn_seq = parallel.tp_axis
    from repro import compat
    from contextlib import nullcontext

    def _span(name, **kw):
        if recorder is None:
            return nullcontext()
        return recorder.span(name, cat="dryrun", arch=arch,
                             shape=shape_name, **kw)

    with compat.set_mesh(mesh), actctx.batch_axes(dp_for_ctx, attn_seq=attn_seq), \
            _span("dryrun.cell", mesh=rec["mesh"]):
        if shape.kind == "train":
            train_step = make_train_step(model, parallel)
            opt_shapes = jax.eval_shape(train_step.opt_init, params_shapes)
            # optimizer states inherit their params' sharding
            def opt_spec(path_leaf):
                return None
            opt_specs = jax.tree.map(
                lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), opt_shapes
            )
            # match param-shaped leaves to param specs: m/v/acc mirror params
            def mirror(tree):
                return jax.tree.map(
                    lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                    tree, psh,
                )
            if parallel.optimizer == "adamw":
                opt_sds = {
                    "m": mirror(opt_shapes["m"]),
                    "v": mirror(opt_shapes["v"]),
                    "step": jax.ShapeDtypeStruct(
                        (), jnp.int32, sharding=NamedSharding(mesh, P())
                    ),
                }
            else:
                opt_sds = jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(
                        l.shape, l.dtype,
                        sharding=NamedSharding(mesh, P(*([None] * l.ndim))),
                    ),
                    opt_shapes,
                )
            bspec = batch_specs("train", parallel, mesh, cfg)
            batch_sds = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, s)
                ),
                ispecs, bspec,
            )
            step_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                            sharding=NamedSharding(mesh, P()))
            fn = jax.jit(train_step, donate_argnums=(0, 1))
            lowered = fn.lower(params_sds, opt_sds, batch_sds, step_sds)
        elif shape.kind == "prefill":
            prefill = make_prefill_step(model, cfg)
            bspec = batch_specs("prefill", parallel, mesh, cfg)
            batch_sds = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, s)
                ),
                ispecs, bspec,
            )
            fn = jax.jit(prefill)
            lowered = fn.lower(params_sds, batch_sds)
        elif shape.kind == "decode":
            decode = make_decode_step(model, cfg)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            kv_mode = cache_head_or_dim(cfg, mesh.shape[parallel.tp_axis])
            cache_parallel = parallel
            if shape.global_batch == 1:
                # batch=1 cannot shard over dp; the data axis instead
                # shards the cache *sequence* (sequence-parallel decode)
                cache_parallel = dataclasses.replace(
                    parallel, dp_axes=(),
                    seq_axis=parallel.seq_axis or "data",
                )
            cspecs = cache_specs(cfg, cache_parallel, mesh,
                                 seq_axis=cache_parallel.seq_axis,
                                 kv_mode=kv_mode)
            cache_sds = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, s)
                ),
                cache_shapes, cspecs,
                is_leaf=lambda x: hasattr(x, "shape") or isinstance(x, P),
            )
            bspec = batch_specs("decode", parallel, mesh, cfg)
            if cfg.family == "audio":
                bspec["enc_states"] = P(None, None, None)
            dp = tuple(a for a in parallel.dp_axes if a in mesh.axis_names)
            if shape.global_batch == 1:
                # batch=1 can't shard over dp — replicate token/position
                bspec = jax.tree.map(
                    lambda s: P(*([None] * len(s))), bspec,
                    is_leaf=lambda x: isinstance(x, P),
                )
            batch_sds = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, s)
                ),
                ispecs, bspec,
            )
            fn = jax.jit(decode, donate_argnums=(2,))
            lowered = fn.lower(params_sds, batch_sds, cache_sds)
        elif shape.kind == "vdm_generate":
            if inject_fault:
                from repro.runtime.faults import parse_fault_plan

                fplan = parse_fault_plan(inject_fault)
                if fplan is not None:
                    rec["fault_drill"] = fplan.describe()
                    rec["wire_nan_guard"] = bool(
                        wire_nan_guard or fplan.corrupt)
            step = _vdm_lp_step(cfg, shape, mesh, parallel, lp_impl,
                                wire_codec=wire_codec,
                                wire_shard=wire_shard,
                                eager_sends=eager_sends,
                                inject_fault=inject_fault,
                                nan_guard=wire_nan_guard)
            batch_sds = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, P())
                ),
                ispecs,
            )
            fn = jax.jit(step)
            lowered = fn.lower(params_sds, batch_sds)
        else:
            raise ValueError(shape.kind)

        with _span("dryrun.compile", kind=shape.kind):
            compiled = lowered.compile()

    rec["lower_compile_s"] = round(perf_s() - t0, 1)
    from repro.compat import cost_analysis as _cost_analysis

    ca = _cost_analysis(compiled)
    # raw XLA numbers (while bodies counted ONCE — kept for reference only)
    rec["xla_flops_body"] = float(ca.get("flops", 0.0))
    rec["memory"] = _mem_summary(compiled)
    hlo = compiled.as_text()
    # trip-count-aware accounting (analysis/hlo_analyzer.py): per-device
    # MXU flops, HBM traffic at fusion boundaries, collective payloads
    from repro.analysis.hlo_analyzer import analyze as hlo_analyze

    anal = hlo_analyze(hlo)
    rec["flops"] = anal.flops
    rec["hbm_bytes"] = anal.hbm_bytes
    rec["collectives"] = {k: float(v) for k, v in anal.collective_bytes.items()}
    rec["collective_counts"] = {
        k: float(v) for k, v in anal.collective_counts.items()
    }
    # replica-group-size breakdown ("all-gather[4]" vs "all-gather[2]"):
    # the inter- vs intra-group split on hybrid meshes
    rec["collectives_by_group"] = {
        k: float(v) for k, v in anal.collective_group_bytes.items()
    }
    # the same vocabulary the serving recorder's derived attribution
    # uses ({"collective", "group_size", "tier", "bytes"}) — one schema,
    # machine-diffable against obs.account.step_wire_attribution
    from repro.obs.account import tiered_collectives

    mesh_axes = dict(mesh.shape)
    M = mesh_axes.get("data", 1)
    T = mesh_axes.get("model", 1)
    rec["wire_tiers"] = tiered_collectives(rec["collectives_by_group"], M, T)
    if recorder is not None:
        recorder.instant("dryrun.wire_tiers", cat="dryrun", arch=arch,
                         shape=shape_name, tiers=rec["wire_tiers"])
        from repro.obs import metrics as obsm

        for row in rec["wire_tiers"]:
            recorder.inc(obsm.WIRE_BYTES, row["bytes"], tier=row["tier"],
                         collective=row["collective"])
    return rec


def _resolve_dryrun_schedule(shape_name: str, mesh,
                             spec: str, psnr_floor: Optional[float],
                             wire_shard: Optional[bool] = None,
                             recorder=None):
    """Resolve ``--codec-schedule`` for one vdm cell against its real
    geometry, sampler trajectory, and the mesh's lp-axis size."""
    from repro.core.comm_model import wan21_comm_config
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.policy import resolve_cli_schedule

    shape = get_shape(shape_name)
    K = mesh.shape["data"]
    tp = dict(mesh.shape).get("model", 1)
    ccfg = wan21_comm_config(shape.num_frames, shape.height, shape.width,
                             num_steps=shape.num_steps)
    return resolve_cli_schedule(
        spec, ccfg, K, ParallelConfig().overlap_ratio,
        FlowMatchEuler(shape.num_steps), shape.num_steps,
        psnr_floor_db=psnr_floor, tp=tp, wire_shard=wire_shard,
        recorder=recorder,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--lp-impl", default="gspmd",
                    choices=["auto", "gspmd", "shard_map", "halo",
                             "halo_hybrid"])
    from repro.comm.codecs import CODEC_NAMES

    ap.add_argument("--wire-codec", default=None, choices=list(CODEC_NAMES),
                    help="compress LP halo payloads (halo/auto impls; "
                         "gspmd takes stateless codecs value-faithfully)")
    ap.add_argument("--codec-schedule", default=None,
                    help="sigma-scheduled codecs for vdm cells: 'auto' "
                         "(cost-model autotuner, docs/step_policy.md) or "
                         "an explicit spec like 'int8-residual@0.45,"
                         "bf16'.  The dry run lowers one cell per "
                         "schedule segment (collective shapes are "
                         "per-segment static) with the PLAN's engine "
                         "(--lp-impl is ignored for those cells) and "
                         "tags each record with its segment.  Excludes "
                         "--wire-codec")
    ap.add_argument("--psnr-floor", type=float, default=None,
                    help="PSNR floor (dB) for --codec-schedule "
                         "resolution (auto default: 40)")
    ap.add_argument("--mesh", default=None,
                    help="MxT hybrid mesh (LP groups x intra-group TP), "
                         "e.g. 4x2 — replaces the production mesh")
    ap.add_argument("--wire-shard", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="shard halo wire payloads over the tp axis "
                         "(hybrid meshes; default on there — the "
                         "two-tier autotuner decides for "
                         "--codec-schedule cells).  The record's "
                         "collectives_by_group shows the inter/intra "
                         "split")
    ap.add_argument("--eager-sends", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="issue halo ppermutes before any accumulation "
                         "(default: on for hybrid meshes)")
    ap.add_argument("--inject-fault", default=None,
                    help="serving-fault drill spec "
                         "(docs/fault_tolerance.md).  dead:G@S / "
                         "slow:GxF are runtime-only (recorded, no "
                         "lowering effect); corrupt@S lowers the vdm "
                         "cell with the NaN-poisoning wire wrapper and "
                         "the decode guard armed so the guarded HLO can "
                         "be inspected")
    ap.add_argument("--wire-nan-guard", default=False,
                    action=argparse.BooleanOptionalAction,
                    help="lower the halo wire decode with the NaN/Inf "
                         "guard (stale-slab fallback); auto-armed by "
                         "--inject-fault corrupt@S")
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of the dry run "
                         "(dryrun-category spans + wire_tiers instants; "
                         "docs/observability.md)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics snapshot (.prom/.txt -> "
                         "Prometheus text, else JSONL)")
    args = ap.parse_args(argv)
    if args.codec_schedule and args.wire_codec:
        ap.error("--codec-schedule and --wire-codec are exclusive")

    todo = []
    if args.all:
        for arch, shape, _ in cells():
            todo.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo.append((args.arch, args.shape))

    recorder = None
    if args.trace_out or args.metrics_out:
        from repro.obs import FlightRecorder

        recorder = FlightRecorder()

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.mesh:
        meshes = [False]  # --mesh overrides; one iteration, one mesh
    results = []
    failures = 0
    for multi_pod in meshes:
        if args.mesh:
            from repro.launch.mesh import make_hybrid_mesh, parse_mesh

            m, t = parse_mesh(args.mesh)
            mesh = make_hybrid_mesh(m, t)
            mesh_tag = f"{m}x{t}"
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
            mesh_tag = "2x16x16" if multi_pod else "16x16"
        for arch, shape in todo:
            tag = f"{arch} x {shape} [{mesh_tag}]"
            try:
                # --codec-schedule: one lowering per schedule segment (a
                # segment's collective shapes are static; only the codec
                # changes at segment boundaries), each record tagged.
                # The PLAN's engine is what gets lowered — the argparse
                # --lp-impl default (gspmd) has no stateful-codec layer
                # and must not leak into schedule cells.
                cells_to_lower = [
                    (args.wire_codec, args.lp_impl, args.wire_shard, None)
                ]
                if args.codec_schedule and \
                        get_shape(shape).kind == "vdm_generate":
                    plan = _resolve_dryrun_schedule(
                        shape, mesh, args.codec_schedule, args.psnr_floor,
                        wire_shard=args.wire_shard, recorder=recorder)
                    print(f"PLAN {tag}: {plan.describe()}", flush=True)
                    cells_to_lower = [
                        (seg.codec, plan.lp_impl, plan.wire_shard, {
                            "codec": seg.codec, "steps": [seg.start,
                                                          seg.stop],
                            "schedule": plan.schedule.spec,
                            "lp_impl": plan.lp_impl,
                            "wire_shard": plan.wire_shard,
                        })
                        for seg in plan.segments
                    ]
                for wire_codec, lp_impl, wire_shard, seg_info in \
                        cells_to_lower:
                    rec = lower_cell(arch, shape, multi_pod, lp_impl,
                                     mesh=mesh, wire_codec=wire_codec,
                                     wire_shard=wire_shard,
                                     eager_sends=args.eager_sends,
                                     inject_fault=args.inject_fault,
                                     wire_nan_guard=args.wire_nan_guard,
                                     recorder=recorder)
                    if seg_info is not None:
                        rec["schedule_segment"] = seg_info
                    if rec.get("skipped"):
                        print(f"SKIP {tag}: {rec['skipped']}", flush=True)
                    else:
                        seg_tag = ("" if seg_info is None else
                                   f" seg={seg_info['codec']}"
                                   f"[{seg_info['steps'][0]}.."
                                   f"{seg_info['steps'][1]}]")
                        print(
                            f"OK   {tag}{seg_tag}: "
                            f"{rec['lower_compile_s']}s "
                            f"flops={rec['flops']:.3e} "
                            f"coll={sum(rec['collectives'].values())/1e9:.2f}GB",
                            flush=True,
                        )
                    results.append(rec)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if multi_pod else "16x16",
                                "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    if recorder is not None:
        if args.trace_out:
            recorder.write_trace(args.trace_out)
            print(f"wrote {args.trace_out} "
                  f"({len(recorder.trace.events)} events)")
        if args.metrics_out:
            recorder.write_metrics(args.metrics_out)
            print(f"wrote {args.metrics_out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
