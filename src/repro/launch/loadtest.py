"""Serving load-test CLI — offered-load replay + SLO report.

  PYTHONPATH=src python -m repro.launch.loadtest --rate 2 --requests 12 \
      --steps 4 --partitions 2 [--arrivals poisson] [--seed 0] \
      [--mix 'clip,shape=6x8x12,priority=interactive;...'] \
      [--slo 'interactive:30@0.99,standard:120@0.95'] \
      [--trace-out artifacts/load_trace.json] \
      [--metrics-out artifacts/load_metrics.jsonl] \
      [--report-out artifacts/slo_report.json]

Drives the serving engine open-loop: a seeded workload
(``serving/loadgen.py`` — Poisson or deterministic arrivals over a
request-mix of ``(latent_shape, guidance, psnr_floor, priority)``
classes) is replayed on a virtual clock the engine advances by each
batch's measured wall, then every request's lifecycle stamps are
evaluated against the ``--slo`` deadlines (``obs/slo.py``): per-class
queue-wait and e2e p50/p99, violations, burn rate, goodput per device.
Before the replay, every ``(shape, guidance)`` bucket in the workload
is compiled at each batch size 1..max_batch so no measured batch pays
JIT inside its wall (``--skip-warm`` disables; the report's
``warmed`` field records which).

Offline mode re-derives the SAME report from a previously written
trace artifact — no engine, no devices::

  python -m repro.launch.loadtest --report-from artifacts/load_trace.json \
      [--slo ...] [--num-devices N]

Because the evaluator only ever reads the raw stamps, the offline
report equals the live one for the same serve
(``benchmarks/serving_load.py`` gates the equality byte-for-byte).

Fleet mode (``--replicas N``, N >= 2) serves the same workload through
``serving/router.ReplicaRouter`` — N independent engine replicas, each
on its own virtual clock, behind one front-door queue with admission
control (``--shed-watermark``), redispatch on replica loss
(``--max-redispatch``; kill a replica mid-run with
``--inject-fault replica:1:dead@3``) and graceful quality degradation.
The SLO report gains per-replica sections and disposition accounting
(completed / shed / terminally failed), and the offline report stays
byte-identical (``benchmarks/router_resilience.py`` gates it).
"""
from __future__ import annotations

import argparse
import json
import os


def _add_engine_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--overlap", type=float, default=0.5)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--lp-impl", default="auto",
                    choices=["auto", "uniform", "shard_map", "halo",
                             "halo_hybrid"])
    ap.add_argument("--wire-codec", default=None)
    ap.add_argument("--codec-schedule", default=None)
    ap.add_argument("--psnr-floor", type=float, default=None)
    ap.add_argument("--mesh", default=None,
                    help="MxT hybrid mesh; M must equal --partitions")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound each engine queue: submit raises "
                         "QueueFull beyond this many queued requests "
                         "(default: unbounded)")


def _add_router_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a ReplicaRouter over this many "
                         "engine replicas (1 = direct single-engine "
                         "replay, the historical path)")
    ap.add_argument("--router-policy", default="least-loaded",
                    choices=["least-loaded", "round-robin"])
    ap.add_argument("--inject-fault", default=None,
                    help="fault drill plan; with --replicas scope "
                         "chunks per replica, e.g. 'replica:1:dead@3,"
                         "replica:0:slow:0x2' (runtime/faults.py)")
    ap.add_argument("--max-redispatch", type=int, default=2,
                    help="redispatch attempts for a request lost to a "
                         "replica death before terminal failure")
    ap.add_argument("--shed-watermark", type=int, default=None,
                    help="aggregate queue depth beyond which the "
                         "lowest-priority requests are shed (default: "
                         "8 x total batch capacity)")
    ap.add_argument("--degrade-watermark", type=int, default=None,
                    help="queue depth that triggers stepwise psnr_floor "
                         "relaxation (default: half the shed watermark)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=2.0,
                    help="offered load, requests/second (virtual time)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arrivals", default="poisson",
                    choices=["poisson", "deterministic"])
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed: arrivals, mix assignment and "
                         "per-request latent seeds are all drawn from it "
                         "(same seed -> byte-identical workload)")
    ap.add_argument("--mix", default=None,
                    help="request-mix classes, ';'-separated "
                         "'name,shape=TxHxW[,guidance=G][,priority=P]"
                         "[,weight=W][,psnr=F]' (default: built-in 3-class "
                         "mix)")
    ap.add_argument("--slo", default=None,
                    help="SLO spec 'priority:deadline_s[@target],...' "
                         "(default: obs/slo.py DEFAULT_SLO_SPEC)")
    ap.add_argument("--num-devices", type=int, default=None,
                    help="devices the goodput is normalized over "
                         "(default: jax.device_count() live, 1 offline)")
    ap.add_argument("--trace-out", default=None,
                    help="write the lifecycle trace artifact here (input "
                         "to --report-from)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics snapshot (.prom/.txt -> "
                         "Prometheus, else JSONL)")
    ap.add_argument("--report-out", default=None,
                    help="write the SLO report JSON here")
    ap.add_argument("--report-from", default=None, metavar="TRACE_JSON",
                    help="offline: recompute the SLO report from a trace "
                         "artifact instead of serving")
    ap.add_argument("--skip-warm", action="store_true",
                    help="skip pre-compiling every (shape, guidance) x "
                         "batch-size bucket before the replay; the first "
                         "batch of each compiled shape then pays JIT "
                         "inside the measured wall, contaminating the "
                         "virtual timeline and the SLO quantiles")
    _add_engine_args(ap)
    _add_router_args(ap)
    args = ap.parse_args(argv)

    from repro.obs.slo import (
        SLOSpec,
        evaluate_slo,
        failures_from_trace,
        format_report,
        rows_from_trace,
        shed_from_trace,
    )

    if args.report_from:
        with open(args.report_from) as f:
            doc = json.load(f)
        rows = rows_from_trace(doc)
        shed = shed_from_trace(doc)
        failed = failures_from_trace(doc)
        # a routed serve is recognizable from its artifact alone (rows
        # carry replica identities / shed / terminal-failure events);
        # only then does the report gain the disposition block, so a
        # single-engine offline report stays byte-identical to its
        # historical live form
        routed = (shed or failed
                  or any(r.get("replica") is not None for r in rows))
        report = evaluate_slo(
            rows, spec=args.slo, num_devices=args.num_devices or 1,
            shed_rows=shed if routed else None,
            failed_rows=failed if routed else None)
        report["source"] = "trace"
        print(format_report(report))
        if args.report_out:
            _write_json(args.report_out, report)
            print(f"report: {args.report_out}")
        return report

    import jax

    from repro import models
    from repro.configs import get_config
    from repro.models import dit
    from repro.obs import FlightRecorder
    from repro.serving.engine import LPServingEngine
    from repro.serving.loadgen import (
        VirtualClock,
        WorkloadSpec,
        build_workload,
        parse_mix,
        run_workload,
        workload_digest,
    )

    spec = WorkloadSpec(rate_rps=args.rate, num_requests=args.requests,
                        arrivals=args.arrivals, seed=args.seed,
                        mix=parse_mix(args.mix))
    workload = build_workload(spec)
    print(f"workload: {len(workload)} requests at {args.rate}rps "
          f"({args.arrivals}, seed={args.seed}) "
          f"digest={workload_digest(workload)[:12]}")

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_hybrid_mesh, parse_mesh

        m, t = parse_mesh(args.mesh)
        if m != args.partitions:
            raise SystemExit(f"--mesh {args.mesh}: LP axis {m} != "
                             f"--partitions {args.partitions}")
        mesh = make_hybrid_mesh(m, t)

    recorder = FlightRecorder()
    slo = SLOSpec.parse(args.slo)   # None -> documented default spec
    if args.replicas < 1:
        raise SystemExit(f"--replicas must be >= 1, got {args.replicas}")

    def _make_engine(inject_fault=None):
        # built without the recorder and on a throwaway clock: the
        # warm-up batches must pollute neither the trace nor the
        # replay's virtual timeline; both are swapped in post-warm
        return LPServingEngine(fwd, params, cfg,
                               num_partitions=args.partitions,
                               overlap_ratio=args.overlap,
                               num_steps=args.steps,
                               max_batch=args.max_batch,
                               max_queue=args.max_queue,
                               lp_impl=args.lp_impl,
                               wire_codec=args.wire_codec,
                               codec_schedule=args.codec_schedule,
                               psnr_floor=args.psnr_floor,
                               mesh=mesh,
                               inject_fault=inject_fault,
                               recorder=None,
                               clock=VirtualClock(),
                               slo=slo)

    num_devices = (args.num_devices if args.num_devices is not None
                   else jax.device_count())
    if args.replicas == 1:
        clock = VirtualClock()
        engine = _make_engine(inject_fault=args.inject_fault)
        print(f"engine: lp_impl={engine.lp_impl} K={engine.K} "
              f"max_batch={engine.max_batch} steps={args.steps} "
              f"slo={engine.slo.spec}")
        if not args.skip_warm:
            nkeys = _warm_compiles(engine, cfg, workload)
            print(f"warm: {nkeys} bucket key(s) x batch sizes "
                  f"1..{engine.max_batch} "
                  f"({engine._compiler.compiles} compiles pre-replay)")
        engine.recorder = recorder
        engine.clock = clock
        results = run_workload(engine, workload)
        report = evaluate_slo(recorder.request_rows, spec=engine.slo,
                              num_devices=num_devices,
                              recorder=recorder)
    else:
        from repro.serving.router import ReplicaRouter

        engines = [_make_engine() for _ in range(args.replicas)]
        if not args.skip_warm:
            for r, eng in enumerate(engines):
                nkeys = _warm_compiles(eng, cfg, workload)
                print(f"warm replica {r}: {nkeys} bucket key(s) "
                      f"({eng._compiler.compiles} compiles)")
        for eng in engines:
            eng.recorder = recorder
            eng.clock = VirtualClock()   # fresh, per-replica
        router = ReplicaRouter(
            engines, recorder=recorder, slo=slo,
            policy=args.router_policy,
            max_redispatch=args.max_redispatch,
            shed_watermark=args.shed_watermark,
            degrade_watermark=args.degrade_watermark,
            inject_fault=args.inject_fault)
        print(f"router: {args.replicas} replicas "
              f"policy={args.router_policy} "
              f"shed_watermark={router.shed_watermark} "
              f"max_redispatch={router.max_redispatch}"
              + (f" fault={args.inject_fault}" if args.inject_fault
                 else ""))
        results = router.serve(workload)
        clock = max((rep.clock for rep in router.replicas),
                    key=lambda c: c.now)
        report = evaluate_slo(recorder.request_rows, spec=router.slo,
                              num_devices=num_devices,
                              recorder=recorder,
                              shed_rows=recorder.shed_rows,
                              failed_rows=recorder.failed_rows)
        report["router"] = {
            "replicas": args.replicas,
            "policy": args.router_policy,
            "states": [rep.state for rep in router.replicas],
            "degrade_level": router.degrade_level,
            **router.stats,
        }
    report["source"] = "live"
    report["warmed"] = not args.skip_warm
    report["workload"] = {
        "rate_rps": args.rate, "requests": len(workload),
        "arrivals": args.arrivals, "seed": args.seed,
        "digest": workload_digest(workload),
    }
    print(format_report(report))
    print(f"served: {len(results)} results over "
          f"{report.get('makespan_s', 0.0):.2f}s virtual "
          f"({clock.now:.2f}s clock)")

    if args.trace_out:
        _ensure_dir(args.trace_out)
        recorder.write_trace(args.trace_out)
        print(f"trace: {args.trace_out} "
              f"({len(recorder.trace.events)} events)")
    if args.metrics_out:
        _ensure_dir(args.metrics_out)
        recorder.write_metrics(args.metrics_out)
        print(f"metrics: {args.metrics_out}")
    if args.report_out:
        _write_json(args.report_out, report)
        print(f"report: {args.report_out}")
    return report


def _warm_compiles(engine, cfg, workload) -> int:
    """Pre-compile every compiled shape the replay can admit.

    Batch size is in the compiled shape and admission is ragged, so
    each ``(latent_shape, guidance)`` bucket key in the workload is
    served once at every batch size ``1..max_batch`` before the
    measured replay — otherwise the first batch of each shape pays JIT
    compilation (often >> service time) inside the measured wall, and
    ``_denoise_batch`` advances the virtual clock by that wall,
    biasing every downstream quantile and SLO verdict
    (``benchmarks/serving_load.py`` warms for the same reason).  The
    engine must be on a throwaway clock with no recorder attached.
    """
    import jax

    from repro.models import frontends
    from repro.serving.engine import VideoRequest

    keys = sorted({(tuple(a.cls.latent_shape), float(a.cls.guidance))
                   for a in workload})
    rid = 1_000_000_000          # out of any real workload's id space
    for shape, guidance in keys:
        for n in range(1, engine.max_batch + 1):
            for _ in range(n):
                engine.submit(VideoRequest(
                    request_id=rid,
                    context=frontends.text_context(
                        jax.random.PRNGKey(rid), 1, cfg),
                    latent_shape=shape, seed=rid, guidance=guidance))
                rid += 1
            engine.run()
    return len(keys)


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)


def _write_json(path: str, obj: dict) -> None:
    _ensure_dir(path)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
