"""Serving driver CLI — LP video generation service.

  PYTHONPATH=src python -m repro.launch.serve --requests 4 --steps 6 \
      --partitions 2 --overlap 0.5 [--lp-impl auto] [--wire-codec int8-residual]

Step policy (docs/step_policy.md): ``--codec-schedule auto`` lets the
cost-model autotuner pick (engine, sigma-scheduled codec) minimizing
analytic wire bytes subject to ``--psnr-floor`` (default 40 dB);
``--codec-schedule 'int8-residual@0.45,bf16'`` pins an explicit schedule.

Hierarchy-aware wire (docs/wire_sharding.md): on a ``--mesh MxT`` hybrid
mesh, ``--wire-shard`` / ``--no-wire-shard`` pins the tp-sharded halo
wire (default: on; the autotuner's two-tier link model decides when
``--codec-schedule`` is set) and ``--eager-sends`` / ``--no-eager-sends``
controls ppermute/compute overlap (default: on for hybrid meshes).
"""
from __future__ import annotations

import argparse

import jax

from repro import models
from repro.comm.codecs import CODEC_NAMES
from repro.configs import get_config
from repro.models import dit, frontends
from repro.serving.engine import LPServingEngine, VideoRequest


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--overlap", type=float, default=0.5)
    ap.add_argument("--frames-latent", type=int, default=6)
    ap.add_argument("--lp-impl", default="auto",
                    choices=["auto", "uniform", "shard_map", "halo",
                             "halo_hybrid"],
                    help="LP engine; auto = psum math at K=2, halo beyond "
                         "(hybrid halo when the mesh has a tp axis)")
    ap.add_argument("--wire-codec", default=None, choices=list(CODEC_NAMES),
                    help="compress LP halo wire payloads (fixed codec)")
    ap.add_argument("--codec-schedule", default=None,
                    help="sigma-scheduled codecs: 'auto' (cost-model "
                         "autotuner) or a spec like "
                         "'int8-residual@0.45,bf16'; excludes "
                         "--wire-codec")
    ap.add_argument("--psnr-floor", type=float, default=None,
                    help="PSNR floor (dB) the codec schedule must meet "
                         "against the conformance envelope (auto "
                         "default: 40)")
    ap.add_argument("--mesh", default=None,
                    help="MxT hybrid mesh (LP groups x intra-group TP), "
                         "e.g. 4x2; M must equal --partitions.  Needs "
                         "M*T local devices")
    ap.add_argument("--wire-shard", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="shard every halo payload over the tp axis "
                         "(1/T chunks across the inter-group links + an "
                         "intra-group reassembly gather; bit-identical "
                         "values).  Default: on for hybrid meshes — the "
                         "autotuner's two-tier link cost model decides "
                         "when --codec-schedule is set")
    ap.add_argument("--eager-sends", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="issue all halo ppermute rounds before any "
                         "accumulation so they can overlap the DiT "
                         "tail.  Default: on for hybrid meshes")
    ap.add_argument("--elastic", action="store_true",
                    help="mid-request re-planning: the per-step hook "
                         "evicts dead/straggler LP groups through the "
                         "health monitor (disables scan fusion)")
    ap.add_argument("--inject-fault", default=None,
                    help="scripted serving-fault drill, e.g. "
                         "'dead:1@4,slow:0x2,corrupt@2' "
                         "(docs/fault_tolerance.md); dead/slow need "
                         "--elastic to recover")
    ap.add_argument("--wire-nan-guard", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="absorb NaN/Inf wire payloads by falling back "
                         "to the rank-local stale slab (bit-identical "
                         "when every message is finite)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/Chrome-trace JSON of the "
                         "request lifecycle here (docs/observability.md)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics snapshot here (.prom/.txt -> "
                         "Prometheus text, else JSONL)")
    args = ap.parse_args(argv)
    if args.codec_schedule and args.wire_codec:
        ap.error("--codec-schedule and --wire-codec are exclusive")

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_hybrid_mesh, parse_mesh

        m, t = parse_mesh(args.mesh)
        if m != args.partitions:
            raise SystemExit(
                f"--mesh {args.mesh}: LP axis {m} != --partitions "
                f"{args.partitions}")
        mesh = make_hybrid_mesh(m, t)

    recorder = None
    if args.trace_out or args.metrics_out:
        from repro.obs import FlightRecorder

        recorder = FlightRecorder()

    engine = LPServingEngine(fwd, params, cfg,
                             num_partitions=args.partitions,
                             overlap_ratio=args.overlap,
                             num_steps=args.steps,
                             lp_impl=args.lp_impl,
                             wire_codec=args.wire_codec,
                             codec_schedule=args.codec_schedule,
                             psnr_floor=args.psnr_floor,
                             mesh=mesh,
                             wire_shard=args.wire_shard,
                             eager_sends=args.eager_sends,
                             elastic=args.elastic,
                             inject_fault=args.inject_fault,
                             wire_nan_guard=args.wire_nan_guard,
                             recorder=recorder)
    print(f"engine: lp_impl={engine.lp_impl} codec={engine.codec.name} "
          f"tp={engine.tp} wire_shard={engine.wire_shard} "
          f"eager_sends={engine.eager_sends}")
    if engine.plan is not None:
        print(f"step policy: {engine.plan.describe()}")
    if engine._fault_plan is not None:
        print(f"fault drill: {engine._fault_plan.describe()} "
              f"(elastic={engine.elastic}, "
              f"nan_guard={engine.wire_nan_guard})")
    for i in range(args.requests):
        engine.submit(VideoRequest(
            request_id=i,
            context=frontends.text_context(jax.random.PRNGKey(i), 1, cfg),
            latent_shape=(args.frames_latent, 8, 12),
            seed=i,
        ))
    results = engine.run()
    for r in sorted(results, key=lambda x: x.request_id):
        resumed = f" resumed_from={r.resumed_from_step}" if r.restarts else ""
        print(f"request {r.request_id}: latent {tuple(r.latent.shape)} "
              f"steps={r.num_steps} wait={r.queue_wait_s:.2f}s "
              f"e2e={r.e2e_s:.2f}s batch_wall={r.batch_wall_s:.1f}s "
              f"batch={r.batch_size} restarts={r.restarts}{resumed}")
    if engine.evictions:
        print(f"elastic: evictions={engine.evictions} K={engine.K} "
              f"steps_lost={engine.last_steps_lost}")
    if recorder is not None:
        if args.trace_out:
            recorder.write_trace(args.trace_out)
            print(f"trace: {args.trace_out} "
                  f"({len(recorder.trace.events)} events)")
        if args.metrics_out:
            recorder.write_metrics(args.metrics_out)
            print(f"metrics: {args.metrics_out}")
        m = recorder.metrics
        if m is not None:
            from repro.obs import metrics as obsm

            steps = m.hist_values(obsm.STEP_LATENCY_S)
            if steps:
                import numpy as np

                p50, p99 = np.percentile(steps, [50, 99])
                print(f"obs: step_latency p50={p50 * 1e3:.1f}ms "
                      f"p99={p99 * 1e3:.1f}ms over {len(steps)} steps")
        for rec in recorder.reconciliations:
            print(f"obs: run[{rec['start']}-{rec['stop']}] "
                  f"codec={rec['codec']} "
                  f"pred_wire={rec['pred_wire_time_ms']:.2f}ms "
                  f"measured={rec['measured_wall_ms']:.1f}ms")


if __name__ == "__main__":
    main()
