"""Wire codecs: encode an array into a compact wire dtype (+ tiny meta)
and decode it back to the compute dtype.

Design rules (all consequences of running inside jit/shard_map/scan):

  * **Static shapes** — ``encode`` maps (shape, f32) -> (wire_shape,
    wire_dtype) deterministically; ``decode`` takes the *logical* decoded
    shape because packing codecs (int4) change the stored shape.
  * **Per-slab scale** — quantizers use one max-abs scale per message,
    shaped ``(1,) * ndim`` so it broadcasts anywhere and survives
    ``ppermute`` / ``all_gather`` unchanged.  ``meta`` is a (possibly
    empty) tuple of such arrays; every leaf crosses the wire next to the
    payload and is charged in the byte model.
  * **Zero maps to zero** — a masked (all-zero) slab encodes to a
    zero wire and decodes to exactly zero, so the halo schedule's
    "no peer at this offset" ranks stay silent through any codec.

``get_codec`` resolves CLI names: fp32 (exact), bf16, int8, int4, and
the ``*-residual`` temporal-delta variants from :mod:`.residual`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple, Union

import jax.numpy as jnp

Meta = Tuple[jnp.ndarray, ...]


@dataclasses.dataclass(frozen=True)
class Codec:
    """Protocol + shared accounting. Subclasses implement encode/decode."""

    name: str = "identity"
    bits: float = 32.0          # wire bits per logical element
    meta_bytes: int = 0         # scale payload per message, bytes
    stateful: bool = False      # True => needs carry state (residual)

    # ------------------------------------------------------------ protocol
    def encode(self, x: jnp.ndarray) -> Tuple[jnp.ndarray, Meta]:
        raise NotImplementedError

    def decode(self, wire: jnp.ndarray, meta: Meta,
               shape: Tuple[int, ...]) -> jnp.ndarray:
        raise NotImplementedError

    # ---------------------------------------------------------- accounting
    def wire_bytes(self, n_elems: int) -> int:
        """Analytic bytes of one message of ``n_elems`` logical elements
        (payload + meta) — must agree with the compiled HLO output shapes
        (cross-checked in comm_model/hlo_analyzer tests)."""
        return int(math.ceil(n_elems * self.bits / 8)) + self.meta_bytes

    @property
    def wire_dtype_bytes(self) -> int:
        """Bytes per element of the wire payload's STORAGE dtype (f32 4,
        bf16-as-u16 2, int8 and packed int4 1).  The tp-sharded wire
        splits the payload at storage-element granularity, so its byte
        model needs this alongside the logical ``bits``."""
        return max(int(self.bits) // 8, 1)

    def wire_elems(self, n_elems: int, last_dim: Union[int, None] = None
                   ) -> int:
        """Number of wire-dtype storage elements of one message of
        ``n_elems`` logical elements — the flat length the tp-sharded
        transport chunks.  ``last_dim`` is the logical last-axis extent,
        needed by packing codecs (int4 packs pairs along that axis)."""
        return int(math.ceil(n_elems * self.bits / 8 / self.wire_dtype_bytes))


@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    """fp32 passthrough — the exact baseline path, zero meta."""

    name: str = "fp32"
    bits: float = 32.0

    def encode(self, x):
        return x.astype(jnp.float32), ()

    def decode(self, wire, meta, shape):
        return wire.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Bf16Codec(Codec):
    """bf16 wire: halves bytes, keeps fp32 dynamic range, no meta.

    The payload is bitcast to u16 so the 2-byte message survives XLA's
    algebraic simplifier — a raw ``convert`` pair around a collective
    gets commuted across it (``ppermute(bf16(x))`` -> f32 permute + a
    local round-trip), silently restoring full-width transfers.
    """

    name: str = "bf16"
    bits: float = 16.0

    def encode(self, x):
        import jax

        return jax.lax.bitcast_convert_type(
            x.astype(jnp.bfloat16), jnp.uint16
        ), ()

    def decode(self, wire, meta, shape):
        import jax

        return jax.lax.bitcast_convert_type(wire, jnp.bfloat16).astype(
            jnp.float32
        )


def _scale_of(x: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """(1,)*ndim max-abs scale; tiny floor so all-zero slabs stay exact."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return (jnp.maximum(amax, 1e-20) / qmax).reshape((1,) * x.ndim)


@dataclasses.dataclass(frozen=True)
class IntCodec(Codec):
    """Per-slab-scaled symmetric integer quantizer (int8 or packed int4).

    int8: wire int8 in [-127, 127], scale = max|x| / 127.
    int4: wire int8 with TWO 4-bit codes per byte, packed along the last
    axis (channels); codes in [-7, 7], scale = max|x| / 7.  An odd last
    dim is zero-padded before packing and sliced off on decode.
    """

    name: str = "int8"
    bits: float = 8.0
    meta_bytes: int = 4

    @property
    def qmax(self) -> int:
        return 127 if self.bits == 8 else 7

    def encode(self, x):
        scale = _scale_of(x, self.qmax)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -self.qmax, self.qmax).astype(jnp.int32)
        if self.bits == 8:
            return q.astype(jnp.int8), (scale,)
        # int4: pack adjacent pairs of the last axis into one byte
        c = x.shape[-1]
        if c % 2:
            q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
        lo = q[..., 0::2] & 0xF
        hi = (q[..., 1::2] & 0xF) << 4
        return (lo | hi).astype(jnp.int8), (scale,)

    def decode(self, wire, meta, shape):
        (scale,) = meta
        if self.bits == 8:
            return wire.astype(jnp.float32) * scale
        p = wire.astype(jnp.int32)
        lo = ((p & 0xF) ^ 8) - 8
        hi = (((p >> 4) & 0xF) ^ 8) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(
            wire.shape[:-1] + (2 * wire.shape[-1],)
        )[..., : shape[-1]]
        return q.astype(jnp.float32) * scale

    def wire_bytes(self, n_elems: int) -> int:
        # packing is along the channel axis; for even channel counts this
        # ceil is exact, and wan21 latents have C=16
        return int(math.ceil(n_elems * self.bits / 8)) + self.meta_bytes

    def wire_elems(self, n_elems: int, last_dim: Union[int, None] = None
                   ) -> int:
        if self.bits == 4 and last_dim:
            # packed along the last axis: exact even for odd extents
            return n_elems // last_dim * ((last_dim + 1) // 2)
        return super().wire_elems(n_elems, last_dim)


def int4_wire_shape(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Stored shape of an int4-packed message of logical ``shape``."""
    return shape[:-1] + ((shape[-1] + 1) // 2,)


CODEC_NAMES = ("fp32", "bf16", "int8", "int4", "int8-residual",
               "int4-residual", "displaced", "displaced:int8-residual",
               "displaced:int4-residual")


def get_codec(name: Union[str, Codec, None]) -> Codec:
    """Resolve a CLI name (or pass a Codec through). ``None`` => fp32."""
    if name is None:
        return IdentityCodec()
    if isinstance(name, Codec):
        return name
    base = {
        "identity": IdentityCodec(),
        "fp32": IdentityCodec(),
        "bf16": Bf16Codec(),
        "int8": IntCodec(name="int8", bits=8.0),
        "int4": IntCodec(name="int4", bits=4.0),
    }
    if name in base:
        return base[name]
    if name == "displaced":
        # bare ``displaced`` is sugar for the default residual base
        name = "displaced:int8-residual"
    if name.startswith("displaced:"):
        from .residual import ResidualCodec

        innerc = get_codec(name[len("displaced:"):])
        if not isinstance(innerc, ResidualCodec):
            raise ValueError(
                "displaced halo needs a *-residual base codec (the EF "
                f"carry is the staleness corrector), got {innerc.name!r}"
            )
        return ResidualCodec(base=innerc.base, name=name, displaced=True)
    if name.endswith("-residual"):
        from .residual import ResidualCodec

        inner = name[: -len("-residual")]
        if inner in base and base[inner].meta_bytes:
            return ResidualCodec(base=base[inner], name=name)
        raise ValueError(
            f"residual coding needs a quantizing base codec, got {inner!r}"
        )
    raise ValueError(f"unknown wire codec {name!r}; know {CODEC_NAMES}")
