"""Temporal-delta coding with error feedback (EF) for wire messages.

Halo slabs change slowly across the fused ``lax.scan`` steps of one
rotation dim (the latent moves by one Euler increment per step), so the
*residual* vs the previous timestep's slab is much smaller than the slab
— a per-slab-scaled quantizer spends its codes on a tighter range, and
the EF carry re-injects each step's quantization error into the next
step's residual so the accumulated error stays bounded instead of
drifting (EF14 construction; cf. *Accelerating Parallel Diffusion Model
Serving with Residual Compression*).

The protocol is symmetric and deterministic, so sender and receiver
track the same reference without any extra communication:

    sender j:   c   = x - prev_send + err          (delta + EF carry)
                w,m = base.encode(c);  d = base.decode(w, m)
                prev_send += d;        err = c - d
    receiver k: d   = base.decode(w, m)
                x_hat = prev_recv + d; prev_recv = x_hat

``prev_send`` on j and ``prev_recv`` on k are both "sum of decoded
residuals so far" — identical by construction as long as the transfer
schedule is static (it is: ``halo_spec``).  All state lives in the
caller's scan carry (``core/lp_step.LPStepCompiler``), never in traced
closures.

The same EF round-trip, without the delta, generalizes the bf16
gradient-compression prototype that used to live in
``distributed/compression.py`` (now a thin wrapper over this module).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from .codecs import Codec, IntCodec, Meta


@dataclasses.dataclass(frozen=True)
class ResidualCodec(Codec):
    """Temporal-delta + error-feedback wrapper around a quantizing base.

    ``encode``/``decode`` are intentionally NOT implemented: a residual
    codec is stateful, so callers go through :func:`residual_encode` /
    :func:`residual_decode` with explicit (prev, err) state.
    """

    base: Codec = dataclasses.field(default_factory=IntCodec)
    name: str = "int8-residual"
    stateful: bool = True
    # displaced mode: the halo exchange deposits the *previous* step's
    # decoded slab (already sitting in the scan carry) into the blend
    # while this step's ppermute lands into the carry for step t+1 — the
    # DistriFusion construction, with the EF carry absorbing staleness.
    # The first step of every scan run stays synchronous (fresh flag in
    # the wire state); resolved via ``get_codec("displaced:<base>")``.
    displaced: bool = False

    def __post_init__(self):
        # mirror the base codec's wire accounting (the delta construction
        # changes *what* is quantized, not the message layout)
        object.__setattr__(self, "bits", self.base.bits)
        object.__setattr__(self, "meta_bytes", self.base.meta_bytes)

    def encode(self, x):  # pragma: no cover - guard
        raise TypeError("residual codecs are stateful: use residual_encode")

    def decode(self, wire, meta, shape):  # pragma: no cover - guard
        raise TypeError("residual codecs are stateful: use residual_decode")

    def wire_elems(self, n_elems, last_dim=None):
        # delegate: the base may pack (int4), and the wire layout of a
        # residual message is exactly its base codec's
        return self.base.wire_elems(n_elems, last_dim)


# ------------------------------------------------------------- primitives
def residual_encode(
    base: Codec,
    x: jnp.ndarray,
    prev_send: jnp.ndarray,
    err: jnp.ndarray,
) -> Tuple[jnp.ndarray, Meta, jnp.ndarray, jnp.ndarray]:
    """Sender side: returns (wire, meta, new_prev_send, new_err)."""
    corrected = x.astype(jnp.float32) - prev_send + err
    wire, meta = base.encode(corrected)
    d = base.decode(wire, meta, corrected.shape)
    return wire, meta, prev_send + d, corrected - d


def residual_decode(
    base: Codec,
    wire: jnp.ndarray,
    meta: Meta,
    prev_recv: jnp.ndarray,
    shape: Tuple[int, ...],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Receiver side: returns (x_hat, new_prev_recv)."""
    d = base.decode(wire, meta, shape)
    x_hat = prev_recv + d
    return x_hat, x_hat


def ef_roundtrip(
    base: Codec, x: jnp.ndarray, err: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Plain error-feedback round-trip (no temporal delta): returns the
    decoded value and the new error carry.  The accumulated sum of the
    decoded stream tracks the true sum to O(one step's quantization
    error) — the gradient-compression construction, generalized to any
    codec."""
    corrected = x.astype(jnp.float32) + err
    wire, meta = base.encode(corrected)
    back = base.decode(wire, meta, corrected.shape)
    return back, corrected - back
