"""Codec-aware LP collectives + a bit-faithful single-process mirror.

Two SPMD building blocks (called per-device, inside shard_map):

  * :func:`compressed_halo_exchange` — wraps
    ``distributed/collectives.halo_exchange``: same transfer schedule,
    but each slab crosses the wire through a :class:`~.codecs.Codec`
    (wire payload + per-slab scale meta per ppermute round).
  * :func:`compressed_core_gather` — the core-slice all-gather with the
    same codec (each rank quantizes its normalized core; wire + scales
    are gathered and decoded locally).

Residual codecs thread explicit state (previous decoded slabs + error
carries, see :mod:`.residual`); the state is created by
:func:`init_halo_wire_state` with a leading lp-axis dim so shard_map can
slice it per rank, and it rides the caller's ``lax.scan`` carry.

:func:`simulate_halo_forward` replays the exact same arithmetic on a
single device (static Python loop over ranks): used by the serving
engine when no mesh is attached, by quality/PSNR benchmarks, and by
tests as the oracle for the SPMD path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.collectives import (
    HaloSpec,
    halo_spec,
    sharded_all_gather,
    sharded_ppermute,
)

from .codecs import Codec, get_codec
from .residual import ResidualCodec, residual_decode, residual_encode

WireState = Dict[str, Any]


def _dir_key(t) -> str:
    """Stable per-direction state key for one halo transfer round.

    ``halo_spec`` emits exactly one transfer per nonzero window offset,
    so the signed offset identifies the direction (``"+1"`` = slab from
    the left neighbor, ``"-1"`` = from the right, ...).  Keying the
    send/err/recv state by direction — instead of positional round
    index — makes it structurally impossible for one direction's stale
    slab to be read back for the other (the directional-mixing bug
    class), and survives any reordering of ``spec.transfers``.
    """
    return f"{t.offset:+d}"


def init_halo_wire_state(codec, spec: HaloSpec,
                         rest_shape: Tuple[int, ...]) -> WireState:
    """Zeroed codec state for one halo-LP geometry.

    Every leaf has a leading ``K`` dim (the lp axis) so shard_map slices
    one rank's state with ``P(lp_axis)``; ``simulate_halo_forward``
    indexes the same leaves with Python rank ints.  ``ag_prev`` is the
    decoded gathered-core table — identical on every rank by
    construction, kept per-rank (K, K, ...) so the layout is uniform.
    The ``pp_*`` leaves are dicts keyed per direction
    (:func:`_dir_key`), one entry per ppermute round.  Stateless codecs
    get an empty dict (still scan-carry compatible).

    Displaced codecs additionally carry a per-rank ``fresh`` flag,
    initialized to ones: the first exchange after ANY state init (start
    of a scan run, dim rotation, codec-segment boundary, replan, resume)
    deposits the freshly decoded slabs — i.e. runs synchronous — and
    zeroes the flag, so later steps in the run deposit the one-step-stale
    carry instead.  This is the dim-rotation flush rule: a rotation
    re-inits state, which re-arms the flag.
    """
    codec = get_codec(codec)
    if not codec.stateful:
        return {}
    K = spec.num_partitions
    rest = tuple(rest_shape)

    def z(shape):
        return jnp.zeros(shape, jnp.float32)

    state = {
        "pp_send": {_dir_key(t): z((K, t.length) + rest)
                    for t in spec.transfers},
        "pp_err": {_dir_key(t): z((K, t.length) + rest)
                   for t in spec.transfers},
        "pp_recv": {_dir_key(t): z((K, t.length) + rest)
                    for t in spec.transfers},
        "ag_prev": z((K, K, spec.core_pad) + rest),
        "ag_err": z((K, spec.core_pad) + rest),
    }
    if getattr(codec, "displaced", False):
        state["fresh"] = jnp.ones((K,), jnp.float32)
    return state


def _pin(x):
    """Keep the encoded dtype ON the wire.

    XLA's algebraic simplifier happily commutes converts across
    collectives (``convert_f32(ppermute(bf16 x))`` becomes
    ``ppermute(f32 x)`` + a fused round-trip), which preserves values
    but silently restores full-width transfers.  An optimization
    barrier on both sides of every collective pins the compact dtype to
    the collective op — this is what makes the analytic byte model
    (``comm_model.comm_lp_halo_codec``) match the compiled HLO.
    """
    return jax.lax.optimization_barrier(x)


def _finite_or(decoded: jnp.ndarray, fallback) -> jnp.ndarray:
    """NaN/Inf decode guard: the whole message or its fallback.

    A corrupted wire payload (flipped bits, truncated DMA, a garbage
    scale) decodes to NaN/Inf; letting even one such element into the
    accumulator poisons the entire latent within a step.  The guard is
    all-or-nothing per message — one non-finite element means the
    payload can't be trusted at all — and falls back to the *stale*
    reference where one exists (residual codecs carry the previous
    decoded slab: DistriFusion's one-step-stale boundary activations,
    absorbed by the same error-feedback machinery) or to zeros for
    stateless codecs (the contribution is skipped; every rank computes
    the same zero, so replication invariants hold).

    Elementwise select only — no new collectives, so the analytic wire
    byte model still matches the compiled HLO exactly; when the wire is
    healthy the select is the identity and values are bit-equal to the
    unguarded path.
    """
    ok = jnp.isfinite(decoded).all()
    fb = jnp.zeros_like(decoded) if fallback is None else fallback
    return jnp.where(ok, decoded, fb)


def _finite_rows_or(decoded: jnp.ndarray, fallback) -> jnp.ndarray:
    """Per-row (leading-axis) variant of :func:`_finite_or` for gathered
    (K, ...) tables: each sender's message is guarded independently."""
    axes = tuple(range(1, decoded.ndim))
    ok = jnp.isfinite(decoded).all(axis=axes, keepdims=True)
    fb = jnp.zeros_like(decoded) if fallback is None else fallback
    return jnp.where(ok, decoded, fb)


def _ppermute_msg(wire, meta, axis_name, perm, shard_axis=None,
                  shard_size=1):
    """Ship (payload, scales) through one ppermute round.

    With ``shard_axis`` (the hybrid mesh's tp axis, size ``shard_size``)
    the payload crosses the group boundary **sharded**: each tp rank
    ppermutes only its 1/T chunk of the coded wire, then the full wire
    is reassembled with one intra-group all-gather.  The meta scales are
    tiny and every tp rank of the source group encodes the identical
    slab, so each rank ships the full meta and no tp gather of it is
    needed.  Both collectives are dtype-pinned so the compact wire (and
    the T-fold inter-group saving) survives XLA's simplifier.
    """
    wire, meta = _pin((wire, meta))
    if shard_axis is not None and shard_size > 1:
        got_wire = sharded_ppermute(wire, axis_name, perm, shard_axis,
                                    shard_size, pin=_pin)
    else:
        got_wire = jax.lax.ppermute(wire, axis_name, perm)
    got_meta = tuple(jax.lax.ppermute(m, axis_name, perm) for m in meta)
    return _pin((got_wire, got_meta))


def _gather_msg(wire, meta, axis_name, shard_axis=None, shard_size=1):
    """All-gather (payload, scales) with the wire dtype pinned.

    Sharded (``shard_axis``): each tp rank contributes only its 1/T
    chunk of the coded payload to the **inter-group** ring all-gather,
    then one intra-group all-gather collects the T chunk columns and
    each device reassembles the full (K, ...) wire table locally.  Meta
    leaves stay on the inter-group gather (K tiny scales are needed in
    full on every device either way).
    """
    wire, meta = _pin((wire, meta))
    if shard_axis is not None and shard_size > 1:
        wires = sharded_all_gather(wire, axis_name, shard_axis, shard_size,
                                   pin=_pin)
    else:
        wires = jax.lax.all_gather(wire, axis_name, axis=0, tiled=False)
    metas = tuple(
        jax.lax.all_gather(m, axis_name, axis=0, tiled=False) for m in meta
    )
    return _pin((wires, metas))


# ----------------------------------------------------------- SPMD pieces
def compressed_halo_exchange(
    wpred: jnp.ndarray,
    spec: HaloSpec,
    rank: jnp.ndarray,
    axis_name: str,
    codec: Codec,
    state: WireState,
    eager_sends: bool = False,
    shard_axis: Optional[str] = None,
    shard_size: int = 1,
    nan_guard: bool = False,
) -> Tuple[jnp.ndarray, WireState]:
    """Codec twin of ``collectives.halo_exchange`` (same contract: padded
    window-first ``wpred`` in, ``(core_pad + max_transfer, ...)`` f32
    accumulator out), plus the updated per-rank codec state.

    Each transfer round sends ``codec.encode`` of the (masked) slab —
    for residual codecs, of the temporal delta with the EF carry — and
    accumulates the decoded slab.  Ranks without a peer at an offset
    send a zero slab and decode ppermute's implicit zeros to exactly
    zero (codecs map 0 -> 0), so the schedule semantics are unchanged.

    ``eager_sends`` mirrors ``halo_exchange``: every round is encoded and
    its ppermute issued before any decode/accumulate, so the wires are
    mutually independent and can overlap the local work (and each other)
    under XLA's async collective scheduling.  Values are identical either
    way — only the op ordering changes.

    ``shard_axis`` / ``shard_size`` shard every coded payload over the
    hybrid mesh's tp axis (see ``_ppermute_msg``).  Encoding always
    happens on the FULL slab — identical on every tp rank, so per-slab
    scales, quantized values, and residual/EF state are bit-equal to the
    unsharded engine and the state stays rank-local on the lp axis —
    only the wire transport is split.

    ``nan_guard`` wraps every decode in :func:`_finite_or`: a corrupted
    payload is replaced by the rank-local stale slab (the SAME
    direction's residual ``pp_recv`` reference — which is then also NOT
    advanced, so the reference stays the last healthy decode) or by
    zeros (stateless).

    Displaced codecs (``codec.displaced``) deposit the *previous* step's
    decoded slab (the ``pp_recv`` carry as of entry) into the
    accumulator while this step's ppermute lands in the carry for the
    next step — one-step-stale boundary activations, DistriFusion-style,
    with the EF delta protocol re-injecting the staleness error into the
    next residual.  The first exchange after a state init runs
    synchronous (``fresh`` flag).  The collectives issued are IDENTICAL
    to the synchronous path (elementwise select only), so wire bytes per
    collective per tier still match ``comm_model`` exactly.
    """
    stateful = isinstance(codec, ResidualCodec)
    base = codec.base if stateful else codec
    displaced = stateful and getattr(codec, "displaced", False)
    acc_len = spec.core_pad + spec.max_transfer
    trail = (1,) * (wpred.ndim - 1)
    acc = jnp.zeros((acc_len,) + wpred.shape[1:], jnp.float32)
    K = spec.num_partitions
    new_state = dict(state) if stateful else {}
    if stateful:
        new_state["pp_send"] = dict(state["pp_send"])
        new_state["pp_err"] = dict(state["pp_err"])
        new_state["pp_recv"] = dict(state["pp_recv"])
    if displaced:
        # per-rank scalar inside shard_map (the lp-axis dim is dropped
        # by the caller); ones right after init_halo_wire_state
        fresh = state["fresh"].reshape(()) > 0.5
        new_state["fresh"] = jnp.zeros_like(state["fresh"])

    def send(t) -> Tuple:
        """Encode + issue one round; returns (wire, meta, slab_shape)."""
        dk = _dir_key(t)
        slab = jax.lax.dynamic_slice_in_dim(
            wpred, jnp.asarray(t.src_start)[rank], t.length, 0
        )
        valid = jnp.arange(t.length) < jnp.asarray(t.src_len)[rank]
        slab = slab * valid.reshape((t.length,) + trail).astype(slab.dtype)
        if stateful:
            wire, meta, n_send, n_err = residual_encode(
                base, slab, state["pp_send"][dk], state["pp_err"][dk]
            )
            new_state["pp_send"][dk] = n_send
            new_state["pp_err"][dk] = n_err
        else:
            wire, meta = codec.encode(slab)
        got_wire, got_meta = _ppermute_msg(
            wire, meta, axis_name, t.perm,
            shard_axis=shard_axis, shard_size=shard_size,
        )
        return got_wire, got_meta, slab.shape

    def deposit(acc, t, msg) -> jnp.ndarray:
        got_wire, got_meta, slab_shape = msg
        if stateful:
            dk = _dir_key(t)
            prev = state["pp_recv"][dk]      # this direction's stale slab
            got, n_recv = residual_decode(
                base, got_wire, got_meta, prev, slab_shape
            )
            if nan_guard:
                got = _finite_or(got, prev)
                n_recv = _finite_or(n_recv, prev)
            new_state["pp_recv"][dk] = n_recv
            if displaced:
                # blend the step-(t-1) slab; the fresh decode only feeds
                # the carry (consumed at step t+1).  First step of a run
                # is synchronous: prev is zeros there, and zeros are NOT
                # a valid boundary activation.
                got = jnp.where(fresh, got, prev)
        else:
            got = codec.decode(got_wire, got_meta, slab_shape)
            if nan_guard:
                got = _finite_or(got, None)
        dst = jnp.asarray(t.dst_start)[rank]
        cur = jax.lax.dynamic_slice_in_dim(acc, dst, t.length, 0)
        return jax.lax.dynamic_update_slice_in_dim(acc, cur + got, dst, 0)

    msgs = ([send(t) for t in spec.transfers] if eager_sends else None)
    # own window -> own core (local, never coded)
    own_off = jnp.asarray([spec.core_start[k] - spec.starts[k] for k in range(K)])
    own = jax.lax.dynamic_slice_in_dim(wpred, own_off[rank], spec.core_pad, 0)
    acc = jax.lax.dynamic_update_slice_in_dim(
        acc, own.astype(jnp.float32), 0, 0
    )
    for ti, t in enumerate(spec.transfers):
        msg = msgs[ti] if eager_sends else send(t)
        acc = deposit(acc, t, msg)
    return acc, new_state


def compressed_core_gather(
    core: jnp.ndarray,
    rank: jnp.ndarray,
    axis_name: str,
    codec: Codec,
    state: WireState,
    num_partitions: int,
    shard_axis: Optional[str] = None,
    shard_size: int = 1,
    nan_guard: bool = False,
) -> Tuple[jnp.ndarray, WireState]:
    """All-gather of the normalized core slices through the codec.

    ``core``: (core_pad, ...) f32.  Returns the decoded (K, core_pad,
    ...) stack plus updated state.  Residual codecs delta-code against
    ``ag_prev`` (the previous gathered table — identical on all ranks,
    so each rank's own row doubles as its sender reference) with an EF
    carry on the rank's own core.  ``shard_axis`` / ``shard_size``
    shard the coded payload over the tp axis (see ``_gather_msg``);
    encode/decode and all state arithmetic stay on full values, so the
    result is bit-equal to the unsharded gather.
    """
    stateful = isinstance(codec, ResidualCodec)
    base = codec.base if stateful else codec
    K = num_partitions
    if not stateful:
        wire, meta = codec.encode(core)
        wires, metas = _gather_msg(wire, meta, axis_name,
                                   shard_axis=shard_axis,
                                   shard_size=shard_size)
        out = codec.decode(wires, metas, (K,) + core.shape)
        if nan_guard:
            out = _finite_rows_or(out, None)
        return out, {}
    corrected = core - state["ag_prev"][rank] + state["ag_err"]
    wire, meta = base.encode(corrected)
    wires, metas = _gather_msg(wire, meta, axis_name,
                               shard_axis=shard_axis,
                               shard_size=shard_size)
    d_all = base.decode(wires, metas, (K,) + core.shape)
    if nan_guard:
        # a corrupted sender's delta is dropped (row -> 0): its gathered
        # core stays the stale ``ag_prev`` slab, identical on every rank
        # (replication-safe), and the sender's own EF carry keeps the
        # full corrected value for the next healthy step
        d_all = _finite_rows_or(d_all, None)
    gathered = state["ag_prev"] + d_all
    new_err = corrected - d_all[rank]
    out_state = dict(state)
    out_state["ag_prev"] = gathered
    out_state["ag_err"] = new_err
    return gathered, out_state


# ---------------------------------------------------- single-process mirror
def simulate_halo_forward(
    denoise_fn,
    z: jnp.ndarray,
    plan,
    axis: int,
    codec=None,
    state: Optional[WireState] = None,
    nan_guard: bool = False,
):
    """Single-device replay of the codec'd halo-LP forward pass.

    Bit-faithful to ``core/spmd.lp_forward_halo(..., codec=...)``: every
    rank's slab is encoded with its own per-slab scale and state slice,
    delivery follows ``halo_spec``'s exact schedule, cores are
    normalized then round-tripped through the gather codec.  Stateless
    codecs return just the latent; stateful ones return
    ``(latent, new_state)`` (global-layout state, see
    :func:`init_halo_wire_state`).  ``nan_guard`` mirrors the SPMD
    decode guard (:func:`_finite_or`) per rank, so guarded-path quality
    tests can run single-process.
    """
    from repro.core.spmd import stack_windows, window_weights

    codec = get_codec(codec)
    stateful = isinstance(codec, ResidualCodec)
    base = codec.base if stateful else codec
    spec = halo_spec(plan)
    K = plan.num_partitions
    windows = stack_windows(z, plan, axis)
    preds = jax.vmap(denoise_fn)(windows).astype(jnp.float32)
    w = jnp.asarray(window_weights(plan))
    wshape = [1] * preds.ndim
    wshape[0] = K
    wshape[axis + 1] = plan.window
    wp = jnp.moveaxis(preds * w.reshape(wshape), axis + 1, 1)  # (K, W, rest)
    wp = jnp.pad(wp, [(0, 0), (0, spec.pad)] + [(0, 0)] * (wp.ndim - 2))
    rest = wp.shape[2:]
    trail = (1,) * len(rest)
    if stateful and state is None:
        raise ValueError(f"codec {codec.name!r} needs init_halo_wire_state")

    acc_len = spec.core_pad + spec.max_transfer
    accs = []
    for k in range(K):
        a = jnp.zeros((acc_len,) + rest, jnp.float32)
        off = spec.core_start[k] - spec.starts[k]
        accs.append(a.at[: spec.core_pad].set(wp[k, off : off + spec.core_pad]))

    displaced = stateful and getattr(codec, "displaced", False)
    new_state: WireState = {}
    if stateful:
        new_state = {
            "pp_send": {d: list(jnp.split(s, K))
                        for d, s in state["pp_send"].items()},
            "pp_err": {d: list(jnp.split(s, K))
                       for d, s in state["pp_err"].items()},
            "pp_recv": {d: list(jnp.split(s, K))
                        for d, s in state["pp_recv"].items()},
        }
    if displaced:
        new_state["fresh"] = jnp.zeros_like(state["fresh"])
    for t in spec.transfers:
        dk = _dir_key(t)
        msgs = []
        for j in range(K):  # every rank encodes (state advances SPMD-like)
            slab = wp[j, t.src_start[j] : t.src_start[j] + t.length]
            valid = jnp.arange(t.length) < t.src_len[j]
            slab = slab * valid.reshape((t.length,) + trail)
            if stateful:
                wire, meta, n_send, n_err = residual_encode(
                    base, slab,
                    state["pp_send"][dk][j], state["pp_err"][dk][j],
                )
                new_state["pp_send"][dk][j] = n_send[None]
                new_state["pp_err"][dk][j] = n_err[None]
            else:
                wire, meta = codec.encode(slab)
            msgs.append((wire, meta))
        delivered = {k: msgs[j] for j, k in t.perm}
        for k in range(K):
            if k in delivered:
                wire, meta = delivered[k]
            else:  # ppermute's implicit zeros for peerless ranks
                wire = jnp.zeros_like(msgs[0][0])
                meta = tuple(jnp.zeros_like(m) for m in msgs[0][1])
            shape = (t.length,) + rest
            if stateful:
                prev = state["pp_recv"][dk][k]  # same-direction stale slab
                got, n_recv = residual_decode(base, wire, meta, prev, shape)
                if nan_guard:
                    got = _finite_or(got, prev)
                    n_recv = _finite_or(n_recv, prev)
                new_state["pp_recv"][dk][k] = n_recv[None]
                if displaced:
                    # deposit the step-(t-1) slab; the fresh decode only
                    # advances the carry (mirrors the SPMD deposit)
                    got = jnp.where(state["fresh"][k] > 0.5, got, prev)
            else:
                got = codec.decode(wire, meta, shape)
                if nan_guard:
                    got = _finite_or(got, None)
            dst = t.dst_start[k]
            accs[k] = accs[k].at[dst : dst + t.length].add(got)

    # normalize own cores (ones-padded normalizer rows, as the SPMD path)
    norm = plan.normalizer()
    cores = []
    for k in range(K):
        nc = np.ones(spec.core_pad, np.float32)
        cl = spec.core_len[k]
        nc[:cl] = norm[spec.core_start[k] : spec.core_end[k]]
        cores.append(
            accs[k][: spec.core_pad] / jnp.asarray(nc).reshape((-1,) + trail)
        )

    core_shape = (spec.core_pad,) + rest
    if stateful:
        correcteds, wires, metas = [], [], []
        for k in range(K):
            c = cores[k] - state["ag_prev"][k][k] + state["ag_err"][k]
            wire, meta = base.encode(c)
            correcteds.append(c)
            wires.append(wire)
            metas.append(meta)
        wires_st = jnp.stack(wires)
        metas_st = tuple(
            jnp.stack([m[i] for m in metas]) for i in range(len(metas[0]))
        )
        d_all = base.decode(wires_st, metas_st, (K,) + core_shape)
        if nan_guard:
            d_all = _finite_rows_or(d_all, None)
        gathered = state["ag_prev"][0] + d_all  # replicas are identical
        new_state["ag_prev"] = jnp.broadcast_to(
            gathered[None], (K,) + gathered.shape
        )
        new_state["ag_err"] = jnp.stack(
            [correcteds[k] - d_all[k] for k in range(K)]
        )
    else:
        wires, metas = [], []
        for k in range(K):
            wire, meta = codec.encode(cores[k])
            wires.append(wire)
            metas.append(meta)
        metas_st = tuple(
            jnp.stack([m[i] for m in metas]) for i in range(len(metas[0]))
        )
        gathered = codec.decode(jnp.stack(wires), metas_st, (K,) + core_shape)
        if nan_guard:
            gathered = _finite_rows_or(gathered, None)

    out = jnp.zeros((plan.extent,) + rest, jnp.float32)
    for j in range(K):
        out = out.at[spec.core_start[j] : spec.core_end[j]].set(
            gathered[j, : spec.core_len[j]]
        )
    out = jnp.moveaxis(out, 0, axis).astype(z.dtype)
    if not stateful:
        return out
    for key in ("pp_send", "pp_err", "pp_recv"):
        new_state[key] = {
            d: jnp.concatenate(s) for d, s in new_state[key].items()
        }
    return out, new_state
