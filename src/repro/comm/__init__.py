"""Wire-codec subsystem: pluggable (lossy) compression for LP collectives.

The paper's thesis is that LP wins by shrinking wire bytes; the halo
engine (PR 1) already reduced reconstruction to overlap-slab ppermutes +
a core all-gather, so the remaining bytes on the wire ARE those payloads.
This package multiplies that win by compressing them:

  * ``codecs``   — the :class:`Codec` protocol and the stock codecs
                   (identity/fp32, bf16, int8, int4 — per-slab-scaled).
  * ``residual`` — temporal-delta coding with error feedback: send only
                   the quantized *residual* vs the previous timestep's
                   decoded slab (halo slabs change slowly across the
                   fused ``lax.scan`` steps of one rotation dim).
  * ``wire``     — ``compressed_halo_exchange`` / ``compressed_core_gather``
                   (the SPMD collectives) and ``simulate_halo_forward``
                   (a bit-faithful single-process mirror used by quality
                   benchmarks and by the serving engine off-mesh).

Byte accounting lives in ``core/comm_model.comm_lp_halo_codec`` and is
cross-checked against ``analysis/hlo_analyzer`` on compiled HLO.
"""
from .codecs import (  # noqa: F401
    Bf16Codec,
    Codec,
    CODEC_NAMES,
    IdentityCodec,
    IntCodec,
    get_codec,
)
from .residual import (  # noqa: F401
    ResidualCodec,
    ef_roundtrip,
    residual_decode,
    residual_encode,
)
from .wire import (  # noqa: F401
    compressed_core_gather,
    compressed_halo_exchange,
    init_halo_wire_state,
    simulate_halo_forward,
)
