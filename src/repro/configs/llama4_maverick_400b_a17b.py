"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, early fusion
(hf:meta-llama/Llama-4-Scout-17B-16E).  Spec implemented verbatim; note
48L x 128e x d_ff 8192 gives ~776B total params (DESIGN.md §Spec notes)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=0,
    d_ff_expert=8192,
    vocab_size=202048,
    head_dim=128,
    num_experts=128,
    experts_top_k=1,
)
