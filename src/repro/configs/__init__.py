"""Architecture + shape registry.

``get_config(arch_id)`` returns the exact assigned config;
``cells()`` enumerates every (arch x shape) dry-run cell with its skip
status (DESIGN.md §Arch-applicability skip matrix).
"""
from __future__ import annotations

import importlib
from typing import Dict, Iterator, Optional, Tuple

from .base import LM_SHAPES, VDM_SHAPES, ArchConfig, ParallelConfig, ShapeConfig

_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "xlstm-1.3b": "xlstm_1p3b",
    "granite-3-2b": "granite_3_2b",
    "llama3-405b": "llama3_405b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "minitron-4b": "minitron_4b",
    "internvl2-26b": "internvl2_26b",
    "whisper-small": "whisper_small",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "wan21-dit-1.3b": "wan21_dit_1p3b",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "wan21-dit-1.3b")
ALL_ARCHS = tuple(_MODULES)

# archs with sub-quadratic attention paths — the only ones long_500k runs on
SUBQUADRATIC = ("zamba2-2.7b", "xlstm-1.3b", "h2o-danube-1.8b")


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name in LM_SHAPES:
        return LM_SHAPES[name]
    if name in VDM_SHAPES:
        return VDM_SHAPES[name]
    raise KeyError(f"unknown shape {name!r}")


def skip_reason(arch: str, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else why it is skipped."""
    cfg = get_config(arch)
    if cfg.family == "vdm":
        return None if shape in VDM_SHAPES else "vdm arch uses vdm shapes"
    if shape in VDM_SHAPES:
        return "LM arch does not take vdm shapes"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "full attention is quadratic at 500k (assignment skip rule)"
    return None


def cells(include_vdm: bool = True) -> Iterator[Tuple[str, str, Optional[str]]]:
    """All (arch, shape, skip_reason) dry-run cells."""
    for arch in ASSIGNED_ARCHS:
        for shape in LM_SHAPES:
            yield arch, shape, skip_reason(arch, shape)
    if include_vdm:
        for shape in VDM_SHAPES:
            yield "wan21-dit-1.3b", shape, None
