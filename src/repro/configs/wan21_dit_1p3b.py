"""wan21-dit-1.3b [vdm]: the paper's own model (WAN2.1-1.3B, arXiv:2503.20314):
30 DiT blocks, d 1536, 12 heads, ffn 8960, patchify (1,2,2), latent C=16,
VAE stride (4,8,8), T5 text context (stubbed as precomputed embeddings)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="wan21-dit-1.3b",
    family="vdm",
    num_layers=30,
    d_model=1536,
    num_heads=12,
    num_kv_heads=12,
    d_ff=8960,
    vocab_size=0,
    head_dim=128,
    latent_channels=16,
    patch_sizes=(1, 2, 2),
    context_len=512,
    context_dim=4096,      # umT5-xxl width
    time_embed_dim=1536,
)
