"""granite-moe-3b-a800m [moe]: 40 experts top-8, d_ff 512/expert
(hf:ibm-granite/granite-3.0-1b-a400m-base).  The assignment's structured
field says 40 experts (trailing comment says 32); we implement 40, padded
to 48 for 16-way expert parallelism (see DESIGN.md)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=0,
    d_ff_expert=512,
    vocab_size=49155,
    num_experts=40,
    experts_top_k=8,
)
