"""zamba2-2.7b [hybrid]: 54 Mamba2 blocks + shared attention block invoked
every 6 blocks through per-invocation LoRA (arXiv:2411.15242; hf)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,       # MHA in the shared block
    d_ff=10240,            # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    attn_every=6,          # 9 shared-attn invocations
    lora_rank=128,
)
