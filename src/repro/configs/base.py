"""Config system: architecture, input-shape, and parallelism configs.

Every assigned architecture gets one ``configs/<id>.py`` exporting
``CONFIG`` (the exact assigned numbers) — the full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).  ``reduced()``
derives a small same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture.  Unused family fields stay at their defaults."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | vdm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention
    attn_type: str = "full"          # full | swa
    window: int = 4096               # SWA window
    rope_theta: float = 10_000.0

    # mixture of experts
    num_experts: int = 0
    experts_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (zamba2): one shared attention block every `attn_every` SSM
    # blocks, adapted per-invocation with LoRA of rank `lora_rank`.
    attn_every: int = 0
    lora_rank: int = 0

    # xLSTM: every `slstm_every`-th block is an sLSTM (rest mLSTM)
    slstm_every: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30 s of audio frames

    # VLM frontend stub
    num_vision_tokens: int = 0

    # VDM / DiT
    latent_channels: int = 0
    patch_sizes: Tuple[int, int, int] = (1, 2, 2)
    context_len: int = 512           # encoded text prompt length
    context_dim: int = 0             # cross-attention context width
    time_embed_dim: int = 256

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(
                f"{self.name}: num_heads {self.num_heads} not divisible by "
                f"kv heads {self.num_kv_heads}"
            )

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so embedding / logits
        tables shard evenly over a 16-way tensor-parallel axis (padded
        logit columns are masked to -inf in ``logits_fn``)."""
        return -(-self.vocab_size // 256) * 256

    def reduced(self) -> "ArchConfig":
        """Same-family config small enough for a CPU smoke test."""
        changes = dict(
            # CPU smoke tests execute in f32 (the CPU backend lacks some
            # bf16 DotThunk fusions); full configs stay bf16 — the dry-run
            # only lowers+compiles them, never executes.
            dtype="float32",
            num_layers=min(self.num_layers, 4 if self.attn_every else 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 16),
            context_len=min(self.context_len, 16),
        )
        if self.is_moe:
            changes.update(
                num_experts=min(self.num_experts, 8),
                experts_top_k=min(self.experts_top_k, 2),
                d_ff_expert=64,
            )
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_headdim=16)
        if self.attn_every:
            changes.update(attn_every=2, lora_rank=4, num_layers=4)
        if self.slstm_every:
            changes.update(slstm_every=2, num_layers=4, num_heads=2,
                           num_kv_heads=2, head_dim=64)
        if self.is_encoder_decoder:
            changes.update(encoder_layers=2, encoder_seq=32)
        if self.num_vision_tokens:
            changes.update(num_vision_tokens=8)
        if self.family == "vdm":
            changes.update(
                latent_channels=4,
                context_dim=128,
                time_embed_dim=32,
                num_layers=2,
            )
        return dataclasses.replace(self, name=self.name + "-reduced", **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what gets lowered in the dry-run."""

    name: str
    kind: str          # train | prefill | decode | vdm_generate
    seq_len: int = 0
    global_batch: int = 0
    # VDM shapes
    num_frames: int = 0
    height: int = 480
    width: int = 832
    num_steps: int = 60

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes (identical across all 10 LM archs).
LM_SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeConfig("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeConfig("long_500k", "decode", seq_len=524288, global_batch=1),
}

# The paper's own workload shapes (WAN2.1 @ 480p).
VDM_SHAPES = {
    "vdm_3s": ShapeConfig("vdm_3s", "vdm_generate", num_frames=49, global_batch=1),
    "vdm_5s": ShapeConfig("vdm_5s", "vdm_generate", num_frames=81, global_batch=1),
    "vdm_10s": ShapeConfig("vdm_10s", "vdm_generate", num_frames=161, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How to map a workload onto the mesh."""

    dp_axes: Tuple[str, ...] = ("pod", "data")   # batch / LP-group axes
    tp_axis: str = "model"                       # tensor-parallel axis
    fsdp_axis: Optional[str] = None              # ZeRO-3 param sharding
    lp_axis: str = "data"                        # latent-parallel axis (VDM)
    cfg_axis: Optional[str] = None               # CFG cond/uncond axis (VDM)
    seq_axis: Optional[str] = None               # long-context cache sharding
    remat: str = "none"                          # none | full | dots
    microbatch: int = 1                          # gradient-accumulation steps
    optimizer: str = "adamw"                     # adamw | adafactor
    overlap_ratio: float = 0.5                   # LP overlap r
