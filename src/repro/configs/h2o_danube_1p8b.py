"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention
(arXiv:2401.16818).  SWA => sub-quadratic => long_500k runs."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attn_type="swa",
    window=4096,
)
