"""internvl2-26b [vlm]: InternLM2-20B backbone; InternViT frontend is a
stub providing precomputed patch embeddings (arXiv:2404.16821)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    num_vision_tokens=256,
)
