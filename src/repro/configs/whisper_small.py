"""whisper-small [audio]: enc-dec; conv frontend is a stub providing
precomputed frame embeddings (arXiv:2212.04356)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,          # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=12,
    encoder_seq=1500,
)
