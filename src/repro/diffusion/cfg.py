"""Classifier-free guidance (paper Eq. 2/4)."""
from __future__ import annotations

import jax.numpy as jnp


def cfg_combine(cond: jnp.ndarray, uncond: jnp.ndarray, w: float) -> jnp.ndarray:
    """f~ = f_uncond + w (f_cond - f_uncond)."""
    return (uncond.astype(jnp.float32)
            + w * (cond.astype(jnp.float32) - uncond.astype(jnp.float32))
            ).astype(cond.dtype)


def cfg_batched(denoise_fn, w: float):
    """Wrap a denoiser so one call computes both CFG passes as a stacked
    leading dim of 2 — the paper's on-device CFG batching (Table 1
    accounting), and the form that maps onto a mesh axis of size 2."""

    def wrapped(z, t, ctx_pair):
        import jax.numpy as jnp

        z2 = jnp.stack([z, z])
        pred = denoise_fn(z2, jnp.stack([t, t]), ctx_pair)  # (2, ...)
        return cfg_combine(pred[0], pred[1], w)

    return wrapped
