from .sampler import DDIM, FlowMatchEuler  # noqa: F401
from .cfg import cfg_combine  # noqa: F401
from .pipeline import (  # noqa: F401
    generate_centralized,
    generate_lp,
    make_guided_denoiser,
    make_guided_step_denoiser,
)
