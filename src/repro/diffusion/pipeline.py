"""End-to-end denoising pipelines: centralized and Latent-Parallel.

``generate_centralized`` is the single-device reference (paper's
"Centralized" row); ``generate_lp`` runs the paper's full workflow
(rotating partition -> parallel denoise -> position-aware reconstruction)
via the reference or uniform engines.  Quality benchmarks diff the two.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import lp_denoise
from repro.diffusion.cfg import cfg_combine
from repro.diffusion.sampler import FlowMatchEuler


def make_guided_denoiser(dit_forward, params, cfg_model, context, null_context,
                         guidance: float = 5.0):
    """Returns f~(z, t) with CFG batched on-device (cond+uncond stacked)."""

    def guided(z, t):
        b = z.shape[0]
        z2 = jnp.concatenate([z, z], axis=0)
        t2 = jnp.concatenate([t, t], axis=0)
        ctx = jnp.concatenate([context, null_context], axis=0)
        pred = dit_forward(params, z2, t2, ctx, cfg_model)
        return cfg_combine(pred[:b], pred[b:], guidance)

    return guided


def generate_centralized(
    guided_denoiser: Callable,
    z_T: jnp.ndarray,
    num_steps: int,
    sampler: Optional[FlowMatchEuler] = None,
) -> jnp.ndarray:
    sampler = sampler or FlowMatchEuler(num_steps)
    z = z_T
    for i in range(1, num_steps + 1):
        t = jnp.full((z.shape[0],), sampler.timestep(i), jnp.float32)
        pred = guided_denoiser(z, t)
        z = sampler.step(z, pred, i)
    return z


def generate_lp(
    guided_denoiser: Callable,
    z_T: jnp.ndarray,
    num_steps: int,
    num_partitions: int,
    overlap_ratio: float,
    patch_sizes: Sequence[int],
    sampler: Optional[FlowMatchEuler] = None,
    spatial_axes: Sequence[int] = (1, 2, 3),   # (B, T, H, W, C) layout
    uniform: bool = False,
) -> jnp.ndarray:
    """Latent-Parallel generation (paper Fig. 3 full loop)."""
    sampler = sampler or FlowMatchEuler(num_steps)

    def denoise_for_step(i, dim):
        t_val = sampler.timestep(i)

        def fn(sub):
            t = jnp.full((sub.shape[0],), t_val, jnp.float32)
            return guided_denoiser(sub, t)

        return fn

    def sched_update(z, pred, i):
        return sampler.step(z, pred, i)

    return lp_denoise(
        denoise_for_step,
        z_T,
        sched_update,
        num_steps,
        num_partitions,
        overlap_ratio,
        patch_sizes,
        spatial_axes,
        uniform=uniform,
    )
