"""End-to-end denoising pipelines: centralized and Latent-Parallel.

``generate_centralized`` is the single-device reference (paper's
"Centralized" row); ``generate_lp`` runs the paper's full workflow
(rotating partition -> parallel denoise -> position-aware reconstruction).
By default it rides the compiled fast path (``core/lp_step.lp_denoise``):
timestep and scheduler coefficients are traced arguments, so a T-step run
compiles at most once per rotation dim; ``compiled=False`` falls back to
the eager reference loop.  Quality benchmarks diff the two against the
centralized output.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import LPStepCompiler, lp_denoise, lp_denoise_reference
from repro.diffusion.cfg import cfg_combine
from repro.diffusion.sampler import FlowMatchEuler


def make_guided_denoiser(dit_forward, params, cfg_model, context, null_context,
                         guidance: float = 5.0):
    """Returns f~(z, t) with CFG batched on-device (cond+uncond stacked)."""

    def guided(z, t):
        b = z.shape[0]
        z2 = jnp.concatenate([z, z], axis=0)
        t2 = jnp.concatenate([t, t], axis=0)
        ctx = jnp.concatenate([context, null_context], axis=0)
        pred = dit_forward(params, z2, t2, ctx, cfg_model)
        return cfg_combine(pred[:b], pred[b:], guidance)

    return guided


def make_guided_step_denoiser(dit_forward, params, cfg_model,
                              guidance_default: float = 5.0):
    """Fully-traced guided denoiser for the compiled LP step cache.

    Unlike :func:`make_guided_denoiser`, the conditioning is NOT closed
    over: ``(window, t, context, null_context, guidance)`` are all traced
    arguments, so one compiled step serves every batch of the same
    geometry — the serving engine builds this once per engine, not once
    per batch.  ``t`` is a traced f32 scalar (the LP step protocol).
    """

    def guided(window, t, context, null_context, guidance=None):
        g = guidance_default if guidance is None else guidance
        b = window.shape[0]
        z2 = jnp.concatenate([window, window], axis=0)
        t2 = jnp.full((2 * b,), t, jnp.float32)
        ctx = jnp.concatenate([context, null_context], axis=0)
        pred = dit_forward(params, z2, t2, ctx, cfg_model)
        return cfg_combine(pred[:b], pred[b:], g)

    return guided


def generate_centralized(
    guided_denoiser: Callable,
    z_T: jnp.ndarray,
    num_steps: int,
    sampler: Optional[FlowMatchEuler] = None,
) -> jnp.ndarray:
    sampler = sampler or FlowMatchEuler(num_steps)
    z = z_T
    for i in range(1, num_steps + 1):
        t = jnp.full((z.shape[0],), sampler.timestep(i), jnp.float32)
        pred = guided_denoiser(z, t)
        z = sampler.step(z, pred, i)
    return z


def generate_lp(
    guided_denoiser: Callable,
    z_T: jnp.ndarray,
    num_steps: int,
    num_partitions: int,
    overlap_ratio: float,
    patch_sizes: Sequence[int],
    sampler: Optional[FlowMatchEuler] = None,
    spatial_axes: Sequence[int] = (1, 2, 3),   # (B, T, H, W, C) layout
    uniform: bool = False,
    compiled: bool = True,
    compiler: Optional[LPStepCompiler] = None,
) -> jnp.ndarray:
    """Latent-Parallel generation (paper Fig. 3 full loop).

    ``guided_denoiser(z, t)`` takes a per-sample timestep vector; the
    compiled path adapts it to the traced-scalar step protocol.  Pass
    ``compiler`` to share the compiled-step cache across calls.
    """
    sampler = sampler or FlowMatchEuler(num_steps)

    if not compiled:
        def denoise_for_step(i, dim):
            t_val = sampler.timestep(i)

            def fn(sub):
                t = jnp.full((sub.shape[0],), t_val, jnp.float32)
                return guided_denoiser(sub, t)

            return fn

        return lp_denoise_reference(
            denoise_for_step, z_T, lambda z, pred, i: sampler.step(z, pred, i),
            num_steps, num_partitions, overlap_ratio, patch_sizes,
            spatial_axes, uniform=uniform,
        )

    def den(window, t):
        tv = jnp.full((window.shape[0],), t, jnp.float32)
        return guided_denoiser(window, tv)

    return lp_denoise(
        den, z_T, sampler, num_steps, num_partitions, overlap_ratio,
        patch_sizes, spatial_axes, uniform=uniform, compiler=compiler,
    )
