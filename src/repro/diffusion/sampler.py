"""Sampling schedulers S(.) (paper Eq. 1/6).

WAN2.1 is a flow-matching model (velocity prediction, Euler integration);
a DDIM eps-parameterization is provided for completeness.  Schedulers are
pure: z_{t-1} = S(z_t, pred, i).

Two call forms per scheduler:

* ``step(z, pred, i)`` — step index static, coefficients baked in as
  Python floats (the eager reference loop).
* ``step_scalars(i)`` + ``update(z, pred, scalars)`` — coefficients as a
  pytree of numpy scalars fed to the compiled LP step as **traced
  arguments**, so one jitted step (or a lax.scan over stacked scalars)
  serves every timestep without retracing (``core/lp_step.LPStepCompiler``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FlowMatchEuler:
    """sigma_i linearly spaced 1 -> 0 over num_steps (shifted optional)."""

    num_steps: int
    shift: float = 3.0  # WAN uses a shifted schedule

    def sigmas(self) -> np.ndarray:
        s = np.linspace(1.0, 0.0, self.num_steps + 1)
        if self.shift != 1.0:
            s = self.shift * s / (1 + (self.shift - 1) * s)
        return s.astype(np.float32)

    def timestep(self, i: int) -> float:
        """Model conditioning timestep for forward pass i (1-indexed)."""
        return float(self.sigmas()[i - 1] * 1000.0)

    def step(self, z: jnp.ndarray, velocity: jnp.ndarray, i: int) -> jnp.ndarray:
        s = self.sigmas()
        dt = float(s[i] - s[i - 1])  # negative
        return z + dt * velocity.astype(z.dtype)

    def step_scalars(self, i: int) -> np.float32:
        s = self.sigmas()
        return np.float32(s[i] - s[i - 1])

    def update(self, z: jnp.ndarray, velocity: jnp.ndarray, dt) -> jnp.ndarray:
        """Euler step with ``dt`` traced (f32 math, cast back to z.dtype)."""
        return (
            z.astype(jnp.float32) + dt * velocity.astype(jnp.float32)
        ).astype(z.dtype)


@dataclasses.dataclass(frozen=True)
class DDIM:
    """Deterministic DDIM over a linear-beta DDPM schedule, eps-pred."""

    num_steps: int
    beta_start: float = 8.5e-4
    beta_end: float = 1.2e-2
    train_steps: int = 1000

    def _alphas(self) -> np.ndarray:
        betas = np.linspace(self.beta_start, self.beta_end, self.train_steps)
        return np.cumprod(1.0 - betas).astype(np.float32)

    def _schedule(self) -> np.ndarray:
        return np.linspace(self.train_steps - 1, 0, self.num_steps).astype(int)

    def timestep(self, i: int) -> float:
        return float(self._schedule()[i - 1])

    def step(self, z: jnp.ndarray, eps: jnp.ndarray, i: int) -> jnp.ndarray:
        sched = self._schedule()
        ab = self._alphas()
        t = sched[i - 1]
        t_next = sched[i] if i < self.num_steps else -1
        a_t = float(ab[t])
        a_next = float(ab[t_next]) if t_next >= 0 else 1.0
        eps = eps.astype(jnp.float32)
        zf = z.astype(jnp.float32)
        x0 = (zf - np.sqrt(1 - a_t) * eps) / np.sqrt(a_t)
        out = np.sqrt(a_next) * x0 + np.sqrt(1 - a_next) * eps
        return out.astype(z.dtype)

    def step_scalars(self, i: int) -> Tuple[np.float32, np.float32]:
        sched = self._schedule()
        ab = self._alphas()
        t = sched[i - 1]
        t_next = sched[i] if i < self.num_steps else -1
        a_next = float(ab[t_next]) if t_next >= 0 else 1.0
        return (np.float32(ab[t]), np.float32(a_next))

    def update(self, z: jnp.ndarray, eps: jnp.ndarray, scalars) -> jnp.ndarray:
        a_t, a_next = scalars
        eps = eps.astype(jnp.float32)
        zf = z.astype(jnp.float32)
        x0 = (zf - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
        out = jnp.sqrt(a_next) * x0 + jnp.sqrt(1 - a_next) * eps
        return out.astype(z.dtype)
