"""Fault-tolerant data-parallel replica router for the LP serving path.

One :class:`ReplicaRouter` fronts N independent :class:`LPServingEngine`
replicas (each its own mesh / compiled-step cache / ``VirtualClock``)
with one front-door queue, and owns the robustness stack the single
engine cannot: a replica dying MID-BATCH must not lose requests, an
overload burst must shed low-priority work loudly, and sustained
pressure must cost quality (cheaper codec schedules) before it costs
high-priority deadlines.

Simulation model — the router generalizes ``loadgen.run_workload``'s
open-loop replay to N replicas as a discrete-event loop on virtual
time: every replica carries its own ``VirtualClock`` (the engine
advances it by each batch's *measured* wall), the router carries the
global ``now`` and only ever moves it forward to the next event (an
arrival, a replica coming free, a retry backoff expiring).  Dispatching
synchronizes the chosen replica's clock to ``now`` before handing it a
batch, so every lifecycle stamp — across all replicas — lives on one
coherent virtual timeline and the per-replica SLO report is exact.

The robustness stack, piece by piece:

* **Health states** (``healthy / degraded / draining / dead``): a
  router-level :class:`~repro.runtime.health.GroupHealthMonitor` treats
  replicas as groups — every dispatch outcome is a heartbeat round
  (batch wall on success, a miss on failure), so a replica that stops
  completing work burns its miss budget and is DRAINED (no new
  dispatches) even if it never raised; engine signals act immediately
  (``ReplicaDeath`` -> dead, a terminal engine fault -> degraded, then
  draining past ``dead_after_failures``; a clean batch after restarts
  recovers degraded -> healthy, with ``health.mark_recovered``).
* **Admission control / backpressure**: engine queues are bounded
  (``max_queue``, ``QueueFull``) and the router holds all waiting work
  in its front-door queue (a dispatch hands an engine at most one
  batch, so engine bounds never trip in routed operation).  When the
  aggregate depth crosses ``shed_watermark``, the LOWEST-priority
  (largest class deadline), newest-arrival requests are shed — each
  with an explicit ``request.shed`` trace row
  (``FlightRecorder.record_shed``), never silently.
* **Retries / redispatch**: a batch lost to a replica death (or a
  terminal engine fault) is requeued with each request's ORIGINAL
  ``submit_s`` preserved — queue-wait accounting stays honest across
  replicas — behind a capped exponential backoff
  (``backoff_base_s * 2^(attempt-1)``, capped at ``backoff_cap_s``),
  up to ``max_redispatch`` attempts before a terminal
  ``request.failed`` row with ``terminal=True``
  (``FlightRecorder.record_failed``).  Dispatch order is
  deadline-aware: the queued request with the earliest absolute
  deadline (``submit_s`` + its SLO class deadline) goes first, and its
  geometry bucket rides along.
* **Graceful degradation**: when the queue sits above
  ``degrade_watermark`` for ``degrade_patience_s`` of virtual time, the
  router relaxes every class's ``psnr_floor`` by ``degrade_step_db``
  (never below ``min_psnr_floor_db``, the int4 conformance envelope) —
  outgoing requests carry the relaxed floor and every live engine with
  an autotuned schedule re-resolves toward cheaper codecs
  (``LPServingEngine.set_psnr_floor``).  Floors restore stepwise on
  recovery.  Both directions are recorded (``router.degrade`` /
  ``router.restore`` instants, ``router.degrade_steps`` /
  ``router.restore_steps`` counters).

Fault drills: the ``replica:<id>:`` grammar
(``runtime/faults.ServingFaultPlan``) scopes chunks to one replica —
``replica:1:dead@3`` kills replica 1 whole at denoise step 3
(:class:`~repro.runtime.faults.ReplicaDeath` propagates out of
``engine.run``; a dead replica cannot retry itself), and
``replica:0:slow:2x3`` runs the ordinary engine-level drill on replica
0 only.  The router splits the plan with
``ServingFaultPlan.for_replica`` at construction; a bare engine refuses
replica-scoped plans.

Everything here is host-side control flow: no jit, no new compiles
(the 0-extra-compiles observability invariant holds), and a fixed
workload seed + fault plan replays byte-identically.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import metrics as obsm
from repro.runtime.faults import ReplicaDeath, ServingFault, \
    parse_fault_plan
from repro.runtime.ft import DeviceFailure
from repro.runtime.health import GroupHealthMonitor

from .engine import LPServingEngine, QueueFull, VideoRequest, VideoResult
from .loadgen import Arrival, VirtualClock, _default_make_context

REPLICA_STATES = ("healthy", "degraded", "draining", "dead")
ROUTER_POLICIES = ("least-loaded", "round-robin")


@dataclasses.dataclass
class _Pending:
    """One front-door queue entry: the request plus its routing state.

    ``submit_s`` is the ORIGINAL arrival stamp and never changes — a
    redispatched request's queue wait keeps accruing from its first
    arrival, not from the retry."""

    request: VideoRequest
    submit_s: float
    deadline_s: float          # absolute: submit_s + class deadline
    class_deadline_s: float    # relative class deadline (shed ranking)
    redispatches: int = 0
    not_before_s: float = 0.0  # retry backoff gate


@dataclasses.dataclass
class _Replica:
    idx: int
    engine: LPServingEngine
    clock: VirtualClock
    state: str = "healthy"
    free_s: float = 0.0        # virtual time the replica is free at
    failures: int = 0          # consecutive terminal engine faults
    last_wall: Optional[float] = None
    dispatches: int = 0

    @property
    def live(self) -> bool:
        return self.state in ("healthy", "degraded")


class ReplicaRouter:
    """Dispatch :class:`VideoRequest` s across N engine replicas."""

    def __init__(
        self,
        engines: Sequence[LPServingEngine],
        *,
        recorder=None,
        slo=None,
        policy: str = "least-loaded",
        max_redispatch: int = 2,
        shed_watermark: Optional[int] = None,
        degrade_watermark: Optional[int] = None,
        degrade_patience_s: float = 0.0,
        restore_patience_s: float = 0.0,
        degrade_step_db: float = 2.0,
        min_psnr_floor_db: float = 24.0,
        backoff_base_s: float = 0.25,
        backoff_cap_s: float = 4.0,
        dead_after_failures: int = 2,
        inject_fault=None,
        health: Optional[GroupHealthMonitor] = None,
    ):
        if not engines:
            raise ValueError("need at least one engine replica")
        if policy not in ROUTER_POLICIES:
            raise ValueError(
                f"router policy must be one of {ROUTER_POLICIES}, "
                f"got {policy!r}")
        clocks = []
        for r, eng in enumerate(engines):
            if not isinstance(eng.clock, VirtualClock):
                raise ValueError(
                    f"replica {r}: engine must be constructed with its "
                    "own VirtualClock (LPServingEngine(clock=...)) — "
                    "the router coordinates per-replica virtual time")
            clocks.append(eng.clock)
        if len({id(c) for c in clocks}) != len(clocks):
            raise ValueError(
                "engine replicas must not share a VirtualClock: each "
                "replica's clock advances by ITS batch walls; sharing "
                "one would serialize concurrent replicas")
        self.policy = policy
        self.recorder = recorder if recorder is not None \
            else engines[0].recorder
        from repro.obs.slo import SLOSpec
        self.slo = SLOSpec.parse(slo if slo is not None
                                 else engines[0].slo)
        self.max_redispatch = int(max_redispatch)
        total_batch = sum(e.max_batch for e in engines)
        self.shed_watermark = (8 * total_batch if shed_watermark is None
                               else int(shed_watermark))
        self.degrade_watermark = (
            max(total_batch, self.shed_watermark // 2)
            if degrade_watermark is None else int(degrade_watermark))
        self.degrade_patience_s = float(degrade_patience_s)
        self.restore_patience_s = float(restore_patience_s)
        self.degrade_step_db = float(degrade_step_db)
        self.min_psnr_floor_db = float(min_psnr_floor_db)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.dead_after_failures = int(dead_after_failures)

        self.replicas: List[_Replica] = []
        for r, eng in enumerate(engines):
            eng.replica_id = r
            self.replicas.append(_Replica(idx=r, engine=eng,
                                          clock=eng.clock))
        # split the fault plan per replica: scoped chunks become each
        # engine's ordinary plan, replica:R:dead@S becomes its die-step
        plan = parse_fault_plan(inject_fault)
        if plan is not None:
            if plan.dead or plan.slow or plan.corrupt or \
                    plan.die_step is not None:
                raise ValueError(
                    f"router fault plan {plan.describe()!r} has "
                    "unscoped chunks — scope every target with "
                    "replica:<id>: so the drill names which replica "
                    "it hits")
            bad = [r for r in plan.replicas_targeted()
                   if not 0 <= r < len(engines)]
            if bad:
                raise ValueError(
                    f"fault plan targets replica(s) {bad}, but only "
                    f"{len(engines)} replicas exist")
            for rep in self.replicas:
                sub = plan.for_replica(rep.idx)
                if sub is not None:
                    rep.engine._fault_plan = sub
        self.fault_plan = plan
        # replica heartbeats: every dispatch outcome is one round; a
        # replica that stops completing batches misses its deadline
        # budget and is drained even without an engine-level signal
        self.health = health if health is not None else \
            GroupHealthMonitor(
                len(engines),
                metrics=None if self.recorder is None
                else self.recorder.metrics)

        self._queue: List[_Pending] = []
        self._rr = 0                       # round-robin cursor
        self.now = 0.0
        self.results: List[VideoResult] = []
        self.degrade_level = 0
        self._overload_since: Optional[float] = None
        self._underload_since: Optional[float] = None
        # base autotuner floors per replica (None = engine has no
        # autotuned schedule; set_psnr_floor no-ops there)
        self._base_floor: Dict[int, Optional[float]] = {
            rep.idx: rep.engine.psnr_floor for rep in self.replicas}
        self.stats = {"admitted": 0, "completed": 0, "shed": 0,
                      "failed": 0, "redispatches": 0,
                      "replica_deaths": 0}
        self._gauge_health()

    # ------------------------------------------------------------ helpers
    def _instant(self, name: str, **args) -> None:
        if self.recorder is not None:
            self.recorder.instant(name, cat="router", **args)

    def _inc(self, name: str, value: float = 1.0, **labels) -> None:
        if self.recorder is not None:
            self.recorder.inc(name, value, **labels)

    def _gauge(self, name: str, value: float, **labels) -> None:
        if self.recorder is not None:
            self.recorder.gauge(name, value, **labels)

    def _gauge_health(self) -> None:
        self._gauge(obsm.ROUTER_HEALTHY_REPLICAS,
                    sum(1 for r in self.replicas
                        if r.state == "healthy"))

    def _set_state(self, rep: _Replica, state: str, reason: str) -> None:
        if state not in REPLICA_STATES:
            raise ValueError(f"unknown replica state {state!r}")
        if state == rep.state:
            return
        prev, rep.state = rep.state, state
        self._instant("router.replica_state", replica=rep.idx,
                      prev=prev, state=state, reason=reason,
                      now_s=self.now)
        self._gauge_health()

    def live_replicas(self) -> List[_Replica]:
        return [r for r in self.replicas if r.live]

    def queue_depth(self) -> int:
        return len(self._queue)

    # ---------------------------------------------------------- admission
    def submit(self, request: VideoRequest,
               submit_s: Optional[float] = None) -> None:
        """Admit one request to the front-door queue at ``submit_s``
        (default: the router's current virtual ``now``)."""
        s = self.now if submit_s is None else float(submit_s)
        cls_deadline = self.slo.deadline_for(str(request.priority))
        self._queue.append(_Pending(
            request=request, submit_s=s,
            deadline_s=s + cls_deadline,
            class_deadline_s=cls_deadline))
        self.stats["admitted"] += 1
        self._gauge(obsm.ROUTER_QUEUE_DEPTH, len(self._queue))

    def _shed_overflow(self) -> None:
        """Enforce the aggregate watermark: shed lowest-priority
        (largest class deadline), newest-arrival first — loudly."""
        while len(self._queue) > self.shed_watermark:
            victim = max(
                self._queue,
                key=lambda p: (p.class_deadline_s, p.submit_s,
                               p.request.request_id))
            self._queue.remove(victim)
            self.stats["shed"] += 1
            row = {
                "request_id": victim.request.request_id,
                "priority": str(victim.request.priority),
                "submit_s": victim.submit_s,
                "shed_s": self.now,
                "reason": "watermark",
                "queue_depth": len(self._queue) + 1,
                "watermark": self.shed_watermark,
            }
            if self.recorder is not None:
                self.recorder.record_shed(row)
            self._gauge(obsm.ROUTER_QUEUE_DEPTH, len(self._queue))

    def _fail_terminal(self, p: _Pending, reason: str) -> None:
        self.stats["failed"] += 1
        row = {
            "request_id": p.request.request_id,
            "priority": str(p.request.priority),
            "submit_s": p.submit_s,
            "failed_s": self.now,
            "redispatches": p.redispatches,
            "reason": reason,
            "terminal": True,
        }
        if self.recorder is not None:
            self.recorder.record_failed(row)

    # ------------------------------------------------------- degradation
    def _effective_floor(self, floor: Optional[float]) -> Optional[float]:
        if floor is None or self.degrade_level == 0:
            return floor
        return max(self.min_psnr_floor_db,
                   floor - self.degrade_level * self.degrade_step_db)

    def _apply_floors(self) -> None:
        for rep in self.replicas:
            if not rep.live:
                continue
            base = self._base_floor[rep.idx]
            if base is not None:
                rep.engine.set_psnr_floor(self._effective_floor(base))

    def _check_degradation(self) -> None:
        """Sustained overload costs quality before it costs deadlines;
        floors restore stepwise once the queue drains."""
        depth = len(self._queue)
        if depth > self.degrade_watermark:
            self._underload_since = None
            if self._overload_since is None:
                self._overload_since = self.now
            if self.now - self._overload_since >= self.degrade_patience_s:
                if self._can_degrade_further():
                    self.degrade_level += 1
                    self._overload_since = self.now  # re-arm patience
                    self._apply_floors()
                    self._instant(
                        "router.degrade", level=self.degrade_level,
                        queue_depth=depth,
                        step_db=self.degrade_step_db,
                        min_floor_db=self.min_psnr_floor_db,
                        now_s=self.now)
                    self._inc(obsm.ROUTER_DEGRADE_STEPS)
        elif depth <= self.degrade_watermark // 2:
            self._overload_since = None
            if self.degrade_level > 0:
                if self._underload_since is None:
                    self._underload_since = self.now
                if self.now - self._underload_since >= \
                        self.restore_patience_s:
                    self.degrade_level -= 1
                    self._underload_since = self.now
                    self._apply_floors()
                    self._instant(
                        "router.restore", level=self.degrade_level,
                        queue_depth=depth, now_s=self.now)
                    self._inc(obsm.ROUTER_RESTORE_STEPS)
        else:
            self._overload_since = None
            self._underload_since = None

    def _can_degrade_further(self) -> bool:
        """At least one class/engine floor is still above the envelope
        minimum — degrading past that would change nothing."""
        floors = [f for f in self._base_floor.values() if f is not None]
        floors += [p.request.psnr_floor for p in self._queue
                   if p.request.psnr_floor is not None]
        if not floors:
            return False
        next_level = self.degrade_level + 1
        return any(f - next_level * self.degrade_step_db
                   > self.min_psnr_floor_db - 1e-9 for f in floors)

    # ---------------------------------------------------------- dispatch
    @staticmethod
    def _bucket_key(p: _Pending) -> Tuple:
        return (tuple(p.request.latent_shape),
                float(p.request.guidance))

    def _pick_batch(self, rep: _Replica) -> List[_Pending]:
        """Deadline-aware batch selection: the dispatchable request with
        the earliest absolute deadline leads, and its geometry bucket
        rides along (a batch shares one compiled denoise)."""
        ready = [p for p in self._queue if p.not_before_s <= self.now]
        if not ready:
            return []
        ready.sort(key=lambda p: (p.deadline_s, p.submit_s,
                                  p.request.request_id))
        head = ready[0]
        key = self._bucket_key(head)
        batch = [p for p in ready if self._bucket_key(p) == key]
        return batch[: rep.engine.max_batch]

    def _pick_replica(self) -> Optional[_Replica]:
        free = [r for r in self.replicas
                if r.live and r.free_s <= self.now]
        if not free:
            return None
        if self.policy == "round-robin":
            n = len(self.replicas)
            for off in range(1, n + 1):
                cand = self.replicas[(self._rr + off) % n]
                if cand in free:
                    self._rr = cand.idx
                    return cand
            return None
        # least-loaded: healthy before degraded, then the replica that
        # has done the least work, then stable index order
        free.sort(key=lambda r: (r.state != "healthy", r.dispatches,
                                 r.idx))
        return free[0]

    def _requeue_lost(self, rep: _Replica, batch: List[_Pending],
                      why: str) -> None:
        """A batch died with its replica (or a terminal engine fault):
        requeue each request with its ORIGINAL submit_s behind a capped
        exponential backoff, up to ``max_redispatch`` attempts."""
        # scrub the dead engine's queue/lifecycle so a later restart
        # cannot resurrect stale stamps
        for p in batch:
            rep.engine._lifecycle.pop(p.request.request_id, None)
            rep.engine._enqueued_at.pop(p.request.request_id, None)
        rep.engine._inflight = []
        rep.engine._queue = []
        for p in batch:
            p.redispatches += 1
            if p.redispatches > self.max_redispatch:
                self._fail_terminal(p, reason=why)
                continue
            backoff = min(
                self.backoff_cap_s,
                self.backoff_base_s * 2.0 ** (p.redispatches - 1))
            p.not_before_s = self.now + backoff
            self._queue.append(p)
            self.stats["redispatches"] += 1
            self._instant("router.redispatch",
                          request_id=p.request.request_id,
                          priority=str(p.request.priority),
                          attempt=p.redispatches,
                          backoff_s=backoff, replica=rep.idx,
                          reason=why, now_s=self.now)
            self._inc(obsm.ROUTER_REDISPATCHES, replica=str(rep.idx))
        self._gauge(obsm.ROUTER_QUEUE_DEPTH, len(self._queue))

    def _heartbeat(self, rep: _Replica, wall: Optional[float]) -> None:
        """One heartbeat round from this dispatch outcome: the serving
        replica reports its wall (None = it failed to complete), idle
        live replicas report their last known wall (still responsive),
        dead/draining replicas miss."""
        rep.last_wall = wall
        neutral = wall if wall is not None else None
        beats: List[Optional[float]] = []
        for r in self.replicas:
            if not r.live:
                beats.append(None)
            elif r.idx == rep.idx:
                beats.append(wall)
            else:
                beats.append(r.last_wall if r.last_wall is not None
                             else neutral)
        self.health.observe(beats)
        for g in self.health.dead_groups():
            r = self.replicas[g]
            if r.live:
                # heartbeat budget exhausted without an engine-level
                # signal: stop dispatching, let in-flight work finish
                self._set_state(r, "draining", "heartbeat_misses")

    def _dispatch(self, rep: _Replica, batch: List[_Pending],
                  max_restarts_per_batch: int = 2) -> None:
        """Hand ``batch`` to ``rep`` at virtual ``now`` and run it to
        completion (the engine is synchronous; concurrency lives in the
        per-replica clocks)."""
        chosen = {id(p) for p in batch}
        self._queue = [p for p in self._queue if id(p) not in chosen]
        rep.clock.advance_to(self.now)
        for p in batch:
            req = p.request
            eff = self._effective_floor(req.psnr_floor)
            if eff != req.psnr_floor:
                req = dataclasses.replace(req, psnr_floor=eff)
            try:
                rep.engine.submit(req, submit_s=p.submit_s)
            except QueueFull:
                # cannot happen in routed operation (a dispatch is at
                # most one batch) unless the operator mis-sized
                # max_queue; requeue rather than lose the request
                self._queue.append(p)
        rep.dispatches += 1
        self._inc(obsm.ROUTER_DISPATCHES, replica=str(rep.idx))
        try:
            results = rep.engine.run(
                max_batches=1,
                max_restarts_per_batch=max_restarts_per_batch)
        except ReplicaDeath as e:
            rep.free_s = rep.clock.now
            self.stats["replica_deaths"] += 1
            self._set_state(rep, "dead", f"replica_death:{e}")
            self._instant("router.replica_dead", replica=rep.idx,
                          step=getattr(e, "step", None), fault=str(e),
                          lost=[p.request.request_id for p in batch],
                          now_s=self.now)
            self._inc(obsm.ROUTER_REPLICA_DEATHS)
            self._heartbeat(rep, None)
            self._requeue_lost(rep, batch, why="replica_death")
            return
        except (DeviceFailure, ServingFault) as e:
            # the engine burned its whole restart budget: the replica
            # is alive but not serving — degrade it, drain it past the
            # failure threshold, and send the batch elsewhere
            rep.free_s = rep.clock.now
            rep.failures += 1
            if rep.failures >= self.dead_after_failures:
                self._set_state(rep, "draining",
                                f"terminal_faults:{rep.failures}")
            else:
                self._set_state(rep, "degraded", f"terminal_fault:{e}")
            self._heartbeat(rep, None)
            self._requeue_lost(rep, batch, why="engine_fault")
            return
        rep.free_s = rep.clock.now
        rep.failures = 0
        wall = results[0].batch_wall_s if results else None
        self._heartbeat(rep, wall)
        if results and results[0].restarts > 0:
            self._set_state(rep, "degraded",
                            f"restarts:{results[0].restarts}")
        elif rep.state == "degraded":
            self._set_state(rep, "healthy", "recovered")
            self.health.mark_recovered(rep.idx)
        self.stats["completed"] += len(results)
        self.results.extend(results)
        self._gauge(obsm.ROUTER_QUEUE_DEPTH, len(self._queue))

    # ------------------------------------------------------------- serve
    def serve(
        self,
        workload: Sequence[Arrival],
        make_context: Optional[Callable[[Arrival], object]] = None,
        max_restarts_per_batch: int = 2,
    ) -> List[VideoResult]:
        """Open-loop replay of ``workload`` across the fleet: the
        N-replica generalization of ``loadgen.run_workload``.  Returns
        the completed :class:`VideoResult` s (shed / terminally failed
        requests have trace rows instead — every admitted request is
        accounted for)."""
        if make_context is None:
            make_context = _default_make_context(self.replicas[0].engine)
        pending = sorted(workload,
                         key=lambda a: (a.arrival_s, a.request_id))
        i = 0
        while True:
            # admit everything that has arrived by now
            while i < len(pending) and \
                    pending[i].arrival_s <= self.now:
                a = pending[i]
                self.submit(VideoRequest(
                    request_id=a.request_id,
                    context=make_context(a),
                    latent_shape=tuple(a.cls.latent_shape),
                    seed=a.seed,
                    guidance=a.cls.guidance,
                    priority=a.cls.priority,
                    psnr_floor=a.cls.psnr_floor,
                ), submit_s=a.arrival_s)
                i += 1
            self._shed_overflow()
            self._check_degradation()
            if not self.live_replicas():
                # total fleet loss: every queued and future request
                # fails terminally, loudly
                while i < len(pending):
                    a = pending[i]
                    self.submit(VideoRequest(
                        request_id=a.request_id,
                        context=make_context(a),
                        latent_shape=tuple(a.cls.latent_shape),
                        seed=a.seed, guidance=a.cls.guidance,
                        priority=a.cls.priority,
                        psnr_floor=a.cls.psnr_floor,
                    ), submit_s=a.arrival_s)
                    i += 1
                for p in list(self._queue):
                    self._fail_terminal(p, reason="no_live_replicas")
                self._queue = []
                break
            rep = self._pick_replica()
            if rep is not None:
                batch = self._pick_batch(rep)
                if batch:
                    self._dispatch(
                        rep, batch,
                        max_restarts_per_batch=max_restarts_per_batch)
                    continue
            if i >= len(pending) and not self._queue:
                break
            # nothing dispatchable at now: advance virtual time to the
            # next event (arrival, replica coming free, backoff expiry)
            nxt: List[float] = []
            if i < len(pending):
                nxt.append(pending[i].arrival_s)
            if self._queue:
                frees = [r.free_s for r in self.live_replicas()
                         if r.free_s > self.now]
                if frees:
                    nxt.append(min(frees))
                gates = [p.not_before_s for p in self._queue
                         if p.not_before_s > self.now]
                if gates:
                    nxt.append(min(gates))
            nxt = [t for t in nxt if t > self.now]
            if not nxt:
                if self._queue:
                    # queued work that can never dispatch (every live
                    # replica free, every gate open, yet no batch —
                    # cannot happen, but an infinite loop would be
                    # worse than a loud failure)
                    for p in list(self._queue):
                        self._fail_terminal(p, reason="stuck")
                    self._queue = []
                break
            self.now = min(nxt)
        # the queue has drained: the overload is over by definition, so
        # unwind any residual degradation before handing the fleet back
        while self.degrade_level > 0:
            self.degrade_level -= 1
            self._apply_floors()
            self._instant("router.restore", level=self.degrade_level,
                          queue_depth=len(self._queue), now_s=self.now)
            self._inc(obsm.ROUTER_RESTORE_STEPS)
        return self.results
