"""Seeded, replayable open-loop traffic generator for the LP serving
engine (the load half of the load-and-SLO harness; evaluation lives in
``repro/obs/slo.py``).

Three pieces:

* a **request-mix spec** — :class:`RequestClass` buckets over
  ``(latent_shape, guidance, psnr_floor, priority)`` with sampling
  weights, parseable from a CLI string (:func:`parse_mix`);
* a **workload builder** — :func:`build_workload` draws arrival times
  (Poisson or deterministic at a fixed offered rate) and per-request
  class/seed assignments from ONE ``numpy`` PRNG, so a fixed
  ``WorkloadSpec.seed`` always yields the byte-identical workload
  (:func:`workload_digest` pins that; ``benchmarks/serving_load.py``
  gates it);
* a **replay driver** — :func:`run_workload` drives
  ``LPServingEngine.submit`` open-loop on a :class:`VirtualClock`:
  requests arrive at their generated offsets regardless of service
  progress (arrivals never wait on completions — the property that
  makes offered-load latency sweeps meaningful), while the clock
  advances by each batch's *measured* wall.  Queue waits and e2e
  latencies therefore live on one consistent virtual timeline: real
  compute, synthetic arrivals.

The engine under replay must be constructed with the same
``VirtualClock`` (``LPServingEngine(clock=...)``); the driver refuses
to replay against a wall clock, where arrival offsets would be
meaningless.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .engine import LPServingEngine, QueueFull, VideoRequest, VideoResult

ARRIVAL_PROCESSES = ("poisson", "deterministic")


class VirtualClock:
    """Monotonic virtual time the replay driver and engine co-advance.

    Callable (returns ``now`` in seconds) so it drops into
    ``LPServingEngine(clock=...)``; the engine calls :meth:`advance`
    with each batch's measured wall, the driver fast-forwards to the
    next arrival when the queue idles.
    """

    def __init__(self, start_s: float = 0.0) -> None:
        self.now = float(start_s)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt_s: float) -> None:
        if dt_s < 0:
            raise ValueError(f"cannot advance time by {dt_s}")
        self.now += float(dt_s)

    def advance_to(self, t_s: float) -> None:
        self.now = max(self.now, float(t_s))


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One bucket of the request mix."""

    name: str
    latent_shape: Tuple[int, int, int]
    guidance: float = 5.0
    psnr_floor: Optional[float] = None
    priority: str = "standard"
    weight: float = 1.0

    def __post_init__(self):
        if len(self.latent_shape) != 3 or \
                any(int(d) <= 0 for d in self.latent_shape):
            raise ValueError(
                f"class {self.name!r}: latent_shape must be 3 positive "
                f"dims, got {self.latent_shape}")
        if self.weight <= 0:
            raise ValueError(
                f"class {self.name!r}: weight must be > 0, "
                f"got {self.weight}")


DEFAULT_MIX = (
    RequestClass("clip", (6, 8, 12), priority="interactive", weight=1.0),
    RequestClass("std", (6, 8, 12), priority="standard", weight=2.0),
    RequestClass("bulk", (4, 8, 12), priority="batch", weight=1.0,
                 guidance=3.0),
)


def parse_mix(spec: Optional[str]) -> Tuple[RequestClass, ...]:
    """CLI request-mix grammar -> class tuple.

    Classes are ``;``-separated; each is a name followed by ``,``-
    separated ``key=value`` fields::

        "clip,shape=6x8x12,priority=interactive,weight=1,guidance=5;
         bulk,shape=4x8x12,priority=batch,weight=2,psnr=40"

    Keys: ``shape`` (``TxHxW``, required), ``guidance``, ``priority``,
    ``weight``, ``psnr`` (the per-request quality floor the priority
    class maps to).  ``None``/empty returns :data:`DEFAULT_MIX`.
    """
    if spec is None or not spec.strip():
        return DEFAULT_MIX
    classes: List[RequestClass] = []
    for chunk in spec.split(";"):
        chunk = chunk.strip()
        if not chunk:
            continue
        parts = [p.strip() for p in chunk.split(",")]
        name = parts[0]
        if not name or "=" in name:
            raise ValueError(
                f"bad mix class {chunk!r}: first field is the name")
        fields = {}
        for kv in parts[1:]:
            k, eq, v = kv.partition("=")
            if not eq:
                raise ValueError(f"bad mix field {kv!r} in {name!r}")
            fields[k.strip()] = v.strip()
        if "shape" not in fields:
            raise ValueError(f"mix class {name!r} needs shape=TxHxW")
        try:
            shape = tuple(int(d) for d in fields.pop("shape").split("x"))
            kwargs = {}
            if "guidance" in fields:
                kwargs["guidance"] = float(fields.pop("guidance"))
            if "priority" in fields:
                kwargs["priority"] = fields.pop("priority")
            if "weight" in fields:
                kwargs["weight"] = float(fields.pop("weight"))
            if "psnr" in fields:
                kwargs["psnr_floor"] = float(fields.pop("psnr"))
        except ValueError as e:
            raise ValueError(f"mix class {name!r}: {e}") from None
        if fields:
            raise ValueError(
                f"mix class {name!r}: unknown fields {sorted(fields)}")
        classes.append(RequestClass(name, shape, **kwargs))
    if not classes:
        raise ValueError(f"mix spec {spec!r} has no classes")
    return tuple(classes)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything that determines a workload, seed included."""

    rate_rps: float                      # offered load, requests/second
    num_requests: int
    arrivals: str = "poisson"            # or "deterministic"
    seed: int = 0
    mix: Tuple[RequestClass, ...] = DEFAULT_MIX

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.num_requests <= 0:
            raise ValueError(
                f"num_requests must be > 0, got {self.num_requests}")
        if self.arrivals not in ARRIVAL_PROCESSES:
            raise ValueError(
                f"arrivals must be one of {ARRIVAL_PROCESSES}, "
                f"got {self.arrivals!r}")
        if not self.mix:
            raise ValueError("mix must not be empty")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated request: when it arrives and what it asks for."""

    request_id: int
    arrival_s: float
    cls: RequestClass
    seed: int                            # the request's latent PRNG seed


def build_workload(spec: WorkloadSpec) -> List[Arrival]:
    """Draw the whole workload from one seeded PRNG — replayable.

    Poisson arrivals are exponential inter-arrival gaps at
    ``rate_rps``; deterministic arrivals are the fixed ``1/rate`` grid
    (same mean offered load, zero burstiness — the A/B pair for
    isolating queueing noise from service noise).  Class choice is
    weight-proportional; per-request seeds come from the same stream.
    """
    rng = np.random.default_rng(spec.seed)
    n = spec.num_requests
    if spec.arrivals == "poisson":
        gaps = rng.exponential(1.0 / spec.rate_rps, size=n)
    else:
        gaps = np.full(n, 1.0 / spec.rate_rps)
    times = np.cumsum(gaps)
    weights = np.asarray([c.weight for c in spec.mix], dtype=np.float64)
    choices = rng.choice(len(spec.mix), size=n, p=weights / weights.sum())
    seeds = rng.integers(0, 2 ** 31 - 1, size=n)
    return [
        Arrival(request_id=i, arrival_s=float(times[i]),
                cls=spec.mix[int(choices[i])], seed=int(seeds[i]))
        for i in range(n)
    ]


def workload_digest(workload: Sequence[Arrival]) -> str:
    """Stable content hash of a generated workload.

    Byte-determinism gate: the same :class:`WorkloadSpec` must always
    digest identically (floats via ``repr`` — exact round-trip), and
    any change to arrivals, mix assignment, or seeds must show."""
    h = hashlib.sha256()
    for a in workload:
        h.update(json.dumps([
            a.request_id, repr(a.arrival_s), a.seed, a.cls.name,
            list(a.cls.latent_shape), repr(a.cls.guidance),
            None if a.cls.psnr_floor is None else repr(a.cls.psnr_floor),
            a.cls.priority,
        ]).encode())
    return h.hexdigest()


def _default_make_context(engine: LPServingEngine):
    import jax

    from repro.models import frontends

    def make_context(arrival: Arrival):
        return frontends.text_context(
            jax.random.PRNGKey(arrival.seed), 1, engine.cfg)

    return make_context


def run_workload(
    engine: LPServingEngine,
    workload: Sequence[Arrival],
    make_context: Optional[Callable[[Arrival], object]] = None,
    max_restarts_per_batch: int = 2,
) -> List[VideoResult]:
    """Open-loop replay: submit at arrival offsets, serve greedily.

    The loop alternates "submit everything that has arrived by now"
    with "serve one batch" (work-conserving: a partially filled bucket
    launches rather than idling — under offered load the admission
    aging knob never binds).  When the queue drains with arrivals
    still pending, the clock fast-forwards to the next arrival — an
    idle server, not time travel.  Arrivals never wait on completions,
    so queue waits are a true function of offered load vs. capacity.

    Each submit is stamped with the request's ``arrival_s`` (the
    engine's ``submit_s`` override), not the submission call time: a
    request that arrived while a batch was in flight can only be
    handed to the synchronous engine after that batch returns, and
    stamping the call would under-report its queue wait and e2e by up
    to a full batch wall.

    On an engine with a bounded queue (``max_queue``), an arrival that
    lands on a full queue is dropped here exactly as a real front door
    would drop it: the engine's ``QueueFull`` is absorbed (it already
    emitted the ``request.rejected`` trace instant and counter), the
    replay continues, and the rejected request simply has no result.
    """
    clock = engine.clock
    if not isinstance(clock, VirtualClock):
        raise ValueError(
            "run_workload needs the engine constructed with a "
            "VirtualClock (LPServingEngine(clock=VirtualClock())); "
            "on a wall clock the workload's arrival offsets would be "
            "meaningless")
    if make_context is None:
        make_context = _default_make_context(engine)
    pending = sorted(workload, key=lambda a: (a.arrival_s, a.request_id))
    results: List[VideoResult] = []
    i = 0
    while i < len(pending) or engine._queue:
        if not engine._queue and i < len(pending):
            clock.advance_to(pending[i].arrival_s)
        while i < len(pending) and pending[i].arrival_s <= clock.now:
            a = pending[i]
            try:
                engine.submit(VideoRequest(
                    request_id=a.request_id,
                    context=make_context(a),
                    latent_shape=tuple(a.cls.latent_shape),
                    seed=a.seed,
                    guidance=a.cls.guidance,
                    priority=a.cls.priority,
                    psnr_floor=a.cls.psnr_floor,
                ), submit_s=a.arrival_s)
            except QueueFull:
                pass
            i += 1
        results.extend(engine.run(
            max_batches=1,
            max_restarts_per_batch=max_restarts_per_batch))
    return results
