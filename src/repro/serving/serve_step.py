"""Serve-step factories: prefill (full-sequence forward -> last-token
logits) and decode (one token against the KV/state cache).

These are exactly what ``launch/dryrun.py`` lowers for the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` cells.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import logits_fn


def make_prefill_step(model, cfg: ArchConfig) -> Callable:
    def prefill(params, batch):
        hidden, _ = model.forward(params, batch)
        if cfg.family == "audio":
            table = {"embed": params["embed"]}
            from repro.models.layers import unembed

            return unembed(table["embed"], hidden[:, -1:, :])
        return logits_fn(params, hidden[:, -1:, :], cfg)

    return prefill


def make_decode_step(model, cfg: ArchConfig) -> Callable:
    if cfg.family == "audio":
        def decode(params, batch, cache):
            return model.decode(
                params, batch["token"], cache, batch["position"],
                batch["enc_states"],
            )
        return decode

    def decode(params, batch, cache):
        return model.decode(params, batch["token"], cache, batch["position"])

    return decode
