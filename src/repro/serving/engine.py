"""LP video-generation serving engine: request queue -> shape-batched LP
denoising -> latents out.

Production behaviours implemented (scaled to the container):
  * request batching by latent geometry (same (frames, res) denoise
    together — LP partitions are geometry-static, so batching avoids
    re-planning / recompiles);
  * compiled-step reuse ACROSS batches: the guided denoiser takes the
    text context / CFG scale as traced arguments and is built once per
    engine (not per batch), and one ``LPStepCompiler`` owns the jitted
    step cache — the second batch of a given geometry runs with zero
    retraces;
  * bounded-latency admission: a batch launches when a geometry bucket is
    full OR when the oldest request has waited ``max_wait_requests``
    queue polls (before this, ``max_wait`` was stored but never read);
  * straggler adaptation: per-partition step-time EMAs re-plan core sizes
    (runtime/straggler.py) when imbalance exceeds the threshold;
  * failure handling: a denoise step that raises re-queues the whole
    batch (LP state is just (z_t, i) — restartable at step granularity,
    checkpointed every ``ckpt_every_steps``);
  * engine auto-selection + wire codecs: ``lp_impl="auto"`` picks the
    psum engine at K=2 and the halo engine beyond (the comm-model
    break-even, ``core/spmd.select_lp_impl``); ``wire_codec`` squeezes
    the halo payloads through ``comm/`` codecs (bf16/int8/int4, or
    int8-residual temporal-delta with error feedback).  Residual codec
    state is zeroed at the start of every same-dim scan run inside
    ``lp_denoise``, so state can never leak across batches/requests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import get_codec
from repro.configs.base import ArchConfig
from repro.core import LPStepCompiler, lp_denoise
from repro.core.spmd import select_lp_impl
from repro.diffusion.pipeline import make_guided_step_denoiser
from repro.diffusion.sampler import FlowMatchEuler
from repro.runtime.straggler import StragglerState


@dataclasses.dataclass
class VideoRequest:
    request_id: int
    context: jnp.ndarray          # (1, L_ctx, ctx_dim) encoded prompt
    latent_shape: Tuple[int, int, int]   # (T_lat, H_lat, W_lat)
    seed: int = 0
    guidance: float = 5.0


@dataclasses.dataclass
class VideoResult:
    request_id: int
    latent: jnp.ndarray
    num_steps: int
    wall_s: float
    restarts: int = 0


class LPServingEngine:
    def __init__(
        self,
        dit_forward: Callable,
        params: Any,
        cfg: ArchConfig,
        num_partitions: int,
        overlap_ratio: float = 0.5,
        num_steps: int = 20,
        max_batch: int = 4,
        max_wait_requests: int = 8,
        uniform: bool = True,
        lp_impl: str = "auto",
        wire_codec: Optional[str] = None,
        mesh=None,
        lp_axis: str = "data",
        tp_axis: str = "model",
    ):
        self.dit_forward = dit_forward
        self.params = params
        self.cfg = cfg
        self.K = num_partitions
        self.r = overlap_ratio
        self.num_steps = num_steps
        self.max_batch = max_batch
        self.max_wait = max_wait_requests
        self.uniform = uniform
        self.straggler = StragglerState(num_partitions)
        self._queue: List[VideoRequest] = []
        self._polls = 0
        self._enqueued_at: Dict[int, int] = {}       # request_id -> poll no.
        self._step_fault: Optional[Callable[[int], None]] = None  # test hook
        self._sampler = FlowMatchEuler(num_steps)
        # Engine selection: "auto" follows the comm model (psum at K=2,
        # halo family beyond — select_lp_impl); a non-trivial wire codec
        # implies the halo family, which is where the codec layer lives.
        # On a 2D (lp, tp) mesh the halo family is the hybrid engine:
        # the group-axis halo schedule with the TP DiT forward as the
        # black-box intra-group Phi_m.
        self.codec = get_codec(wire_codec)
        codec_active = self.codec.name not in ("fp32", "identity")
        explicit_halo = lp_impl in ("halo", "halo_hybrid")
        tp = 1
        if mesh is not None and tp_axis in mesh.axis_names:
            tp = mesh.shape[tp_axis]
        if lp_impl == "auto":
            if codec_active:
                lp_impl = "halo_hybrid" if tp > 1 else "halo"
            else:
                lp_impl = select_lp_impl(self.K, tp)
        if codec_active and lp_impl not in ("halo", "halo_hybrid"):
            raise ValueError(
                f"wire_codec={self.codec.name!r} needs the halo family "
                f"(the codec layer lives there), got lp_impl={lp_impl!r}"
            )
        self.lp_impl = lp_impl
        self.mesh = mesh
        self.tp = tp
        forward = None
        compiler_codec = None
        if mesh is not None:
            from repro.core.hybrid import lp_forward_halo_hybrid
            from repro.core.spmd import lp_forward_halo, lp_forward_shard_map

            if self.lp_impl in ("halo", "halo_hybrid"):
                codec = self.codec
                if self.lp_impl == "halo_hybrid":
                    def halo_fwd(fn, z, plan, axis, **kw):
                        return lp_forward_halo_hybrid(
                            fn, z, plan, axis, mesh, lp_axis, tp_axis, **kw)
                else:
                    def halo_fwd(fn, z, plan, axis, **kw):
                        return lp_forward_halo(
                            fn, z, plan, axis, mesh, lp_axis, **kw)
                if codec.stateful:
                    forward = (lambda fn, z, plan, axis, st:
                               halo_fwd(fn, z, plan, axis, codec=codec,
                                        codec_state=st))
                else:
                    forward = (lambda fn, z, plan, axis:
                               halo_fwd(fn, z, plan, axis, codec=codec))
                compiler_codec = codec
            else:
                forward = (lambda fn, z, plan, axis:
                           lp_forward_shard_map(fn, z, plan, axis, mesh,
                                                lp_axis))
        elif self.lp_impl in ("halo", "halo_hybrid") and \
                (codec_active or explicit_halo):
            # off-mesh: the single-process mirror of the halo collective
            # (comm.wire.simulate_halo_forward — LPStepCompiler's codec
            # default), bit-faithful incl. the codec round-trips.  Only
            # taken when a codec is active or halo was asked for by name:
            # with fp32 wires an auto-selected halo has nothing to
            # simulate and the uniform vmapped engine is the same math
            # for a fraction of the dispatch work.
            compiler_codec = self.codec
        # else: uniform vmapped engine (psum-equivalent math, no wire)
        # Hoisted out of the batch loop: conditioning is traced, so this
        # closure (and every step it compiles) is batch-independent.
        self._guided = make_guided_step_denoiser(dit_forward, params, cfg)
        self._compiler = LPStepCompiler(
            denoise_fn=self._guided,
            update_fn=self._sampler.update,
            num_partitions=self.K,
            overlap_ratio=self.r,
            patch_sizes=cfg.patch_sizes,
            spatial_axes=(1, 2, 3),
            uniform=uniform,
            forward=forward,
            codec=compiler_codec,
            mesh_shape=None if mesh is None else (self.K, tp),
        )

    # ------------------------------------------------------------- queue
    def submit(self, req: VideoRequest) -> None:
        self._queue.append(req)
        self._enqueued_at[req.request_id] = self._polls

    def _next_batch(self, force: bool = False) -> List[VideoRequest]:
        """Admission: full geometry bucket, aged-out oldest bucket, or
        (``force``, used when draining) the oldest bucket regardless."""
        if not self._queue:
            return []
        self._polls += 1
        by_shape: Dict[Tuple, List[VideoRequest]] = defaultdict(list)
        for r in self._queue:
            by_shape[r.latent_shape].append(r)
        batch: List[VideoRequest] = []
        for bucket in by_shape.values():
            if len(bucket) >= self.max_batch:
                batch = bucket[: self.max_batch]
                break
        if not batch:
            oldest = self._queue[0]
            age = self._polls - self._enqueued_at.get(
                oldest.request_id, self._polls
            )
            if force or age >= self.max_wait:
                batch = by_shape[oldest.latent_shape][: self.max_batch]
            else:
                return []
        chosen = {id(r) for r in batch}
        self._queue = [r for r in self._queue if id(r) not in chosen]
        for r in batch:
            self._enqueued_at.pop(r.request_id, None)
        return batch

    # ------------------------------------------------------------ serving
    def _denoise_batch(self, reqs: List[VideoRequest]) -> List[VideoResult]:
        t0 = time.time()
        shape = reqs[0].latent_shape
        ctx = jnp.concatenate([r.context for r in reqs], axis=0)
        null_ctx = jnp.zeros_like(ctx)
        guidance = jnp.float32(reqs[0].guidance)
        keys = [jax.random.PRNGKey(r.seed) for r in reqs]
        z_T = jnp.concatenate([
            jax.random.normal(k, (1, *shape, self.cfg.latent_channels))
            for k in keys
        ], axis=0)

        # a step hook disables scan fusion, so only install one when a
        # fault injector is actually registered
        z0 = lp_denoise(
            None, z_T, self._sampler, self.num_steps, self.K, self.r,
            self.cfg.patch_sizes, (1, 2, 3), uniform=self.uniform,
            extras=(ctx, null_ctx, guidance), compiler=self._compiler,
            step_hook=self._step_fault,
        )
        wall = time.time() - t0
        return [
            VideoResult(r.request_id, z0[i : i + 1], self.num_steps, wall)
            for i, r in enumerate(reqs)
        ]

    def run(self, max_batches: Optional[int] = None,
            max_restarts_per_batch: int = 2) -> List[VideoResult]:
        """Drain the queue; failed batches re-queue (bounded retries)."""
        out: List[VideoResult] = []
        batches = 0
        while self._queue and (max_batches is None or batches < max_batches):
            # draining: don't wait out the admission age, force-launch
            reqs = self._next_batch(force=True)
            if not reqs:
                break
            restarts = 0
            while True:
                try:
                    results = self._denoise_batch(reqs)
                    for res in results:
                        res.restarts = restarts
                    out.extend(results)
                    break
                except RuntimeError:
                    restarts += 1
                    if restarts > max_restarts_per_batch:
                        raise
            batches += 1
        return out
