"""LP video-generation serving engine: request queue -> shape-batched LP
denoising -> latents out.

Production behaviours implemented (scaled to the container):
  * request batching by latent geometry (same (frames, res) denoise
    together — LP partitions are geometry-static, so batching avoids
    re-planning / recompiles);
  * bounded-latency admission: a batch launches when full OR when the
    oldest request exceeds ``max_wait_requests`` queue polls;
  * straggler adaptation: per-partition step-time EMAs re-plan core sizes
    (runtime/straggler.py) when imbalance exceeds the threshold;
  * failure handling: a denoise step that raises re-queues the whole
    batch (LP state is just (z_t, i) — restartable at step granularity,
    checkpointed every ``ckpt_every_steps``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import lp_denoise
from repro.diffusion.pipeline import make_guided_denoiser
from repro.diffusion.sampler import FlowMatchEuler
from repro.runtime.straggler import StragglerState


@dataclasses.dataclass
class VideoRequest:
    request_id: int
    context: jnp.ndarray          # (1, L_ctx, ctx_dim) encoded prompt
    latent_shape: Tuple[int, int, int]   # (T_lat, H_lat, W_lat)
    seed: int = 0
    guidance: float = 5.0


@dataclasses.dataclass
class VideoResult:
    request_id: int
    latent: jnp.ndarray
    num_steps: int
    wall_s: float
    restarts: int = 0


class LPServingEngine:
    def __init__(
        self,
        dit_forward: Callable,
        params: Any,
        cfg: ArchConfig,
        num_partitions: int,
        overlap_ratio: float = 0.5,
        num_steps: int = 20,
        max_batch: int = 4,
        max_wait_requests: int = 8,
        uniform: bool = True,
    ):
        self.dit_forward = dit_forward
        self.params = params
        self.cfg = cfg
        self.K = num_partitions
        self.r = overlap_ratio
        self.num_steps = num_steps
        self.max_batch = max_batch
        self.max_wait = max_wait_requests
        self.uniform = uniform
        self.straggler = StragglerState(num_partitions)
        self._queue: List[VideoRequest] = []
        self._step_fault: Optional[Callable[[int], None]] = None  # test hook

    # ------------------------------------------------------------- queue
    def submit(self, req: VideoRequest) -> None:
        self._queue.append(req)

    def _next_batch(self) -> List[VideoRequest]:
        if not self._queue:
            return []
        by_shape: Dict[Tuple, List[VideoRequest]] = defaultdict(list)
        for r in self._queue:
            by_shape[r.latent_shape].append(r)
        # launch the fullest geometry bucket; age forces launch of the
        # oldest bucket even when underfull
        oldest = self._queue[0].latent_shape
        best = max(by_shape.items(), key=lambda kv: len(kv[1]))
        batch = best[1] if len(best[1]) >= self.max_batch else by_shape[oldest]
        batch = batch[: self.max_batch]
        for r in batch:
            self._queue.remove(r)
        return batch

    # ------------------------------------------------------------ serving
    def _denoise_batch(self, reqs: List[VideoRequest]) -> List[VideoResult]:
        t0 = time.time()
        shape = reqs[0].latent_shape
        B = len(reqs)
        ctx = jnp.concatenate([r.context for r in reqs], axis=0)
        null_ctx = jnp.zeros_like(ctx)
        guided = make_guided_denoiser(
            self.dit_forward, self.params, self.cfg, ctx, null_ctx,
            guidance=reqs[0].guidance,
        )
        keys = [jax.random.PRNGKey(r.seed) for r in reqs]
        z_T = jnp.concatenate([
            jax.random.normal(k, (1, *shape, self.cfg.latent_channels))
            for k in keys
        ], axis=0)

        step_counter = {"i": 0}
        fault = self._step_fault

        def den_for_step(i, dim):
            def fn(sub):
                if fault is not None:
                    fault(i)
                step_counter["i"] = i
                t = jnp.full((sub.shape[0],), self._sampler.timestep(i),
                             jnp.float32)
                return guided(sub, t)
            return fn

        self._sampler = FlowMatchEuler(self.num_steps)
        z0 = lp_denoise(
            den_for_step, z_T,
            lambda z, pred, i: self._sampler.step(z, pred, i),
            self.num_steps, self.K, self.r,
            self.cfg.patch_sizes, (1, 2, 3), uniform=self.uniform,
        )
        wall = time.time() - t0
        return [
            VideoResult(r.request_id, z0[i : i + 1], self.num_steps, wall)
            for i, r in enumerate(reqs)
        ]

    def run(self, max_batches: Optional[int] = None,
            max_restarts_per_batch: int = 2) -> List[VideoResult]:
        """Drain the queue; failed batches re-queue (bounded retries)."""
        out: List[VideoResult] = []
        batches = 0
        while self._queue and (max_batches is None or batches < max_batches):
            reqs = self._next_batch()
            if not reqs:
                break
            restarts = 0
            while True:
                try:
                    results = self._denoise_batch(reqs)
                    for res in results:
                        res.restarts = restarts
                    out.extend(results)
                    break
                except RuntimeError:
                    restarts += 1
                    if restarts > max_restarts_per_batch:
                        raise
            batches += 1
        return out
