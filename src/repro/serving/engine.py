"""LP video-generation serving engine: request queue -> shape-batched LP
denoising -> latents out.

Production behaviours implemented (scaled to the container):
  * request batching by latent geometry (same (frames, res) denoise
    together — LP partitions are geometry-static, so batching avoids
    re-planning / recompiles);
  * compiled-step reuse ACROSS batches: the guided denoiser takes the
    text context / CFG scale as traced arguments and is built once per
    engine (not per batch), and one ``LPStepCompiler`` owns the jitted
    step cache — the second batch of a given geometry runs with zero
    retraces;
  * bounded-latency admission: a batch launches when a geometry bucket is
    full OR when the oldest request has waited ``max_wait_requests``
    queue polls (before this, ``max_wait`` was stored but never read);
  * group health: per-LP-group step times feed a
    ``runtime/health.GroupHealthMonitor`` (heartbeat deadlines with
    bounded retry-backoff on top of the straggler EMA) — a group that is
    merely *slow* gets EMA-driven rebalancing / eventual eviction, a
    group that stops reporting is declared *dead* after its retry budget
    and proposed for immediate eviction;
  * failure handling: a denoise step that raises a *recoverable* fault
    (``runtime/ft.DeviceFailure``, ``runtime/faults.ServingFault``)
    retries the batch from its last **boundary snapshot** — there is no
    ``ckpt_every_steps`` wall-clock checkpoint; instead ``lp_denoise``
    records ``(z, step)`` into a per-batch ``core.DenoiseSnapshot`` at
    every dim-rotation / codec-segment boundary (exactly where residual
    codec state re-zeroes, so (z, step) IS the whole restartable state),
    and a retry resumes there instead of from ``z_T``, losing at most
    one dim-run of steps.  Any other exception surfaces immediately;
  * fault injection: ``inject_fault="dead:G@S,slow:GxF,corrupt@S"``
    (``runtime/faults.ServingFaultPlan``, CLI ``--inject-fault``)
    scripts group death, synthetic stragglers and one-step wire
    corruption against the per-step hook for drills and the
    ``benchmarks/fault_recovery.py`` gate; ``wire_nan_guard`` (default
    on) arms the halo-wire decode guard that absorbs a NaN/Inf payload
    by falling back to the rank-local stale slab (bit-identical when
    every wire message is finite);
  * engine auto-selection + wire codecs: ``lp_impl="auto"`` picks the
    psum engine at K=2 and the halo engine beyond (the comm-model
    break-even, ``core/spmd.select_lp_impl``); ``wire_codec`` squeezes
    the halo payloads through ``comm/`` codecs (bf16/int8/int4, or
    int8-residual temporal-delta with error feedback).  Residual codec
    state is zeroed at the start of every same-dim scan run inside
    ``lp_denoise``, so state can never leak across batches/requests;
  * step policy: ``codec_schedule`` replaces the frozen per-request
    codec with a sigma-scheduled one (``policy/`` subsystem) — ``auto``
    lets the cost-model autotuner pick (engine, schedule) minimizing
    analytic wire bytes subject to ``psnr_floor`` against the
    conformance PSNR envelope; an explicit spec (e.g.
    ``int8-residual@0.45,bf16``) is taken as-is.  Scheduled segments
    run as segmented scans through the shared ``LPStepCompiler``
    (segment codec in the cache key, <= 3 x num_segments compiles);
  * hierarchy-aware wire on hybrid meshes: ``wire_shard`` (default on
    when the mesh has a tp axis; the autotuner's two-tier link model
    decides when a schedule is planned) ships each halo payload as 1/T
    chunks across the inter-group links + an intra-group reassembly
    gather — T-fold fewer inter-group bytes, bit-identical values
    (docs/wire_sharding.md); ``eager_sends`` (default on for hybrid
    meshes) issues the ppermute rounds before any accumulation so they
    overlap the Phi_m tail;
  * mid-request re-planning: with ``elastic=True`` the per-step hook
    consults ``GroupHealthMonitor.propose`` (dead groups first, then the
    EMA slow test) and applies a proposed eviction through
    ``runtime.elastic.replan_lp_compiler`` WHILE a batch is denoising —
    the compiled-step cache can never serve a stale-geometry entry and
    codec state resets exactly once.  Mesh-bound engines shrink too:
    ``launch/mesh.shrink_hybrid_mesh`` rebuilds the ``(M-1, T)`` mesh
    from the survivors and :meth:`LPServingEngine._build_forward` hands
    ``replan_lp_compiler`` forward hooks re-bound to it, so the hybrid
    halo engine evicts mid-request instead of limping to the batch
    boundary.  A resolved codec schedule is re-derived for the shrunken
    K (the analytic byte model changed), taking effect next batch.
    The engine cannot time remote LP groups itself: an external
    monitor must feed per-group step times through
    :meth:`LPServingEngine.observe_group_times` (from another thread,
    mid-batch, is fine — the hook reads the EMA at the next step
    boundary).  Note ``elastic=True`` installs a per-step hook, which
    disables scan fusion; leave it off when no monitor is attached;
  * request-lifecycle observability: every request is stamped
    submit/admit/denoise-start/done on the engine ``clock`` (injectable
    — the load harness passes a ``serving/loadgen.VirtualClock`` so
    open-loop arrivals and measured service times share one replayable
    timeline), carries a ``priority`` SLO class, and lands per-request
    ``queue_wait_s`` / ``e2e_s`` on its :class:`VideoResult` plus —
    with a recorder — a ``request.lifecycle`` trace span and
    per-priority latency histograms (``serve.queue_wait_s`` /
    ``serve.e2e_latency_s``).  An optional ``slo`` spec (``obs/slo.py``
    grammar) counts deadline violations live
    (``serve.slo_violations``); the offline evaluator recomputes the
    same per-class report from the ``--trace-out`` artifact.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import get_codec
from repro.configs.base import ArchConfig
from repro.core import DenoiseSnapshot, LPStepCompiler, lp_denoise
from repro.core.spmd import select_lp_impl
from repro.diffusion.pipeline import make_guided_step_denoiser
from repro.diffusion.sampler import FlowMatchEuler
from repro.obs import metrics as obsm
from repro.obs.clock import perf_s
from repro.runtime.faults import CorruptingCodec, ReplicaDeath, \
    ServingFault, parse_fault_plan
from repro.runtime.ft import DeviceFailure
from repro.runtime.health import GroupHealthMonitor

from contextlib import nullcontext

_NULL_CM = nullcontext()


class QueueFull(RuntimeError):
    """``submit`` rejected a request because the engine queue is at its
    ``max_queue`` bound.  Backpressure, made explicit: an overload burst
    must surface to the caller (the load harness records it; the replica
    router's requeue path sheds or re-routes) instead of growing engine
    memory without limit.  The request was NOT enqueued and acquired no
    lifecycle state."""

    def __init__(self, msg: str, request_id: Optional[int] = None,
                 depth: Optional[int] = None):
        super().__init__(msg)
        self.request_id = request_id
        self.depth = depth


@dataclasses.dataclass
class VideoRequest:
    request_id: int
    context: jnp.ndarray          # (1, L_ctx, ctx_dim) encoded prompt
    latent_shape: Tuple[int, int, int]   # (T_lat, H_lat, W_lat)
    seed: int = 0
    guidance: float = 5.0
    # SLA metadata (obs/slo.py, serving/loadgen.py): ``priority`` names
    # the request's SLO class (deadline via an SLOSpec) and labels its
    # lifecycle metrics; ``psnr_floor`` is the per-request quality
    # floor the class maps to — carried through the lifecycle records
    # today, consumed by per-request plan selection when the replica
    # router lands (docs/step_policy.md).  Neither enters the batch
    # bucketing key: requests of different classes share a compiled
    # denoise.
    priority: str = "standard"
    psnr_floor: Optional[float] = None


@dataclasses.dataclass
class VideoResult:
    request_id: int
    latent: jnp.ndarray
    num_steps: int
    # the denoise is batched, so a request's wall time is the batch's:
    # report it as such (with the batch size) instead of pretending the
    # whole-batch wall belongs to each request individually
    batch_wall_s: float
    batch_size: int
    restarts: int = 0
    # denoise step the last retry resumed from (0 = from z_T / no retry):
    # together with ``restarts`` this quantifies the work a fault cost
    resumed_from_step: int = 0
    # per-request lifecycle latencies on the engine clock (virtual time
    # under the load harness): submit -> batch admission, and submit ->
    # batch done.  Unlike ``batch_wall_s`` these ARE per-request — two
    # riders of one batch differ by their queue waits.
    queue_wait_s: float = 0.0
    e2e_s: float = 0.0


class LPServingEngine:
    def __init__(
        self,
        dit_forward: Callable,
        params: Any,
        cfg: ArchConfig,
        num_partitions: int,
        overlap_ratio: float = 0.5,
        num_steps: int = 20,
        max_batch: int = 4,
        max_wait_requests: int = 8,
        max_queue: Optional[int] = None,
        replica_id: Optional[int] = None,
        uniform: bool = True,
        lp_impl: str = "auto",
        wire_codec: Optional[str] = None,
        codec_schedule: Optional[str] = None,
        psnr_floor: Optional[float] = None,
        plan_geometry: Tuple[int, int, int] = (13, 60, 104),
        elastic: bool = False,
        mesh=None,
        lp_axis: str = "data",
        tp_axis: str = "model",
        wire_shard: Optional[bool] = None,
        eager_sends: Optional[bool] = None,
        inject_fault=None,
        wire_nan_guard: bool = True,
        snapshots: bool = True,
        recorder=None,
        clock: Optional[Callable[[], float]] = None,
        slo=None,
    ):
        self.dit_forward = dit_forward
        self.params = params
        self.cfg = cfg
        self.K = num_partitions
        self.r = overlap_ratio
        self.num_steps = num_steps
        self.max_batch = max_batch
        self.max_wait = max_wait_requests
        # bounded admission: ``submit`` raises ``QueueFull`` beyond this
        # many queued requests (None = unbounded, the historical
        # behaviour).  The bound is on the QUEUE, not in-flight work —
        # a router that dispatches at most max_batch at a time never
        # trips it, while an unrouted overload burst fails loudly.
        if max_queue is not None and max_queue < max_batch:
            raise ValueError(
                f"max_queue={max_queue} < max_batch={max_batch}: the "
                f"queue could never fill a batch")
        self.max_queue = max_queue
        # fleet identity: set by the replica router (or the operator) so
        # lifecycle rows and serve.* metrics carry a per-replica label;
        # None (a bare engine) emits the exact historical schema.
        self.replica_id = replica_id
        self.uniform = uniform
        # ``recorder`` (repro.obs.FlightRecorder) is the optional
        # observability plane: request/batch spans, serve metrics, and
        # derived per-step wire attribution.  Host state only — it is
        # never traced and never enters the step-cache key, so enabling
        # it cannot cause a recompile (benchmarks/obs_overhead.py).
        self.recorder = recorder
        # ``clock`` is the request-lifecycle time source (submit/admit/
        # done stamps).  Default: the shared monotonic perf clock.  The
        # load harness passes a ``serving.loadgen.VirtualClock`` so
        # open-loop arrival times and measured service times share one
        # replayable timeline; the engine advances a virtual clock by
        # each batch's measured wall (see ``_denoise_batch``).  The
        # clock is host state only — never traced, never in a cache key.
        self.clock: Callable[[], float] = clock if clock is not None \
            else perf_s
        # optional SLO spec (obs/slo.py, or its string grammar): when
        # set, completed requests are checked against their priority
        # class's deadline and ``serve.slo_violations`` counts live.
        # The offline evaluator recomputes violations from stamps, so
        # serving without a spec loses nothing but the live counter.
        if slo is not None:
            from repro.obs.slo import SLOSpec
            slo = SLOSpec.parse(slo)
        self.slo = slo
        self._lifecycle: Dict[int, dict] = {}   # request_id -> stamps
        self._batch_seq = 0
        self.health = GroupHealthMonitor(
            num_partitions,
            metrics=None if recorder is None else recorder.metrics)
        # back-compat alias: external monitors (and the elastic tests)
        # that fed the EMA directly keep working — the health monitor
        # wraps the very same StragglerState
        self.straggler = self.health.straggler
        self.elastic = elastic
        self.evictions = 0
        self._queue: List[VideoRequest] = []
        self._polls = 0
        self._enqueued_at: Dict[int, int] = {}       # request_id -> poll no.
        self._step_fault: Optional[Callable[[int], None]] = None  # test hook
        self._fault_plan = parse_fault_plan(inject_fault)
        if self._fault_plan is not None and \
                self._fault_plan.has_replica_targets:
            raise ValueError(
                f"fault plan {self._fault_plan.describe()!r} carries "
                "replica:-scoped targets, which a bare engine cannot "
                "interpret (it does not know which replica it is) — "
                "route it through serving.router.ReplicaRouter, which "
                "splits per-replica sub-plans"
            )
        # in-flight batch (set by run() while a batch is denoising,
        # cleared on success/terminal failure): the replica router reads
        # this to requeue a batch lost to a whole-replica death
        self._inflight: List[VideoRequest] = []
        self.wire_nan_guard = bool(wire_nan_guard)
        self.snapshots = bool(snapshots)
        self.last_steps_lost: Optional[int] = None
        self._corrupt_active = False
        self._saved_codec = None
        self._plan_resolver: Optional[Callable] = None
        self._sampler = FlowMatchEuler(num_steps)
        tp = 1
        if mesh is not None and tp_axis in mesh.axis_names:
            tp = mesh.shape[tp_axis]
        # Hierarchy-aware wire knobs.  ``eager_sends=None`` resolves to
        # on for hybrid meshes running a halo-family engine (the
        # ppermute rounds can overlap the Phi_m tail there) and off
        # otherwise; ``wire_shard=None`` lets the autotuner's two-tier
        # link model decide when a schedule is being planned, and
        # otherwise defaults to on for hybrid meshes (T-fold fewer
        # inter-group bytes; bit-identical values).  BOTH tri-states
        # resolve AFTER plan resolution + engine selection below — the
        # autotuner may flip the engine family (e.g. a fp32-only
        # schedule to psum), and resolving from ``tp`` alone here would
        # bake wire knobs for an engine the plan then discards.
        eager_sends_pinned = eager_sends is not None
        if wire_shard and tp <= 1:
            raise ValueError(
                "wire_shard shards the halo wire over the tp axis; the "
                "mesh has no tp axis (need --mesh MxT with T >= 2)"
            )
        wire_shard_pinned = wire_shard is True  # explicit operator pin
        # Step policy: a codec schedule (explicit spec or cost-model
        # "auto") subsumes the fixed wire_codec — they are exclusive.
        self.codec = get_codec(wire_codec)
        codec_active = self.codec.name not in ("fp32", "identity")
        self.plan = None
        schedule = None
        # mutable quality floor: the replica router's graceful-
        # degradation path relaxes it under overload (set_psnr_floor),
        # re-resolving the autotuner plan toward cheaper codec
        # schedules, and restores it on recovery.  Meaningful only with
        # codec_schedule="auto"; None otherwise.
        self.psnr_floor = psnr_floor
        if codec_schedule is not None:
            from repro.core.comm_model import VDMCommConfig
            from repro.policy import resolve_cli_schedule

            if codec_active:
                raise ValueError(
                    "pass wire_codec= (fixed) or codec_schedule= "
                    "(sigma-scheduled), not both"
                )
            # the plan geometry only anchors the byte model; the chosen
            # schedule depends on codec bit-widths and the sigma
            # trajectory, both geometry-robust
            ccfg = VDMCommConfig(
                latent_dims=tuple(plan_geometry),
                latent_channels=cfg.latent_channels,
                patch_sizes=cfg.patch_sizes,
                d_model=cfg.d_model,
                num_blocks=cfg.num_layers,
                num_steps=num_steps,
            )
            # kept re-invocable: an elastic eviction shrinks K, which
            # changes the analytic byte model the schedule was tuned
            # against, so _maybe_evict_straggler re-resolves the plan
            # (closing over the ORIGINAL cli wire_shard tri-state, not
            # the value the first resolution pinned)
            wire_shard_cli = wire_shard

            def _resolve_plan(k):
                return resolve_cli_schedule(
                    codec_schedule, ccfg, k, self.r, self._sampler,
                    num_steps, psnr_floor_db=self.psnr_floor, tp=tp,
                    wire_shard=wire_shard_cli, recorder=self.recorder,
                )

            self._plan_resolver = _resolve_plan
            self.plan = self._plan_resolver(self.K)
            if lp_impl == "auto":
                lp_impl = self.plan.lp_impl
            if set(self.plan.step_codecs) != {"fp32"}:
                schedule = self.plan.schedule
            wire_shard = self.plan.wire_shard
        elif psnr_floor is not None:
            raise ValueError("psnr_floor needs codec_schedule")
        # Engine selection: "auto" follows the comm model (psum at K=2,
        # halo family beyond — select_lp_impl); a non-trivial wire codec
        # or schedule implies the halo family, which is where the codec
        # layer lives.  On a 2D (lp, tp) mesh the halo family is the
        # hybrid engine: the group-axis halo schedule with the TP DiT
        # forward as the black-box intra-group Phi_m.
        explicit_halo = lp_impl in ("halo", "halo_hybrid")
        if lp_impl == "auto":
            if codec_active:
                lp_impl = "halo_hybrid" if tp > 1 else "halo"
            else:
                lp_impl = select_lp_impl(self.K, tp)
        if (codec_active or schedule is not None) and \
                lp_impl not in ("halo", "halo_hybrid"):
            what = (f"wire_codec={self.codec.name!r}" if codec_active
                    else f"codec_schedule={schedule.spec!r}")
            names = (list(self.plan.step_codecs) if self.plan is not None
                     else [self.codec.name])
            if any(str(n).startswith("displaced") for n in names):
                raise ValueError(
                    f"{what} uses a displaced halo codec, which needs "
                    "carry-resident slab state — only the halo family "
                    "keeps one (the psum/gspmd engines have no "
                    f"per-direction slab carry); got lp_impl={lp_impl!r}"
                )
            raise ValueError(
                f"{what} needs the halo family (the codec layer lives "
                f"there), got lp_impl={lp_impl!r}"
            )
        self.lp_impl = lp_impl
        self.mesh = mesh
        self.tp = tp
        # tri-state resolution, now that the engine family is final
        # (satellite fix: was previously derived from ``tp`` alone,
        # before the plan could flip the family)
        halo_family = self.lp_impl in ("halo", "halo_hybrid")
        self.eager_sends = bool(eager_sends) if eager_sends_pinned else \
            (tp > 1 and halo_family)
        self.wire_shard = (tp > 1 and halo_family) if wire_shard is None \
            else bool(wire_shard)
        if self.lp_impl not in ("halo", "halo_hybrid") or tp <= 1 or \
                mesh is None:
            # sharding is a property of the mesh-bound halo wire; the
            # psum engine and the off-mesh simulate mirror have no tp
            # wire to split (simulate is bit-identical either way).  An
            # EXPLICIT pin that cannot be honored is a config error
            # (dryrun raises for the same combination), not a silent
            # downgrade.
            if wire_shard_pinned:
                raise ValueError(
                    f"wire_shard=True needs the mesh-bound halo family, "
                    f"got lp_impl={self.lp_impl!r} "
                    f"(mesh={'yes' if mesh is not None else 'no'}, tp={tp})"
                )
            self.wire_shard = False
        self._lp_axis = lp_axis
        self._tp_axis = tp_axis
        self._schedule = schedule
        # off-mesh halo family runs the single-process simulate mirror
        # (comm.wire.simulate_halo_forward — LPStepCompiler's codec
        # default), bit-faithful incl. the codec round-trips.  Only when
        # a codec is active or halo was asked for by name: with fp32
        # wires an auto-selected halo has nothing to simulate and the
        # uniform vmapped engine is the same math for a fraction of the
        # dispatch work.  A schedule needs no compiler codec — the
        # per-segment codecs route every step through the same mirror.
        self._simulate_codec = (
            self.lp_impl in ("halo", "halo_hybrid")
            and (codec_active or explicit_halo) and schedule is None
        )
        forward, forward_factory, compiler_codec = self._build_forward(mesh)
        if self._fault_plan is not None and self._fault_plan.corrupt:
            # the corrupt fault swaps the live wire codec for one step;
            # that only means something on an engine with a fixed wire
            if schedule is not None:
                raise ValueError(
                    "corrupt@S faults need a fixed wire codec — "
                    "sigma-scheduled segments own their codecs"
                )
            if compiler_codec is None:
                raise ValueError(
                    "corrupt@S faults poison the halo wire, but this "
                    f"engine has none (lp_impl={self.lp_impl!r}); use "
                    "the halo family with a wire codec"
                )
            if compiler_codec.stateful:
                raise ValueError(
                    "corrupt@S faults need a stateless wire codec: the "
                    "residual EF protocol is symmetric (sender and "
                    "receiver decode the same base payload), so a "
                    "poisoned decode would desync the sender's own EF "
                    "state, not just the wire"
                )
        # Hoisted out of the batch loop: conditioning is traced, so this
        # closure (and every step it compiles) is batch-independent.
        self._guided = make_guided_step_denoiser(dit_forward, params, cfg)
        self._compiler = LPStepCompiler(
            denoise_fn=self._guided,
            update_fn=self._sampler.update,
            num_partitions=self.K,
            overlap_ratio=self.r,
            patch_sizes=cfg.patch_sizes,
            spatial_axes=(1, 2, 3),
            uniform=uniform,
            forward=forward,
            forward_factory=forward_factory,
            codec=compiler_codec,
            schedule=schedule,
            mesh_shape=None if mesh is None else (self.K, tp),
            wire_shard=self.wire_shard,
            nan_guard=self.wire_nan_guard,
        )
        # Wire-attribution timelines (repro.obs.account): one geometry
        # entry per (from_step, K) epoch and one codec entry per
        # (from_step, step_codec_names) epoch; reset per batch, appended
        # to by mid-request evictions / schedule re-plans.
        self._cur_step = 1
        self._geom_events: List[Tuple[int, int]] = [(1, self.K)]
        self._codec_events: List[Tuple[int, List[str]]] = []
        self._batch_codecs: List[str] = []
        self._runs_mark = 0

    # ----------------------------------------------------------- forward
    def _build_forward(self, mesh):
        """(Re-)build the engine's forward hook family for ``mesh``.

        Returns ``(forward, forward_factory, compiler_codec)`` in
        ``LPStepCompiler`` terms.  Factored out of ``__init__`` so
        elastic mesh-shrink recovery can re-invoke it: after
        ``launch.mesh.shrink_hybrid_mesh`` drops a dead LP group, the
        rebuilt ``(M-1, T)`` mesh needs hooks closing over IT, and
        ``runtime.elastic.replan_lp_compiler`` refuses to change K on a
        mesh-bound compiler without them.

        Fixed-codec hooks read ``self._compiler.codec`` at trace time
        (late-bound, not captured) so the one-step ``corrupt@S`` codec
        swap reaches the mesh-bound wire — the codec name is in the
        step-cache key, so the swap always keys a distinct entry.
        """
        forward = None
        forward_factory = None
        compiler_codec = None
        schedule = self._schedule
        if mesh is not None:
            from repro.core.hybrid import lp_forward_halo_hybrid
            from repro.core.spmd import lp_forward_halo, lp_forward_shard_map

            lp_axis, tp_axis = self._lp_axis, self._tp_axis
            if self.lp_impl in ("halo", "halo_hybrid"):
                if self.lp_impl == "halo_hybrid":
                    def halo_fwd(fn, z, plan, axis, **kw):
                        return lp_forward_halo_hybrid(
                            fn, z, plan, axis, mesh, lp_axis, tp_axis,
                            eager_sends=self.eager_sends,
                            wire_shard=self.wire_shard,
                            nan_guard=self.wire_nan_guard, **kw)
                else:
                    # the plain halo engine composes with extra mesh
                    # axes; slabs are replicated over tp there too, so
                    # the wire can still be sharded over it
                    halo_shard = tp_axis if (self.wire_shard and
                                             self.tp > 1) else None

                    def halo_fwd(fn, z, plan, axis, **kw):
                        return lp_forward_halo(
                            fn, z, plan, axis, mesh, lp_axis,
                            eager_sends=self.eager_sends,
                            shard_axis=halo_shard,
                            nan_guard=self.wire_nan_guard, **kw)
                if schedule is not None:
                    # scheduled: LPStepCompiler asks for a hook per
                    # segment codec; each bound hook is the same halo
                    # collective, just encoding with that segment's codec
                    def forward_factory(seg_codec):
                        if seg_codec.stateful:
                            return (lambda fn, z, plan, axis, st:
                                    halo_fwd(fn, z, plan, axis,
                                             codec=seg_codec,
                                             codec_state=st))
                        return (lambda fn, z, plan, axis:
                                halo_fwd(fn, z, plan, axis,
                                         codec=seg_codec))
                elif self.codec.stateful:
                    forward = (lambda fn, z, plan, axis, st:
                               halo_fwd(fn, z, plan, axis,
                                        codec=self._compiler.codec,
                                        codec_state=st))
                else:
                    forward = (lambda fn, z, plan, axis:
                               halo_fwd(fn, z, plan, axis,
                                        codec=self._compiler.codec))
                if schedule is None:
                    compiler_codec = self.codec
            else:
                forward = (lambda fn, z, plan, axis:
                           lp_forward_shard_map(fn, z, plan, axis, mesh,
                                                lp_axis))
        elif self._simulate_codec:
            compiler_codec = self.codec
        # else: uniform vmapped engine (psum-equivalent math, no wire)
        return forward, forward_factory, compiler_codec

    # ------------------------------------------------------------- queue
    def _rlabels(self) -> Dict[str, str]:
        """Per-replica metric labels: ``{}`` for a bare engine (the
        exact historical metric schema), ``{"replica": "<id>"}`` when a
        router assigned this engine a fleet identity.  Read live (not
        cached) because the router sets ``replica_id`` after
        construction."""
        if self.replica_id is None:
            return {}
        return {"replica": str(self.replica_id)}

    def submit(self, req: VideoRequest,
               submit_s: Optional[float] = None) -> None:
        if self.max_queue is not None and \
                len(self._queue) >= self.max_queue:
            rec = self.recorder
            if rec is not None:
                rec.instant("request.rejected", cat="serve",
                            request_id=req.request_id,
                            priority=req.priority,
                            depth=len(self._queue), **self._rlabels())
                rec.inc(obsm.REQUESTS_REJECTED, **self._rlabels())
            raise QueueFull(
                f"engine queue full ({len(self._queue)} >= "
                f"max_queue={self.max_queue}); request "
                f"{req.request_id} not enqueued",
                request_id=req.request_id, depth=len(self._queue))
        self._queue.append(req)
        self._enqueued_at[req.request_id] = self._polls
        # lifecycle stamps are kept engine-side (not only recorder-side)
        # so VideoResult.queue_wait_s/e2e_s work without a recorder.
        # ``submit_s`` lets an open-loop replay stamp the request's
        # ARRIVAL time instead of the call time: a synchronous driver
        # can only submit a mid-batch arrival after that batch returns,
        # and stamping the call would under-report its queue wait (and
        # e2e) by up to a full batch wall.
        self._lifecycle[req.request_id] = {
            "request_id": req.request_id,
            "priority": str(req.priority),
            "latent_shape": list(req.latent_shape),
            "guidance": float(req.guidance),
            "psnr_floor": req.psnr_floor,
            "submit_s": (float(self.clock()) if submit_s is None
                         else float(submit_s)),
        }
        if self.replica_id is not None:
            self._lifecycle[req.request_id]["replica"] = self.replica_id
        rec = self.recorder
        if rec is not None:
            rec.instant("request.enqueue", cat="serve",
                        request_id=req.request_id,
                        latent_shape=req.latent_shape,
                        guidance=req.guidance,
                        priority=req.priority, **self._rlabels())
            rec.inc(obsm.REQUESTS, **self._rlabels())
            rec.gauge(obsm.QUEUE_DEPTH, len(self._queue),
                      **self._rlabels())

    @staticmethod
    def _bucket_key(req: VideoRequest) -> Tuple:
        """Batching key: geometry AND guidance.  A batch shares one
        compiled denoise with ONE traced guidance scalar, so bucketing
        by shape alone would silently apply the first request's
        guidance to every rider."""
        return (req.latent_shape, float(req.guidance))

    def _next_batch(self, force: bool = False) -> List[VideoRequest]:
        """Admission: full bucket, aged-out oldest bucket, or
        (``force``, used when draining) the oldest bucket regardless."""
        if not self._queue:
            return []
        self._polls += 1
        by_key: Dict[Tuple, List[VideoRequest]] = defaultdict(list)
        for r in self._queue:
            by_key[self._bucket_key(r)].append(r)
        batch: List[VideoRequest] = []
        for bucket in by_key.values():
            if len(bucket) >= self.max_batch:
                batch = bucket[: self.max_batch]
                break
        if not batch:
            oldest = self._queue[0]
            age = self._polls - self._enqueued_at.get(
                oldest.request_id, self._polls
            )
            if force or age >= self.max_wait:
                batch = by_key[self._bucket_key(oldest)][: self.max_batch]
            else:
                return []
        chosen = {id(r) for r in batch}
        self._queue = [r for r in self._queue if id(r) not in chosen]
        self._batch_seq += 1
        admit_s = float(self.clock())
        for r in batch:
            self._enqueued_at.pop(r.request_id, None)
            life = self._lifecycle.get(r.request_id)
            if life is not None:
                life["admit_s"] = admit_s
                life["batch_seq"] = self._batch_seq
                life["batch_size"] = len(batch)
        rec = self.recorder
        if rec is not None:
            rec.instant("batch.admit", cat="serve", size=len(batch),
                        latent_shape=batch[0].latent_shape,
                        guidance=batch[0].guidance,
                        request_ids=[r.request_id for r in batch],
                        batch_seq=self._batch_seq)
            rec.observe(obsm.BATCH_SIZE, len(batch), **self._rlabels())
            rec.observe(obsm.BATCH_OCCUPANCY,
                        len(batch) / max(1, self.max_batch),
                        **self._rlabels())
            rec.gauge(obsm.QUEUE_DEPTH, len(self._queue),
                      **self._rlabels())
        return batch

    # ------------------------------------------------------------ serving
    def observe_group_times(self, step_times) -> None:
        """Feed per-LP-group step times (seconds) into the health
        monitor (heartbeat deadlines + the straggler EMA).  This is the
        ``elastic=True`` data source: the engine runs single-process and
        cannot time remote groups, so an external monitor (per-host
        heartbeats, profiler stream) calls this — any thread, any time;
        the elastic step hook consumes the verdicts at the next step
        boundary.  Pass ``None``/``inf`` for a group that failed to
        report: enough missed rounds declare it dead."""
        self.health.observe(step_times)

    def set_psnr_floor(self, floor: Optional[float]) -> bool:
        """Move the per-engine quality floor (dB) and re-resolve the
        codec schedule against it — the replica router's graceful-
        degradation lever: a LOWER floor admits cheaper (fewer-bit)
        codec schedules, trading conformance PSNR for wire bytes and
        wall.  No-op (returns False) when the engine has no autotuned
        schedule (``codec_schedule`` unset or explicit) or the floor is
        unchanged.  Takes effect at the next batch, like every other
        re-plan — the in-flight denoise keeps its resolved segments."""
        if self._plan_resolver is None or floor == self.psnr_floor:
            return False
        self.psnr_floor = floor
        self._replan_schedule()
        return True

    def _replan_schedule(self) -> None:
        """Post-eviction: re-resolve the codec schedule at the new K.

        The schedule was tuned against the analytic byte model of the
        OLD partition count; keeping it would mis-price every remaining
        segment (the stale-plan bug this fixes: ``self.K`` shrank but
        ``self.plan`` never followed).  The re-resolved schedule is
        installed on the shared compiler and takes effect at the next
        batch — the in-flight denoise keeps its resolved segment layout,
        which stays valid because hooks bind per segment codec."""
        if self._plan_resolver is None:
            return
        self.plan = self._plan_resolver(self.K)
        new_sched = self.plan.schedule
        if new_sched is not None and \
                set(self.plan.step_codecs) != {"fp32"}:
            from repro.policy.schedule import parse_schedule

            self._schedule = parse_schedule(new_sched)
            self._compiler.schedule = self._schedule
            if self.recorder is not None:
                # codec timeline entry: a resumed retry re-resolves its
                # runs from the compiler's NEW schedule, so steps from
                # the current one onward are attributed under it
                self._codec_events.append(
                    (self._cur_step, self._step_codec_names()))

    def _maybe_evict_straggler(self) -> None:
        """Per-step elastic hook: apply a group-eviction proposal (dead
        group first, straggler EMA second) WHILE a batch is denoising.

        ``GroupHealthMonitor.propose`` fires when a group exhausted its
        heartbeat retry budget (dead) or its step-time EMA is far beyond
        the median (slow: dying host, broken link);
        ``replan_lp_compiler`` retargets the live compiler — full
        geometry in the step-cache key, codec state reset exactly once —
        and the in-flight ``lp_denoise`` loop picks up the new plan at
        the next step boundary.  Mesh-bound compilers shrink too:
        ``shrink_hybrid_mesh`` rebuilds the ``(M-1, T)`` mesh from the
        survivors and :meth:`_build_forward` supplies hooks re-bound to
        it, which ``replan_lp_compiler`` requires before changing K on a
        mesh-bound compiler.  A resolved codec schedule is re-derived
        for the shrunken K (:meth:`_replan_schedule`)."""
        proposal = self.health.propose((self.K, self.tp))
        if proposal is None:
            return
        from repro.runtime.elastic import replan_lp_compiler

        evicted, new_shape = proposal.group, proposal.new_mesh_shape
        forward = forward_factory = None
        new_mesh = self.mesh
        if self.mesh is not None:
            from repro.launch.mesh import shrink_hybrid_mesh

            new_mesh = shrink_hybrid_mesh(self.mesh, evicted, self.tp)
            forward, forward_factory, _ = self._build_forward(new_mesh)
        if replan_lp_compiler(self._compiler, new_shape, forward=forward,
                              forward_factory=forward_factory,
                              recorder=self.recorder):
            self.health.evict(evicted)
            self.K = new_shape[0]
            self.mesh = new_mesh
            self.evictions += 1
            if self._fault_plan is not None:
                # the dead hardware left the ring: its scripted faults
                # stop firing and the survivors re-index
                self._fault_plan.mark_recovered(evicted)
            rec = self.recorder
            if rec is not None:
                # geometry timeline entry: the eviction applies in the
                # step hook BEFORE step ``_cur_step`` executes, so that
                # step (and everything after) runs — and is attributed —
                # at the new K
                self._geom_events.append((self._cur_step, self.K))
                rec.instant("elastic.evict", cat="elastic",
                            group=evicted, reason=proposal.reason,
                            step=self._cur_step,
                            new_mesh_shape=list(new_shape))
                rec.inc(obsm.EVICTIONS, reason=proposal.reason,
                        **self._rlabels())
            self._replan_schedule()

    # ------------------------------------------------------ fault drills
    def _activate_corrupt(self) -> None:
        """Swap the live wire codec for its NaN-decoding twin for ONE
        step.  The codec name is part of the step-cache key, so this
        keys (and compiles) a distinct entry — the healthy executable is
        never poisoned and is re-hit verbatim after the restore."""
        comp = self._compiler
        self._saved_codec = comp.codec
        comp.codec = CorruptingCodec.wrap(comp.codec)
        self._corrupt_active = True

    def _restore_codec(self) -> None:
        if self._corrupt_active:
            self._compiler.codec = self._saved_codec
            self._corrupt_active = False

    def _step_hook(self) -> Optional[Callable[[int], None]]:
        """Compose the per-step hooks.  A hook disables scan fusion, so
        return None (fused fast path) unless a fault injector is
        registered or elastic mid-request re-planning is on.

        Hook order is load-bearing for recovery: scripted heartbeats
        feed the health monitor FIRST, the eviction attempt runs SECOND,
        and the dead-group raise comes LAST — so the step on which the
        monitor finally declares the group dead evicts it (marking the
        fault recovered) instead of burning another restart."""
        if self._step_fault is None and not self.elastic and \
                self._fault_plan is None:
            return None

        def hook(i: int) -> None:
            # the hook fires before step ``i`` executes, so an eviction
            # applied here changes the geometry step ``i`` runs under —
            # the wire-attribution timeline depends on this ordering
            self._cur_step = i
            rec = self.recorder
            plan = self._fault_plan
            if plan is not None and plan.die_fires(i):
                # whole-replica death: NOT a ServingFault — the dead
                # replica cannot retry itself, so run() must not catch
                # this; it propagates to the replica router, which
                # requeues ``self._inflight`` on a survivor
                if rec is not None:
                    for ev in plan.drain_events():
                        rec.instant("fault." + ev["kind"], cat="fault",
                                    **ev)
                        rec.inc(obsm.FAULTS_INJECTED, kind=ev["kind"],
                                **self._rlabels())
                raise ReplicaDeath(
                    f"replica {plan.die_replica} died (denoise step "
                    f"{i})", replica=plan.die_replica, step=i)
            if plan is not None:
                if self._corrupt_active:
                    # the corrupt step is behind us: restore the wire
                    self._restore_codec()
                if plan.touches_health:
                    self.health.observe(plan.heartbeats(i, self.K))
                if plan.corrupt_fires(i):
                    self._activate_corrupt()
            if self._step_fault is not None:
                self._step_fault(i)
            if self.elastic:
                self._maybe_evict_straggler()
            if plan is not None:
                dead = plan.active_dead(i)
                if rec is not None:
                    # scripted drill events fired at this step (corrupt
                    # swaps, first-time group deaths) — NaN-guard trips
                    # happen inside compiled code, so the host-side
                    # count is the injected corrupt steps forcing them
                    for ev in plan.drain_events():
                        rec.instant("fault." + ev["kind"], cat="fault",
                                    **ev)
                        rec.inc(obsm.FAULTS_INJECTED, kind=ev["kind"],
                                **self._rlabels())
                if dead is not None:
                    # the group is gone and not (yet) evicted: the halo
                    # collective would hang on it — surface a
                    # recoverable fault so run() retries from the last
                    # boundary snapshot
                    raise ServingFault(
                        f"LP group {dead} stopped heartbeating "
                        f"(denoise step {i})", step=i)

        return hook

    def _denoise_batch(
        self, reqs: List[VideoRequest],
        snapshot: Optional[DenoiseSnapshot] = None,
    ) -> List[VideoResult]:
        t0 = perf_s()
        rec = self.recorder
        shape = reqs[0].latent_shape
        # service start on the lifecycle clock; setdefault so a
        # snapshot-resumed retry keeps the FIRST dispatch stamp (the
        # retry cost is visible as done - denoise_start growing)
        start_s = float(self.clock())
        for r in reqs:
            life = self._lifecycle.get(r.request_id)
            if life is not None:
                life.setdefault("denoise_start_s", start_s)
        ctx = jnp.concatenate([r.context for r in reqs], axis=0)
        null_ctx = jnp.zeros_like(ctx)
        guidance = jnp.float32(reqs[0].guidance)
        keys = [jax.random.PRNGKey(r.seed) for r in reqs]
        z_T = jnp.concatenate([
            jax.random.normal(k, (1, *shape, self.cfg.latent_channels))
            for k in keys
        ], axis=0)

        compiles0 = self._compiler.compiles
        span = (rec.span("batch.denoise", cat="serve", size=len(reqs),
                         latent_shape=shape, steps=self.num_steps,
                         K=self.K, lp_impl=self.lp_impl)
                if rec is not None else _NULL_CM)
        try:
            with span:
                z0 = lp_denoise(
                    None, z_T, self._sampler, self.num_steps, self.K,
                    self.r, self.cfg.patch_sizes, (1, 2, 3),
                    uniform=self.uniform,
                    extras=(ctx, null_ctx, guidance),
                    compiler=self._compiler,
                    step_hook=self._step_hook(), snapshot=snapshot,
                    recorder=rec,
                )
        finally:
            # a corrupt-wire drill must never outlive its batch (the
            # swap is one-step; a fault between swap and restore would
            # otherwise leak the corrupting codec into the next batch)
            self._restore_codec()
        wall = perf_s() - t0
        # a virtual lifecycle clock (load harness) advances by the
        # batch's MEASURED wall: arrivals follow the offered-load
        # process, service times are real — the standard open-loop
        # replay for a synchronous engine.  The perf clock (default)
        # has already advanced by exactly this much on its own.
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(wall)
        if rec is not None:
            rec.observe(obsm.BATCH_WALL_S, wall, **self._rlabels())
            rec.inc(obsm.COMPILES, self._compiler.compiles - compiles0,
                    epoch=self._compiler.plan_epoch, **self._rlabels())
        return [
            VideoResult(r.request_id, z0[i : i + 1], self.num_steps,
                        batch_wall_s=wall, batch_size=len(reqs))
            for i, r in enumerate(reqs)
        ]

    # ------------------------------------------------- wire attribution
    def _step_codec_names(self) -> List[str]:
        """The codec name each forward pass runs under, resolved the
        same way ``lp_denoise`` resolves its runs (schedule against the
        sampler's sigma trajectory, else the fixed wire codec)."""
        if self._schedule is not None:
            from repro.policy.schedule import trajectory_sigmas

            sigmas = trajectory_sigmas(self._sampler, self.num_steps)
            return list(self._schedule.step_codecs(sigmas))
        return [self.codec.name] * self.num_steps

    def _record_batch_wire(self, shape: Tuple[int, int, int],
                           batch_size: int) -> None:
        """Derive the completed batch's per-step wire bytes by replaying
        ``comm_model`` over the recorded geometry/codec timelines
        (``repro.obs.account`` — exact per collective per tier, the
        repo-wide byte-model invariant).  Steps duplicated by
        snapshot-resumed retries are billed once, under the geometry
        their surviving execution used; the duplicated work shows up in
        ``serve.restarts``, not here."""
        rec = self.recorder
        if rec is None:
            return
        from repro.core.comm_model import VDMCommConfig
        from repro.obs.account import attribute_denoise_steps

        ccfg = VDMCommConfig(
            latent_dims=tuple(shape),
            latent_channels=self.cfg.latent_channels,
            patch_sizes=self.cfg.patch_sizes,
            d_model=self.cfg.d_model,
            num_blocks=self.cfg.num_layers,
            num_steps=self.num_steps,
        )
        # merge the codec timeline: latest event at or before each step
        codecs = list(self._batch_codecs)
        for from_step, names in self._codec_events:
            for i in range(from_step, self.num_steps + 1):
                codecs[i - 1] = names[i - 1]
        records = attribute_denoise_steps(
            ccfg, self.r, codecs, self._geom_events, tp=self.tp,
            wire_shard=self.wire_shard, lp_impl=self.lp_impl,
            links=rec.links, batch_size=batch_size,
        )
        rec.record_wire_steps(records)
        runs = rec.measured_runs[self._runs_mark:]
        if runs:
            from repro.obs.account import reconcile_segments

            rec.record_reconciliations(reconcile_segments(records, runs))

    # ------------------------------------------------ request lifecycle
    def _finalize_requests(self, results: List[VideoResult]) -> None:
        """Close each request's lifecycle: stamp ``done_s``, derive
        ``queue_wait_s`` / ``e2e_s`` (onto the :class:`VideoResult` and
        the lifecycle row), check the SLO deadline for the request's
        priority class, and hand the row to the recorder — which emits
        it as a ``request.lifecycle`` trace span and feeds the
        per-priority latency histograms.  All stamps share the engine
        clock, so under the load harness the row lives entirely on the
        workload's virtual timeline."""
        done_s = float(self.clock())
        rec = self.recorder
        for res in results:
            life = self._lifecycle.pop(res.request_id, None)
            if life is None:
                continue
            life["done_s"] = done_s
            life["queue_wait_s"] = life["admit_s"] - life["submit_s"]
            life["e2e_s"] = done_s - life["submit_s"]
            life["restarts"] = res.restarts
            res.queue_wait_s = life["queue_wait_s"]
            res.e2e_s = life["e2e_s"]
            if self.slo is not None:
                deadline = self.slo.deadline_for(life["priority"])
                life["deadline_s"] = (deadline
                                      if deadline != float("inf") else None)
                life["violated"] = bool(life["e2e_s"] > deadline)
            if rec is not None:
                rec.record_request(life)

    def run(self, max_batches: Optional[int] = None,
            max_restarts_per_batch: int = 2) -> List[VideoResult]:
        """Drain the queue.  A batch that fails with a *recoverable*
        fault (``DeviceFailure`` — lost hardware; ``ServingFault`` —
        group death / injected wire fault) retries from its last
        boundary snapshot, bounded by ``max_restarts_per_batch``.  Any
        other exception is a programming/XLA error and surfaces
        immediately instead of burning restarts on a deterministic
        failure."""
        out: List[VideoResult] = []
        batches = 0
        while self._queue and (max_batches is None or batches < max_batches):
            # draining: don't wait out the admission age, force-launch
            reqs = self._next_batch(force=True)
            if not reqs:
                break
            # visible to the replica router: if this batch dies with the
            # replica (ReplicaDeath propagates — it is deliberately not
            # a ServingFault, a dead replica cannot retry itself) the
            # router requeues these requests elsewhere.  Cleared only on
            # success, so a terminal ServingFault leaves them readable
            # too (the router may still redispatch them).
            self._inflight = list(reqs)
            restarts = 0
            resumed_from = 0
            snapshot = DenoiseSnapshot() if self.snapshots else None
            rec = self.recorder
            # fresh attribution timelines for this batch (retries keep
            # appending to them: the timeline describes the geometry of
            # each logical step's SURVIVING execution)
            self._geom_events = [(1, self.K)]
            self._codec_events = []
            self._batch_codecs = self._step_codec_names()
            self._runs_mark = 0 if rec is None else len(rec.measured_runs)
            while True:
                try:
                    results = self._denoise_batch(reqs, snapshot)
                    for res in results:
                        res.restarts = restarts
                        res.resumed_from_step = resumed_from
                    self._finalize_requests(results)
                    self._inflight = []
                    out.extend(results)
                    self._record_batch_wire(reqs[0].latent_shape,
                                            len(reqs))
                    if rec is not None:
                        rec.inc(obsm.BATCHES, **self._rlabels())
                    break
                except (DeviceFailure, ServingFault) as e:
                    restarts += 1
                    step = getattr(e, "step", None)
                    if snapshot is not None and step is not None:
                        self.last_steps_lost = max(
                            0, int(step) - 1 - snapshot.step)
                    resumed_from = 0 if snapshot is None else snapshot.step
                    if rec is not None:
                        rec.instant("batch.restart", cat="serve",
                                    restarts=restarts,
                                    fault=str(e),
                                    resume_from=resumed_from,
                                    **self._rlabels())
                        rec.inc(obsm.RESTARTS, **self._rlabels())
                    if restarts > max_restarts_per_batch:
                        # terminal: this batch will never be finalized
                        # — drop its lifecycle rows (a later reused
                        # request_id must not inherit stale stamps)
                        # with a failed-lifecycle marker in the trace
                        failed_s = float(self.clock())
                        for r in reqs:
                            life = self._lifecycle.pop(
                                r.request_id, None)
                            if rec is not None and life is not None:
                                rec.instant(
                                    "request.failed", cat="serve",
                                    request_id=r.request_id,
                                    priority=life["priority"],
                                    submit_s=life["submit_s"],
                                    failed_s=failed_s,
                                    restarts=restarts, fault=str(e))
                        raise
            batches += 1
        return out
