"""Train-step factory: value_and_grad + microbatch accumulation + remat +
optimizer update, ready for jit with sharded params/batch.

Microbatch accumulation scans over batch slices with fp32 grad
accumulators — the standard way a 1M-token global batch fits HBM on a
256-chip pod (llama3-405b: microbatch 8 sequences/device-step x 32
accumulation steps; see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from repro.models.scan_util import pscan

from repro.configs.base import ArchConfig, ParallelConfig
from repro.optim import get_optimizer
from repro.optim.schedule import warmup_cosine


def make_train_step(
    model,
    parallel: ParallelConfig,
    peak_lr: float = 3e-4,
    total_steps: int = 10_000,
) -> Callable:
    opt_init, opt_update = get_optimizer(parallel.optimizer)

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=(parallel.remat != "none"))

    def train_step(params, opt_state, batch, step):
        k = parallel.microbatch
        if k <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % k == 0, f"batch {b} not divisible by microbatch {k}"
                return x.reshape(k, b // k, *x.shape[1:])

            mbs = jax.tree.map(resh, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc_body(carry, mb):
                tot_loss, acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g
                )
                return (tot_loss + l, acc), None

            (loss, grads), _ = pscan(
                acc_body, (jnp.float32(0.0), zero), mbs
            )
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)

        lr = warmup_cosine(step, peak_lr, total=total_steps)
        new_params, new_state, gnorm = opt_update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    train_step.opt_init = opt_init
    return train_step
