"""Sigma-scheduled wire codecs: the spec grammar and its resolution
against a sampler's sigma trajectory.

A schedule is an ordered list of **sigma-threshold segments**::

    int4-residual@0.85,int8-residual@0.45,bf16

reads "int4-residual while sigma >= 0.85, int8-residual while
sigma >= 0.45, bf16 for the rest (the tail)".  Thresholds must be
strictly decreasing and the last segment must be thresholdless so every
sigma is covered.  ``fp32`` (or a bare codec name with no thresholds)
is the degenerate single-segment schedule — fixed-codec behaviour.

Resolution is **trajectory-derived**: forward pass ``i`` (1-indexed)
runs at the sampler's ``sigma_i``, so the same spec maps to different
step ranges for different samplers / step counts / shifts — e.g. WAN's
shift=3 schedule spends half its steps above sigma 0.75, so a 0.85
threshold covers a third of the run rather than the naive 15%.
``segment_steps`` returns the contiguous per-codec step runs that
``core/lp_step.lp_denoise`` turns into segmented scans (one ``lax.scan``
per dim-run x segment, residual state reset at each boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple, Union

import numpy as np

#: Default sigma thresholds (see docs/step_policy.md): calibrated so the
#: WAN shift=3 trajectory splits roughly into thirds — high-noise head,
#: mid, and precision tail.
DEFAULT_S_HI = 0.85
DEFAULT_S_LO = 0.45


@dataclasses.dataclass(frozen=True)
class ScheduleSegment:
    """One spec segment: use ``codec`` while sigma >= ``sigma_lo``."""

    codec: str
    sigma_lo: float  # 0.0 for the tail segment


@dataclasses.dataclass(frozen=True)
class StepRun:
    """A resolved contiguous run of forward passes on one codec.
    ``start``/``stop`` are 1-indexed inclusive pass numbers."""

    codec: str
    start: int
    stop: int

    @property
    def num_steps(self) -> int:
        return self.stop - self.start + 1


@dataclasses.dataclass(frozen=True)
class CodecSchedule:
    """Validated sigma-threshold codec schedule."""

    segments: Tuple[ScheduleSegment, ...]

    def __post_init__(self):
        from repro.comm.codecs import get_codec

        if not self.segments:
            raise ValueError("schedule needs at least one segment")
        if self.segments[-1].sigma_lo != 0.0:
            raise ValueError(
                "the last schedule segment must be thresholdless (it is "
                "the tail covering sigma down to 0)"
            )
        prev = 1.0  # sigma never exceeds 1: a larger threshold is a typo
        for seg in self.segments:
            get_codec(seg.codec)  # unknown names fail loudly here
            if not 0.0 <= seg.sigma_lo < prev:
                raise ValueError(
                    f"sigma thresholds must be strictly decreasing in "
                    f"[0, 1): got {[s.sigma_lo for s in self.segments]}"
                )
            prev = seg.sigma_lo

    # ------------------------------------------------------------ queries
    @property
    def spec(self) -> str:
        """Round-trippable spec string (``parse_schedule(s.spec) == s``)."""
        return ",".join(
            seg.codec if seg.sigma_lo == 0.0 else f"{seg.codec}@{seg.sigma_lo:g}"
            for seg in self.segments
        )

    @property
    def fixed_codec(self) -> Union[str, None]:
        """The codec name if this is a single-segment (fixed) schedule."""
        return self.segments[0].codec if len(self.segments) == 1 else None

    def codec_for_sigma(self, sigma: float) -> str:
        for seg in self.segments:
            if sigma >= seg.sigma_lo:
                return seg.codec
        return self.segments[-1].codec  # sigma < 0 never happens; guard

    def step_codecs(self, sigmas: Sequence[float]) -> Tuple[str, ...]:
        """Per-forward-pass codec names for a sigma trajectory
        (``sigmas[i]`` is the noise level of pass ``i+1``)."""
        return tuple(self.codec_for_sigma(float(s)) for s in sigmas)

    @classmethod
    def fixed(cls, codec: str) -> "CodecSchedule":
        return cls((ScheduleSegment(codec, 0.0),))


def parse_schedule(spec: Union[str, CodecSchedule, None]) -> CodecSchedule:
    """Parse a CLI spec (``codec[@sigma],...``) into a schedule.

    ``None`` means fp32 everywhere (the exact baseline), mirroring
    ``comm.codecs.get_codec(None)``.  A bare codec name is the fixed
    single-segment schedule of that codec.
    """
    if spec is None:
        return CodecSchedule.fixed("fp32")
    if isinstance(spec, CodecSchedule):
        return spec
    segments = []
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if not parts:
        raise ValueError(f"empty schedule spec {spec!r}")
    for i, part in enumerate(parts):
        if "@" in part:
            codec, _, thr = part.partition("@")
            if i == len(parts) - 1:
                raise ValueError(
                    f"schedule spec {spec!r}: the last segment is the "
                    f"tail and must not carry a sigma threshold"
                )
            try:
                sigma_lo = float(thr)
            except ValueError:
                raise ValueError(
                    f"schedule spec {spec!r}: bad sigma threshold {thr!r}"
                ) from None
        else:
            codec, sigma_lo = part, 0.0
            if i != len(parts) - 1:
                raise ValueError(
                    f"schedule spec {spec!r}: only the tail segment may "
                    f"omit its sigma threshold"
                )
        segments.append(ScheduleSegment(codec.strip(), sigma_lo))
    return CodecSchedule(tuple(segments))


def trajectory_sigmas(sampler, num_steps: int) -> Tuple[float, ...]:
    """Per-forward-pass noise levels from the sampler.

    Flow-matching samplers expose ``sigmas()`` directly (pass ``i`` runs
    at ``sigmas()[i-1]``).  Timestep-indexed samplers (DDIM) fall back
    to the normalized conditioning timestep — monotone in noise level,
    which is all the threshold comparison needs.
    """
    if hasattr(sampler, "sigmas"):
        s = np.asarray(sampler.sigmas(), np.float64)
        if len(s) < num_steps:
            raise ValueError(
                f"sampler provides {len(s)} sigmas for {num_steps} steps"
            )
        return tuple(float(x) for x in s[:num_steps])
    tmax = max(float(sampler.timestep(i)) for i in range(1, num_steps + 1))
    return tuple(
        float(sampler.timestep(i)) / max(tmax, 1e-9)
        for i in range(1, num_steps + 1)
    )


def segment_steps(
    schedule: CodecSchedule, sigmas: Sequence[float]
) -> Tuple[StepRun, ...]:
    """Contiguous per-codec step runs of a resolved schedule.

    Adjacent spec segments that resolve to the same codec merge (one
    scan, one residual state): ``num_segments`` for the compile-count
    contract (<= 3 x num_segments per denoise) is ``len()`` of this.
    """
    codecs = schedule.step_codecs(sigmas)
    runs = []
    for i, c in enumerate(codecs, start=1):
        if runs and runs[-1][0] == c:
            runs[-1][2] = i
        else:
            runs.append([c, i, i])
    return tuple(StepRun(c, lo, hi) for c, lo, hi in runs)
