"""Step-policy subsystem: per-denoise decisions the engine used to freeze.

The paper's comm wins come from exploiting what the denoising trajectory
tolerates at each timestep.  PR 2's wire codecs were chosen once per
request; this package owns two decisions per denoise instead:

  * ``schedule`` — a **codec schedule over timesteps**: sigma-threshold
    segments (e.g. int8-residual while sigma >= s_hi, int8 mid, bf16
    tail), with segment boundaries resolved against the sampler's actual
    sigma trajectory.  ``core/lp_step.lp_denoise`` executes a schedule
    as segmented scans: one ``lax.scan`` per (rotation-dim run x codec
    segment), residual codec state reset exactly once per segment
    boundary, segment codec in the compiled-step cache key (compiles
    <= 3 x num_segments per denoise).
  * ``envelope`` — the conformance-matrix PSNR envelope (the per-codec
    floors ``tests/test_lp_conformance.py`` gates: bf16 >= 50 dB,
    int8* >= 40 dB, int4* >= 24 dB) plus the sigma-credit model that
    says how much of that floor a high-noise step can spend.
  * ``autotune`` — the cost-model-driven planner: picks (engine, codec
    schedule) by minimizing ``core/comm_model`` analytic wire bytes
    subject to a caller PSNR floor against the envelope.

Wired through ``LPStepCompiler(schedule=)``, ``LPServingEngine
(codec_schedule=)``, and ``--codec-schedule auto|<spec>`` /
``--psnr-floor`` in ``launch/serve.py`` and ``launch/dryrun.py``.
"""
from .envelope import (  # noqa: F401
    HIGH_NOISE_CREDIT_DB,
    PSNR_ENVELOPE_DB,
    codec_floor_db,
    effective_floor_db,
    schedule_envelope_db,
)
from .schedule import (  # noqa: F401
    CodecSchedule,
    ScheduleSegment,
    parse_schedule,
    segment_steps,
)
from .autotune import (  # noqa: F401
    DEFAULT_LINKS,
    LinkModel,
    StepPolicyPlan,
    auto_plan,
    resolve_cli_schedule,
)
