"""Cost-model-driven plan autotuning: pick (engine, codec schedule) by
minimizing analytic wire bytes subject to a PSNR floor.

The search space is small and the cost model is exact, so this is a
closed-form walk rather than a search:

1. Rank candidate codecs by their fixed-codec per-denoise wire bytes
   (``comm_model.comm_lp_halo_codec`` — bits dominate, residual variants
   tie with their base and win the tie on measured quality).
2. For each codec, the envelope gives the *lowest sigma it is admissible
   at* for the requested floor: ``(floor - codec_floor) / credit``
   (``policy/envelope``).  The byte-minimal schedule is then "cheapest
   admissible codec at every sigma", which is exactly a stack of
   sigma-threshold segments — cheaper codecs on top (high noise),
   precision codecs at the tail.
3. Resolve the schedule against the sampler's sigma trajectory and
   charge it with ``comm_model.comm_lp_halo_scheduled``; if the psum
   engine's fp32 bytes (``comm_lp_spmd``) undercut the scheduled halo
   bytes (short schedules at K=2 with a strict floor), the plan keeps
   the psum engine instead — the same break-even rule
   ``core/spmd.select_lp_impl`` hardcodes, now derived per request.
4. On hybrid ``(M, T)`` meshes, price the two link tiers separately
   (:class:`LinkModel`: ``inter_gbps`` for the slow inter-group links,
   ``intra_gbps`` for the fast intra-group fabric) and rank the
   wire-shard choice by **weighted wire time**, not raw bytes: sharding
   the halo wire over the tp axis cuts inter-group bytes T-fold but
   adds an intra-group reassembly gather
   (``comm_model.lp_halo_wire_profile``), so it wins exactly when the
   inter links are the binding constraint — at T=4 with the default
   10:1 ratio the sharded wire dominates every unsharded plan, while
   equal-bandwidth links flip the decision back (the reassembly gather
   then costs more than the inter saving).
5. ``displaced:*`` candidates (stale-slab halo, ``comm/wire.py``) tie
   their residual bases on bytes but zero out the slab-ppermute term of
   the EXPOSED wire profile (``lp_halo_wire_profile``'s ``hidden``
   tier), so the ranking schedules them wherever the envelope's sigma
   credit admits the staleness floor — trading quality headroom at the
   noise-dominated head for wire time the compute can hide.  They are
   only offered on single-rotation-dim geometries (a dim switch forces
   a synchronous first step, so length-1 runs hide nothing) and pin the
   plan to the halo family (psum/gspmd keep no slab carry).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

from repro.core import comm_model as cm
from repro.core.schedule import usable_dims

from .envelope import (
    HIGH_NOISE_CREDIT_DB,
    codec_floor_db,
    schedule_envelope_db,
)
from .schedule import (
    CodecSchedule,
    ScheduleSegment,
    StepRun,
    parse_schedule,
    segment_steps,
    trajectory_sigmas,
)

#: Candidate codecs the planner may schedule, all conformance-gated.
#: The ``displaced:*`` variants move the same bytes as their residual
#: bases but hide the slab-ppermute portion behind compute (see
#: ``comm_model.lp_halo_wire_profile``'s ``hidden`` tier), at a steep
#: quality floor — the envelope's sigma credit confines them to the
#: high-noise head.  They are dropped on multi-rotation-dim geometries,
#: where every (dim x codec) run has length 1 and the mandatory
#: synchronous first step means nothing would ever be hidden.
DEFAULT_CANDIDATES = (
    "displaced:int4-residual", "displaced:int8-residual",
    "int4-residual", "int4", "int8-residual", "int8", "bf16", "fp32",
)


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Two-tier link bandwidths (GB/s per device) for the weighted
    wire-time ranking.

    ``inter_gbps`` prices the inter-group links the lp-axis collectives
    cross (DCN / inter-host ICI — the binding constraint the paper and
    DualParal identify); ``intra_gbps`` the intra-group fabric the tp
    reassembly gathers ride (NVLink / same-host ICI).  The default 10:1
    ratio is the conventional fast-fabric : network gap; operators
    should calibrate both to their topology.
    """

    inter_gbps: float = 25.0
    intra_gbps: float = 250.0

    def wire_time_ms(self, inter_bytes: float, intra_bytes: float) -> float:
        """Per-device wire time of (inter, intra) bytes, milliseconds."""
        return (inter_bytes / (self.inter_gbps * 1e9)
                + intra_bytes / (self.intra_gbps * 1e9)) * 1e3


DEFAULT_LINKS = LinkModel()


@dataclasses.dataclass(frozen=True)
class StepPolicyPlan:
    """One denoise's resolved policy: engine + codec schedule + the
    analytic bytes that justified it."""

    lp_impl: str                        # halo | halo_hybrid | shard_map
    schedule: CodecSchedule
    step_codecs: Tuple[str, ...]        # resolved, one per forward pass
    segments: Tuple[StepRun, ...]       # contiguous same-codec step runs
    wire_bytes: int                     # analytic bytes of this plan
    fp32_halo_bytes: int                # fp32 halo baseline, same steps
    psum_bytes: int                     # fp32 psum engine, same steps
    psnr_floor_db: Optional[float]      # the constraint (None = unchecked)
    envelope_db: float                  # conservative schedule envelope
    # two-tier wire profile (hybrid meshes; zeros when tp == 1):
    wire_shard: bool = False            # shard the halo wire over tp
    inter_bytes: int = 0                # per-device EXPOSED inter bytes
    intra_bytes: int = 0                # per-device intra-group LP bytes
    wire_time_ms: float = 0.0           # weighted two-tier wire time
    # displaced-halo slab ppermutes that overlap compute instead of
    # gating the step (``lp_halo_wire_profile``'s hidden tier); the
    # compiled HLO still moves inter_bytes + hidden_bytes on the inter
    # links, but wire_time_ms prices only the exposed portion
    hidden_bytes: int = 0

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def reduction_vs_fp32_halo(self) -> float:
        return self.fp32_halo_bytes / max(self.wire_bytes, 1)

    def describe(self) -> str:
        segs = " ".join(
            f"{s.codec}[{s.start}..{s.stop}]" for s in self.segments
        )
        shard = " wire_shard" if self.wire_shard else ""
        hidden = (f", {self.hidden_bytes} B hidden"
                  if self.hidden_bytes else "")
        return (
            f"{self.lp_impl}{shard} schedule={self.schedule.spec} -> {segs} "
            f"({self.reduction_vs_fp32_halo:.2f}x vs fp32 halo, "
            f"envelope {self.envelope_db:.0f} dB{hidden})"
        )


def _rank_candidates(
    cfg: cm.VDMCommConfig, K: int, r: float, names: Sequence[str]
) -> Tuple[str, ...]:
    """Cheapest-first by fixed-codec denoise bytes; displaced variants
    win byte ties over their bases (same wire layout, strictly less
    EXPOSED wire time — and sorting them first is what lets the
    sigma-threshold stacker give them the high-noise head while the
    synchronous base covers the range below), then residual variants
    over plain (same layout, strictly better measured PSNR)."""
    def key(name):
        return (
            cm.comm_lp_halo_codec(cfg, K, r, name),
            0 if name.startswith("displaced") else 1,
            0 if name.endswith("-residual") else 1,
            -codec_floor_db(name),
        )
    return tuple(sorted(names, key=key))


def schedule_for_floor(
    cfg: cm.VDMCommConfig,
    K: int,
    r: float,
    psnr_floor_db: float,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    credit_db: float = HIGH_NOISE_CREDIT_DB,
) -> CodecSchedule:
    """Byte-minimal sigma-threshold schedule meeting the floor.

    Each candidate is admissible down to sigma = (floor - codec_floor)
    / credit; stacking candidates cheapest-first yields the optimal
    segments directly (per-step byte costs are additive and the
    admissible set only shrinks as sigma falls).
    """
    segments = []
    covered_down_to = float("inf")
    for name in _rank_candidates(cfg, K, r, candidates):
        floor = codec_floor_db(name)
        if floor >= psnr_floor_db:
            adm = 0.0
        else:
            adm = (psnr_floor_db - floor) / credit_db
        if adm >= min(covered_down_to, 1.0):
            continue  # a cheaper codec already covers every sigma <= 1
        segments.append(ScheduleSegment(name, adm))
        covered_down_to = adm
        if adm == 0.0:
            break
    if not segments or segments[-1].sigma_lo != 0.0:
        raise ValueError(
            f"no candidate codec meets psnr_floor={psnr_floor_db} dB at "
            f"the tail (envelope tops out below the floor): {candidates}"
        )
    return CodecSchedule(tuple(segments))


def _plan_from_schedule(
    cfg: cm.VDMCommConfig,
    K: int,
    r: float,
    schedule: CodecSchedule,
    sigmas: Sequence[float],
    tp: int,
    psnr_floor_db: Optional[float],
    credit_db: float,
    allow_engine_flip: bool = True,
    links: LinkModel = DEFAULT_LINKS,
    wire_shard: Optional[bool] = None,
) -> StepPolicyPlan:
    from repro.core.spmd import select_lp_impl

    num_steps = len(sigmas)
    step_codecs = schedule.step_codecs(sigmas)
    segments = segment_steps(schedule, sigmas)
    displaced = any(str(c).startswith("displaced") for c in step_codecs)
    wire = cm.comm_lp_halo_scheduled(cfg, K, r, step_codecs)
    fp32_halo = cm.comm_lp_halo_scheduled(cfg, K, r, ("fp32",) * num_steps)
    cfg_t = dataclasses.replace(cfg, num_steps=num_steps)
    psum = cm.comm_lp_spmd(cfg_t, K, r)
    envelope = schedule_envelope_db(step_codecs, sigmas, credit_db)
    if set(step_codecs) == {"fp32"}:
        # nothing to compress: fall back to the static break-even rule
        lp_impl = select_lp_impl(K, tp)
        if lp_impl == "shard_map":
            wire = psum
    elif allow_engine_flip and psum < wire and tp == 1 and not displaced:
        # (``not displaced``: a displaced schedule was chosen to HIDE
        # wire time behind compute — a raw-bytes comparison against the
        # psum ring would discard exactly that, and the psum engine has
        # no carry-resident slab state to run it on anyway)
        # a strict floor shrank the compressible range enough that the
        # psum engine's fp32 ring beats the codec'd halo schedule.
        # Auto plans only: an explicit operator schedule is a pin, not
        # a suggestion — silently swapping it for fp32/psum would drop
        # the codecs the operator asked for.
        lp_impl = "shard_map"
        schedule = CodecSchedule.fixed("fp32")
        step_codecs = ("fp32",) * num_steps
        segments = segment_steps(schedule, sigmas)
        wire = psum
        envelope = float("inf")
    else:
        lp_impl = "halo_hybrid" if tp > 1 else "halo"
    if displaced and lp_impl not in ("halo", "halo_hybrid"):
        raise ValueError(
            f"schedule {schedule.spec!r} uses a displaced halo codec, "
            f"which needs carry-resident slab state — the {lp_impl!r} "
            "engine keeps none (psum/gspmd family)"
        )
    # two-tier wire profile + the wire-shard decision (weighted TIME,
    # not bytes: sharding trades inter-group bytes for an intra-group
    # reassembly gather, and only the link ratio says which wins).  The
    # profile's ``inter`` is the EXPOSED portion — displaced steps'
    # hidden slab ppermutes are priced at zero, which is exactly how
    # displaced wins the ranking without moving fewer bytes.
    ws = False
    inter = intra = hidden = 0
    if lp_impl == "halo_hybrid" and tp > 1:
        prof_off = cm.lp_halo_wire_profile(cfg, K, tp, r, step_codecs,
                                           wire_shard=False)
        prof_on = cm.lp_halo_wire_profile(cfg, K, tp, r, step_codecs,
                                          wire_shard=True)
        t_off = links.wire_time_ms(prof_off["inter"], prof_off["intra"])
        t_on = links.wire_time_ms(prof_on["inter"], prof_on["intra"])
        ws = (t_on < t_off) if wire_shard is None else bool(wire_shard)
        prof = prof_on if ws else prof_off
        inter, intra, hidden = prof["inter"], prof["intra"], prof["hidden"]
    elif lp_impl == "halo":
        prof = cm.lp_halo_wire_profile(cfg, K, 1, r, step_codecs,
                                       wire_shard=False)
        inter, hidden = prof["inter"], prof["hidden"]
    else:  # shard_map: the psum ring, per device
        inter = psum // K
    return StepPolicyPlan(
        lp_impl=lp_impl,
        schedule=schedule,
        step_codecs=tuple(step_codecs),
        segments=segments,
        wire_bytes=int(wire),
        fp32_halo_bytes=int(fp32_halo),
        psum_bytes=int(psum),
        psnr_floor_db=psnr_floor_db,
        envelope_db=envelope,
        wire_shard=ws,
        inter_bytes=int(inter),
        intra_bytes=int(intra),
        wire_time_ms=links.wire_time_ms(inter, intra),
        hidden_bytes=int(hidden),
    )


def auto_plan(
    cfg: cm.VDMCommConfig,
    K: int,
    r: float,
    sampler,
    num_steps: int,
    psnr_floor_db: float = 40.0,
    tp: int = 1,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    credit_db: float = HIGH_NOISE_CREDIT_DB,
    links: LinkModel = DEFAULT_LINKS,
    wire_shard: Optional[bool] = None,
    recorder=None,
) -> StepPolicyPlan:
    """The auto-plan: byte-minimal (engine, codec schedule) meeting the
    PSNR floor on this workload geometry and sigma trajectory.  On
    hybrid meshes (``tp > 1``) the wire-shard decision is made by
    weighted wire time under ``links`` (``wire_shard=None``); pass a
    bool to pin it.

    ``recorder`` (``repro.obs.FlightRecorder``, optional) gets the
    chosen plan plus the autotuner's ranked candidate field — cheapest
    first, each priced by its fixed-codec denoise bytes — so a trace
    shows not just what was picked but what it beat."""
    dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, K)
    if not dims:
        raise ValueError(
            f"no latent dim of {cfg.latent_dims} has >= {K} patches"
        )
    if len(dims) > 1:
        # the dim rotation re-inits wire state every step here, so every
        # (dim x codec) run has length 1 and its mandatory synchronous
        # first step is the WHOLE run: displaced would hide zero bytes
        # while still paying the staleness floor — never worth offering
        candidates = tuple(
            c for c in candidates if not str(c).startswith("displaced")
        )
    sigmas = trajectory_sigmas(sampler, num_steps)
    schedule = schedule_for_floor(cfg, K, r, psnr_floor_db, candidates,
                                  credit_db)
    plan = _plan_from_schedule(cfg, K, r, schedule, sigmas, tp,
                               psnr_floor_db, credit_db, links=links,
                               wire_shard=wire_shard)
    if recorder is not None:
        ranked = [
            {"codec": name,
             "denoise_bytes": int(cm.comm_lp_halo_codec(cfg, K, r, name)),
             "floor_db": float(codec_floor_db(name))}
            for name in _rank_candidates(cfg, K, r, candidates)
        ]
        recorder.record_plan(plan, candidates=ranked, context="auto")
    return plan


def resolve_cli_schedule(
    spec: Union[str, CodecSchedule, None],
    cfg: cm.VDMCommConfig,
    K: int,
    r: float,
    sampler,
    num_steps: int,
    psnr_floor_db: Optional[float] = None,
    tp: int = 1,
    links: LinkModel = DEFAULT_LINKS,
    wire_shard: Optional[bool] = None,
    recorder=None,
) -> StepPolicyPlan:
    """Shared ``--codec-schedule`` resolution for serve/dryrun.

    ``"auto"`` runs :func:`auto_plan` (floor defaults to 40 dB, the
    serving-tolerance gate).  An explicit spec is parsed and charged;
    it is validated against the envelope only when the caller also
    passed a floor — an explicit spec is an operator override, but an
    explicit spec AND an explicit floor that contradict each other is
    a config error worth failing loudly on.  ``wire_shard`` follows the
    same convention: ``None`` lets the two-tier cost model decide on
    hybrid meshes, a bool pins the operator's choice.
    """
    if isinstance(spec, str) and spec.strip().lower() == "auto":
        return auto_plan(cfg, K, r, sampler, num_steps,
                         psnr_floor_db=40.0 if psnr_floor_db is None
                         else psnr_floor_db, tp=tp, links=links,
                         wire_shard=wire_shard, recorder=recorder)
    schedule = parse_schedule(spec)
    sigmas = trajectory_sigmas(sampler, num_steps)
    plan = _plan_from_schedule(cfg, K, r, schedule, sigmas, tp,
                               psnr_floor_db, HIGH_NOISE_CREDIT_DB,
                               allow_engine_flip=False, links=links,
                               wire_shard=wire_shard)
    if psnr_floor_db is not None and plan.envelope_db < psnr_floor_db:
        raise ValueError(
            f"schedule {schedule.spec!r} has envelope "
            f"{plan.envelope_db:.0f} dB < requested floor "
            f"{psnr_floor_db:.0f} dB (see docs/step_policy.md)"
        )
    if recorder is not None:
        # explicit spec: an operator pin, so there is no candidate field
        recorder.record_plan(plan, context="explicit")
    return plan
