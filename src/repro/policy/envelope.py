"""The PSNR envelope: what quality each wire codec is good for, per sigma.

Two facts combine into the admissibility rule the autotuner uses:

1. **Per-codec floors** — the conformance matrix
   (``tests/test_lp_conformance.py``) gates every engine x codec cell at
   a documented single-forward-pass PSNR floor on N(0,1) latents.  Those
   floors ARE the envelope: they are the worst-case reconstruction
   quality a codec is allowed to deliver, enforced in CI for every
   engine, so the planner can rely on them without profiling.

2. **Sigma credit** — a quantization error injected while the latent is
   still mostly noise is cheaper than the same error near the end of the
   trajectory.  Early high-noise forward passes see a z that is sigma
   parts noise; the denoiser re-estimates from the perturbed latent at
   every subsequent step, so per-step wire error at noise level sigma is
   attenuated before it reaches z_0, while tail-step error (sigma -> 0)
   lands on the output unlaundered.  We model the relaxation as linear
   in sigma: a segment whose smallest sigma is s may use a codec whose
   floor is up to ``HIGH_NOISE_CREDIT_DB * s`` dB below the requested
   end-to-end floor.  The constant is calibrated against measured
   end-to-end PSNR of scheduled runs on the reduced WAN DiT
   (``benchmarks/codec_schedule.py`` gates the result at >= 40 dB), and
   deliberately conservative: at sigma = 0 the credit vanishes, so the
   tail segment must meet the requested floor outright.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence, Tuple

#: Conformance-matrix floors (dB), single forward pass vs the fp32 psum
#: reference — the single source of truth; ``tests/test_lp_conformance``
#: imports these so the CI gate and the planner can never disagree.
PSNR_ENVELOPE_DB = {
    "fp32": math.inf,
    "bf16": 50.0,
    "int8": 40.0,
    "int8-residual": 40.0,
    "int4": 24.0,
    "int4-residual": 24.0,
    # Displaced halo (``comm/wire.py``): the exchange blends one-step-
    # stale slabs through the residual EF carry, so per-step error is a
    # full Euler increment of the boundary rows — staleness dominates
    # quantization, which is why the int8/int4 variants sit within 2 dB
    # of each other and FAR below their synchronous bases.  Calibrated
    # against multi-step scheduled runs (tests/test_wire_codec.py): a
    # fully-displaced 6-step denoise measures ~17 dB with min sigma
    # ~0.17, bounding the int8 floor at 14; prefix schedules confined
    # to sigma >= 0.75 recover 40+ dB, which the sigma credit predicts.
    # The planner therefore only admits displaced segments where the
    # credit is large (early, noise-dominated steps).
    "displaced:int8-residual": 14.0,
    "displaced:int4-residual": 12.0,
}

#: dB of floor a segment may give back per unit of (minimum) sigma.
HIGH_NOISE_CREDIT_DB = 20.0


def codec_floor_db(name: str) -> float:
    """Envelope floor of one codec (KeyError on unknown names is a bug
    guard: a codec without a conformance floor cannot be scheduled)."""
    try:
        return PSNR_ENVELOPE_DB[name]
    except KeyError:
        raise ValueError(
            f"codec {name!r} has no conformance-envelope floor; know "
            f"{sorted(PSNR_ENVELOPE_DB)}"
        ) from None


def effective_floor_db(
    name: str,
    sigma_min: float,
    credit_db: float = HIGH_NOISE_CREDIT_DB,
) -> float:
    """Envelope floor of ``name`` credited for running at noise level
    >= ``sigma_min``: the quality the codec is good for *end to end*
    when every step it covers still carries that much noise."""
    return codec_floor_db(name) + credit_db * max(float(sigma_min), 0.0)


def admissible_codecs(
    psnr_floor_db: float,
    sigma_min: float,
    names: Iterable[str] = None,
    credit_db: float = HIGH_NOISE_CREDIT_DB,
) -> Tuple[str, ...]:
    """Codecs whose credited floor meets ``psnr_floor_db`` at
    ``sigma_min`` (candidate set for one schedule segment)."""
    if names is None:
        names = PSNR_ENVELOPE_DB.keys()
    return tuple(
        n for n in names
        if effective_floor_db(n, sigma_min, credit_db) >= psnr_floor_db
    )


def schedule_envelope_db(
    step_codecs: Sequence[str],
    sigmas: Sequence[float],
    credit_db: float = HIGH_NOISE_CREDIT_DB,
) -> float:
    """Conservative end-to-end envelope of a resolved schedule: the
    minimum credited floor over steps (the worst step bounds the run).

    ``step_codecs[i]`` is the codec of forward pass ``i+1``;
    ``sigmas[i]`` the noise level that pass runs at.
    """
    if len(step_codecs) != len(sigmas):
        raise ValueError(
            f"{len(step_codecs)} step codecs vs {len(sigmas)} sigmas"
        )
    if not step_codecs:
        raise ValueError("empty schedule has no envelope")
    return min(
        effective_floor_db(c, s, credit_db)
        for c, s in zip(step_codecs, sigmas)
    )
