"""Adafactor (Shazeer & Stern 2018): factored second moments, no momentum.

State for an (a, b) matrix is an (a,) row accumulator + (b,) column
accumulator instead of (a, b) — the reason llama3-405b training fits a
single v5e pod.  Leading stacked-layer axes are treated as batch dims
(factoring applies to the trailing two dims).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor_init(params):
    def init(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "acc": jax.tree.map(init, params, is_leaf=lambda x: hasattr(x, "ndim")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    grads, state, params, lr,
    decay_exp: float = 0.8, eps1: float = 1e-30, eps2: float = 1e-3,
    clip_threshold: float = 1.0, weight_decay: float = 0.0,
    max_grad_norm: float = 1.0,
):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    gclip = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-decay_exp)

    def upd(p, g, acc):
        g = g.astype(jnp.float32) * gclip
        g2 = jnp.square(g) + eps1
        if _factored(p):
            vr = beta2 * acc["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * acc["vc"] + (1 - beta2) * g2.mean(axis=-2)
            rfac = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps1))
            u = g * jax.lax.rsqrt(rfac)[..., None] * jax.lax.rsqrt(
                jnp.maximum(vc, eps1)
            )[..., None, :]
            new_acc = {"vr": vr, "vc": vc}
        else:
            v = beta2 * acc["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps1))
            new_acc = {"v": v}
        # update clipping by RMS (adafactor's d=1 rule)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        scale = jnp.maximum(
            jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))), eps2
        )
        newp = p.astype(jnp.float32) - lr * scale * u
        if weight_decay and p.ndim >= 2:
            newp = newp - lr * weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), new_acc

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    accs = treedef.flatten_up_to(state["acc"])
    out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, accs)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_acc = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, {"acc": new_acc, "step": step}, gnorm
