"""AdamW with decoupled weight decay; fp32 moments, bf16-safe updates."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, state, params, lr,
    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, max_grad_norm: float = 1.0,
):
    """Returns (new_params, new_state, grad_norm)."""
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    clip = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        u = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/bias
        newp = p.astype(jnp.float32) - lr * (u + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
