"""Optimizers (pure JAX): AdamW, Adafactor, LR schedules.

Optimizer states mirror the param tree structure, so the parameter
PartitionSpecs apply verbatim to the states (ZeRO: states live wherever
their params live).  Adafactor exists because Adam's fp32 (m, v) for a
405B model is ~3.2 TB — factored second moments make the 126-layer config
fit a 256-chip pod (DESIGN.md §4).
"""
from .adamw import adamw_init, adamw_update  # noqa: F401
from .adafactor import adafactor_init, adafactor_update  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401


def get_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
