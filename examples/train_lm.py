"""Train a language model end-to-end with the full production substrate:
synthetic sharded data, AdamW + cosine schedule, per-layer remat,
fault-tolerant checkpoint/restart (a failure is injected mid-run to prove
it), and final perplexity report.

Default is a ~1M-param granite-family model for 200 steps (CPU-friendly);
``--preset 100m --steps 300`` runs the deliverable-scale configuration
(expect ~hours on CPU; it is the same code path the dry-run lowers for
the 16x16 mesh).

Run:  PYTHONPATH=src python examples/train_lm.py [--arch granite-3-2b]
"""
import argparse
import dataclasses
import math
import tempfile

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.data.pipeline import SyntheticLMStream
from repro.runtime.ft import FailureInjector, run_training
from repro.train.loop import make_train_step


def build_cfg(arch: str, preset: str):
    cfg = get_config(arch).reduced()
    if preset == "100m":
        cfg = dataclasses.replace(
            cfg, name=arch + "-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64,
            d_ff=2048 if cfg.d_ff else 0, vocab_size=32000,
        )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a device failure at this step")
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.preset)
    model = models.build(cfg)
    parallel = ParallelConfig(dp_axes=(), fsdp_axis=None,
                              remat="full" if args.preset == "100m" else "none")
    raw_step = make_train_step(model, parallel, peak_lr=1e-3,
                               total_steps=args.steps)
    train_step = jax.jit(raw_step)
    data = SyntheticLMStream(cfg, batch=args.batch, seq_len=args.seq)
    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return params, raw_step.opt_init(params)

    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2
    injector = FailureInjector(fail_at=(fail_at,))
    print(f"training {cfg.name}: {args.steps} steps, failure injected at "
          f"step {fail_at}, checkpoints -> {ckpt_dir}")
    report = run_training(
        train_step, init_state, data.batch_at, args.steps, ckpt_dir,
        ckpt_every=max(args.steps // 10, 1), injector=injector,
    )
    first = report.losses[min(report.losses)]
    last = report.losses[max(report.losses)]
    print(f"done: {report.final_step} steps, {report.restarts} restart(s)")
    print(f"loss {first:.4f} -> {last:.4f}  "
          f"(ppl {math.exp(min(first, 20)):.1f} -> {math.exp(min(last, 20)):.1f})")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
