"""Quickstart: the three things this framework does, in 2 minutes on CPU.

1. Latent Parallelism on a toy latent — partition, denoise, reconstruct.
2. Train a small LM (any assigned arch, reduced) with checkpointing.
3. Serve it: prefill-free decode loop with a KV cache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config
from repro.core import comm_model, plan_partition, rotation_schedule
from repro.data.pipeline import SyntheticLMStream
from repro.diffusion import FlowMatchEuler, generate_centralized, generate_lp
from repro.runtime.checkpoint import latest_step, restore, save
from repro.train.loop import make_train_step
from repro.configs.base import ParallelConfig


def demo_lp():
    print("=== 1. Latent Parallelism in 20 lines " + "=" * 30)
    cfg = comm_model.wan21_comm_config(num_frames=81)
    print(f"WAN2.1 81-frame latent: {cfg.latent_dims}, S_z = "
          f"{cfg.latent_bytes/2**20:.1f} MB, S_H = "
          f"{cfg.activation_bytes/2**20:.1f} MB  (S_z/S_H = "
          f"{cfg.latent_bytes/cfg.activation_bytes:.1%})")
    for name, fn in [("NMP", comm_model.comm_nmp), ("PP", comm_model.comm_pp),
                     ("HP(xDiT)", comm_model.comm_hp_xdit)]:
        print(f"  {name:9} communication / request: {fn(cfg, 4)/2**30:6.2f} GiB")
    for r in (0.5, 1.0):
        lp = comm_model.comm_lp_measured(cfg, 4, r)
        print(f"  LP r={r:3}  communication / request: {lp/2**30:6.2f} GiB "
              f"({1 - lp/comm_model.comm_nmp(cfg, 4):.1%} less than NMP)")
    print("rotation schedule (first 6 steps):",
          rotation_schedule(6), "(0=T, 1=H, 2=W)")
    plan = plan_partition(extent=60, patch=2, num_partitions=4,
                          overlap_ratio=0.5, dim=1)
    print("height partition, K=4, r=0.5 -> latent slices:",
          list(zip(plan.lat_start, plan.lat_end)))

    # tiny end-to-end: LP == centralized with a local denoiser
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(1, 8, 8, 12, 4)).astype(np.float32))
    den = lambda zz, t: 0.1 * zz  # trivially local
    sampler = FlowMatchEuler(6)
    z_c = generate_centralized(den, z, 6, sampler)
    z_lp = generate_lp(den, z, 6, num_partitions=2, overlap_ratio=1.0,
                       patch_sizes=(1, 2, 2), sampler=sampler)
    err = float(jnp.abs(z_c - z_lp).max())
    print(f"LP vs centralized (local denoiser): max|diff| = {err:.2e}\n")


def demo_train(arch="granite-3-2b", steps=30):
    print(f"=== 2. Train {arch} (reduced) for {steps} steps " + "=" * 16)
    cfg = get_config(arch).reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    parallel = ParallelConfig(dp_axes=(), fsdp_axis=None)
    train_step = jax.jit(make_train_step(model, parallel, peak_lr=3e-3))
    opt_state = train_step.opt_init(params)
    data = SyntheticLMStream(cfg, batch=4, seq_len=64)
    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    first = last = None
    for step in range(steps):
        batch = data.batch_at(step)
        params, opt_state, m = train_step(params, opt_state, batch,
                                          jnp.int32(step))
        if step == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if step % 10 == 0:
            print(f"  step {step:3d}  loss {float(m['loss']):.4f}")
    save(ckpt_dir, steps, (params, opt_state))
    print(f"  loss {first:.3f} -> {last:.3f}; checkpoint at step "
          f"{latest_step(ckpt_dir)} in {ckpt_dir}\n")
    return cfg, model, params


def demo_serve(cfg, model, params, n_tokens=12):
    print("=== 3. Serve: greedy decode with a KV cache " + "=" * 18)
    cache = model.init_cache(1, 64)
    tok = jnp.array([[1]], jnp.int32)
    decode = jax.jit(model.decode)
    toks = [1]
    for t in range(n_tokens):
        logits, cache = decode(params, tok, cache, jnp.array([t], jnp.int32))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    print(f"  greedy tokens: {toks}\n")


if __name__ == "__main__":
    demo_lp()
    cfg, model, params = demo_train()
    demo_serve(cfg, model, params)
    print("quickstart done.")
