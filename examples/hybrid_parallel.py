"""Hierarchical hybrid parallelism (paper supplementary §11): inter-group
LP + intra-group tensor parallelism, demonstrated on 8 virtual devices.

Mesh (4, 2) ("data", "model"): 4 LP groups each splitting the latent, 2-way
TP inside each group.  The script lowers the LP step with the explicit
shard_map engine, prints the collective schedule from the compiled HLO
(the proof that only latent-sized tensors cross group boundaries), and
compares the §11 analytic cost model against pure-NMP / pure-TP.

Run:  PYTHONPATH=src python examples/hybrid_parallel.py
(uses 8 virtual CPU devices; re-execs itself to set XLA_FLAGS first)
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis.hlo_analyzer import analyze  # noqa: E402
from repro.core import comm_model, plan_uniform  # noqa: E402
from repro.core.spmd import lp_forward_shard_map  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}  (4 LP groups x 2-way TP)")

    # toy "DiT" with an intra-group TP matmul over channels: each TP rank
    # computes half the contraction and the group psums the partials —
    # the Megatron pattern, inside every LP group
    d = 16
    w1 = jnp.eye(d) * 0.1

    def denoise(window):  # runs per device inside shard_map
        tp = jax.lax.axis_index("model")
        half = d // 2
        lo = tp * half
        w_slice = jax.lax.dynamic_slice_in_dim(w1, lo, half, 0)   # (d/2, d)
        x_slice = jax.lax.dynamic_slice_in_dim(window, lo, half, 3)
        partial = jnp.einsum("thwc,cd->thwd", x_slice, w_slice)
        return jax.lax.psum(partial, "model")  # intra-group TP reduce

    plan = plan_uniform(extent=32, patch=2, num_partitions=4,
                        overlap_ratio=0.5, dim=0)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(32, 8, 4, 16)).astype(np.float32))

    from repro import compat

    with compat.set_mesh(mesh):
        fn = jax.jit(lambda zz: lp_forward_shard_map(denoise, zz, plan, 0,
                                                     mesh, "data"))
        compiled = fn.lower(z).compile()
        out = fn(z)
    assert np.isfinite(np.asarray(out)).all()

    a = analyze(compiled.as_text())
    print("\ncompiled collective schedule (per device, one LP step):")
    for kind, nbytes in sorted(a.collective_bytes.items()):
        print(f"  {kind:20} {int(a.collective_counts[kind]):3d} ops  "
              f"{nbytes/2**20:8.2f} MiB")
    sz = z.size * 4 / 2**20
    print(f"  (latent S_z = {sz:.2f} MiB -> reconstruction psum is "
          f"latent-scale, as designed)")

    # ---- the production hybrid engine: halo schedule over the group
    # axis, TP Phi_m as a black box, eager ppermute issue (PR 3)
    from repro.core.hybrid import lp_forward_halo_hybrid

    with compat.set_mesh(mesh):
        fn_h = jax.jit(lambda zz: lp_forward_halo_hybrid(
            denoise, zz, plan, 0, mesh, "data", "model", codec="int8"))
        compiled_h = fn_h.lower(z).compile()
        out_h = fn_h(z)
    np.testing.assert_allclose(np.asarray(out_h), np.asarray(out),
                               atol=0.1 * float(np.abs(out).max()))
    ah = analyze(compiled_h.as_text())
    print("\nhybrid halo engine (int8 wire), same step:")
    for kind, nbytes in sorted(ah.collective_bytes.items()):
        print(f"  {kind:20} {int(ah.collective_counts[kind]):3d} ops  "
              f"{nbytes/2**20:8.2f} MiB")
    print("  (all-reduce = the intra-group TP psum only; LP moved to "
          "overlap-slab ppermutes + a coded core all-gather)")

    # ---- §11 analytic comparison at production scale
    cfgm = comm_model.wan21_comm_config(num_frames=81)
    K = 16
    print(f"\n§11 cost model, WAN2.1 81f on {K} devices:")
    print(f"  pure NMP            : {comm_model.comm_nmp(cfgm, K)/2**30:8.2f} GiB")
    print(f"  pure TP             : {comm_model.comm_tp(cfgm, K)/2**30:8.2f} GiB")
    for M in (2, 4, 8):
        hyb = comm_model.comm_hybrid(cfgm, K, M, 0.5, intra="nmp")
        bound = (K - M) / (K - 1)
        print(f"  LP({M:2d} groups)+NMP   : {hyb/2**30:8.2f} GiB   "
              f"(Eq. 54 bound: {bound:.2f}x of NMP)")
    lp = comm_model.comm_lp_measured(cfgm, K, 0.5)
    print(f"  pure LP (K={K})      : {lp/2**30:8.2f} GiB")


if __name__ == "__main__":
    main()
