"""End-to-end driver (the paper's kind: SERVING): a batched LP video
service on a reduced WAN-style DiT.

Submits a queue of text-to-video requests (stub T5 embeddings), serves
them through the LPServingEngine (shape-batched, straggler-aware,
restartable), and compares quality + communication against the
centralized baseline.

Run:  PYTHONPATH=src python examples/serve_video_lp.py [--requests 6]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.comm.codecs import CODEC_NAMES
from repro.configs import get_config
from repro.core import comm_model
from repro.diffusion import FlowMatchEuler, generate_centralized
from repro.diffusion.pipeline import make_guided_denoiser
from repro.models import dit, frontends
from repro.obs.clock import perf_s
from repro.serving.engine import LPServingEngine, VideoRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--overlap", type=float, default=0.5)
    ap.add_argument("--lp-impl", default="auto",
                    choices=["auto", "uniform", "shard_map", "halo"],
                    help="LP engine; auto = psum math at K=2, halo beyond")
    ap.add_argument("--wire-codec", default=None, choices=list(CODEC_NAMES),
                    help="compress LP halo wire payloads")
    args = ap.parse_args()

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    engine = LPServingEngine(
        fwd, params, cfg,
        num_partitions=args.partitions,
        overlap_ratio=args.overlap,
        num_steps=args.steps,
        max_batch=2,
        lp_impl=args.lp_impl,
        wire_codec=args.wire_codec,
    )
    shape = (6, 8, 12)
    print(f"Submitting {args.requests} requests (latent {shape}, "
          f"{args.steps} steps, K={args.partitions}, r={args.overlap}, "
          f"impl={engine.lp_impl}, codec={engine.codec.name})")
    for i in range(args.requests):
        engine.submit(VideoRequest(
            request_id=i,
            context=frontends.text_context(jax.random.PRNGKey(i), 1, cfg),
            latent_shape=shape,
            seed=i,
        ))
    t0 = perf_s()
    results = engine.run()
    wall = perf_s() - t0
    print(f"Served {len(results)} requests in {wall:.1f}s "
          f"({wall/len(results):.1f}s/request on CPU)")
    for r in sorted(results, key=lambda x: x.request_id):
        print(f"  request {r.request_id}: wait={r.queue_wait_s:.2f}s "
              f"e2e={r.e2e_s:.2f}s (batch of {r.batch_size})")

    # ---- quality: LP vs centralized on request 0
    req0 = [r for r in results if r.request_id == 0][0]
    ctx = frontends.text_context(jax.random.PRNGKey(0), 1, cfg)
    guided = make_guided_denoiser(fwd, params, cfg, ctx,
                                  jnp.zeros_like(ctx), guidance=5.0)
    z_T = jax.random.normal(
        jax.random.PRNGKey(0), (1, *shape, cfg.latent_channels))
    z_c = generate_centralized(guided, z_T, args.steps,
                               FlowMatchEuler(args.steps))
    a, b = np.asarray(req0.latent, np.float64), np.asarray(z_c, np.float64)
    rel = np.linalg.norm(a - b) / np.linalg.norm(b)
    mse = float(np.mean((a - b) ** 2))
    peak = float(np.abs(b).max())
    psnr = 10 * np.log10(peak ** 2 / max(mse, 1e-12))
    print(f"LP vs centralized: rel_L2={rel:.4f}  PSNR={psnr:.1f} dB")

    # ---- what this buys at production scale (paper Table 1 geometry)
    prod = comm_model.wan21_comm_config(num_frames=81)
    print("\nAt production scale (WAN2.1-1.3B, 81 frames, 4 devices):")
    print(f"  NMP  per-request comm: {comm_model.comm_nmp(prod, 4)/2**30:7.2f} GiB")
    print(f"  HP   per-request comm: {comm_model.comm_hp_xdit(prod, 4)/2**30:7.2f} GiB")
    lp = comm_model.comm_lp_measured(prod, 4, args.overlap)
    print(f"  LP   per-request comm: {lp/2**30:7.2f} GiB "
          f"(r={args.overlap}; {1 - lp/comm_model.comm_nmp(prod, 4):.1%} "
          f"reduction vs NMP — paper reports up to 97%)")
    halo = comm_model.comm_lp_halo(prod, 4, args.overlap)
    codec_name = args.wire_codec or "int8-residual"
    halo_c = comm_model.comm_lp_halo_codec(prod, 4, args.overlap, codec_name)
    print(f"  LP-halo      (ours)  : {halo/2**30:7.2f} GiB")
    print(f"  LP-halo+{codec_name:13s}: {halo_c/2**30:7.2f} GiB "
          f"({halo/halo_c:.1f}x below the fp32 halo wire)")


if __name__ == "__main__":
    main()
