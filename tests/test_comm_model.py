"""Validate the analytic communication model against paper Table 1."""
import pytest

from repro.core.comm_model import (
    comm_hp_xdit,
    comm_hybrid,
    comm_lp_hub,
    comm_lp_measured,
    comm_lp_spmd,
    comm_nmp,
    comm_pp,
    comm_tp,
    gamma_factor,
    reduction_vs_nmp,
    wan21_comm_config,
)

MB = 1024 * 1024

# Paper Table 1 totals (MB): (frames, method) -> value
PAPER = {
    (49, "nmp"): 57950.17,
    (49, "pp"): 57590.16,
    (49, "hp"): 4758.08,
    (49, "lp_1.0"): 1811.88,
    (49, "lp_0.5"): 1354.34,
    (81, "nmp"): 93050.17,
    (81, "pp"): 92690.16,
    (81, "hp"): 7686.12,
    (81, "lp_1.0"): 2912.81,
    (81, "lp_0.5"): 2191.29,
}


@pytest.mark.parametrize("frames", [49, 81])
def test_nmp_magnitude(frames):
    """Model within 35% of paper (paper ships extra per-hop context: text
    embeddings, timestep embeddings, residual skips)."""
    cfg = wan21_comm_config(frames)
    ours = comm_nmp(cfg, 4) / MB
    assert ours == pytest.approx(PAPER[(frames, "nmp")], rel=0.35)
    assert comm_pp(cfg, 4) == comm_nmp(cfg, 4)  # Eq. 23


@pytest.mark.parametrize("frames", [49, 81])
def test_hp_calibrated_model(frames):
    cfg = wan21_comm_config(frames)
    ours = comm_hp_xdit(cfg, 4) / MB
    assert ours == pytest.approx(PAPER[(frames, "hp")], rel=0.005)


@pytest.mark.parametrize("frames,r", [(49, 1.0), (49, 0.5), (81, 1.0), (81, 0.5)])
def test_lp_measured_matches_table1(frames, r):
    cfg = wan21_comm_config(frames)
    ours = comm_lp_measured(cfg, 4, r) / MB
    assert ours == pytest.approx(PAPER[(frames, f"lp_{r}")], rel=0.15)


@pytest.mark.parametrize("frames", [49, 81])
def test_headline_97pct_reduction(frames):
    """Paper abstract: LP reduces comm by up to 97% over baselines."""
    cfg = wan21_comm_config(frames)
    red = 1.0 - comm_lp_measured(cfg, 4, 0.5) / comm_nmp(cfg, 4)
    assert red > 0.95
    # and ~72% vs HP (paper §5.2): our calibrated HP gives the same story
    red_hp = 1.0 - comm_lp_measured(cfg, 4, 0.5) / comm_hp_xdit(cfg, 4)
    assert 0.5 < red_hp < 0.85


def test_lp_eq26_theory_is_4x_sum():
    """Eq. 27: C_LP = 4 T sum_{k>=2} S_sub (scatter+gather, x2 CFG)."""
    cfg = wan21_comm_config(49)
    assert comm_lp_hub(cfg, 4, 1.0) == pytest.approx(
        2.0 * comm_lp_hub(cfg, 4, 1.0, scatter_gather_factor=1), rel=1e-9
    )


def test_spmd_variant_beats_hub_at_scale():
    """All-reduce reconstruction has no master hot-spot and scales O(S_z)."""
    cfg = wan21_comm_config(81)
    for K in (4, 8, 16):
        spmd = comm_lp_spmd(cfg, K, 1.0)
        nmp = comm_nmp(cfg, K)
        assert spmd < 0.06 * nmp


def test_gamma_bounds():
    """gamma >= 1, grows with r (Eq. 19 discussion)."""
    cfg = wan21_comm_config(49)
    g0 = gamma_factor(cfg, 4, 0.0)
    g5 = gamma_factor(cfg, 4, 0.5)
    g10 = gamma_factor(cfg, 4, 1.0)
    assert 1.0 <= g0 + 1e-6 and g0 <= g5 <= g10
    assert g10 <= 4.0  # gamma/K bounded by 1 (paper §7.4)


def test_critical_ratio_sz_over_sh():
    """Paper §7.4: S_z / S_H ~ 5% for WAN2.1."""
    cfg = wan21_comm_config(81)
    ratio = cfg.latent_bytes / cfg.activation_bytes
    assert 0.02 < ratio < 0.08


def test_hybrid_beats_pure_nmp():
    """Eq. 54: hybrid <= (K-M)/(K-1) of NMP."""
    cfg = wan21_comm_config(81)
    K, M = 16, 4
    hyb = comm_hybrid(cfg, K, M, 0.5, intra="nmp")
    nmp = comm_nmp(cfg, K)
    assert hyb / nmp < (K - M) / (K - 1) + 0.35  # + LP inter-group term


def test_duration_scaling_fig9():
    """Fig. 9: LP overhead grows ~4 GB from 3 s to 10 s while HP grows ~10 GB."""
    c3 = wan21_comm_config(49)
    c10 = wan21_comm_config(161)
    lp_growth = (comm_lp_measured(c10, 4, 1.0) - comm_lp_measured(c3, 4, 1.0)) / MB
    hp_growth = (comm_hp_xdit(c10, 4) - comm_hp_xdit(c3, 4)) / MB
    assert lp_growth < hp_growth
    assert lp_growth < 6000  # paper: "increases by only 4GB"
