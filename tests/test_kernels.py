"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret mode (CPU container; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _mk_qkv(rng, B, Sq, Skv, H, KV, D, dtype):
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, KV, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, KV, D)), dtype)
    qp = jnp.broadcast_to(jnp.arange(Skv - Sq, Skv)[None], (B, Sq)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv)).astype(jnp.int32)
    return q, k, v, qp, kp


# ------------------------------------------------------------------ flash
@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (1, 16, 16, 4, 4, 32),       # MHA square
    (2, 33, 65, 8, 2, 64),       # GQA, ragged (padding path)
    (1, 128, 256, 4, 4, 128),    # MXU-aligned
    (2, 8, 200, 8, 8, 32),       # short q, long kv
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_shapes(B, Sq, Skv, H, KV, D, causal):
    rng = np.random.default_rng(B * 100 + Sq)
    q, k, v, qp, kp = _mk_qkv(rng, B, Sq, Skv, H, KV, D, jnp.float32)
    out = ops.flash_attention(q, k, v, qp, kp, causal=causal,
                              blk_q=32, blk_k=64)
    want = ref.flash_attention_ref(q, k, v, qp, kp, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_sliding_window():
    rng = np.random.default_rng(7)
    q, k, v, qp, kp = _mk_qkv(rng, 2, 64, 64, 4, 4, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, qp, kp, causal=True, window=16,
                              blk_q=16, blk_k=16)
    want = ref.flash_attention_ref(q, k, v, qp, kp, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(3)
    q, k, v, qp, kp = _mk_qkv(rng, 1, 32, 64, 4, 2, 64, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, qp, kp, causal=True, blk_q=16, blk_k=32)
    want = ref.flash_attention_ref(q, k, v, qp, kp, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2)


def test_flash_kv_len_mask():
    """decode-style valid-length masking via ops wrapper."""
    rng = np.random.default_rng(9)
    q, k, v, qp, kp = _mk_qkv(rng, 2, 4, 64, 4, 4, 32, jnp.float32)
    kv_len = jnp.array([40, 17], jnp.int32)
    out = ops.flash_attention(q, k, v, qp, kp, causal=False, kv_len=kv_len,
                              blk_q=4, blk_k=16)
    from repro.models.attention import attention_dense

    want = attention_dense(q, k, v, qp, kp, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_skip_upper_matches():
    """the causal block-skip fast path must not change results."""
    rng = np.random.default_rng(11)
    q, k, v, qp, kp = _mk_qkv(rng, 1, 128, 128, 2, 2, 32, jnp.float32)
    a = ops.flash_attention(q, k, v, qp, kp, causal=True, blk_q=32,
                            blk_k=32, skip_upper=True)
    b = ops.flash_attention(q, k, v, qp, kp, causal=True, blk_q=32,
                            blk_k=32, skip_upper=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@given(
    sq=st.integers(4, 96), skv=st.integers(4, 96),
    h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 32]), causal=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_flash_property(sq, skv, h, g, d, causal):
    if causal and skv < sq:
        skv = sq
    kv = h // g
    rng = np.random.default_rng(sq * 97 + skv)
    q, k, v, qp, kp = _mk_qkv(rng, 1, sq, skv, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, qp, kp, causal=causal,
                              blk_q=16, blk_k=16)
    want = ref.flash_attention_ref(q, k, v, qp, kp, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------------ blend
def _mk_blend(rng, K, extent, patch, r, F, dtype=jnp.float32):
    from repro.core import plan_uniform
    from repro.core.spmd import window_weights

    plan = plan_uniform(extent, patch, K, r)
    preds = jnp.asarray(rng.normal(size=(K, plan.window, F)), dtype)
    w = jnp.asarray(window_weights(plan))
    z = jnp.asarray(plan.normalizer())
    return plan, preds, w, z


@pytest.mark.parametrize("K,extent,patch,r,F", [
    (4, 26, 2, 1.0, 48),
    (2, 16, 1, 0.5, 130),     # F not a multiple of blk
    (8, 64, 2, 0.25, 64),
    (3, 21, 1, 0.0, 96),      # no overlap
])
def test_latent_blend_shapes(K, extent, patch, r, F):
    rng = np.random.default_rng(K * 7 + extent)
    plan, preds, w, z = _mk_blend(rng, K, extent, patch, r, F)
    out = ops.latent_blend(preds, w, z, plan.starts, plan.window,
                           plan.extent, blk_f=32)
    want = ref.latent_blend_ref(preds, w, z, plan.starts, plan.window,
                                plan.extent)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_latent_blend_is_partition_of_unity():
    """identical predictions in every window -> exact passthrough."""
    rng = np.random.default_rng(0)
    from repro.core import plan_uniform
    from repro.core.spmd import window_weights

    plan = plan_uniform(24, 2, 4, 1.0)
    truth = jnp.asarray(rng.normal(size=(24, 33)).astype(np.float32))
    preds = jnp.stack([
        truth[plan.starts[k]:plan.starts[k] + plan.window] for k in range(4)
    ])
    w = jnp.asarray(window_weights(plan))
    z = jnp.asarray(plan.normalizer())
    out = ops.latent_blend(preds, w, z, plan.starts, plan.window, plan.extent,
                           blk_f=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth), atol=1e-5)


@given(
    K=st.integers(2, 6), n_patches=st.integers(6, 40),
    patch=st.sampled_from([1, 2]), r=st.floats(0.0, 1.0),
    F=st.sampled_from([8, 33]),
)
@settings(max_examples=20, deadline=None)
def test_latent_blend_property(K, n_patches, patch, r, F):
    if n_patches < K:
        return
    rng = np.random.default_rng(K * 31 + n_patches)
    plan, preds, w, z = _mk_blend(rng, K, n_patches * patch, patch, r, F)
    out = ops.latent_blend(preds, w, z, plan.starts, plan.window,
                           plan.extent, blk_f=16)
    want = ref.latent_blend_ref(preds, w, z, plan.starts, plan.window,
                                plan.extent)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------- guidance
@pytest.mark.parametrize("shape", [(4, 8, 8, 4), (1, 13, 60, 104, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_guidance_update(shape, dtype):
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.normal(size=shape), dtype)
    c = jnp.asarray(rng.normal(size=shape), dtype)
    u = jnp.asarray(rng.normal(size=shape), dtype)
    out = ops.guidance_update(z, c, u, w=5.0, dt=-0.02, blk=4096)
    want = ref.guidance_update_ref(z, c, u, 5.0, -0.02)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


# -------------------------------------------------------------- mamba ssd
@pytest.mark.parametrize("b,s,h,p,n,chunk,hb", [
    (2, 100, 16, 32, 16, 32, 8),
    (1, 64, 8, 16, 8, 16, 8),     # hb == h
    (2, 37, 4, 8, 4, 16, 2),      # ragged seq (padding path)
])
def test_mamba_ssd_kernel(b, s, h, p, n, chunk, hb):
    rng = np.random.default_rng(s * 7 + h)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 8.0, size=(h,)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    out = ops.mamba_ssd(x, dt * A[None, None, :], dt, B, C,
                        chunk=chunk, head_block=hb)
    want = ref.mamba_ssd_ref(x, dt * A[None, None, :], dt, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-4, rtol=5e-4)


@given(
    s=st.integers(8, 80), h=st.sampled_from([4, 8]),
    p=st.sampled_from([8, 16]), chunk=st.sampled_from([8, 16, 32]),
)
@settings(max_examples=15, deadline=None)
def test_mamba_ssd_property(s, h, p, chunk):
    rng = np.random.default_rng(s * 13 + h)
    b, n = 1, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.15, size=(b, s, h)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 4.0, size=(h,)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    out = ops.mamba_ssd(x, dt * A[None, None, :], dt, B, C,
                        chunk=chunk, head_block=h)
    want = ref.mamba_ssd_ref(x, dt * A[None, None, :], dt, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-4, rtol=5e-4)
