"""Checkpoint / fault-tolerance / elastic / straggler / data / optim tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.optim import adafactor_init, adafactor_update, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.runtime import checkpoint as ckpt
from repro.runtime.elastic import reshard_tree
from repro.runtime.ft import DeviceFailure, FailureInjector, run_training
from repro.runtime.straggler import StragglerState, plan_weighted_partition


# ------------------------------------------------------------- checkpoint
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    restored, meta = ckpt.restore(str(tmp_path), t)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_pointer_and_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2  # retention pruned the rest


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((4, 3)), "nested": {"c": jnp.zeros(5)}}
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), bad)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros(5, jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    c.save(3, _tree())
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------- fault tolerance
def _toy_training(tmp_path, injector=None, num_steps=25):
    """y = <w, x> regression; deterministic batches by step."""

    def init_state():
        return jnp.zeros((4,)), {"m": jnp.zeros((4,)), "step": jnp.int32(0)}

    w_true = jnp.array([1.0, -2.0, 3.0, 0.5])

    def batch_for_step(step):
        k = jax.random.PRNGKey(step)
        x = jax.random.normal(k, (8, 4))
        return x, x @ w_true

    @jax.jit
    def train_step(w, opt, batch, step):
        x, y = batch

        def loss_fn(w):
            return jnp.mean((x @ w - y) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(w)
        m = 0.9 * opt["m"] + g
        w = w - 0.05 * m
        return w, {"m": m, "step": opt["step"] + 1}, {"loss": loss}

    return run_training(
        train_step, init_state, batch_for_step, num_steps,
        str(tmp_path), ckpt_every=5, injector=injector,
    )


def test_ft_clean_run(tmp_path):
    rep = _toy_training(tmp_path / "clean")
    assert rep.final_step == 25 and rep.restarts == 0
    assert rep.losses[24] < rep.losses[0]


def test_ft_recovers_from_failures(tmp_path):
    inj = FailureInjector(fail_at=(7, 13))
    rep = _toy_training(tmp_path / "faulty", injector=inj)
    assert rep.final_step == 25 and rep.restarts == 2


def test_ft_recovery_matches_clean_run(tmp_path):
    """Restart-replayed training must land on the same final state."""
    clean = _toy_training(tmp_path / "c")
    faulty = _toy_training(tmp_path / "f", injector=FailureInjector(fail_at=(12,)))
    assert abs(clean.losses[24] - faulty.losses[24]) < 1e-6


def test_ft_exceeds_max_restarts(tmp_path):
    inj = FailureInjector(fail_at=(3, 4, 6, 8, 9))
    with pytest.raises(DeviceFailure):
        _toy_training(tmp_path / "dead", injector=inj)


# ----------------------------------------------------------------- elastic
def test_elastic_reshard_roundtrip(tmp_path):
    """Save sharded on a 1-dev mesh config, restore under a different
    ParallelConfig — values identical."""
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import local_mesh

    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    mesh = local_mesh()
    par = ParallelConfig(dp_axes=("data",), fsdp_axis=None)
    restored, _ = ckpt.restore(str(tmp_path), t)
    placed = reshard_tree(restored, mesh, par)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------- straggler
def test_straggler_detection():
    st = StragglerState(num_partitions=4)
    st.observe([1.0, 1.0, 1.0, 1.0])
    assert not st.needs_rebalance()
    for _ in range(10):
        st.observe([1.0, 1.0, 1.0, 2.0])  # device 3 is 2x slower
    assert st.needs_rebalance()
    s = st.speeds
    assert s[3] < s[0]


def test_weighted_partition_shrinks_straggler():
    plan = plan_weighted_partition(
        extent=32, patch=1, overlap_ratio=0.5, speeds=[1.0, 1.0, 1.0, 0.5]
    )
    sizes = [b - a for a, b in zip(plan.core_start, plan.core_end)]
    assert sum(sizes) == 32
    assert sizes[3] < sizes[0]          # straggler gets less work
    plan.validate()
    # reconstruction machinery still works on the weighted plan
    from repro.core import extract, reconstruct

    z = jnp.asarray(np.random.default_rng(0).normal(size=(32, 3)).astype(np.float32))
    preds = [extract(z, plan, k, 0) for k in range(4)]
    out = reconstruct(preds, plan, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z), atol=1e-5)


def test_weighted_partition_equal_speeds_is_balanced():
    plan = plan_weighted_partition(31, 1, 0.0, [1, 1, 1, 1])
    sizes = [b - a for a, b in zip(plan.core_start, plan.core_end)]
    assert max(sizes) - min(sizes) <= 1


# -------------------------------------------------------------------- data
def test_data_deterministic_and_restartable():
    cfg = get_config("granite-3-2b").reduced()
    s1 = SyntheticLMStream(cfg, batch=4, seq_len=16)
    s2 = SyntheticLMStream(cfg, batch=4, seq_len=16)
    b1, b2 = s1.batch_at(5), s2.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert (np.asarray(b1["tokens"]) < cfg.vocab_size).all()
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"])[:, 1:], np.asarray(b1["labels"])[:, :-1]
    )


def test_data_host_sharding_partitions_batch():
    cfg = get_config("granite-3-2b").reduced()
    h0 = SyntheticLMStream(cfg, batch=8, seq_len=8, host_id=0, num_hosts=2)
    h1 = SyntheticLMStream(cfg, batch=8, seq_len=8, host_id=1, num_hosts=2)
    assert h0.local_batch == 4
    a, b = h0.batch_at(0), h1.batch_at(0)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


# ------------------------------------------------------------------- optim
def _quad_problem():
    params = {"w": jnp.array([1.0, 2.0, -1.5]), "b": jnp.array(0.5)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    return params, loss


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_descend(opt):
    params, loss = _quad_problem()
    init, update = (adamw_init, adamw_update) if opt == "adamw" else (
        adafactor_init, adafactor_update)
    state = init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, gnorm = update(g, state, params, 0.05,
                                      weight_decay=0.0)
    assert float(loss(params)) < 0.25 * l0
    assert np.isfinite(float(gnorm))


def test_adafactor_factored_state_is_small():
    p = {"big": jnp.zeros((256, 512))}
    st = adafactor_init(p)
    n_state = sum(np.prod(l.shape) for l in jax.tree.leaves(st["acc"]))
    assert n_state == 256 + 512  # factored, not 256*512


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), 1.0, warmup=10, total=100))
           for s in range(0, 100, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert lrs[-1] < 0.3


def test_gradient_compression_error_feedback():
    from repro.distributed.compression import (
        compressed_psum, init_error_feedback)

    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(128,)) * 1e-3,
                          jnp.float32)}
    err = init_error_feedback(g)
    # accumulate 200 compressed steps; error feedback keeps the *sum*
    # close to the uncompressed sum despite bf16's ~8-bit mantissa
    total_c = jnp.zeros(128)
    for _ in range(200):
        cg, err = compressed_psum(g, err, axis_name=None)
        total_c = total_c + cg["w"]
    total_u = g["w"] * 200
    rel = float(jnp.abs(total_c - total_u).max() / jnp.abs(total_u).max())
    assert rel < 0.01, f"error feedback drifted {rel}"
