"""Observability plane: trace schema stability, metrics correctness,
and EXACT derived wire attribution vs ``core/comm_model``.

The load-bearing contract (docs/observability.md): per-step wire bytes
are *derived* by replaying the analytic byte model over the engine's
recorded geometry/codec timelines — and because ``comm_model`` matches
compiled HLO exactly, the derived attribution must equal the model
byte-for-byte, per collective, per tier, across codecs, the sharded
hybrid wire, and mid-request mesh shrinks.
"""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import comm_model as cm
from repro.core.schedule import rotation_dim, usable_dims
from repro.obs import (
    FlightRecorder,
    MetricsRegistry,
    TRACE_SCHEMA,
    TraceRecorder,
    attribute_denoise_steps,
    perf_s,
    step_wire_attribution,
    tier_for_group_size,
    tiered_collectives,
    validate_trace,
)
from repro.obs import metrics as obsm

CODECS = ["fp32", "bf16", "int8", "int4", "int8-residual"]
R = 0.5


def _ccfg(dims=(8, 8, 12), steps=6):
    return cm.VDMCommConfig(
        latent_dims=dims, latent_channels=16, patch_sizes=(1, 2, 2),
        d_model=96, num_blocks=2, num_steps=steps,
    )


# --------------------------------------------------------------- clock
def test_clock_monotonic_and_stamps():
    a = perf_s()
    b = perf_s()
    assert b >= a
    from repro.obs.clock import perf_us, wall_stamp_s

    assert perf_us() > 0
    # wall stamps are epoch-scale (for snapshot metadata, never durations)
    assert wall_stamp_s() > 1e9


# --------------------------------------------------------------- trace
def test_trace_span_schema_and_validation():
    tr = TraceRecorder()
    with tr.span("batch.denoise", cat="serve", size=2):
        with tr.span("denoise.run", cat="denoise", dim=1):
            pass
    tr.instant("snapshot.record", cat="serve", step=3)
    tr.counter("wire.bytes_by_tier", {"inter": 10.0, "intra": 0.0},
               cat="wire")
    doc = tr.to_json()
    assert doc["otherData"]["schema"] == TRACE_SCHEMA
    assert validate_trace(doc) == []
    phases = sorted(e["ph"] for e in doc["traceEvents"])
    assert phases == ["C", "X", "X", "i"]
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    for e in spans:
        assert e["dur"] >= 0 and e["ts"] >= 0
    # nesting: the inner run opened after and closed before the batch
    by_name = {e["name"]: e for e in spans}
    outer, inner = by_name["batch.denoise"], by_name["denoise.run"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1


def test_trace_validation_rejects_malformed_docs():
    assert validate_trace({"traceEvents": []})  # missing schema tag
    base = {"otherData": {"schema": TRACE_SCHEMA}}
    bad_phase = {**base, "traceEvents": [
        {"ph": "Z", "name": "x", "ts": 0, "pid": 1, "tid": 1}]}
    assert validate_trace(bad_phase)
    bad_cat = {**base, "traceEvents": [
        {"ph": "i", "name": "x", "ts": 0, "pid": 1, "tid": 1,
         "cat": "nonsense"}]}
    assert validate_trace(bad_cat)
    no_dur = {**base, "traceEvents": [
        {"ph": "X", "name": "x", "ts": 0, "pid": 1, "tid": 1,
         "cat": "serve"}]}
    assert validate_trace(no_dur)


def test_trace_args_are_json_clean():
    tr = TraceRecorder()
    tr.instant("x", cat="obs", arr=np.arange(3), f=np.float32(1.5),
               nested={"t": (1, 2)})
    doc = tr.to_json()
    json.dumps(doc)  # must not raise
    assert validate_trace(doc) == []
    args = doc["traceEvents"][0]["args"]
    assert args["arr"] == [0, 1, 2] and args["f"] == 1.5


# ------------------------------------------------------------- metrics
def test_metrics_registry_counter_gauge_histogram():
    m = MetricsRegistry()
    m.inc(obsm.REQUESTS)
    m.inc(obsm.REQUESTS, 2)
    m.set(obsm.QUEUE_DEPTH, 7)
    for v in (0.1, 0.2, 0.3, 0.4):
        m.observe(obsm.STEP_LATENCY_S, v)
    m.inc(obsm.WIRE_BYTES, 100.0, tier="inter", collective="all-gather")
    m.inc(obsm.WIRE_BYTES, 50.0, tier="inter", collective="all-gather")
    assert m.counter_value(obsm.REQUESTS) == 3.0
    assert m.gauge_value(obsm.QUEUE_DEPTH) == 7.0
    assert m.counter_value(obsm.WIRE_BYTES, tier="inter",
                           collective="all-gather") == 150.0
    assert m.hist_values(obsm.STEP_LATENCY_S) == [0.1, 0.2, 0.3, 0.4]
    rows = {(r["name"], tuple(sorted(r["labels"].items())))
            for r in m.snapshot()}
    assert (obsm.WIRE_BYTES,
            (("collective", "all-gather"), ("tier", "inter"))) in rows


def test_metrics_exporters():
    m = MetricsRegistry()
    m.inc(obsm.WIRE_BYTES, 1024.0, tier="inter", collective="all-gather")
    m.inc(obsm.WIRE_BYTES, 10.0, tier="intra", collective="all-gather")
    m.set(obsm.DEAD_GROUPS, 1)
    m.observe(obsm.STEP_LATENCY_S, 0.5)
    jsonl = m.to_jsonl()
    rows = [json.loads(l) for l in jsonl.strip().splitlines()]
    assert all("stamp_s" in r for r in rows)
    assert {r["name"] for r in rows} == {
        obsm.WIRE_BYTES, obsm.DEAD_GROUPS, obsm.STEP_LATENCY_S}
    prom = m.to_prometheus()
    assert 'repro_wire_bytes{collective="all-gather",tier="inter"} 1024.0' \
        in prom
    assert "# TYPE repro_wire_bytes counter" in prom
    # one TYPE line per metric name even with multiple label sets
    assert prom.count("# TYPE repro_wire_bytes counter") == 1
    assert "repro_denoise_step_s_count" in prom
    assert 'quantile="0.5"' in prom


def test_histogram_reservoir_keeps_exact_totals_and_counts_dropped():
    """Satellite regression: ``observe`` used to silently stop keeping
    samples at hist_cap, freezing quantiles on the warm-up window.  The
    reservoir must (a) hold exactly ``hist_cap`` samples, (b) keep
    count/sum/min/max EXACT over the whole stream, (c) export the
    dropped-sample count, and (d) keep late samples reachable so the
    quantiles track the stream, not its head."""
    cap = 64
    m = MetricsRegistry(hist_cap=cap, seed=0)
    n = 1000
    for i in range(n):
        m.observe(obsm.E2E_LATENCY_S, float(i))
    held = m.hist_values(obsm.E2E_LATENCY_S)
    assert len(held) == cap
    assert m.hist_dropped(obsm.E2E_LATENCY_S) == n - cap
    row = [r for r in m.snapshot()
           if r["name"] == obsm.E2E_LATENCY_S][0]
    assert row["count"] == n                      # exact, not cap
    assert row["sum"] == float(sum(range(n)))     # exact
    assert row["min"] == 0.0 and row["max"] == float(n - 1)
    assert row["dropped"] == n - cap
    # an all-first-cap reservoir would put p50 at ~cap/2; a uniform one
    # tracks the stream median ~n/2
    assert row["p50"] > n * 0.2
    # below cap nothing ever drops
    m2 = MetricsRegistry(hist_cap=cap)
    for v in (0.1, 0.2):
        m2.observe(obsm.QUEUE_WAIT_S, v)
    assert m2.hist_values(obsm.QUEUE_WAIT_S) == [0.1, 0.2]
    assert m2.hist_dropped(obsm.QUEUE_WAIT_S) == 0


def test_histogram_reservoir_is_seed_deterministic():
    """Same observation sequence + same registry seed -> identical held
    samples (replayable snapshots under a fixed workload seed)."""
    def fill(seed):
        m = MetricsRegistry(hist_cap=16, seed=seed)
        for i in range(500):
            m.observe(obsm.STEP_LATENCY_S, float(i) * 0.01)
        return m.hist_values(obsm.STEP_LATENCY_S)

    assert fill(0) == fill(0)
    assert fill(0) != fill(1)


def test_prometheus_exposition_format_parses():
    """Format-level lint of ``to_prometheus()``: every sample line must
    match the exposition grammar (mangled names without dots, escaped
    label values), and histograms must export quantile + _sum/_count/
    _dropped rows."""
    import re

    m = MetricsRegistry(hist_cap=4)
    m.inc(obsm.WIRE_BYTES, 7.0, tier="inter", collective="all-gather")
    m.set(obsm.QUEUE_DEPTH, 3, source='we"ird\\lab\nel')
    for v in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6):
        m.observe(obsm.E2E_LATENCY_S, v, priority="interactive")
    text = m.to_prometheus()
    name_re = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
    label_re = r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\["\\n])*"'
    sample_re = re.compile(
        rf"^({name_re})(\{{{label_re}(,{label_re})*\}})? (-?[0-9.einf+-]+)$")
    names = set()
    fam, kind = None, None
    for line in text.strip().splitlines():
        if line.startswith("# TYPE "):
            _, _, fam, kind = line.split(" ")
            assert kind in ("counter", "gauge", "summary")
            continue
        match = sample_re.match(line)
        assert match, f"unparseable exposition line: {line!r}"
        name = match.group(1)
        names.add(name)
        assert "." not in name                     # dots mangled away
        float(match.group(4))                      # value parses
        # family grouping: every sample must belong to the TYPE line
        # above it.  The only valid summary children are the quantile
        # / _sum / _count rows — in particular '_dropped' must NOT
        # ride inside a summary family (strict OpenMetrics parsers
        # reject it); it is its own counter family.
        if kind == "summary":
            assert name in (fam, f"{fam}_sum", f"{fam}_count"), \
                f"{name!r} is not a summary child of {fam!r}"
        else:
            assert name == fam
    e2e = "repro_serve_e2e_latency_s"
    assert {e2e, f"{e2e}_sum", f"{e2e}_count", f"{e2e}_dropped"} <= names
    assert f"# TYPE {e2e}_dropped counter" in text
    assert f'{e2e}{{priority="interactive",quantile="0.5"}}' in text
    # escaped label round-trip: backslash, quote, newline
    assert r'source="we\"ird\\lab\nel"' in text
    # histogram past cap: _count is the exact stream length, _dropped
    # the truncation
    assert f"{e2e}_count{{priority=\"interactive\"}} 6" in text
    assert f"{e2e}_dropped{{priority=\"interactive\"}} 2" in text


def test_metrics_write_format_by_extension(tmp_path):
    m = MetricsRegistry()
    m.inc(obsm.BATCHES)
    p1, p2 = tmp_path / "m.prom", tmp_path / "m.jsonl"
    m.write(str(p1))
    m.write(str(p2))
    assert p1.read_text().startswith("# TYPE")
    assert json.loads(p2.read_text().splitlines()[0])["name"] == obsm.BATCHES


# -------------------------------------------- derived wire attribution
@pytest.mark.parametrize("codec", CODECS)
def test_step_attribution_matches_comm_model_unsharded(codec):
    cfg = _ccfg()
    K = 3
    for dim in usable_dims(cfg.latent_dims, cfg.patch_sizes, K):
        got = step_wire_attribution(cfg, K, R, dim, codec)
        want = cm.lp_halo_codec_step_collectives(cfg, K, R, dim,
                                                 codec=codec)
        assert got["inter"] == {k: float(v) for k, v in want.items()}
        assert got["intra"] == {}


@pytest.mark.parametrize("codec", CODECS)
def test_step_attribution_matches_comm_model_wire_sharded(codec):
    """The hierarchy-aware hybrid wire: tier split must equal
    ``lp_halo_sharded_step_collectives`` exactly (inter cp+ag chunks,
    intra reassembly gather)."""
    cfg = _ccfg()
    M, T = 3, 2
    for dim in usable_dims(cfg.latent_dims, cfg.patch_sizes, M):
        got = step_wire_attribution(cfg, M, R, dim, codec, tp=T,
                                    wire_shard=True, lp_impl="halo_hybrid")
        want = cm.lp_halo_sharded_step_collectives(cfg, M, T, R, dim,
                                                   codec=codec)
        for tier in ("inter", "intra"):
            assert got[tier] == {k: float(v) for k, v in
                                 want[tier].items()}, (codec, dim, tier)


def test_step_attribution_psum_family_is_codec_blind():
    cfg = _ccfg()
    for impl in ("shard_map", "uniform", "gspmd"):
        got = step_wire_attribution(cfg, 2, R, 0, "int8", lp_impl=impl)
        assert got == {"inter": {"all-reduce": float(cfg.latent_bytes)},
                       "intra": {}}


@pytest.mark.parametrize("wire_shard", [False, True])
def test_attribution_sums_match_wire_profile(wire_shard):
    """Whole-denoise totals equal ``lp_halo_wire_profile`` — the same
    quantity the step-policy autotuner prices."""
    cfg = _ccfg(steps=6)
    M, T = 3, 2
    step_codecs = ["int4", "int4", "int8", "int8", "int8-residual",
                   "int8-residual"]
    recs = attribute_denoise_steps(
        cfg, R, step_codecs, [(1, M)], tp=T, wire_shard=wire_shard,
        lp_impl="halo_hybrid")
    prof = cm.lp_halo_wire_profile(cfg, M, T, R, step_codecs,
                                   wire_shard=wire_shard)
    assert sum(r["inter_bytes"] for r in recs) == float(prof["inter"])
    assert sum(r["intra_bytes"] for r in recs) == float(prof["intra"])


def test_attribution_replays_geometry_timeline():
    """A mid-denoise eviction re-derives usable dims and the rotation
    sequence at the new K — steps at or after the event are billed on
    the shrunken mesh."""
    cfg = _ccfg(steps=4)
    geometry = [(1, 3), (3, 2)]  # evicted in the hook before step 3
    recs = attribute_denoise_steps(cfg, R, ["int8"] * 4, geometry,
                                   tp=2, wire_shard=True,
                                   lp_impl="halo_hybrid")
    assert [r["K"] for r in recs] == [3, 3, 2, 2]
    assert [r["plan_epoch"] for r in recs] == [0, 0, 1, 1]
    for r in recs:
        dims = usable_dims(cfg.latent_dims, cfg.patch_sizes, r["K"])
        assert r["dim"] == rotation_dim(r["step"], dims)
        want = cm.lp_halo_sharded_step_collectives(
            cfg, r["K"], 2, R, r["dim"], codec="int8")
        assert r["inter"] == {k: float(v) for k, v in
                              want["inter"].items()}
        assert r["intra"] == {k: float(v) for k, v in
                              want["intra"].items()}


def test_attribution_rejects_gapped_timeline():
    cfg = _ccfg()
    with pytest.raises(ValueError, match="step 1"):
        attribute_denoise_steps(cfg, R, ["int8"], [(2, 3)])


def test_attribution_prices_wire_time_with_links():
    from repro.policy.autotune import DEFAULT_LINKS

    cfg = _ccfg(steps=2)
    recs = attribute_denoise_steps(cfg, R, ["fp32", "fp32"], [(1, 3)],
                                   tp=2, wire_shard=True,
                                   lp_impl="halo_hybrid",
                                   links=DEFAULT_LINKS)
    for r in recs:
        want = DEFAULT_LINKS.wire_time_ms(r["inter_bytes"],
                                          r["intra_bytes"])
        assert r["pred_wire_time_ms"] == want > 0


def test_attribution_displaced_hidden_bytes_match_wire_profile():
    """Displaced attribution: ``inter_bytes`` stays the TOTAL (HLO-
    matching) payload; ``hidden_bytes`` marks the slab-ppermute portion
    of every step that is NOT the first of its (dim x codec x K) run;
    and the exposed/hidden split sums to exactly what the autotuner
    prices via ``lp_halo_wire_profile``."""
    from repro.policy.autotune import DEFAULT_LINKS

    cfg = _ccfg(dims=(8, 2, 2), steps=4)   # single usable dim at K=3
    assert usable_dims(cfg.latent_dims, cfg.patch_sizes, 3) == (0,)
    step_codecs = ["displaced:int8-residual"] * 3 + ["int8-residual"]
    recs = attribute_denoise_steps(cfg, R, step_codecs, [(1, 3)],
                                   links=DEFAULT_LINKS)
    sync = cm.lp_halo_codec_step_collectives(cfg, 3, R, 0,
                                             codec="int8-residual")
    pp = float(sync["collective-permute"])
    # first-of-run exposed, later displaced steps hide their ppermutes,
    # and the codec-segment boundary (step 4) is first-of-run again
    assert [r["hidden_bytes"] for r in recs] == [0.0, pp, pp, 0.0]
    for r in recs:
        assert r["inter"] == {k: float(v) for k, v in sync.items()}
        assert r["pred_wire_time_ms"] == DEFAULT_LINKS.wire_time_ms(
            r["inter_bytes"] - r["hidden_bytes"], r["intra_bytes"])
    prof = cm.lp_halo_wire_profile(cfg, 3, 1, R, step_codecs)
    assert sum(r["inter_bytes"] - r["hidden_bytes"] for r in recs) == \
        float(prof["inter"])
    assert sum(r["hidden_bytes"] for r in recs) == float(prof["hidden"])
    # the HLO contract is exposed + hidden: identical to the sync total
    sync_recs = attribute_denoise_steps(cfg, R, ["int8-residual"] * 4,
                                        [(1, 3)])
    assert sum(r["inter_bytes"] for r in recs) == \
        sum(r["inter_bytes"] for r in sync_recs)


def test_attribution_displaced_hides_nothing_across_dim_rotation():
    """With >1 usable dim the rotation flushes the stale carry every
    step (each step is first-of-run), so nothing is ever hidden — the
    rule that makes ``auto_plan`` drop displaced candidates there."""
    cfg = _ccfg(steps=4)    # (8, 8, 12): three usable dims at K=3
    assert len(usable_dims(cfg.latent_dims, cfg.patch_sizes, 3)) == 3
    recs = attribute_denoise_steps(
        cfg, R, ["displaced:int8-residual"] * 4, [(1, 3)])
    assert [r["hidden_bytes"] for r in recs] == [0.0] * 4
    prof = cm.lp_halo_wire_profile(cfg, 3, 1, R,
                                   ["displaced:int8-residual"] * 4)
    assert float(prof["hidden"]) == 0.0


def test_reconcile_counts_unattributed_steps_and_trace_fails():
    """Satellite regression: a measured run whose steps have no
    attribution record (or no priced prediction) must surface a nonzero
    ``unattributed_steps`` — and a trace carrying such a reconciliation
    row must FAIL validation, never read as free wire time."""
    from repro.obs import reconcile_segments
    from repro.policy.autotune import DEFAULT_LINKS

    cfg = _ccfg(steps=4)
    recs = attribute_denoise_steps(cfg, R, ["int8"] * 2, [(1, 3)],
                                   links=DEFAULT_LINKS)
    measured = [
        {"start": 1, "stop": 2, "wall_s": 0.2, "codec": "int8"},
        {"start": 3, "stop": 4, "wall_s": 0.2, "codec": "int8"},
    ]
    rows = reconcile_segments(recs, measured)
    assert rows[0]["unattributed_steps"] == 0
    assert rows[0]["measured_over_pred"] > 0
    assert rows[1]["unattributed_steps"] == 2    # steps 3-4: no records
    assert "measured_over_pred" not in rows[1]   # never ratio'd vs a hole
    # records lacking pred_wire_time_ms (no links) count as holes too
    unpriced = attribute_denoise_steps(cfg, R, ["int8"] * 4, [(1, 3)])
    rows2 = reconcile_segments(unpriced, measured)
    assert all(r["unattributed_steps"] == 2 for r in rows2)

    rec = FlightRecorder()
    rec.record_reconciliations([rows[0]])
    assert validate_trace(rec.trace.to_json()) == []  # clean row passes
    rec.record_reconciliations([rows[1]])
    errs = validate_trace(rec.trace.to_json())
    assert errs and any("unattributed_steps=2" in e for e in errs)
    assert any("wire.reconcile" in e for e in errs)


def test_record_wire_steps_carries_hidden_bytes():
    """``hidden_bytes`` rides the wire.step instants and the by-tier
    counter sample as an attribution of inter bytes — the collective
    byte counters themselves stay HLO-exact (unchanged)."""
    cfg = _ccfg(dims=(8, 2, 2), steps=3)
    rec = FlightRecorder()
    recs = attribute_denoise_steps(cfg, R, ["displaced:int8-residual"] * 3,
                                   [(1, 3)], links=rec.links)
    rec.record_wire_steps(recs)
    steps = [e for e in rec.trace.events if e["name"] == "wire.step"]
    assert [e["args"]["hidden_bytes"] for e in steps] == \
        [r["hidden_bytes"] for r in recs]
    counter = [e for e in rec.trace.events
               if e["name"] == "wire.bytes_by_tier"][0]
    assert counter["args"]["hidden"] == sum(r["hidden_bytes"]
                                            for r in recs) > 0
    # counters (the HLO-exactness gate) bill the TOTAL inter payload
    total = sum(rec.metrics.counter_value(obsm.WIRE_BYTES, tier="inter",
                                          collective=c)
                for c in ("all-gather", "collective-permute"))
    assert total == sum(r["inter_bytes"] for r in recs)
    assert validate_trace(rec.trace.to_json()) == []


def test_tiered_collectives_unifies_dryrun_schema():
    """dryrun's ``collectives_by_group`` -> the wire-schema records,
    keyed by the same tier vocabulary the derived attribution uses."""
    rows = tiered_collectives(
        {"all-gather[3]": 600.0, "collective-permute[3]": 400.0,
         "all-gather[2]": 1000.0, "all-reduce": 8.0}, M=3, T=2)
    by = {(r["collective"], r["group_size"]): r for r in rows}
    assert by[("all-gather", 3)]["tier"] == "inter"
    assert by[("collective-permute", 3)]["tier"] == "inter"
    assert by[("all-gather", 2)]["tier"] == "intra"
    assert by[("all-reduce", 3)]["tier"] == "inter"  # ungrouped -> M
    assert tier_for_group_size(4, 4, 4) == "ambiguous"
    assert tier_for_group_size(5, 3, 2) == "unknown"


# ------------------------------------------------------ FlightRecorder
def test_flight_recorder_disabled_planes_noop():
    rec = FlightRecorder(trace=False, metrics=False)
    with rec.span("x"):
        pass
    with rec.device_span("y"):
        pass
    rec.instant("z")
    rec.inc(obsm.REQUESTS)
    rec.gauge(obsm.QUEUE_DEPTH, 1)
    rec.observe(obsm.STEP_LATENCY_S, 0.1)
    rec.record_snapshot(1)
    rec.record_resume(1)
    assert rec.trace is None and rec.metrics is None


def test_flight_recorder_derives_step_samples_from_fused_runs():
    rec = FlightRecorder()
    rec.record_run(1, 3, wall_s=0.3, dim=1, codec="int8")
    steps = rec.metrics.hist_values(obsm.STEP_LATENCY_S)
    assert len(steps) == 3
    assert all(abs(s - 0.1) < 1e-12 for s in steps)
    assert rec.metrics.hist_values(obsm.RUN_WALL_S) == [0.3]
    assert rec.measured_runs[0]["start"] == 1


def test_flight_recorder_wire_steps_feed_counters_and_trace():
    rec = FlightRecorder()
    cfg = _ccfg(steps=3)
    recs = attribute_denoise_steps(cfg, R, ["int8"] * 3, [(1, 3)],
                                   links=rec.links)
    rec.record_wire_steps(recs)
    want_inter = sum(r["inter_bytes"] for r in recs)
    assert rec.metrics.counter_value(
        obsm.WIRE_BYTES, tier="inter", collective="all-gather") + \
        rec.metrics.counter_value(
            obsm.WIRE_BYTES, tier="inter",
            collective="collective-permute") == want_inter
    names = [e["name"] for e in rec.trace.events]
    assert names.count("wire.step") == 3
    assert "wire.bytes_by_tier" in names
    assert validate_trace(rec.trace.to_json()) == []


def test_plan_recording_via_resolve_cli_schedule():
    """The autotuner feeds the recorder its chosen plan + ranked
    candidate field; explicit schedules record without candidates."""
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.policy import resolve_cli_schedule

    cfg = _ccfg(steps=6)
    rec = FlightRecorder()
    plan = resolve_cli_schedule("auto", cfg, 3, R, FlowMatchEuler(6), 6,
                                recorder=rec)
    assert plan is not None
    assert len(rec.plans) == 1
    row = rec.plans[0]
    assert row["context"] == "auto"
    assert row["schedule"] == plan.schedule.spec
    assert row["wire_bytes"] == float(plan.wire_bytes)
    cands = row["candidates"]
    assert cands and all(
        {"codec", "denoise_bytes", "floor_db"} <= set(c) for c in cands)
    assert rec.metrics.gauge_value(obsm.PLAN_WIRE_BYTES,
                                   context="auto") == plan.wire_bytes
    rec2 = FlightRecorder()
    resolve_cli_schedule("int8-residual@0.45,bf16", cfg, 3, R,
                         FlowMatchEuler(6), 6, recorder=rec2)
    assert rec2.plans[0]["context"] == "explicit"
    assert "candidates" not in rec2.plans[0]


# ----------------------------------------- engine end-to-end (1 device)
def test_engine_emits_exact_attribution_and_valid_trace():
    from repro import models
    from repro.configs import get_config
    from repro.models import dit, frontends
    from repro.serving.engine import LPServingEngine, VideoRequest

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    rec = FlightRecorder()
    eng = LPServingEngine(fwd, params, cfg, num_partitions=2,
                          overlap_ratio=0.5, num_steps=3, max_batch=2,
                          lp_impl="halo", wire_codec="int8",
                          recorder=rec)
    shape = (4, 8, 12)
    for i in range(2):
        eng.submit(VideoRequest(
            request_id=i,
            context=frontends.text_context(jax.random.PRNGKey(i), 1, cfg),
            latent_shape=shape, seed=i))
    results = eng.run()
    assert len(results) == 2

    doc = rec.trace.to_json()
    assert validate_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    for required in ("request.enqueue", "batch.admit", "batch.denoise",
                     "denoise.run", "wire.step"):
        assert required in names, names

    # derived attribution == comm_model exactly, per step, per collective
    ccfg = cm.VDMCommConfig(
        latent_dims=shape, latent_channels=cfg.latent_channels,
        patch_sizes=cfg.patch_sizes, d_model=cfg.d_model,
        num_blocks=cfg.num_layers, num_steps=3)
    dims = usable_dims(shape, cfg.patch_sizes, 2)
    assert len(rec.wire_steps) == 3
    for r in rec.wire_steps:
        assert r["K"] == 2 and r["codec"] == "int8"
        assert r["dim"] == rotation_dim(r["step"], dims)
        want = cm.lp_halo_codec_step_collectives(ccfg, 2, 0.5, r["dim"],
                                                 codec="int8")
        assert r["inter"] == {k: float(v) for k, v in want.items()}
        assert r["intra"] == {}
        assert r["batch_size"] == 2

    m = rec.metrics
    assert m.counter_value(obsm.REQUESTS) == 2.0
    assert m.counter_value(obsm.BATCHES) == 1.0
    assert m.counter_value(obsm.COMPILES, epoch="0") > 0
    assert len(m.hist_values(obsm.STEP_LATENCY_S)) == 3
    assert m.hist_values(obsm.BATCH_WALL_S)
    total_wire = sum(
        row["value"] for row in m.snapshot()
        if row["name"] == obsm.WIRE_BYTES)
    assert total_wire == sum(r["inter_bytes"] + r["intra_bytes"]
                             for r in rec.wire_steps)
    # reconciliation rows: every measured run got a prediction
    assert rec.reconciliations
    for row in rec.reconciliations:
        assert row["measured_wall_ms"] > 0
        assert row["pred_wire_time_ms"] >= 0


# ---------------------------------------------------- launch CLI (fast)
ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}


def test_dryrun_trace_out_is_schema_valid(tmp_path):
    """Tier-1 CI gate: ``dryrun --trace-out`` must produce schema-valid
    trace JSON (the fast skip-rule cell — no compile)."""
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.jsonl"
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-3-2b", "--shape", "long_500k",
         "--trace-out", str(trace), "--metrics-out", str(metrics)],
        capture_output=True, text=True, cwd="/root/repo", env=ENV,
        timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SKIP" in res.stdout
    doc = json.load(open(trace))
    assert validate_trace(doc) == []
    assert doc["otherData"]["schema"] == TRACE_SCHEMA
    events = {e["name"] for e in doc["traceEvents"]}
    assert "dryrun.skip" in events
    assert metrics.exists()


# ----------------------------------- fault-drill attribution (multi-dev)
_DRILL_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro import models
from repro.configs import get_config
from repro.launch.mesh import make_hybrid_mesh
from repro.models import dit, frontends
from repro.obs import FlightRecorder
from repro.serving.engine import LPServingEngine, VideoRequest

M, T, STEPS = 3, 2, 4
SHAPE = (8, 8, 12)
cfg = get_config("wan21-dit-1.3b").reduced()
model = models.build(cfg)
params = model.init(jax.random.PRNGKey(0))
def fwd(p, z, t, c, cfg_model):
    return dit.forward(p, z, t, c, cfg_model)

rec = FlightRecorder()
eng = LPServingEngine(
    fwd, params, cfg, num_partitions=M, overlap_ratio=0.5,
    num_steps=STEPS, max_batch=1, wire_codec="int8-residual",
    lp_impl="halo_hybrid", mesh=make_hybrid_mesh(M, T), elastic=True,
    inject_fault="dead:1@3", recorder=rec)
eng.submit(VideoRequest(
    request_id=0,
    context=frontends.text_context(jax.random.PRNGKey(1), 1, cfg),
    latent_shape=SHAPE, seed=0))
res = eng.run()[0]
rec.write_trace(os.environ["DRILL_TRACE"])
out = {
    "wire_steps": rec.wire_steps,
    "geometry": eng._geom_events,
    "evictions": eng.evictions,
    "K": eng.K,
    "tp": eng.tp,
    "wire_shard": eng.wire_shard,
    "restarts": res.restarts,
    "overlap": eng.r,
    "resumes": rec.metrics.counter_value("snapshot.resumes"),
    "eviction_count": rec.metrics.counter_value(
        "serve.evictions", reason="dead"),
    "faults_injected": rec.metrics.counter_value(
        "faults.injected", kind="dead"),
}
print("JSON:" + json.dumps(out))
"""


@pytest.mark.slow
def test_fault_drill_attribution_exact_across_mesh_shrink(tmp_path):
    """The acceptance drill: dead:1@3 on a (3, 2) mesh.  The recorder's
    per-step byte attribution must match ``comm_model`` exactly per
    tier both BEFORE the eviction (K=3) and AFTER the shrink (K=2),
    and the trace must carry the fault/evict/restart story."""
    trace_path = tmp_path / "drill_trace.json"
    res = subprocess.run(
        [sys.executable, "-c", _DRILL_SCRIPT],
        capture_output=True, text=True, cwd="/root/repo",
        env={**ENV, "DRILL_TRACE": str(trace_path)}, timeout=560)
    rec = None
    for line in res.stdout.splitlines():
        if line.startswith("JSON:"):
            rec = json.loads(line[len("JSON:"):])
    assert rec is not None, res.stdout + res.stderr[-2000:]

    M, T, steps, shape = 3, 2, 4, (8, 8, 12)
    assert rec["evictions"] == 1 and rec["K"] == M - 1
    assert rec["restarts"] >= 1
    assert rec["wire_shard"] is True and rec["tp"] == T

    geometry = [tuple(g) for g in rec["geometry"]]
    assert geometry[0] == (1, M)
    assert len(geometry) == 2 and geometry[1][1] == M - 1
    evict_step = geometry[1][0]

    from repro.configs import get_config

    mcfg = get_config("wan21-dit-1.3b").reduced()
    ccfg = cm.VDMCommConfig(
        latent_dims=shape, latent_channels=mcfg.latent_channels,
        patch_sizes=mcfg.patch_sizes, d_model=mcfg.d_model,
        num_blocks=mcfg.num_layers, num_steps=steps)

    ws = rec["wire_steps"]
    assert [w["step"] for w in ws] == list(range(1, steps + 1))
    saw_pre = saw_post = False
    for w in ws:
        K = M if w["step"] < evict_step else M - 1
        assert w["K"] == K, (w, evict_step)
        dims = usable_dims(shape, mcfg.patch_sizes, K)
        dim = rotation_dim(w["step"], dims)
        assert w["dim"] == dim
        want = cm.lp_halo_sharded_step_collectives(
            ccfg, K, T, rec["overlap"], dim, codec="int8-residual")
        for tier in ("inter", "intra"):
            assert w[tier] == {k: float(v) for k, v in
                               want[tier].items()}, (w["step"], tier)
        saw_pre |= K == M
        saw_post |= K == M - 1
    assert saw_pre and saw_post  # exact on both sides of the shrink

    # trace tells the drill story
    doc = json.load(open(trace_path))
    assert validate_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert "fault.dead" in names
    assert "elastic.evict" in names
    assert "batch.restart" in names
    assert "snapshot.resume" in names
    evict = [e for e in doc["traceEvents"]
             if e["name"] == "elastic.evict"][0]
    assert evict["args"]["step"] == evict_step
    assert evict["args"]["reason"] == "dead"
    assert evict["args"]["new_mesh_shape"] == [M - 1, T]
    assert rec["eviction_count"] == 1.0
    assert rec["faults_injected"] == 1.0
    assert rec["resumes"] >= 1.0
