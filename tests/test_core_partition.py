"""Unit + property tests for LP partitioning, weights, reconstruction."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    blend_weight_1d,
    extract,
    global_normalizer,
    partition_weights,
    plan_partition,
    plan_partition_balanced,
    plan_uniform,
    reconstruct,
    rotation_dim,
    rotation_schedule,
    usable_dims,
)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- schedule
def test_rotation_matches_eq3():
    # d_i = M[(i-1) mod 3 + 1]: i=1 -> temporal(0), i=2 -> height(1), ...
    assert rotation_schedule(7) == (0, 1, 2, 0, 1, 2, 0)


def test_rotation_consecutive_steps_differ():
    sched = rotation_schedule(60)
    for a, b in zip(sched, sched[1:]):
        assert a != b


def test_rotation_restricted_dims():
    sched = rotation_schedule(5, dims=(1, 2))
    assert sched == (1, 2, 1, 2, 1)


def test_usable_dims_drops_short_extents():
    # 13 frames, 60x104 spatial, patch (1,2,2), K=16: temporal has 13 < 16.
    assert usable_dims((13, 60, 104), (1, 2, 2), 16) == (1, 2)
    assert usable_dims((13, 60, 104), (1, 2, 2), 4) == (0, 1, 2)


# ---------------------------------------------------------------- partition
def test_paper_partition_matches_eqs_7_to_9():
    # N=13 patches, K=4, r=1.0 -> L=4, O=4 (the 49-frame temporal case).
    plan = plan_partition(extent=13, patch=1, num_partitions=4, overlap_ratio=1.0)
    assert plan.core_patches == 4 and plan.overlap_patches == 4
    assert plan.core_start == (0, 4, 8, 12)
    assert plan.core_end == (4, 8, 12, 13)  # beta clamped to N
    assert plan.ext_start == (0, 0, 4, 8)
    assert plan.ext_end == (8, 12, 13, 13)
    assert plan.lat_start == (0, 0, 4, 8)
    assert plan.lat_end == (8, 12, 13, 13)


def test_partition_latent_mapping_scales_by_patch():
    plan = plan_partition(extent=60, patch=2, num_partitions=4, overlap_ratio=0.5)
    # N=30, L=8, O=4
    assert plan.num_patches == 30 and plan.core_patches == 8
    assert plan.overlap_patches == 4
    for s, a in zip(plan.lat_start, plan.ext_start):
        assert s == a * 2


def test_partition_absorbs_remainder():
    # extent 61 with patch 2 -> N=30 patches, one latent unit remainder.
    plan = plan_partition(extent=61, patch=2, num_partitions=4, overlap_ratio=0.5)
    assert plan.lat_end[-1] == 61
    plan.validate()


def test_balanced_partition_no_empty_cores():
    # N=21, K=16: the paper formula (L=2) would leave 5 empty partitions.
    plan = plan_partition_balanced(21, 1, 16, 0.5)
    sizes = [b - a for a, b in zip(plan.core_start, plan.core_end)]
    assert min(sizes) >= 1 and sum(sizes) == 21
    paper = plan_partition(21, 1, 16, 0.5)
    paper_sizes = [b - a for a, b in zip(paper.core_start, paper.core_end)]
    assert min(paper_sizes) == 0  # documents why balanced exists


@given(
    n_patches=st.integers(2, 120),
    patch=st.integers(1, 4),
    K=st.integers(1, 8),
    r=st.floats(0.0, 2.0),
)
@settings(max_examples=150, deadline=None)
def test_partition_properties(n_patches, patch, K, r):
    r = min(r, K - 1.0)
    extent = n_patches * patch
    plan = plan_partition(extent, patch, K, r)
    plan.validate()  # cover + nesting invariants
    # patch alignment: every boundary except the absorbed tail is a multiple
    for s in plan.lat_start:
        assert s % patch == 0
    # cores tile the patch range exactly (with clamping)
    covered = np.zeros(n_patches, dtype=int)
    for a, b in zip(plan.core_start, plan.core_end):
        covered[a:b] += 1
    assert (covered == 1).all()


@given(
    n_patches=st.integers(2, 120),
    patch=st.integers(1, 4),
    K=st.integers(1, 8),
    r=st.floats(0.0, 2.0),
)
@settings(max_examples=100, deadline=None)
def test_balanced_partition_properties(n_patches, patch, K, r):
    if n_patches < K:
        return
    r = min(r, K - 1.0)
    extent = n_patches * patch
    plan = plan_partition_balanced(extent, patch, K, r)
    plan.validate()
    covered = np.zeros(n_patches, dtype=int)
    for a, b in zip(plan.core_start, plan.core_end):
        assert b > a  # non-empty
        covered[a:b] += 1
    assert (covered == 1).all()


# ---------------------------------------------------------------- weights
def test_blend_weight_shapes_eq12():
    w = blend_weight_1d(10, 3, 2)
    np.testing.assert_allclose(w[:3], [0, 1 / 3, 2 / 3])
    np.testing.assert_allclose(w[3:8], 1.0)
    np.testing.assert_allclose(w[8:], [2 / 2, 1 / 2])


def test_blend_weight_no_overlap_is_ones():
    np.testing.assert_array_equal(blend_weight_1d(7, 0, 0), np.ones(7))


def test_normalizer_positive_and_core_exact():
    plan = plan_partition(26, 2, 4, 1.0)
    z = global_normalizer(plan)
    assert (z > 0).all()
    # where only one partition covers (e.g. x=0 region), Z == 1
    assert z[0] == pytest.approx(1.0)


@given(
    n_patches=st.integers(4, 80),
    K=st.integers(1, 6),
    r=st.floats(0.0, 1.5),
)
@settings(max_examples=100, deadline=None)
def test_normalizer_positive_property(n_patches, K, r):
    r = min(r, max(0.0, K - 1.0))
    plan = plan_partition(n_patches, 1, K, r)
    assert (global_normalizer(plan) > 0).all()


# ------------------------------------------------------------ reconstruct
def test_reconstruct_identity():
    """If every partition predicts the truth restricted to its slice, the
    reconstruction is the truth: F is a partition of unity after norm."""
    rng = np.random.default_rng(0)
    truth = jnp.asarray(rng.normal(size=(13, 6, 8, 4)).astype(np.float32))
    for r in (0.0, 0.5, 1.0):
        plan = plan_partition(13, 1, 4, r)
        preds = [extract(truth, plan, k, axis=0) for k in range(4)]
        out = reconstruct(preds, plan, axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(truth), atol=1e-6)


def test_reconstruct_k1_is_identity():
    rng = np.random.default_rng(1)
    truth = jnp.asarray(rng.normal(size=(10, 3)).astype(np.float32))
    plan = plan_partition(10, 1, 1, 0.0)
    out = reconstruct([truth], plan, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth), atol=1e-7)


def test_reconstruct_blends_disagreement_smoothly():
    """Two partitions predicting constants a and b must blend monotonically
    from a to b across the overlap — no seams (boundary-artifact check)."""
    plan = plan_partition(16, 1, 2, 0.5)  # L=8, O=4
    a = jnp.zeros((plan.sizes[0],), dtype=jnp.float32)
    b = jnp.ones((plan.sizes[1],), dtype=jnp.float32)
    out = np.asarray(reconstruct([a, b], plan, axis=0))
    assert (np.diff(out) >= -1e-6).all()  # monotone non-decreasing
    assert out[0] == 0.0 and out[-1] == 1.0


@given(
    n_patches=st.integers(4, 40),
    patch=st.integers(1, 3),
    K=st.integers(1, 5),
    r=st.floats(0.0, 1.5),
    channels=st.integers(1, 3),
)
@settings(max_examples=60, deadline=None)
def test_reconstruct_identity_property(n_patches, patch, K, r, channels):
    r = min(r, max(0.0, K - 1.0))
    extent = n_patches * patch
    rng = np.random.default_rng(n_patches * 31 + K)
    truth = jnp.asarray(
        rng.normal(size=(extent, channels)).astype(np.float32)
    )
    plan = plan_partition(extent, patch, K, r)
    preds = [extract(truth, plan, k, axis=0) for k in range(K)]
    out = reconstruct(preds, plan, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth), atol=1e-5)


# ---------------------------------------------------------------- uniform
def test_uniform_plan_shapes_equal():
    plan = plan_uniform(extent=26, patch=2, num_partitions=4, overlap_ratio=1.0)
    assert len(set([plan.window])) == 1
    plan.validate()
    assert (plan.normalizer() > 0).all()


def test_uniform_reconstruct_identity():
    from repro.core import lp_forward_uniform

    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.normal(size=(24, 5)).astype(np.float32))
    plan = plan_uniform(24, 2, 4, 0.5)
    out = lp_forward_uniform(lambda x: x, z, plan, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z), atol=1e-5)


@given(
    n_patches=st.integers(4, 60),
    patch=st.integers(1, 3),
    K=st.integers(2, 6),
    r=st.floats(0.0, 1.5),
)
@settings(max_examples=60, deadline=None)
def test_uniform_plan_properties(n_patches, patch, K, r):
    if n_patches < K:
        return
    r = min(r, K - 1.0)
    plan = plan_uniform(n_patches * patch, patch, K, r)
    plan.validate()
    assert (plan.normalizer() > 0).all()
    # windows are patch-aligned and identical size
    assert plan.window % patch == 0
    for s in plan.starts:
        assert s % patch == 0 and s + plan.window <= plan.extent


# ------------------------------------------------------- 2-completeness
def test_two_completeness_receptive_field():
    """Supplementary Thm. 1: receptive field covers Z after 2 steps when
    consecutive steps partition along different dims."""
    dims = (5, 6, 7)
    K = 2
    # step 1: partition temporal; step 2: partition height
    def partition_sets(extent, K):
        plan = plan_partition_balanced(extent, 1, K, 0.0)
        return [set(range(a, b)) for a, b in zip(plan.core_start, plan.core_end)]

    t_parts = partition_sets(dims[0], K)
    h_parts = partition_sets(dims[1], K)
    # receptive field of position p=(0,0,0) after step 1 (temporal split):
    rf1 = {
        (t, h, w)
        for t in next(p for p in t_parts if 0 in p)
        for h in range(dims[1])
        for w in range(dims[2])
    }
    # after step 2 (height split), union over all p1 in rf1:
    rf2 = set()
    for (_, h1, _) in rf1:
        hp = next(p for p in h_parts if h1 in p)
        rf2 |= {
            (t, h, w) for t in range(dims[0]) for h in hp for w in range(dims[2])
        }
    full = {
        (t, h, w)
        for t in range(dims[0])
        for h in range(dims[1])
        for w in range(dims[2])
    }
    assert rf2 == full


# ------------------------------------------------------------------ hybrid
def test_hybrid_group_layout():
    from repro.core.hybrid import make_groups

    layout = make_groups(16, 4)
    layout.validate()
    assert len(layout.groups) == 4 and all(len(g) == 4 for g in layout.groups)
    with pytest.raises(ValueError):
        make_groups(16, 5)


def test_hybrid_forward_identity():
    """Inter-group LP with identity intra-group operators == identity."""
    from repro.core.hybrid import hybrid_forward

    rng = np.random.default_rng(5)
    z = jnp.asarray(rng.normal(size=(24, 5)).astype(np.float32))
    ops = [lambda s: s for _ in range(3)]
    out = hybrid_forward(ops, z, extent_axis=0, patch=2, overlap_ratio=0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z), atol=1e-5)
