"""LP end-to-end quality validation (the paper's §5.2 claims as tests).

1. EXACTNESS: with a denoiser whose receptive field <= the overlap, LP
   reconstruction equals centralized bit-for-bit (up to float assoc) —
   validating partition + blend machinery end-to-end.
2. DiT PROXY: with a random-init DiT, LP's final latent stays close to
   centralized (local spatio-temporal dependency assumption), and
3. ROTATION ABLATION (paper Fig. 10): rotating partitions beat
   temporal-only partitioning on divergence from centralized.
4. OVERLAP TREND (paper Figs. 6-7): divergence decreases as r grows.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.diffusion import (
    FlowMatchEuler,
    generate_centralized,
    generate_lp,
    make_guided_denoiser,
)
from repro.models import dit, frontends

STEPS = 6
K = 2


def _local_denoiser(width: int):
    """Depthwise 3D box filter — receptive field `width` in every dim."""

    def fn(z, t):
        acc = z * 2.0
        for axis in (1, 2, 3):
            for shift in range(1, width + 1):
                acc = acc + jnp.roll(z, shift, axis) * 0.3 ** shift
                acc = acc + jnp.roll(z, -shift, axis) * 0.3 ** shift
        return acc * 0.1

    # roll wraps around, which breaks locality at the global edges; a
    # valid local denoiser must not wrap — mask by shrinking via pad+crop
    def nonwrap(z, t):
        pad = [(0, 0)] + [(width, width)] * 3 + [(0, 0)]
        zp = jnp.pad(z, pad, mode="edge")
        out = fn(zp, t)
        sl = (slice(None),) + tuple(slice(width, -width) for _ in range(3)) \
            + (slice(None),)
        return out[sl]

    return nonwrap


def _rel_err(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-9))


def test_lp_exact_with_local_denoiser():
    """Receptive field (1) <= overlap per side => centralized == LP in
    every position: 2*K windows each see enough context."""
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(1, 8, 8, 12, 4)).astype(np.float32))
    den = _local_denoiser(width=1)
    sampler = FlowMatchEuler(STEPS)
    z_c = generate_centralized(den, z, STEPS, sampler)
    for uniform in (False, True):
        z_lp = generate_lp(
            den, z, STEPS, num_partitions=K, overlap_ratio=1.0,
            patch_sizes=(1, 2, 2), sampler=sampler, uniform=uniform,
        )
        err = _rel_err(z_lp, z_c)
        assert err < 1e-5, f"uniform={uniform}: {err}"


def _dit_setup(seed=0):
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    ctx = frontends.text_context(jax.random.PRNGKey(seed + 1), 1, cfg)
    null_ctx = jnp.zeros_like(ctx)

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    den = make_guided_denoiser(fwd, params, cfg, ctx, null_ctx, guidance=3.0)
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(1, 6, 8, 12, cfg.latent_channels))
                    .astype(np.float32))
    return den, z


def test_lp_dit_close_to_centralized():
    den, z = _dit_setup()
    sampler = FlowMatchEuler(STEPS)
    z_c = generate_centralized(den, z, STEPS, sampler)
    z_lp = generate_lp(den, z, STEPS, num_partitions=K, overlap_ratio=1.0,
                       patch_sizes=(1, 2, 2), sampler=sampler)
    err = _rel_err(z_lp, z_c)
    assert err < 0.25, f"LP diverged from centralized: rel_err={err}"
    assert np.isfinite(np.asarray(z_lp)).all()


def test_rotation_beats_temporal_only():
    """Paper Fig. 10: dynamic rotation < fixed-dim partitioning error."""
    den, z = _dit_setup(seed=1)
    sampler = FlowMatchEuler(STEPS)
    z_c = generate_centralized(den, z, STEPS, sampler)

    from repro.core import lp_denoise

    def run(dims):
        def den_for_step(i, dim):
            def f(sub):
                t = jnp.full((sub.shape[0],), sampler.timestep(i), jnp.float32)
                return den(sub, t)
            return f

        from repro.core.lp_step import lp_forward
        from repro.core.partition import plan_partition
        from repro.core.schedule import rotation_dim

        zz = z
        for i in range(1, STEPS + 1):
            dim = rotation_dim(i, dims)
            axis = 1 + dim
            plan = plan_partition(zz.shape[axis], (1, 2, 2)[dim], K, 0.5, dim)
            pred = lp_forward(den_for_step(i, dim), zz, plan, axis)
            zz = sampler.step(zz, pred, i)
        return zz

    err_rot = _rel_err(run((0, 1, 2)), z_c)
    err_fixed = _rel_err(run((0,)), z_c)
    assert err_rot < err_fixed, (
        f"rotation ({err_rot}) should beat temporal-only ({err_fixed})"
    )


def test_overlap_ratio_monotone_trend():
    """Paper Figs. 6-7: larger r => closer to centralized (allowing noise,
    compare r=0 vs r=1)."""
    den, z = _dit_setup(seed=2)
    sampler = FlowMatchEuler(STEPS)
    z_c = generate_centralized(den, z, STEPS, sampler)
    errs = {}
    for r in (0.0, 1.0):
        z_lp = generate_lp(den, z, STEPS, num_partitions=K, overlap_ratio=r,
                           patch_sizes=(1, 2, 2), sampler=sampler)
        errs[r] = _rel_err(z_lp, z_c)
    assert errs[1.0] < errs[0.0], errs


def test_lp_uniform_engine_matches_reference_engine():
    """Variable-size (paper-exact) vs uniform-window (SPMD) engines agree
    in the *core* regions when overlap geometry is identical."""
    den, z = _dit_setup(seed=3)
    sampler = FlowMatchEuler(3)
    a = generate_lp(den, z, 3, num_partitions=K, overlap_ratio=1.0,
                    patch_sizes=(1, 2, 2), sampler=sampler, uniform=False)
    b = generate_lp(den, z, 3, num_partitions=K, overlap_ratio=1.0,
                    patch_sizes=(1, 2, 2), sampler=sampler, uniform=True)
    # engines differ only in edge-window context (uniform sees more);
    # results must be close globally
    assert _rel_err(a, b) < 0.15
