"""Hybrid LP×TP halo engine: byte-model contract, compile-count
guarantee, mesh helpers, and the TP CFG-pair Phi_m building block."""
import subprocess
import sys
import textwrap

import pytest

from repro.core import comm_model as cm
from repro.core.spmd import LP_IMPLS, select_lp_impl
from repro.launch.mesh import parse_mesh


# ----------------------------------------------------------- pure helpers
def test_parse_mesh():
    assert parse_mesh("4x2") == (4, 2)
    assert parse_mesh("16X16") == (16, 16)
    assert parse_mesh("4") == (4, 1)
    for bad in ("1x2", "4x0", "4x2x2", "ax2", ""):
        with pytest.raises(ValueError):
            parse_mesh(bad)


def test_select_lp_impl_tp_aware():
    assert "halo_hybrid" in LP_IMPLS
    assert select_lp_impl(2) == "shard_map"
    assert select_lp_impl(2, tp=4) == "shard_map"   # break-even unchanged
    assert select_lp_impl(4) == "halo"
    assert select_lp_impl(4, tp=2) == "halo_hybrid"
    assert select_lp_impl(16, tp=16) == "halo_hybrid"


def test_comm_lp_halo_hybrid_model():
    cfg = cm.wan21_comm_config(49)
    # T parallel lp rings: group bytes scale linearly in T, per-device
    # payloads (the HLO contract) are T-independent
    one = cm.comm_lp_halo_hybrid(cfg, 4, 1, 0.5)
    assert one == cm.comm_lp_halo_codec(cfg, 4, 0.5, "fp32")
    assert cm.comm_lp_halo_hybrid(cfg, 4, 4, 0.5) == 4 * one
    step1 = cm.lp_halo_hybrid_step_collectives(cfg, 4, 1, 0.5, dim=1)
    step8 = cm.lp_halo_hybrid_step_collectives(cfg, 4, 8, 0.5, dim=1)
    assert step1 == step8
    assert step1 == cm.lp_halo_codec_step_collectives(cfg, 4, 0.5, dim=1,
                                                      codec="fp32")
    with pytest.raises(ValueError):
        cm.comm_lp_halo_hybrid(cfg, 4, 0, 0.5)
    # codec'd gspmd saves zero bytes by construction
    assert cm.comm_lp_gspmd_codec(cfg, 4, 0.5, "int8") == \
        cm.comm_lp_spmd(cfg, 4, 0.5)


# --------------------------------------------------- multi-device (slow)
HYBRID_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec
    from repro.core import LPStepCompiler, comm_model as cm, lp_denoise
    from repro.core import plan_uniform
    from repro.core.hybrid import (
        lp_forward_halo_hybrid, tp_cfg_branch, tp_cfg_combine)
    from repro.core.lp_step import lp_forward_uniform
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.launch.mesh import make_hybrid_mesh

    M, T = 4, 2
    mesh = make_hybrid_mesh(M, T)
    rng = np.random.default_rng(0)

    # ---- byte-model contract: modeled == measured EXACTLY per codec
    z = jnp.asarray(rng.normal(size=(26, 6, 4)).astype(np.float32))
    plan = plan_uniform(26, 2, M, 0.5)
    d = 4
    w1 = jnp.eye(d) * 0.1 + 0.05

    def tp_den(x):
        tp = jax.lax.axis_index("model")
        half = d // 2
        ws = jax.lax.dynamic_slice_in_dim(w1, tp * half, half, 0)
        xs = jax.lax.dynamic_slice_in_dim(x, tp * half, half, x.ndim - 1)
        part = jnp.einsum("...c,cd->...d", xs, ws)
        return jnp.tanh(x) * 0.5 + jax.lax.psum(part, "model")

    ccfg = cm.VDMCommConfig(
        latent_dims=(26, 6, 4), latent_channels=1, patch_sizes=(2, 1, 1),
        d_model=1, num_blocks=1, num_steps=1,
    )
    for name in ("fp32", "bf16", "int8"):
        c = None if name == "fp32" else name
        fn = jax.jit(lambda zz: lp_forward_halo_hybrid(
            tp_den, zz, plan, 0, mesh, codec=c))
        a = analyze(fn.lower(z).compile().as_text())
        want = cm.lp_halo_hybrid_step_collectives(
            ccfg, M, T, 0.5, dim=0, codec=name)
        for kind in ("all-gather", "collective-permute"):
            got = a.collective_bytes.get(kind, 0)
            assert got == want[kind], (name, kind, got, want)
        # the ONLY all-reduce is the intra-group Phi_m psum (never LP)
        n_ar = a.collective_counts.get("all-reduce", 0)
        assert n_ar <= 1, (name, a.collective_counts)
    print("BYTES-OK")

    # ---- compile-count guarantee: T-step denoise on the (M, T) mesh
    # with a residual codec still compiles <= 3 times (state in the
    # scan carry, hybrid collectives inside the compiled step)
    codec = get_codec("int8-residual")
    z5 = jnp.asarray(rng.normal(size=(1, 8, 12, 10, 4)).astype(np.float32))
    sampler = FlowMatchEuler(12)
    traces = {"n": 0}

    def den_step(w, t):
        traces["n"] += 1  # fires only while tracing
        g = tp_cfg_branch("model").astype(jnp.float32)  # exercise tp axis
        pred = jnp.tanh(w) * (0.1 + 0.01 * g) + w * 1e-4 * t
        return tp_cfg_combine(pred, "model", 1.0)

    fwd = lambda fn, zz, plan, axis, st: lp_forward_halo_hybrid(
        fn, zz, plan, axis, mesh, "data", "model",
        codec=codec, codec_state=st)
    comp = LPStepCompiler(
        den_step, sampler.update, M, 0.5, (1, 2, 2), (1, 2, 3),
        uniform=True, forward=fwd, codec=codec, mesh_shape=(M, T),
    )
    out = lp_denoise(None, z5, sampler, 12, M, 0.5, (1, 2, 2), (1, 2, 3),
                     uniform=True, compiler=comp)
    assert np.isfinite(np.asarray(out)).all()
    assert traces["n"] <= 3, traces
    assert comp.compiles <= 3 and comp.hits >= 9, (comp.compiles, comp.hits)
    before = comp.compiles
    lp_denoise(None, z5, sampler, 12, M, 0.5, (1, 2, 2), (1, 2, 3),
               uniform=True, compiler=comp)
    assert comp.compiles == before  # second run fully cache-served
    print("COMPILES-OK", comp.compiles, comp.hits)
    """
)


@pytest.mark.slow
def test_hybrid_bytes_contract_and_compile_count():
    res = subprocess.run(
        [sys.executable, "-c", HYBRID_SCRIPT],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        cwd="/root/repo",
        timeout=580,
    )
    assert res.returncode == 0, f"stdout={res.stdout}\nstderr={res.stderr}"
    assert "BYTES-OK" in res.stdout and "COMPILES-OK" in res.stdout
