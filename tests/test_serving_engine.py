"""Serving engine: batching, failure re-queue, straggler re-planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.models import dit, frontends
from repro.runtime.faults import ServingFault
from repro.runtime.ft import DeviceFailure
from repro.serving.engine import LPServingEngine, VideoRequest


def _engine(num_steps=3, max_batch=2):
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    return cfg, LPServingEngine(fwd, params, cfg, num_partitions=2,
                                overlap_ratio=0.5, num_steps=num_steps,
                                max_batch=max_batch)


def _req(cfg, i, shape=(4, 8, 12), guidance=5.0):
    return VideoRequest(
        request_id=i,
        context=frontends.text_context(jax.random.PRNGKey(100 + i), 1, cfg),
        latent_shape=shape,
        seed=i,
        guidance=guidance,
    )


def test_engine_serves_batched_requests():
    cfg, eng = _engine()
    for i in range(4):
        eng.submit(_req(cfg, i))
    results = eng.run()
    assert sorted(r.request_id for r in results) == [0, 1, 2, 3]
    for r in results:
        assert r.latent.shape == (1, 4, 8, 12, cfg.latent_channels)
        assert np.isfinite(np.asarray(r.latent, np.float32)).all()


def test_engine_groups_by_geometry():
    cfg, eng = _engine(max_batch=4)
    eng.submit(_req(cfg, 0, shape=(4, 8, 12)))
    eng.submit(_req(cfg, 1, shape=(6, 8, 12)))
    eng.submit(_req(cfg, 2, shape=(4, 8, 12)))
    results = eng.run()
    assert len(results) == 3
    shapes = {r.request_id: r.latent.shape[1] for r in results}
    assert shapes == {0: 4, 1: 6, 2: 4}


def test_engine_requeues_failed_batch():
    cfg, eng = _engine()
    eng.submit(_req(cfg, 0))
    fired = {"n": 0}

    def fault(step):
        if step == 2 and fired["n"] == 0:
            fired["n"] += 1
            raise ServingFault("injected LP group failure", step=step)

    eng._step_fault = fault
    results = eng.run()
    assert len(results) == 1 and results[0].restarts == 1
    assert np.isfinite(np.asarray(results[0].latent, np.float32)).all()


def test_engine_retry_is_narrowed_to_recoverable_faults():
    """The retry loop must only catch DeviceFailure/ServingFault — a bare
    RuntimeError (XLA error, programming bug) is deterministic and must
    surface immediately instead of burning the restart budget."""
    cfg, eng = _engine()
    eng.submit(_req(cfg, 0))
    calls = {"n": 0}

    def bug(step):
        if step == 2:
            calls["n"] += 1
            raise RuntimeError("not a serving fault")

    eng._step_fault = bug
    with pytest.raises(RuntimeError, match="not a serving fault"):
        eng.run()
    assert calls["n"] == 1  # surfaced on first occurrence, no retries

    # DeviceFailure (lost hardware) stays retryable
    cfg2, eng2 = _engine()
    eng2.submit(_req(cfg2, 0))
    fired = {"n": 0}

    def dev_fault(step):
        if step == 1 and fired["n"] == 0:
            fired["n"] += 1
            raise DeviceFailure("host fell out of the ring")

    eng2._step_fault = dev_fault
    results = eng2.run()
    assert len(results) == 1 and results[0].restarts == 1


def test_engine_retry_resumes_from_boundary_snapshot():
    """A recoverable fault at step s resumes from the last dim-rotation
    boundary, not from z_T: with a 3-dim latent every step is its own
    dim-run, so the retry re-executes ONLY the faulted step and the
    result matches a fault-free serve bit-for-bit."""
    cfg, eng = _engine(num_steps=3)
    eng.submit(_req(cfg, 0))
    clean = eng.run()[0].latent

    cfg2, eng2 = _engine(num_steps=3)
    eng2.submit(_req(cfg2, 0))
    fired = {"n": 0}

    def fault(step):
        if step == 3 and fired["n"] == 0:
            fired["n"] += 1
            raise ServingFault("late fault", step=step)

    eng2._step_fault = fault
    res = eng2.run()[0]
    assert res.restarts == 1
    assert res.resumed_from_step == 2      # boundary right before step 3
    assert eng2.last_steps_lost == 0       # nothing beyond the boundary
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(res.latent))


def test_engine_reuses_compiled_steps_across_batches():
    """Second batch of the same geometry must hit the compiled-step cache
    (no retrace): conditioning is traced, not baked into closures."""
    cfg, eng = _engine(num_steps=2, max_batch=1)
    eng.submit(_req(cfg, 0))
    eng.run()
    compiles_after_first = eng._compiler.compiles
    assert compiles_after_first >= 1
    eng.submit(_req(cfg, 1))
    eng.submit(_req(cfg, 2))
    results = eng.run()
    assert len(results) == 2
    assert eng._compiler.compiles == compiles_after_first
    assert eng._compiler.hits > 0


def test_engine_buckets_by_guidance_not_just_shape():
    """A batch shares ONE traced guidance scalar, so two requests with
    different guidance must never ride the same batch (the old
    shape-only bucketing silently applied reqs[0].guidance to all)."""
    cfg, eng = _engine(num_steps=2, max_batch=4)
    eng.submit(_req(cfg, 0, guidance=5.0))
    eng.submit(_req(cfg, 1, guidance=1.5))
    eng.submit(_req(cfg, 2, guidance=5.0))
    results = {r.request_id: r for r in eng.run()}
    assert sorted(results) == [0, 1, 2]
    # guidance-5 pair batched together; the odd one ran alone
    assert results[0].batch_size == 2 and results[2].batch_size == 2
    assert results[1].batch_size == 1
    # and the lone request really computed with ITS guidance: same
    # request served solo at guidance 1.5 must match bit-for-bit
    cfg2, eng2 = _engine(num_steps=2, max_batch=1)
    eng2.submit(_req(cfg2, 1, guidance=1.5))
    solo = eng2.run()[0].latent
    np.testing.assert_allclose(np.asarray(results[1].latent),
                               np.asarray(solo), atol=2e-4, rtol=2e-3)


def test_engine_reports_batch_wall_and_size():
    cfg, eng = _engine(num_steps=2, max_batch=2)
    for i in range(3):
        eng.submit(_req(cfg, i))
    results = sorted(eng.run(), key=lambda r: r.request_id)
    assert [r.batch_size for r in results] == [2, 2, 1]
    assert all(r.batch_wall_s > 0 for r in results)
    # riders of one batch share the batch wall; separate batches don't
    assert results[0].batch_wall_s == results[1].batch_wall_s
    assert results[1].batch_wall_s != results[2].batch_wall_s


def test_engine_elastic_evicts_straggler_mid_request():
    """Satellite (ROADMAP open item): StragglerState.propose_group_
    eviction is wired into the serving step hook — a far-gone straggler
    group is evicted WHILE the batch denoises, the compiled-step cache
    re-plans (epoch bump, no stale entries), and the result is sane."""
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    eng = LPServingEngine(fwd, params, cfg, num_partitions=4,
                          overlap_ratio=0.5, num_steps=3, max_batch=1,
                          elastic=True, wire_codec="int8-residual")
    # group 3's EMA is 9x the median: eviction threshold well exceeded
    for _ in range(5):
        eng.straggler.observe([1.0, 1.0, 1.0, 9.0])
    eng.submit(_req(cfg, 0, shape=(8, 8, 12)))
    results = eng.run()
    assert eng.evictions == 1
    assert eng.K == 3 and eng._compiler.num_partitions == 3
    assert eng._compiler.plan_epoch == 1
    assert eng.straggler.num_partitions == 3
    assert np.isfinite(np.asarray(results[0].latent, np.float32)).all()
    # a healthy ring proposes nothing: second request, no further evicts
    eng.submit(_req(cfg, 1, shape=(8, 8, 12)))
    eng.run()
    assert eng.evictions == 1 and eng.K == 3


def test_engine_codec_schedule_auto_plans_and_serves():
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    eng = LPServingEngine(fwd, params, cfg, num_partitions=2, num_steps=3,
                          max_batch=2, codec_schedule="auto")
    assert eng.plan is not None
    assert eng.plan.envelope_db >= 40.0
    assert eng.lp_impl == "halo"  # codec'd halo beats psum even at K=2
    assert eng._compiler.schedule is not None
    eng.submit(_req(cfg, 0))
    eng.submit(_req(cfg, 1))
    results = eng.run()
    assert len(results) == 2
    for r in results:
        assert np.isfinite(np.asarray(r.latent, np.float32)).all()
    # compiled steps are shared across batches: serve again, no retrace
    before = eng._compiler.compiles
    eng.submit(_req(cfg, 2))
    eng.submit(_req(cfg, 3))
    eng.run()
    assert eng._compiler.compiles == before
    # exclusivity guards
    with pytest.raises(ValueError, match="not both"):
        LPServingEngine(fwd, params, cfg, num_partitions=2, num_steps=2,
                        wire_codec="int8", codec_schedule="auto")
    with pytest.raises(ValueError, match="psnr_floor"):
        LPServingEngine(fwd, params, cfg, num_partitions=2, num_steps=2,
                        psnr_floor=40.0)


def test_engine_determinism_across_batching():
    """A request's output must not depend on which batch it rode in —
    but CFG context batching means same-seed requests in one batch are
    independent computations; check same request alone == with neighbor."""
    cfg, eng1 = _engine(num_steps=2, max_batch=1)
    eng1.submit(_req(cfg, 7))
    solo = eng1.run()[0].latent

    cfg2, eng2 = _engine(num_steps=2, max_batch=2)
    eng2.submit(_req(cfg2, 7))
    eng2.submit(_req(cfg2, 8))
    paired = {r.request_id: r.latent for r in eng2.run()}
    np.testing.assert_allclose(
        np.asarray(solo), np.asarray(paired[7]), atol=2e-4, rtol=2e-3,
    )


class _FakeMesh:
    """Axis-shape stand-in: the constructor's tri-state resolution only
    reads ``axis_names`` and ``shape`` (closures capture the mesh but
    are not traced until a batch is served)."""

    def __init__(self, lp, tp):
        self.axis_names = ("data", "model")
        self.shape = {"data": lp, "model": tp}


def test_engine_wire_knob_tri_states_resolve_after_plan():
    """Satellite regression (pinned-vs-auto matrix): ``eager_sends`` /
    ``wire_shard`` tri-states must resolve from the FINAL engine family
    — the autotuner may flip a fp32-only schedule to the psum engine,
    and the pre-fix resolution from ``tp`` alone baked hybrid wire
    knobs for an engine the plan then discarded."""
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    def mk(**kw):
        return LPServingEngine(fwd, params, cfg, num_partitions=2,
                               num_steps=2, **kw)

    # tp=1, off-mesh: autos resolve off; an eager pin is still honored
    eng = mk(wire_codec="int8-residual")
    assert (eng.eager_sends, eng.wire_shard) == (False, False)
    assert mk(wire_codec="int8", eager_sends=True).eager_sends is True
    with pytest.raises(ValueError, match="tp axis"):
        mk(wire_shard=True)  # nothing to shard over

    # hybrid mesh, halo family: autos resolve on; pins override both
    mesh = _FakeMesh(2, 2)
    eng = mk(wire_codec="int8-residual", mesh=mesh)
    assert eng.lp_impl == "halo_hybrid"
    assert (eng.eager_sends, eng.wire_shard) == (True, True)
    eng = mk(wire_codec="int8-residual", mesh=mesh,
             eager_sends=False, wire_shard=False)
    assert (eng.eager_sends, eng.wire_shard) == (False, False)

    # THE regression: a fp32-only schedule on the hybrid mesh flips the
    # family to the psum engine at K=2 — the wire knobs must follow the
    # final family, not the mesh shape
    eng = mk(codec_schedule="fp32", mesh=mesh)
    assert eng.lp_impl == "shard_map"
    assert (eng.eager_sends, eng.wire_shard) == (False, False)
    # an auto-resolving pin that the flip leaves nothing to honor on is
    # a loud config error, not a silent downgrade
    with pytest.raises(ValueError, match="mesh-bound halo family"):
        mk(codec_schedule="fp32", mesh=mesh, wire_shard=True)
    # a schedule that keeps the halo family keeps the pins verbatim
    eng = mk(codec_schedule="int8-residual", mesh=mesh, eager_sends=True)
    assert eng.lp_impl == "halo_hybrid"
    assert (eng.eager_sends, eng.wire_shard) == (True, True)

    # displaced codecs are halo-family-only at the engine boundary too
    with pytest.raises(ValueError, match="displaced halo codec"):
        mk(codec_schedule="displaced:int8-residual@0.5,int8-residual",
           lp_impl="shard_map")


def test_engine_request_lifecycle_on_virtual_clock():
    """Lifecycle stamps live on the injectable engine clock: with a
    VirtualClock, queue wait is exact virtual time submit -> admit and
    e2e closes at admit + the batch's measured wall (the clock only
    advances by measured service time), landing on the VideoResult and
    — with a recorder + SLO spec — as lifecycle rows, per-priority
    histograms and a live violation count."""
    from repro.obs import FlightRecorder
    from repro.obs import metrics as obsm
    from repro.serving.loadgen import VirtualClock

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    rec = FlightRecorder()
    clock = VirtualClock()
    eng = LPServingEngine(fwd, params, cfg, num_partitions=2,
                          num_steps=2, max_batch=2, recorder=rec,
                          clock=clock, slo="interactive:1e-9,standard:60")
    eng.submit(VideoRequest(
        request_id=0,
        context=frontends.text_context(jax.random.PRNGKey(1), 1, cfg),
        latent_shape=(4, 8, 12), seed=0, priority="interactive"))
    clock.advance(0.25)          # request 1 arrives 0.25s later
    eng.submit(VideoRequest(
        request_id=1,
        context=frontends.text_context(jax.random.PRNGKey(2), 1, cfg),
        latent_shape=(4, 8, 12), seed=1, priority="standard"))
    results = {r.request_id: r for r in eng.run()}

    r0, r1 = results[0], results[1]
    # both admitted at t=0.25; the clock advanced only by the wall
    assert r0.queue_wait_s == pytest.approx(0.25)
    assert r1.queue_wait_s == 0.0
    assert r0.e2e_s == pytest.approx(0.25 + r0.batch_wall_s)
    assert r1.e2e_s == r1.batch_wall_s      # exact: same float path
    assert clock.now == pytest.approx(0.25 + r0.batch_wall_s)
    assert eng._lifecycle == {}             # every row closed out

    rows = {row["request_id"]: row for row in rec.request_rows}
    assert rows[0]["violated"] is True      # 1ns interactive deadline
    assert rows[1]["violated"] is False
    assert rows[0]["deadline_s"] == 1e-9
    assert rows[0]["batch_seq"] == rows[1]["batch_seq"] == 1
    assert rows[0]["batch_size"] == 2
    assert rows[0]["denoise_start_s"] == pytest.approx(0.25)
    m = rec.metrics
    assert m.counter_value(obsm.SLO_VIOLATIONS, priority="interactive") \
        == 1.0
    assert m.counter_value(obsm.SLO_VIOLATIONS, priority="standard") == 0.0
    assert m.hist_values(obsm.QUEUE_WAIT_S, priority="interactive") \
        == [pytest.approx(0.25)]
    assert m.hist_values(obsm.E2E_LATENCY_S, priority="standard") \
        == [pytest.approx(r1.e2e_s)]
    assert m.hist_values(obsm.BATCH_OCCUPANCY) == [1.0]
    # the lifecycle span rides the trace in the virtual-time domain
    evs = [e for e in rec.trace.events if e["name"] == "request.lifecycle"]
    assert len(evs) == 2
    by_id = {e["args"]["request_id"]: e for e in evs}
    assert by_id[0]["ts"] == 0.0
    assert by_id[1]["ts"] == pytest.approx(0.25e6)
    assert by_id[0]["dur"] == pytest.approx(r0.e2e_s * 1e6)
