"""Serving engine: batching, failure re-queue, straggler re-planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.models import dit, frontends
from repro.serving.engine import LPServingEngine, VideoRequest


def _engine(num_steps=3, max_batch=2):
    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    return cfg, LPServingEngine(fwd, params, cfg, num_partitions=2,
                                overlap_ratio=0.5, num_steps=num_steps,
                                max_batch=max_batch)


def _req(cfg, i, shape=(4, 8, 12)):
    return VideoRequest(
        request_id=i,
        context=frontends.text_context(jax.random.PRNGKey(100 + i), 1, cfg),
        latent_shape=shape,
        seed=i,
    )


def test_engine_serves_batched_requests():
    cfg, eng = _engine()
    for i in range(4):
        eng.submit(_req(cfg, i))
    results = eng.run()
    assert sorted(r.request_id for r in results) == [0, 1, 2, 3]
    for r in results:
        assert r.latent.shape == (1, 4, 8, 12, cfg.latent_channels)
        assert np.isfinite(np.asarray(r.latent, np.float32)).all()


def test_engine_groups_by_geometry():
    cfg, eng = _engine(max_batch=4)
    eng.submit(_req(cfg, 0, shape=(4, 8, 12)))
    eng.submit(_req(cfg, 1, shape=(6, 8, 12)))
    eng.submit(_req(cfg, 2, shape=(4, 8, 12)))
    results = eng.run()
    assert len(results) == 3
    shapes = {r.request_id: r.latent.shape[1] for r in results}
    assert shapes == {0: 4, 1: 6, 2: 4}


def test_engine_requeues_failed_batch():
    cfg, eng = _engine()
    eng.submit(_req(cfg, 0))
    fired = {"n": 0}

    def fault(step):
        if step == 2 and fired["n"] == 0:
            fired["n"] += 1
            raise RuntimeError("injected LP group failure")

    eng._step_fault = fault
    results = eng.run()
    assert len(results) == 1 and results[0].restarts == 1
    assert np.isfinite(np.asarray(results[0].latent, np.float32)).all()


def test_engine_reuses_compiled_steps_across_batches():
    """Second batch of the same geometry must hit the compiled-step cache
    (no retrace): conditioning is traced, not baked into closures."""
    cfg, eng = _engine(num_steps=2, max_batch=1)
    eng.submit(_req(cfg, 0))
    eng.run()
    compiles_after_first = eng._compiler.compiles
    assert compiles_after_first >= 1
    eng.submit(_req(cfg, 1))
    eng.submit(_req(cfg, 2))
    results = eng.run()
    assert len(results) == 2
    assert eng._compiler.compiles == compiles_after_first
    assert eng._compiler.hits > 0


def test_engine_determinism_across_batching():
    """A request's output must not depend on which batch it rode in —
    but CFG context batching means same-seed requests in one batch are
    independent computations; check same request alone == with neighbor."""
    cfg, eng1 = _engine(num_steps=2, max_batch=1)
    eng1.submit(_req(cfg, 7))
    solo = eng1.run()[0].latent

    cfg2, eng2 = _engine(num_steps=2, max_batch=2)
    eng2.submit(_req(cfg2, 7))
    eng2.submit(_req(cfg2, 8))
    paired = {r.request_id: r.latent for r in eng2.run()}
    np.testing.assert_allclose(
        np.asarray(solo), np.asarray(paired[7]), atol=2e-4, rtol=2e-3,
    )
