"""LP engine conformance matrix — the single source of truth.

One parametrized suite asserting numerical equivalence across every LP
SPMD engine x K x rotation dim x wire codec, against the fp32 psum-math
reference (``lp_forward_uniform``).  Cells with an exact wire (fp32
codec, or no codec) must match to 1e-5; lossy codecs are gated at the
documented PSNR floors below (vs the fp32 reference — int8-family cells
sit >= 40 dB, int4 trades quality for an 8x wire and gets its own
documented floor; see docs/hybrid_lp_tp.md).

Engines (``ENGINE_CODECS`` is the support matrix — a future engine joins
the suite by adding a row here and a branch in the subprocess runner):

  * ``psum``        — ``core/spmd.lp_forward_shard_map`` (fp32 wire only)
  * ``gspmd``       — ``core/spmd.lp_forward_gspmd`` (stateless codecs,
                      value-faithful blend; single-axis mesh on jax 0.4.x)
  * ``halo``        — ``core/spmd.lp_forward_halo`` (all codecs)
  * ``halo_hybrid`` — ``core/hybrid.lp_forward_halo_hybrid`` on a
                      ``(K, 2)`` mesh with a Megatron-style TP Phi_m
                      (all codecs)
  * ``halo_hybrid_ws`` — the hybrid engine with ``wire_shard=True``
                      (tp-sharded wire, same ``(K, 2)`` mesh, all
                      codecs incl. the residual scan-carry state).
                      These cells additionally assert BIT-equality
                      with the unsharded hybrid engine — sharding is
                      transport-only
  * ``halo_hybrid_ws4`` — wire-shard at T=4 (``(2, 4)`` mesh; K=2
                      only — 8 fake devices), int8 + int8-residual
  * ``simulate``    — ``comm.wire.simulate_halo_forward``, the
                      single-process mirror (all codecs; runs in-process
                      in the fast tier too)

The SPMD cells run on 8 fake CPU devices in one subprocess per K (the
device-count XLA flag must not leak into this process).
"""
import os
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import get_codec, init_halo_wire_state, simulate_halo_forward
from repro.core import plan_uniform
from repro.core.lp_step import lp_forward_uniform
from repro.distributed.collectives import halo_spec

# ------------------------------------------------------------ the matrix
KS = (2, 3, 4)
# z is (T, H, W, C); dim d partitions axis d with patch PATCHES[d]
Z_SHAPE = (8, 12, 10, 4)
PATCHES = (1, 2, 2)
R = 0.5

ALL_CODECS = ("fp32", "bf16", "int8", "int4", "int8-residual")
STATELESS = ("fp32", "bf16", "int8", "int4")
# displaced (stale-slab) halo cells: the dim-rotation flush makes the
# FIRST step of every run synchronous, so a single-pass cell must land
# exactly where its residual base does — well above the displaced
# envelope floor, which prices multi-step staleness (the multi-step
# staleness bound itself is property-tested in test_wire_codec.py).
DISPLACED = ("displaced:int8-residual", "displaced:int4-residual")
ENGINE_CODECS = {
    "psum": ("fp32",),            # the psum engine has no codec layer
    "gspmd": STATELESS,           # residual state needs the halo schedule
    "halo": ALL_CODECS + DISPLACED[:1],
    "halo_hybrid": ALL_CODECS + DISPLACED[:1],
    # tp-sharded wire: every codec incl. BOTH residual scan-carry
    # variants and BOTH displaced variants (whose state adds the
    # staleness flag) — the cells assert bit-equality with the
    # unsharded hybrid engine (output AND codec state)
    "halo_hybrid_ws": ALL_CODECS + ("int4-residual",) + DISPLACED,
    "simulate": ALL_CODECS,
}
# wire-shard at T=4: K=2 fits the (2, 4) mesh on 8 fake devices
WS4_CODECS = ("int8", "int8-residual")
# documented PSNR floors (dB) for lossy wires vs the fp32 psum reference,
# single forward pass on N(0,1) latents; exact cells use allclose 1e-5.
# The floors live in policy/envelope.py — they double as the quality
# envelope the step-policy autotuner plans against, and importing them
# here means the CI gate and the planner can never disagree.
from repro.policy.envelope import PSNR_ENVELOPE_DB

PSNR_FLOOR_DB = {k: v for k, v in PSNR_ENVELOPE_DB.items() if k != "fp32"}


def _psnr(a, b) -> float:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    mse = float(np.mean((a - b) ** 2))
    return float(10 * np.log10(float(np.abs(b).max()) ** 2 / max(mse, 1e-30)))


def _check_cell(out, ref, codec_name: str, tag: str) -> None:
    if codec_name == "fp32":
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5, err_msg=tag
        )
    else:
        db = _psnr(out, ref)
        floor = PSNR_FLOOR_DB[codec_name]
        assert db >= floor, f"{tag}: {db:.1f} dB < {floor} dB floor"


def _cells_for(engine: str, K: int):
    for dim in range(3):
        for codec in ENGINE_CODECS[engine]:
            yield dim, codec


# --------------------------------------------- fast tier: simulate engine
def _den(x):
    return jnp.tanh(x) * 0.5 + x


@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("dim", [0, 1, 2])
@pytest.mark.parametrize("codec_name",
                         ALL_CODECS + ("int4-residual",) + DISPLACED)
def test_simulate_engine_conformance(K, dim, codec_name):
    """The single-process mirror passes every cell of the matrix without
    needing fake devices — this is the tier-1 face of the suite."""
    rng = np.random.default_rng(7)
    z = jnp.asarray(rng.normal(size=Z_SHAPE).astype(np.float32))
    plan = plan_uniform(Z_SHAPE[dim], PATCHES[dim], K, R, dim)
    ref = lp_forward_uniform(_den, z, plan, axis=dim)
    codec = get_codec(codec_name)
    if codec.stateful:
        rest = tuple(s for i, s in enumerate(Z_SHAPE) if i != dim)
        st = init_halo_wire_state(codec, halo_spec(plan), rest)
        out, _ = simulate_halo_forward(_den, z, plan, dim, codec, st)
    else:
        out = simulate_halo_forward(_den, z, plan, dim, codec_name)
    _check_cell(out, ref, codec_name, f"simulate/K{K}/dim{dim}/{codec_name}")


# ------------------------------------------- slow tier: SPMD engine matrix
SPMD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import plan_uniform
    from repro.core.hybrid import lp_forward_halo_hybrid
    from repro.core.lp_step import lp_forward_uniform
    from repro.core.spmd import (
        lp_forward_gspmd, lp_forward_halo, lp_forward_shard_map)
    from repro.distributed.collectives import halo_spec
    from repro.launch.mesh import make_hybrid_mesh

    K = %(K)d
    Z_SHAPE, PATCHES, R = %(Z_SHAPE)r, %(PATCHES)r, %(R)r
    mesh1 = Mesh(np.asarray(jax.devices()[:K]), ("data",))
    mesh2 = make_hybrid_mesh(K, 2)
    mesh4 = make_hybrid_mesh(K, 4) if K * 4 <= len(jax.devices()) else None

    rng = np.random.default_rng(7)
    z = jnp.asarray(rng.normal(size=Z_SHAPE).astype(np.float32))
    C = Z_SHAPE[-1]
    w1 = jnp.asarray(rng.normal(size=(C, C)).astype(np.float32)) * 0.1

    def den(x):  # same math every engine computes
        return jnp.tanh(x) * 0.5 + jnp.einsum("...c,cd->...d", x, w1)

    def make_tp_den(T):  # Megatron Phi_m: 1/T of the contraction per rank
        def tp_den(x):
            tp = jax.lax.axis_index("model")
            part = C // T
            ws = jax.lax.dynamic_slice_in_dim(w1, tp * part, part, 0)
            xs = jax.lax.dynamic_slice_in_dim(x, tp * part, part, x.ndim - 1)
            p = jnp.einsum("...c,cd->...d", xs, ws)
            return jnp.tanh(x) * 0.5 + jax.lax.psum(p, "model")
        return tp_den

    tp_den = make_tp_den(2)

    def run_hybrid(dim, name, plan, rest, mesh, tden, wire_shard):
        codec = get_codec(name)
        if codec.stateful:
            st = init_halo_wire_state(codec, halo_spec(plan), rest)
            return jax.jit(lambda zz, s: lp_forward_halo_hybrid(
                tden, zz, plan, dim, mesh, codec=codec, codec_state=s,
                wire_shard=wire_shard))(z, st)
        c = None if name == "fp32" else codec
        return jax.jit(lambda zz: lp_forward_halo_hybrid(
            tden, zz, plan, dim, mesh, codec=c,
            wire_shard=wire_shard))(z), None

    def run_cell(engine, dim, name, plan, rest):
        codec = get_codec(name)
        st = (init_halo_wire_state(codec, halo_spec(plan), rest)
              if codec.stateful else None)
        c = None if name == "fp32" else codec
        if engine == "psum":
            return jax.jit(lambda zz: lp_forward_shard_map(
                den, zz, plan, dim, mesh1, "data"))(z)
        if engine == "gspmd":
            return jax.jit(lambda zz: lp_forward_gspmd(
                den, zz, plan, dim, mesh1, "data", codec=c))(z)
        if engine == "halo":
            if st is not None:
                return jax.jit(lambda zz, s: lp_forward_halo(
                    den, zz, plan, dim, mesh1, "data", codec=codec,
                    codec_state=s))(z, st)[0]
            return jax.jit(lambda zz: lp_forward_halo(
                den, zz, plan, dim, mesh1, "data", codec=c))(z)
        if engine == "halo_hybrid":
            return run_hybrid(dim, name, plan, rest, mesh2, tp_den,
                              False)[0]
        raise ValueError(engine)

    cells = %(CELLS)r
    for engine, dim, name in cells:
        plan = plan_uniform(Z_SHAPE[dim], PATCHES[dim], K, R, dim)
        rest = tuple(s for i, s in enumerate(Z_SHAPE) if i != dim)
        ref = lp_forward_uniform(den, z, plan, axis=dim)
        extra = ""
        if engine in ("halo_hybrid_ws", "halo_hybrid_ws4"):
            # the wire-sharded engine must be BIT-identical to the
            # unsharded one (output and residual scan-carry state):
            # sharding only rearranges the transport
            T = 4 if engine == "halo_hybrid_ws4" else 2
            mesh = mesh4 if T == 4 else mesh2
            tden = make_tp_den(T)
            out, st_ws = run_hybrid(dim, name, plan, rest, mesh, tden, True)
            ref_out, st_un = run_hybrid(dim, name, plan, rest, mesh, tden,
                                        False)
            bit = bool(jnp.all(out == ref_out))
            if st_ws is not None:
                bit = bit and all(
                    bool(jnp.all(x == y)) for x, y in
                    zip(jax.tree.leaves(st_ws), jax.tree.leaves(st_un)))
            extra = f" bit={int(bit)}"
        else:
            out = run_cell(engine, dim, name, plan, rest)
        a = np.asarray(out, np.float64)
        b = np.asarray(ref, np.float64)
        mse = float(np.mean((a - b) ** 2))
        db = float(10 * np.log10(float(np.abs(b).max()) ** 2
                                 / max(mse, 1e-30)))
        rel = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        print(f"CELL {engine} dim={dim} codec={name} "
              f"psnr={db:.1f} rel={rel:.2e}{extra}")
    print(f"DONE {len(cells)}")
    """
)


def _run_matrix(K: int):
    cells = [
        (engine, dim, codec)
        for engine in ("psum", "gspmd", "halo", "halo_hybrid",
                       "halo_hybrid_ws")
        for dim, codec in _cells_for(engine, K)
    ]
    if K * 4 <= 8:  # the (K, 4) wire-shard mesh fits the fake devices
        cells += [
            ("halo_hybrid_ws4", dim, codec)
            for dim in range(3) for codec in WS4_CODECS
        ]
    res = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT % {
            "K": K, "Z_SHAPE": Z_SHAPE, "PATCHES": PATCHES, "R": R,
            "CELLS": cells,
        }],
        capture_output=True, text=True,
        env={"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
             "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # skip the TPU-runtime probe
        cwd=REPO_ROOT,
        timeout=580,
    )
    assert res.returncode == 0, f"stdout={res.stdout}\nstderr={res.stderr}"
    lines = [l for l in res.stdout.splitlines() if l.startswith("CELL ")]
    assert f"DONE {len(cells)}" in res.stdout, res.stdout
    assert len(lines) == len(cells)
    return cells, lines


# --------------------------------------- scheduled codecs (step policy)
# A mid-denoise codec switch must be invisible: running a schedule
# [codec A on steps 1..k, codec B on steps k+1..T] must equal the
# composition of two fixed-codec runs over the same step ranges — exact
# for stateless codecs, and exact for residual codecs too because the
# error-feedback state resets at the segment boundary in BOTH paths.

class _OffsetSampler:
    """View of a sampler shifted by ``offset`` forward passes, so the
    composition's second run continues the SAME trajectory."""

    def __init__(self, base, offset):
        self._base = base
        self._offset = offset

    def timestep(self, i):
        return self._base.timestep(i + self._offset)

    def step_scalars(self, i):
        return self._base.step_scalars(i + self._offset)

    @property
    def update(self):
        return self._base.update


def _single_dim_z(seed=0):
    # spatial (8, 2, 2) with patches (1, 2, 2): only the temporal dim
    # rotates, so the schedule's segment boundary is the ONLY structural
    # break between the two runs being compared
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(1, 8, 2, 2, 3)).astype(np.float32))


@pytest.mark.parametrize("codec_a,codec_b", [
    ("fp32", "bf16"),
    ("bf16", "int8"),
    ("int8", "int4"),
    ("int8-residual", "int8"),
    ("int8-residual", "int4-residual"),
])
def test_scheduled_codec_equals_fixed_composition(codec_a, codec_b):
    from repro.core import LPStepCompiler, lp_denoise
    from repro.diffusion.sampler import FlowMatchEuler
    from repro.policy.schedule import segment_steps, trajectory_sigmas
    from repro.policy import parse_schedule

    steps, boundary = 6, 4  # codec A on 1..4, codec B on 5..6
    sampler = FlowMatchEuler(steps)
    sigmas = trajectory_sigmas(sampler, steps)
    thr = (sigmas[boundary - 1] + sigmas[boundary]) / 2
    spec = f"{codec_a}@{thr:.6f},{codec_b}"
    schedule = parse_schedule(spec)
    runs = segment_steps(schedule, sigmas)
    assert [(r.start, r.stop) for r in runs] == [
        (1, boundary), (boundary + 1, steps)]

    z = _single_dim_z(11)
    args = (2, 0.5, (1, 2, 2), (1, 2, 3))

    comp = LPStepCompiler(lambda w, t: _den(w) * (1 + 1e-4 * t),
                          sampler.update, *args[:2], args[2], args[3],
                          uniform=True, schedule=spec)
    scheduled = lp_denoise(None, z, sampler, steps, *args, uniform=True,
                           compiler=comp)

    def fixed(codec, z0, smp, n):
        c = LPStepCompiler(lambda w, t: _den(w) * (1 + 1e-4 * t),
                           smp.update, *args[:2], args[2], args[3],
                           uniform=True, codec=codec)
        return lp_denoise(None, z0, smp, n, *args, uniform=True,
                          compiler=c)

    z_mid = fixed(codec_a, z, sampler, boundary)
    composed = fixed(codec_b, z_mid, _OffsetSampler(sampler, boundary),
                     steps - boundary)
    np.testing.assert_allclose(
        np.asarray(scheduled), np.asarray(composed), atol=1e-5,
        err_msg=f"schedule {spec} != composition {codec_a}->{codec_b}",
    )
    # compile-count contract: <= 3 x num_segments (single rotation dim
    # here, so exactly one compile per segment)
    assert comp.compiles <= 3 * len(runs), (comp.compiles, len(runs))


def test_scheduled_cell_meets_min_segment_floor():
    """A scheduled run sits above the WORST segment codec's envelope
    floor vs the fp32 reference — the conservative bound the planner
    assumes (sigma credit only helps)."""
    from repro.core import LPStepCompiler, lp_denoise
    from repro.diffusion.sampler import FlowMatchEuler

    steps = 6
    sampler = FlowMatchEuler(steps)
    z = _single_dim_z(5)
    args = (2, 0.5, (1, 2, 2), (1, 2, 3))

    def run(**kw):
        c = LPStepCompiler(lambda w, t: _den(w) * (1 + 1e-4 * t),
                           sampler.update, *args[:2], args[2], args[3],
                           uniform=True, **kw)
        return lp_denoise(None, z, sampler, steps, *args, uniform=True,
                          compiler=c)

    ref = run(codec="fp32")
    out = run(schedule="int8-residual@0.7,bf16")
    db = _psnr(out, ref)
    assert db >= PSNR_FLOOR_DB["int8-residual"], db


@pytest.mark.slow
@pytest.mark.parametrize("K", KS)
def test_spmd_engine_conformance_matrix(K):
    """Every SPMD engine x dim x supported codec, on 8 fake CPU devices.

    Exact cells (fp32) must sit at numerical-noise PSNR; lossy cells at
    their documented floors.  ONE subprocess per K amortizes the ~50
    tiny XLA compiles."""
    cells, lines = _run_matrix(K)
    for (engine, dim, codec), line in zip(cells, lines):
        db = float(line.split("psnr=")[1].split()[0])
        rel = float(line.split("rel=")[1].split()[0])
        tag = f"{engine}/K{K}/dim{dim}/{codec}: {line}"
        if engine in ("halo_hybrid_ws", "halo_hybrid_ws4"):
            # transport-only rearrangement: sharded == unsharded, bitwise
            # (output AND residual scan-carry state)
            assert "bit=1" in line, f"{tag} not bit-equal to unsharded"
        if codec == "fp32":
            assert rel < 1e-5, tag
        else:
            assert db >= PSNR_FLOOR_DB[codec], (
                f"{tag} < {PSNR_FLOOR_DB[codec]} dB floor"
            )
