"""Replica router: health states, backpressure/shedding, redispatch
with preserved arrival stamps, graceful degradation, and the engine's
bounded-queue rejection the router builds on.

Logic tests run against a stub engine (the router only touches the
engine's queue/lifecycle/clock surface), so tier-1 stays fast; one
end-to-end chaos test drives real engines through a mid-run replica
kill and pins the zero-lost-requests property the
``benchmarks/router_resilience.py`` gate scales up.
"""
import json

import pytest

from repro.obs import FlightRecorder
from repro.obs import metrics as obsm
from repro.obs.slo import (
    SLOSpec,
    disposition,
    evaluate_slo,
    failures_from_trace,
    rows_from_trace,
    shed_from_trace,
)
from repro.runtime.faults import ReplicaDeath, ServingFault
from repro.serving.engine import QueueFull, VideoRequest, VideoResult
from repro.serving.loadgen import (
    Arrival,
    RequestClass,
    VirtualClock,
    WorkloadSpec,
    build_workload,
)
from repro.serving.router import ReplicaRouter

SLO = SLOSpec.parse("interactive:2,standard:8,batch:30")


def _req(i, priority="standard", shape=(4, 8, 12), psnr=None):
    return VideoRequest(request_id=i, context=None, latent_shape=shape,
                        seed=i, guidance=5.0, priority=priority,
                        psnr_floor=psnr)


class _StubEngine:
    """The engine surface the router touches, minus jax: submits queue,
    run() serves the whole queue as one batch after ``wall`` virtual
    seconds, ``fail(dispatch_no)`` scripts an exception for a given
    dispatch."""

    def __init__(self, clock, wall=0.1, max_batch=2, recorder=None,
                 fail=None, psnr_floor=None):
        self.clock = clock
        self.wall = wall
        self.max_batch = max_batch
        self.max_queue = None
        self.replica_id = None
        self.recorder = recorder
        self.slo = SLO
        self.psnr_floor = psnr_floor
        self._plan_resolver = None
        self._fault_plan = None
        self._queue = []
        self._lifecycle = {}
        self._enqueued_at = {}
        self._inflight = []
        self.dispatches = 0
        self.fail = fail or (lambda n: None)
        self.floor_history = []
        self.served = []          # (request, submit_s) pairs served

    def submit(self, req, submit_s=None):
        self._queue.append((req, submit_s))
        self._lifecycle[req.request_id] = {"submit_s": submit_s}

    def set_psnr_floor(self, floor):
        self.psnr_floor = floor
        self.floor_history.append(floor)
        return True

    def run(self, max_batches=None, max_restarts_per_batch=2):
        self.dispatches += 1
        batch, self._queue = self._queue, []
        self._inflight = [r for r, _ in batch]
        exc = self.fail(self.dispatches)
        if exc is not None:
            raise exc
        self.clock.advance(self.wall)
        done = self.clock.now
        out = []
        for req, s in batch:
            self._lifecycle.pop(req.request_id, None)
            res = VideoResult(req.request_id, None, 2,
                              batch_wall_s=self.wall,
                              batch_size=len(batch))
            res.queue_wait_s = 0.0
            res.e2e_s = done - s
            out.append(res)
            self.served.append((req, s))
        self._inflight = []
        return out


def _router(engines, **kw):
    kw.setdefault("slo", SLO)
    return ReplicaRouter(engines, **kw)


# --------------------------------------------------- engine bounded queue
def test_engine_submit_rejects_beyond_max_queue():
    """Satellite regression: the engine queue is bounded and the bound
    is loud — QueueFull carries the request id and depth, the request
    acquires NO lifecycle state, and the queue is unchanged."""
    import jax

    from repro import models
    from repro.configs import get_config
    from repro.models import dit
    from repro.serving.engine import LPServingEngine

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    rec = FlightRecorder()
    eng = LPServingEngine(fwd, params, cfg, num_partitions=2,
                          num_steps=2, max_batch=2, max_queue=2,
                          recorder=rec, clock=VirtualClock())
    from repro.models import frontends

    def ctx(i):
        return frontends.text_context(jax.random.PRNGKey(i), 1, cfg)

    eng.submit(VideoRequest(0, ctx(0), (4, 8, 12)))
    eng.submit(VideoRequest(1, ctx(1), (4, 8, 12)))
    with pytest.raises(QueueFull) as ei:
        eng.submit(VideoRequest(2, ctx(2), (4, 8, 12)))
    assert ei.value.request_id == 2 and ei.value.depth == 2
    assert len(eng._queue) == 2
    assert 2 not in eng._lifecycle          # nothing half-admitted
    assert rec.metrics.counter_value(obsm.REQUESTS_REJECTED) == 1.0
    names = [e["name"] for e in rec.trace.events]
    assert "request.rejected" in names
    # the bound must be able to hold a batch
    with pytest.raises(ValueError, match="max_queue"):
        LPServingEngine(fwd, params, cfg, num_partitions=2,
                        num_steps=2, max_batch=4, max_queue=2)


def test_run_workload_drops_rejected_arrivals_and_continues():
    """Open-loop replay over a bounded engine queue: an arrival that
    lands on a full queue is dropped (request.rejected row), not a
    crash, and the replay serves everything that was admitted."""
    import jax

    from repro import models
    from repro.configs import get_config
    from repro.models import dit
    from repro.serving.engine import LPServingEngine
    from repro.serving.loadgen import run_workload

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    rec = FlightRecorder()
    eng = LPServingEngine(fwd, params, cfg, num_partitions=2,
                          num_steps=2, max_batch=2, max_queue=2,
                          recorder=rec, clock=VirtualClock())
    cls_ = RequestClass("s", (4, 8, 12))
    wl = [Arrival(i, 0.0, cls_, seed=i) for i in range(3)]
    results = run_workload(eng, wl)
    assert sorted(r.request_id for r in results) == [0, 1]
    assert rec.metrics.counter_value(obsm.REQUESTS_REJECTED) == 1.0


def test_engine_refuses_replica_scoped_fault_plan():
    import jax

    from repro import models
    from repro.configs import get_config
    from repro.models import dit
    from repro.serving.engine import LPServingEngine

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    with pytest.raises(ValueError, match="replica"):
        LPServingEngine(fwd, params, cfg, num_partitions=2,
                        num_steps=2, inject_fault="replica:0:dead@1")


# ------------------------------------------------------- router plumbing
def test_router_validates_engines_and_policy():
    clock = VirtualClock()
    with pytest.raises(ValueError, match="at least one"):
        _router([])
    with pytest.raises(ValueError, match="policy"):
        _router([_StubEngine(VirtualClock())], policy="random")
    e1, e2 = _StubEngine(clock), _StubEngine(clock)
    with pytest.raises(ValueError, match="share"):
        _router([e1, e2])
    with pytest.raises(ValueError, match="unscoped"):
        _router([_StubEngine(VirtualClock()),
                 _StubEngine(VirtualClock())],
                inject_fault="dead:1@3")
    with pytest.raises(ValueError, match="replica"):
        _router([_StubEngine(VirtualClock()),
                 _StubEngine(VirtualClock())],
                inject_fault="replica:7:dead@3")


def test_router_dispatch_spreads_and_assigns_replica_ids():
    engines = [_StubEngine(VirtualClock()), _StubEngine(VirtualClock())]
    r = _router(engines)
    assert [e.replica_id for e in engines] == [0, 1]
    cls_ = RequestClass("s", (4, 8, 12))
    wl = [Arrival(i, 0.0, cls_, seed=i) for i in range(4)]
    out = r.serve(wl, make_context=lambda a: None)
    assert sorted(res.request_id for res in out) == [0, 1, 2, 3]
    # both replicas served a batch (least-loaded spreads work the
    # moment replica 0 is busy)
    assert engines[0].dispatches >= 1 and engines[1].dispatches >= 1
    assert r.stats["completed"] == 4 and r.stats["admitted"] == 4


def test_router_round_robin_policy_rotates():
    engines = [_StubEngine(VirtualClock(), wall=0.0, max_batch=1)
               for _ in range(3)]
    r = _router(engines, policy="round-robin")
    cls_ = RequestClass("s", (4, 8, 12))
    wl = [Arrival(i, 0.0, cls_, seed=i) for i in range(6)]
    r.serve(wl, make_context=lambda a: None)
    assert [e.dispatches for e in engines] == [2, 2, 2]


@pytest.mark.chaos
def test_router_requeues_lost_batch_with_original_submit_stamp():
    """A replica death mid-batch requeues its riders on a survivor
    with their ORIGINAL submit_s — queue-wait accounting stays honest
    across the redispatch."""
    rec = FlightRecorder()
    dead = _StubEngine(VirtualClock(), recorder=rec,
                       fail=lambda n: ReplicaDeath("boom", replica=0,
                                                   step=1))
    ok = _StubEngine(VirtualClock(), recorder=rec)
    r = _router([dead, ok], recorder=rec, backoff_base_s=0.01)
    cls_ = RequestClass("s", (4, 8, 12))
    wl = [Arrival(0, 0.0, cls_, seed=0), Arrival(1, 0.0, cls_, seed=1)]
    out = r.serve(wl, make_context=lambda a: None)
    assert sorted(res.request_id for res in out) == [0, 1]
    assert r.replicas[0].state == "dead"
    assert r.stats["replica_deaths"] == 1
    assert r.stats["redispatches"] == 2
    # the survivor saw the original arrival stamps, not the retry time
    assert [s for _, s in ok.served] == [0.0, 0.0]
    names = [e["name"] for e in rec.trace.events]
    assert "router.replica_dead" in names
    assert "router.redispatch" in names
    assert rec.metrics.counter_value(obsm.ROUTER_REPLICA_DEATHS) == 1.0


@pytest.mark.chaos
def test_router_terminal_failure_after_max_redispatch():
    """Every replica eats the batch: after max_redispatch attempts the
    request fails TERMINALLY with a trace row — never silently."""
    rec = FlightRecorder()
    engines = [
        _StubEngine(VirtualClock(), recorder=rec,
                    fail=lambda n: ReplicaDeath("boom", replica=i))
        for i in range(2)
    ]
    r = _router(engines, recorder=rec, max_redispatch=1,
                backoff_base_s=0.01)
    cls_ = RequestClass("s", (4, 8, 12))
    out = r.serve([Arrival(0, 0.0, cls_, seed=0)],
                  make_context=lambda a: None)
    assert out == []
    assert r.stats["failed"] == 1
    assert len(rec.failed_rows) == 1
    row = rec.failed_rows[0]
    assert row["terminal"] is True and row["request_id"] == 0
    assert row["submit_s"] == 0.0
    d = disposition([], rec.shed_rows, rec.failed_rows)
    assert d["failed"] == 1 and d["accounted"] == 1


def test_router_engine_fault_degrades_then_drains_replica():
    rec = FlightRecorder()
    flaky = _StubEngine(VirtualClock(), recorder=rec,
                        fail=lambda n: ServingFault("wire fault"))
    ok = _StubEngine(VirtualClock(), recorder=rec)
    r = _router([flaky, ok], recorder=rec, dead_after_failures=2,
                backoff_base_s=0.01)
    cls_ = RequestClass("s", (4, 8, 12))
    wl = [Arrival(i, float(i), cls_, seed=i) for i in range(6)]
    out = r.serve(wl, make_context=lambda a: None)
    assert sorted(res.request_id for res in out) == list(range(6))
    # the flaky replica degraded on its first terminal fault and
    # drained on the second; nothing was lost
    assert r.replicas[0].state in ("degraded", "draining")
    assert r.stats["failed"] == 0


def test_router_sheds_lowest_priority_newest_first_with_trace_rows():
    rec = FlightRecorder()
    # one slow replica so the queue builds: watermark 3
    eng = _StubEngine(VirtualClock(), wall=5.0, max_batch=1,
                      recorder=rec)
    r = _router([eng], recorder=rec, shed_watermark=3)
    # 1 interactive + 5 batch requests arrive at once: depth 6 > 3,
    # so the router sheds back down to the watermark
    icls = RequestClass("i", (4, 8, 12), priority="interactive")
    bcls = RequestClass("b", (4, 8, 12), priority="batch")
    wl = [Arrival(0, 0.0, icls, seed=0)] + \
         [Arrival(i, 0.0, bcls, seed=i) for i in range(1, 6)]
    out = r.serve(wl, make_context=lambda a: None)
    assert r.stats["shed"] == 3
    shed_ids = {row["request_id"] for row in rec.shed_rows}
    # lowest-priority (batch, largest deadline) newest arrivals go
    # first; the interactive request is never shed
    assert shed_ids == {3, 4, 5}
    for row in rec.shed_rows:
        assert row["reason"] == "watermark"
        assert row["priority"] == "batch"
    assert 0 in {res.request_id for res in out}
    assert rec.metrics.counter_value(
        obsm.ROUTER_SHED, priority="batch") == 3.0
    d = disposition(
        [{"request_id": res.request_id} for res in out],
        rec.shed_rows, rec.failed_rows)
    assert d["accounted"] == 6 and d["shed"] == 3


def test_router_degrades_floors_under_overload_and_restores():
    rec = FlightRecorder()
    eng = _StubEngine(VirtualClock(), wall=1.0, max_batch=1,
                      recorder=rec, psnr_floor=32.0)
    r = _router([eng], recorder=rec, shed_watermark=100,
                degrade_watermark=2, degrade_step_db=2.0,
                min_psnr_floor_db=24.0)
    cls_ = RequestClass("s", (4, 8, 12), priority="standard",
                        psnr_floor=32.0)
    # burst of 6 at t=0: queue sits above the watermark -> degrade
    wl = [Arrival(i, 0.0, cls_, seed=i) for i in range(6)]
    r.serve(wl, make_context=lambda a: None)
    assert r.stats["completed"] == 6
    names = [e["name"] for e in rec.trace.events]
    assert "router.degrade" in names
    assert "router.restore" in names            # queue drained
    assert rec.metrics.counter_value(obsm.ROUTER_DEGRADE_STEPS) >= 1.0
    assert rec.metrics.counter_value(obsm.ROUTER_RESTORE_STEPS) >= 1.0
    # dispatched requests carried relaxed floors while degraded, never
    # below the envelope minimum
    floors = [req.psnr_floor for req, _ in eng.served]
    assert any(f < 32.0 for f in floors)
    assert all(f >= 24.0 for f in floors)
    # the engine's autotuner floor moved too, and was restored
    assert eng.floor_history and eng.floor_history[-1] == 32.0
    assert r.degrade_level == 0


def test_router_degrade_instant_precedes_queue_blowup_violations():
    """The degrade signal must fire while requests can still meet
    their deadlines — pinned here on virtual timestamps."""
    rec = FlightRecorder()
    eng = _StubEngine(VirtualClock(), wall=0.5, max_batch=1,
                      recorder=rec)
    r = _router([eng], recorder=rec, shed_watermark=100,
                degrade_watermark=1)
    cls_ = RequestClass("s", (4, 8, 12), priority="standard",
                        psnr_floor=30.0)
    wl = [Arrival(i, 0.0, cls_, seed=i) for i in range(5)]
    r.serve(wl, make_context=lambda a: None)
    degrades = [e for e in rec.trace.events
                if e["name"] == "router.degrade"]
    assert degrades
    assert degrades[0]["args"]["now_s"] == 0.0   # before any service


@pytest.mark.chaos
def test_router_all_replicas_dead_fails_terminally_not_silently():
    rec = FlightRecorder()
    engines = [
        _StubEngine(VirtualClock(), recorder=rec,
                    fail=lambda n: ReplicaDeath("gone"))
        for _ in range(2)
    ]
    r = _router(engines, recorder=rec, max_redispatch=0)
    cls_ = RequestClass("s", (4, 8, 12))
    wl = [Arrival(i, float(i) * 0.1, cls_, seed=i) for i in range(4)]
    out = r.serve(wl, make_context=lambda a: None)
    assert out == []
    assert all(rep.state == "dead" for rep in r.replicas)
    # every admitted request has a terminal trace row
    assert r.stats["admitted"] == 4
    assert len(rec.failed_rows) == 4
    assert all(row["terminal"] for row in rec.failed_rows)


# -------------------------------------------------- end-to-end (chaos)
@pytest.mark.chaos
def test_router_replica_kill_end_to_end_zero_lost():
    """Real engines, real denoises: kill replica 1 at denoise step 1
    mid-run; every admitted request must complete (redispatched), the
    per-replica SLO report must exist, and the offline report must
    equal the live one byte-for-byte."""
    import jax

    from repro import models
    from repro.configs import get_config
    from repro.models import dit
    from repro.serving.engine import LPServingEngine

    cfg = get_config("wan21-dit-1.3b").reduced()
    model = models.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fwd(p, z, t, c, cfg_model):
        return dit.forward(p, z, t, c, cfg_model)

    rec = FlightRecorder()
    slo = SLOSpec.parse("interactive:60,standard:120")

    def mk():
        return LPServingEngine(fwd, params, cfg, num_partitions=2,
                               num_steps=2, max_batch=2, max_queue=8,
                               recorder=rec, clock=VirtualClock(),
                               slo=slo)

    router = ReplicaRouter([mk(), mk()], recorder=rec, slo=slo,
                           inject_fault="replica:1:dead@1",
                           max_redispatch=2)
    mix = (RequestClass("i", (4, 8, 12), priority="interactive"),
           RequestClass("s", (4, 8, 12), priority="standard"))
    wl = build_workload(WorkloadSpec(rate_rps=50.0, num_requests=8,
                                     seed=3, mix=mix))
    results = router.serve(wl)
    assert sorted(r.request_id for r in results) == list(range(8))
    assert router.replicas[1].state == "dead"
    assert router.stats["replica_deaths"] == 1
    assert router.stats["redispatches"] >= 1
    # lifecycle rows carry the serving replica and live on one timeline
    assert all(row.get("replica") == 0 for row in rec.request_rows
               if row["request_id"] in
               {r.request_id for r in results})

    live = evaluate_slo(rec.request_rows, spec=slo, num_devices=2,
                        shed_rows=rec.shed_rows,
                        failed_rows=rec.failed_rows)
    assert live["disposition"]["accounted"] == 8
    assert set(live["replicas"]) == {"0"}
    doc = json.loads(json.dumps(rec.trace.to_json()))
    offline = evaluate_slo(rows_from_trace(doc), spec=slo,
                           num_devices=2,
                           shed_rows=shed_from_trace(doc),
                           failed_rows=failures_from_trace(doc))
    assert json.loads(json.dumps(live)) == json.loads(json.dumps(offline))
