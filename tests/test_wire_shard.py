"""Hierarchy-aware halo wire: byte model, shard helpers, and the
two-tier autotuner.

Tier-1 cells validate the analytic machinery in-process (no fake
devices); the slow cell cross-checks the sharded byte model against the
compiled 2D-mesh HLO exactly, per collective per link tier, in a
subprocess (the conformance matrix owns the value/bit-equality cells,
``benchmarks/wire_shard.py`` the T=4 gate).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.comm.codecs import get_codec
from repro.core import comm_model as cm
from repro.distributed.collectives import (
    wire_shard_len,
    wire_shard_slice,
    wire_unshard,
)

CFG = cm.VDMCommConfig(
    latent_dims=(13, 60, 104), latent_channels=16,
    patch_sizes=(1, 2, 2), d_model=1536, num_blocks=30, num_steps=12,
)


# ------------------------------------------------------- shard helpers
@pytest.mark.parametrize("shape,T", [
    ((7, 3, 5), 4), ((8, 2), 2), ((13,), 3), ((6, 4), 8),
])
def test_wire_shard_roundtrip_is_identity(shape, T):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    chunks = jnp.stack([
        wire_shard_slice(x, jnp.int32(t), T) for t in range(T)
    ])
    assert chunks.shape == (T, wire_shard_len(int(np.prod(shape)), T))
    back = wire_unshard(chunks, shape)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_wire_unshard_rows_batched_identity():
    from repro.distributed.collectives import wire_unshard_rows

    rng = np.random.default_rng(1)
    K, T, shape = 3, 4, (5, 2)
    wires = jnp.asarray(rng.normal(size=(K,) + shape).astype(np.float32))
    cols = jnp.stack([  # (T, K, s): one tp gather of a K-row lp gather
        jnp.stack([wire_shard_slice(wires[k], jnp.int32(t), T)
                   for k in range(K)])
        for t in range(T)
    ])
    np.testing.assert_array_equal(
        np.asarray(wire_unshard_rows(cols, shape)), np.asarray(wires))


def test_halo_forward_rejects_shard_axis_eq_lp_axis():
    """Sharding over the transfer axis itself would reassemble chunks of
    different senders' slabs — must fail loudly, not corrupt."""
    from repro.core import plan_uniform
    from repro.core.spmd import lp_forward_halo
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    plan = plan_uniform(8, 1, 1, 0.0, 0)
    with pytest.raises(ValueError, match="differ from the lp axis"):
        lp_forward_halo(lambda x: x, jnp.zeros((8, 2)), plan, 0, mesh,
                        lp_axis="data", shard_axis="data")


def test_replan_wire_shard_needs_rebound_hook():
    """Flipping the wire layout on a compiler with a bound forward hook
    must demand a re-bound hook (the old one closes over the old
    layout) — and must leave the plan untouched on the raise."""
    from repro.core import LPStepCompiler

    def hook(fn, z, plan, axis):  # stands in for a mesh-bound engine
        raise AssertionError("never traced")

    comp = LPStepCompiler(lambda w, t: w, lambda z, p, s: z, 2, 0.5,
                          (1, 2, 2), (1, 2, 3), uniform=True,
                          forward=hook, wire_shard=False)
    with pytest.raises(ValueError, match="re-bound forward"):
        comp.replan(wire_shard=True, num_partitions=3)
    assert comp.num_partitions == 2 and comp.plan_epoch == 0
    # re-binding in the same call is the sanctioned path
    def hook2(fn, z, plan, axis):
        raise AssertionError("never traced")
    assert comp.replan(wire_shard=True, forward=hook2)
    assert comp.wire_shard and comp.plan_epoch == 1


def test_serving_engine_rejects_unhonorable_wire_shard_pin():
    """An explicit wire_shard=True the engine cannot honor is a config
    error (mirroring dryrun), never a silent downgrade."""
    from repro.configs import get_config
    from repro.serving.engine import LPServingEngine

    cfg = get_config("wan21-dit-1.3b").reduced()
    with pytest.raises(ValueError, match="tp axis"):
        LPServingEngine(lambda *a, **k: None, {}, cfg, num_partitions=2,
                        wire_shard=True)  # no mesh -> no tp axis


def test_wire_shard_helpers_int_dtypes():
    x = jnp.arange(11, dtype=jnp.int8).reshape(11)
    chunks = jnp.stack([wire_shard_slice(x, jnp.int32(t), 4)
                        for t in range(4)])
    assert chunks.dtype == jnp.int8
    np.testing.assert_array_equal(
        np.asarray(wire_unshard(chunks, (11,))), np.asarray(x))


# ------------------------------------------------- codec wire accounting
def test_wire_dtype_bytes():
    assert get_codec("fp32").wire_dtype_bytes == 4
    assert get_codec("bf16").wire_dtype_bytes == 2
    assert get_codec("int8").wire_dtype_bytes == 1
    assert get_codec("int4").wire_dtype_bytes == 1
    assert get_codec("int8-residual").wire_dtype_bytes == 1
    assert get_codec("int4-residual").wire_dtype_bytes == 1


def test_wire_elems_matches_wire_shapes():
    # int4 packs pairs along the last axis — exact even for odd extents
    int4 = get_codec("int4")
    assert int4.wire_elems(6 * 16, last_dim=16) == 6 * 8
    assert int4.wire_elems(6 * 5, last_dim=5) == 6 * 3
    assert get_codec("int4-residual").wire_elems(6 * 5, 5) == 6 * 3
    # storage elems x storage bytes == payload bytes (even extents)
    for name in ("fp32", "bf16", "int8", "int8-residual"):
        c = get_codec(name)
        n = 60 * 104 * 16
        assert c.wire_elems(n, 16) * c.wire_dtype_bytes == \
            c.wire_bytes(n) - c.meta_bytes


# ------------------------------------------------------ two-tier model
@pytest.mark.parametrize("codec", ["fp32", "bf16", "int8", "int8-residual"])
@pytest.mark.parametrize("T", [2, 4])
def test_sharded_step_inter_is_t_fold_smaller(codec, T):
    """Per-device inter-group bytes of the sharded step ~ 1/T of the
    unsharded hybrid step (exactly, up to chunk ceil-padding and the
    T-replicated meta)."""
    M = 4
    un = cm.lp_halo_hybrid_step_collectives(CFG, M, T, 0.5, dim=1,
                                            codec=codec)
    sh = cm.lp_halo_sharded_step_collectives(CFG, M, T, 0.5, dim=1,
                                             codec=codec)
    inter = sum(sh["inter"].values())
    ratio = sum(un.values()) / inter
    assert T - 0.2 <= ratio <= T + 0.01, (codec, T, ratio)
    # the reassembly gathers move ~the full payload on the intra tier
    assert sh["intra"]["all-gather"] > 0


def test_sharded_group_totals_split():
    """Group totals: inter collapses ~T-fold vs the T-replicated hybrid
    wire; inter+intra stays within ~2x of the 1D model (nothing is
    free, it just moves to the cheap tier)."""
    M, T = 2, 4
    hyb = cm.comm_lp_halo_hybrid(CFG, M, T, 0.5, codec="int8")
    sh = cm.comm_lp_halo_sharded(CFG, M, T, 0.5, codec="int8")
    assert sh["total"] == sh["inter"] + sh["intra"]
    assert hyb / sh["inter"] >= T - 0.2
    # scheduled variant == sum of fixed-codec steps
    sched = cm.comm_lp_halo_sharded(
        CFG, M, T, 0.5, step_codecs=["int8"] * CFG.num_steps)
    assert sched == sh


def test_sharded_rejects_degenerate_tp():
    with pytest.raises(ValueError):
        cm.lp_halo_sharded_step_collectives(CFG, 4, 1, 0.5, dim=1)


def test_wire_profile_tiers():
    codecs = ["int8"] * CFG.num_steps
    off = cm.lp_halo_wire_profile(CFG, 4, 2, 0.5, codecs, wire_shard=False)
    on = cm.lp_halo_wire_profile(CFG, 4, 2, 0.5, codecs, wire_shard=True)
    assert off["intra"] == 0
    assert on["inter"] < off["inter"]
    assert on["intra"] > 0


def test_comm_hybrid_wire_shard_charges_reassembly():
    """The hub-model fix: with the striped wire the intra-group total
    must include the reassembly gather, not pretend it is free."""
    base = cm.comm_hybrid(CFG, 8, 2, 0.5, intra="nmp")
    shard = cm.comm_hybrid(CFG, 8, 2, 0.5, intra="nmp", wire_shard=True)
    assert shard > base
    # k_m == 1: no striping possible, accounting unchanged
    assert cm.comm_hybrid(CFG, 2, 2, 0.5, wire_shard=True) == \
        cm.comm_hybrid(CFG, 2, 2, 0.5)


# -------------------------------------------------- two-tier autotuner
def _sampler(n):
    from repro.diffusion.sampler import FlowMatchEuler

    return FlowMatchEuler(n)


def test_auto_plan_shards_on_slow_inter_links():
    """T=4 with the default 10:1 link ratio: the sharded wire dominates
    every unsharded plan (the ISSUE's headline decision)."""
    from repro.policy import auto_plan

    plan = auto_plan(CFG, 2, 0.5, _sampler(12), 12, psnr_floor_db=40.0,
                     tp=4)
    assert plan.lp_impl == "halo_hybrid"
    assert plan.wire_shard
    assert plan.intra_bytes > 0
    assert "wire_shard" in plan.describe()


def test_auto_plan_keeps_unsharded_on_equal_links():
    """Equal-bandwidth tiers: the reassembly gather costs more than the
    inter saving — weighted TIME flips the decision, raw bytes never
    would."""
    from repro.policy import LinkModel, auto_plan

    plan = auto_plan(CFG, 2, 0.5, _sampler(12), 12, psnr_floor_db=40.0,
                     tp=4, links=LinkModel(inter_gbps=50, intra_gbps=50))
    assert not plan.wire_shard
    assert plan.intra_bytes == 0


def test_auto_plan_wire_shard_pin_and_tp1():
    from repro.policy import auto_plan

    pinned = auto_plan(CFG, 2, 0.5, _sampler(12), 12, psnr_floor_db=40.0,
                       tp=4, wire_shard=False)
    assert not pinned.wire_shard
    flat = auto_plan(CFG, 4, 0.5, _sampler(12), 12, psnr_floor_db=40.0)
    assert not flat.wire_shard and flat.intra_bytes == 0
    assert flat.inter_bytes > 0  # single-tier profile still reported


def test_link_model_weighted_time():
    from repro.policy import LinkModel

    links = LinkModel(inter_gbps=10, intra_gbps=100)
    assert links.wire_time_ms(10e9, 0) == pytest.approx(1000.0)
    assert links.wire_time_ms(0, 100e9) == pytest.approx(1000.0)
    # 10:1 ratio: a byte on the inter tier costs 10x an intra byte
    assert links.wire_time_ms(1e9, 0) == \
        pytest.approx(10 * links.wire_time_ms(0, 1e9))


def test_step_compiler_wire_shard_in_cache_key():
    from repro.core import LPStepCompiler

    def den(w, t):
        return w * (1 + 1e-4 * t)

    def upd(z, pred, sc):
        return z - pred

    comp = LPStepCompiler(den, upd, 2, 0.5, (1, 2, 2), (1, 2, 3),
                          uniform=True, wire_shard=False)
    z = jnp.zeros((1, 8, 4, 4, 2), jnp.float32)
    comp.step_fn(0, z, 1, np.float32(0.1), ())
    assert comp.compiles == 1
    # flipping the wire layout must never hit the old entry
    assert comp.replan(wire_shard=True)
    comp.step_fn(0, z, 1, np.float32(0.1), ())
    assert comp.compiles == 2
    assert not comp.replan(wire_shard=True)  # no-op: already set


# ------------------------------------------ hlo_analyzer group detail
def test_analyzer_replica_group_detail():
    from repro.analysis.hlo_analyzer import analyze

    hlo = textwrap.dedent("""
    ENTRY %main (p0: f32[8]) -> f32[16] {
      %p0 = f32[8]{0} parameter(0)
      %ag = f32[16]{0} all-gather(%p0), replica_groups={{0,2},{1,3}}, dimensions={0}
      %ar = f32[16]{0} all-reduce(%ag), replica_groups=[2,4]<=[8], to_apply=%add
      ROOT %cp = f32[16]{0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
    }
    """)
    a = analyze(hlo)
    assert a.collective_group_bytes["all-gather[2]"] == 64
    assert a.collective_group_bytes["all-reduce[4]"] == 64
    assert a.collective_group_bytes["collective-permute"] == 64
    # the kind-level totals are unchanged by the detail
    assert a.collective_bytes["all-gather"] == 64


# ------------------------------------------------- slow: HLO cross-check
SLOW_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis.hlo_analyzer import analyze
    from repro.comm import get_codec, init_halo_wire_state
    from repro.core import comm_model as cm
    from repro.core import plan_uniform
    from repro.core.hybrid import lp_forward_halo_hybrid
    from repro.distributed.collectives import halo_spec
    from repro.launch.mesh import make_hybrid_mesh

    M, T = 2, 4
    mesh = make_hybrid_mesh(M, T)
    rng = np.random.default_rng(3)
    Z = (8, 12, 10, 4)
    z = jnp.asarray(rng.normal(size=Z).astype(np.float32))
    ccfg = cm.VDMCommConfig(latent_dims=Z[:3], latent_channels=Z[3],
                            patch_sizes=(1, 2, 2), d_model=1, num_blocks=1,
                            num_steps=1)
    def den(x):
        return jnp.tanh(x) * 0.5 + x

    for dim in (0, 1, 2):
        plan = plan_uniform(Z[dim], (1, 2, 2)[dim], M, 0.5, dim)
        for name in ("fp32", "int8", "int4", "int8-residual"):
            codec = get_codec(name)
            if codec.stateful:
                st = init_halo_wire_state(
                    codec, halo_spec(plan),
                    tuple(s for i, s in enumerate(Z) if i != dim))
                fn = jax.jit(lambda zz, s: lp_forward_halo_hybrid(
                    den, zz, plan, dim, mesh, codec=codec, codec_state=s,
                    wire_shard=True)[0])
                hlo = fn.lower(z, st).compile().as_text()
            else:
                c = None if name == "fp32" else codec
                fn = jax.jit(lambda zz: lp_forward_halo_hybrid(
                    den, zz, plan, dim, mesh, codec=c, wire_shard=True))
                hlo = fn.lower(z).compile().as_text()
            got = {k: float(v) for k, v in
                   analyze(hlo).collective_group_bytes.items()}
            want = cm.lp_halo_sharded_step_collectives(
                ccfg, M, T, 0.5, dim=dim, codec=name)
            exp = {
                "collective-permute": want["inter"]["collective-permute"],
                "all-gather[%d]" % M: want["inter"]["all-gather"],
                "all-gather[%d]" % T: want["intra"]["all-gather"],
            }
            for kind, v in exp.items():
                assert got.get(kind, 0) == v, (dim, name, kind, got, exp)
            print(f"MATCH dim={dim} {name}")
    print("DONE")
    """
)


@pytest.mark.slow
def test_sharded_byte_model_matches_hlo_exactly():
    """Analytic inter- and intra-group bytes == measured 2D-mesh HLO,
    per collective, every codec, every rotation dim (8 fake devices)."""
    res = subprocess.run(
        [sys.executable, "-c", SLOW_SCRIPT],
        capture_output=True, text=True,
        env={"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
             "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=REPO_ROOT,
        timeout=580,
    )
    assert res.returncode == 0, f"{res.stdout}\n{res.stderr[-2000:]}"
    assert "DONE" in res.stdout, res.stdout
