"""Mid-request re-planning regression tests (ROADMAP open item).

A straggler-triggered re-plan (runtime/straggler.py proposal applied via
runtime/elastic.replan_lp_compiler from a ``lp_denoise`` step hook) must:

  * reset codec residual state EXACTLY once (old state shapes are
    garbage on the new plan; re-zeroing more than once throws away the
    temporal-delta reference and wastes wire quality);
  * never serve a ``LPStepCompiler`` cache entry compiled for the old
    mesh shape / partition geometry (the full geometry is in the key);
  * keep the denoise loop running — rotation dims are re-derived from
    the compiler's new geometry at the next step boundary.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LPStepCompiler, lp_denoise
from repro.core.lp_step import DenoiseSnapshot
from repro.diffusion.sampler import FlowMatchEuler
from repro.runtime.elastic import replan_lp_compiler
from repro.runtime.straggler import StragglerState


def _den(w, t):
    return jnp.tanh(w) * 0.1 + w * 1e-4 * t


def _single_dim_z(seed=0):
    # spatial (8, 2, 2) with patches (1, 2, 2): only dim 0 has enough
    # patches, for every K in this test — one rotation dim, so every
    # state reset is attributable to either the start or the re-plan
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(1, 8, 2, 2, 3)).astype(np.float32))


def test_replan_resets_codec_state_exactly_once_and_never_reuses_stale():
    z = _single_dim_z()
    sampler = FlowMatchEuler(10)
    comp = LPStepCompiler(
        _den, sampler.update, 4, 0.5, (1, 2, 2), (1, 2, 3),
        uniform=True, codec="int8-residual", mesh_shape=(4, 1),
    )

    # straggler EMA: group 3 is 5x slower -> propose evicting it
    straggler = StragglerState(num_partitions=4)
    for _ in range(5):
        straggler.observe([1.0, 1.0, 1.0, 5.0])
    proposal = straggler.propose_group_eviction((4, 1))
    assert proposal is not None
    evicted, new_shape = proposal
    assert evicted == 3 and new_shape == (3, 1)

    replanned = {"n": 0}

    def hook(i):
        if i == 6:
            assert replan_lp_compiler(comp, new_shape)
            replanned["n"] += 1

    out = lp_denoise(None, z, sampler, 10, 4, 0.5, (1, 2, 2), (1, 2, 3),
                     uniform=True, compiler=comp, step_hook=hook)
    assert np.isfinite(np.asarray(out)).all()
    assert replanned["n"] == 1
    # applying the eviction keeps the monitor consistent on the new ring
    straggler.evict(evicted)
    assert straggler.num_partitions == 3
    straggler.observe([1.0, 1.0, 1.0])  # new layout: no shape blowup
    assert not straggler.needs_rebalance()
    # geometry swapped in place
    assert comp.num_partitions == 3 and comp.mesh_shape == (3, 1)
    assert comp.plan_epoch == 1
    # codec residual state was (re)zeroed exactly twice: once at step 1,
    # once — and only once — at the re-plan boundary (state otherwise
    # carries across the same-dim steps of the unfused loop)
    assert comp.state_inits == 2, comp.state_inits
    # exactly one compile per geometry; every other step was a cache hit
    # on its OWN geometry's entry (a stale K=4 hit after the re-plan
    # would leave compiles at 1)
    assert comp.compiles == 2, comp.compiles
    assert comp.hits == 8, comp.hits
    # both geometries present in the key space, old one merely dormant
    keys = list(comp._cache.keys())
    assert {k[-5] for k in keys} == {3, 4}  # num_partitions key slot


def test_replan_fault_resume_twice_bit_identical_to_fault_free():
    """Satellite regression (post-replan boundary snapshot): replan ->
    fault -> resume -> replan-on-the-first-resumed-step -> fault ->
    resume must finish bit-identical to a fault-free run that took the
    same final geometry.  The sharp edge is the second replan firing at
    ``i == start + 1``: no step has advanced since the resume, but the
    boundary must still be re-stamped with the NEW plan epoch (the old
    ``i - 1 > start`` guard skipped it, leaving a stamp whose epoch
    disagreed with the geometry a later replay re-derives)."""
    z = _single_dim_z(2)
    steps = 10
    sampler = FlowMatchEuler(steps)

    class Fault(RuntimeError):
        pass

    def mk_comp(K, shape):
        return LPStepCompiler(
            _den, sampler.update, K, 0.5, (1, 2, 2), (1, 2, 3),
            uniform=True, codec="int8-residual", mesh_shape=shape,
        )

    def run(comp, hook, snap):
        return lp_denoise(None, z, sampler, steps, 4, 0.5, (1, 2, 2),
                          (1, 2, 3), uniform=True, compiler=comp,
                          step_hook=hook, snapshot=snap)

    # fault-free twin: one replan straight to the final (2, 1) ring
    ref_comp = mk_comp(4, (4, 1))
    ref = run(ref_comp, lambda i: (
        i == 4 and ref_comp.plan_epoch == 0
        and replan_lp_compiler(ref_comp, (2, 1))), None)

    comp = mk_comp(4, (4, 1))
    snap = DenoiseSnapshot()
    # attempt 1: shrink at step 4, die at step 6
    with pytest.raises(Fault):
        def hook1(i):
            if i == 4:
                assert replan_lp_compiler(comp, (3, 1))
            if i == 6:
                raise Fault
        run(comp, hook1, snap)
    assert (snap.step, snap.plan_epoch) == (3, 1)

    # attempt 2: resumes at the boundary; a SECOND shrink fires on the
    # first resumed step, then the fault repeats
    with pytest.raises(Fault):
        def hook2(i):
            if i == 4 and comp.plan_epoch == 1:
                assert replan_lp_compiler(comp, (2, 1))
            if i == 6:
                raise Fault
        run(comp, hook2, snap)
    assert snap.resumes == 1
    # the regression: same boundary step, re-stamped with the new epoch
    assert (snap.step, snap.plan_epoch) == (3, 2)
    assert snap.plan_epoch == comp.plan_epoch

    # attempt 3: clean replay from the re-stamped boundary
    out = run(comp, lambda i: None, snap)
    assert snap.resumes == 2
    assert comp.num_partitions == 2 and comp.plan_epoch == 2
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_replan_mesh_bound_compiler_requires_rebound_forward():
    """A compiler whose forward hook closes over a Mesh must get a
    re-bound hook when K changes — fail fast, not at trace time."""
    import pytest

    def fake_mesh_bound_forward(fn, z, plan, axis):  # stands in for an
        raise AssertionError("never traced")          # SPMD engine hook

    comp = LPStepCompiler(
        _den, FlowMatchEuler(2).update, 4, 0.5, (1, 2, 2), (1, 2, 3),
        uniform=True, forward=fake_mesh_bound_forward, mesh_shape=(4, 2),
    )
    with pytest.raises(ValueError, match="re-bound forward"):
        replan_lp_compiler(comp, (3, 2))
    # tp-only change keeps K: the old hook stays valid, no error
    assert replan_lp_compiler(comp, (4, 1))
    # and a re-bound hook makes the K change legal
    def new_forward(fn, z, plan, axis):
        raise AssertionError("never traced")

    assert replan_lp_compiler(comp, (3, 2), forward=new_forward)
    assert comp.num_partitions == 3 and comp.forward is new_forward


def test_straggler_ema_survives_layout_change_without_evict():
    st = StragglerState(num_partitions=4)
    st.observe([1.0, 1.0, 1.0, 2.0])
    st.observe([1.0, 1.0, 1.0])  # caller shrank without evict(): reset
    assert st.num_partitions == 3
    assert st.speeds.shape == (3,)


def test_replan_noop_is_free():
    comp = LPStepCompiler(
        _den, FlowMatchEuler(2).update, 4, 0.5, (1, 2, 2), (1, 2, 3),
        uniform=True, codec="int8-residual", mesh_shape=(4, 2),
    )
    assert not replan_lp_compiler(comp, (4, 2))
    assert comp.plan_epoch == 0 and comp.state_inits == 0


def test_unfused_loop_carries_residual_state_across_same_dim_steps():
    """Without a re-plan, a hooked (unfused) single-dim run inits codec
    state ONCE — the temporal-delta reference survives between steps
    instead of being re-zeroed per step (pre-PR behavior)."""
    z = _single_dim_z(1)
    sampler = FlowMatchEuler(6)
    comp = LPStepCompiler(
        _den, sampler.update, 2, 0.5, (1, 2, 2), (1, 2, 3),
        uniform=True, codec="int8-residual",
    )
    lp_denoise(None, z, sampler, 6, 2, 0.5, (1, 2, 2), (1, 2, 3),
               uniform=True, compiler=comp, step_hook=lambda i: None)
    assert comp.state_inits == 1, comp.state_inits
    assert comp.compiles == 1 and comp.hits == 5


def test_straggler_no_eviction_below_threshold():
    st = StragglerState(num_partitions=4)
    for _ in range(5):
        st.observe([1.0, 1.1, 1.0, 1.2])  # mild imbalance: re-size cores,
    assert st.propose_group_eviction((4, 1)) is None   # don't evict
    # K=2 rings can't shrink further
    st2 = StragglerState(num_partitions=2)
    for _ in range(5):
        st2.observe([1.0, 99.0])
    assert st2.propose_group_eviction((2, 1)) is None
